"""Activation exponent statistics feeding the simulator.

An :class:`ActStats` is a probability histogram over live (non-pruned)
LOG2 exponents ``[-7..7]`` plus the pruned fraction.  Two sources:

* :func:`measure` — from a real activation tensor produced by the JAX model
  zoo (the primary path; benchmarks/fig2 uses it).
* :func:`paper_preset` — synthetic discretized-Gaussian histograms whose
  negative-exponent fraction and pruned fraction match the numbers printed
  in the paper (Fig. 2 and §VI-B), used to cross-check the simulator against
  the paper's own activation distributions independent of our model weights.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.logquant import LogQuantized, zero_sentinel

EXP_LO, EXP_HI = -7, 7          # live exponent range (-8 is the sentinel)
N_BINS = EXP_HI - EXP_LO + 1


@dataclass(frozen=True)
class ActStats:
    hist: np.ndarray            # (15,) probs over exponents -7..7 (live acts)
    zero_frac: float            # pruned fraction (zeros + clipped-small)

    @property
    def negative_fraction(self) -> float:
        return float(self.hist[: -EXP_LO].sum())

    def mean_needed_bits(self, weight_bits: int = 8) -> float:
        """E[bits fetched per live activation] under the QeiHaN layout."""
        exps = np.arange(EXP_LO, EXP_HI + 1)
        need = np.where(exps < 0, weight_bits + exps, weight_bits)
        return float((self.hist * need).sum())

    def estimated_memory_savings(self, weight_bits: int = 8) -> float:
        """Paper Fig. 3: ignored weight-bit fraction over live activations."""
        return 1.0 - self.mean_needed_bits(weight_bits) / weight_bits


def measure(q: LogQuantized, n_bits: int = 4) -> ActStats:
    exp = np.asarray(q.exp).reshape(-1).astype(np.int64)
    sentinel = zero_sentinel(n_bits)
    live = exp[exp != sentinel]
    zero_frac = 1.0 - live.size / max(exp.size, 1)
    hist = np.bincount(live - EXP_LO, minlength=N_BINS).astype(np.float64)
    hist = hist / max(hist.sum(), 1.0)
    return ActStats(hist=hist, zero_frac=float(zero_frac))


def gaussian_stats(center: float, sigma: float, zero_frac: float) -> ActStats:
    exps = np.arange(EXP_LO, EXP_HI + 1, dtype=np.float64)
    h = np.exp(-0.5 * ((exps - center) / sigma) ** 2)
    h /= h.sum()
    return ActStats(hist=h, zero_frac=zero_frac)


def _calibrate_center(target_neg: float, sigma: float,
                      zero_frac: float) -> ActStats:
    """Binary-search the Gaussian center to hit a negative-exponent target."""
    lo, hi = -8.0, 8.0
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        s = gaussian_stats(mid, sigma, zero_frac)
        if s.negative_fraction > target_neg:
            lo = mid
        else:
            hi = mid
    return gaussian_stats(0.5 * (lo + hi), sigma, zero_frac)


# (negative-exponent fraction [Fig. 2], pruned fraction [§VI-B], sigma)
_PAPER_NUMBERS = {
    "alexnet": (0.36, 0.47, 2.6),      # "most symmetric distribution"
    "transformer": (0.57, 0.03, 2.6),
    "ptblm": (0.98, 0.55, 1.6),        # concentrated around -3
    "bert-base": (0.82, 0.07, 1.9),
    "bert-large": (0.85, 0.13, 1.9),
}


def paper_preset(model: str) -> ActStats:
    neg, zero, sigma = _PAPER_NUMBERS[model]
    return _calibrate_center(neg, sigma, zero)
