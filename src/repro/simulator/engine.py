"""Cycle/energy/access simulation of the three accelerators (paper §V-§VI).

Modeling assumptions (documented deviations in DESIGN.md):

* **IS weight traffic** — the 64 B weight buffer holds only the M-weight
  vector for the current activation, so weights are re-fetched per token
  (per output row).  Per live activation the vault streams ``N`` weights of
  ``weight_bits`` (NaHiD) or ``mean_needed_bits`` (QeiHaN, Fig. 7 layout).
* **OS traffic** — outputs stationary; per token the accelerator makes
  ``ceil(N / 256)`` passes (16 PEs x 16 MACs concurrent outputs); every pass
  re-streams the K inputs; weights stream once per (token, weight).
* **Pipeline** — per layer, time = max(compute, memory) (paper: "all the
  main steps are carried out in parallel in a deep pipeline").
* **Pruning** — IS designs skip all weight fetches and ADDs of pruned
  activations; Neurocube computes everything (paper §VI-A).
* **Output/NoC** — partial-output reduction crosses the 2D mesh once per
  output (IS); final outputs written back at ``out_bits_dram``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Sequence

from repro.simulator.config import AcceleratorConfig
from repro.simulator.stats import ActStats
from repro.simulator.workload import LayerWork


@dataclass
class LayerResult:
    name: str
    dram_bits_weights: float
    dram_bits_acts: float
    dram_bits_out: float
    compute_s: float
    memory_s: float
    time_s: float
    energy_j: float
    energy_breakdown: Dict[str, float]

    @property
    def dram_bits_total(self) -> float:
        return self.dram_bits_weights + self.dram_bits_acts + self.dram_bits_out


@dataclass
class SimResult:
    accel: str
    layers: List[LayerResult]

    def total(self, field: str) -> float:
        return sum(getattr(l, field) for l in self.layers)

    @property
    def dram_bits(self) -> float:
        return sum(l.dram_bits_total for l in self.layers)

    @property
    def time_s(self) -> float:
        return self.total("time_s")

    @property
    def energy_j(self) -> float:
        return self.total("energy_j")

    def energy_by(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for l in self.layers:
            for k, v in l.energy_breakdown.items():
                out[k] = out.get(k, 0.0) + v
        return out


def simulate_layer(cfg: AcceleratorConfig, layer: LayerWork,
                   stats: ActStats) -> LayerResult:
    e = cfg.energy
    live_frac = (1.0 - stats.zero_frac) if cfg.prune_activations else 1.0

    if cfg.dataflow == "IS":
        k_live = layer.k * live_frac
        if cfg.bitplane_weights:
            wbits_per_act = stats.mean_needed_bits(cfg.weight_bits)
        else:
            wbits_per_act = float(cfg.weight_bits)
        dram_w = layer.m * k_live * wbits_per_act * layer.n / 1.0
        # IS reads each *distinct* activation exactly once.
        dram_a = layer.unique_acts * cfg.act_bits_dram
        ops = layer.m * k_live * layer.n              # shifted ADDs
        shifts = ops
        quants = layer.m * k_live
    else:  # OS (Neurocube)
        passes = math.ceil(layer.n / cfg.os_concurrent_outputs)
        dram_w = layer.m * layer.k * layer.n * cfg.weight_bits
        dram_a = layer.m * passes * layer.k * cfg.act_bits_dram
        ops = layer.m * layer.k * layer.n             # MACs
        shifts = 0.0
        quants = 0.0

    dram_o = layer.m * layer.n * cfg.out_bits_dram

    total_bits = dram_w + dram_a + dram_o
    # Closed-page DRAM: time is transaction-bound (bus_bits per tRC per bank),
    # floored by the raw TSV bandwidth.
    transactions = total_bits / cfg.bus_bits
    latency_s = transactions * cfg.t_rc_s / (cfg.vaults * cfg.banks_per_vault)
    bw_s = (total_bits / 8.0) / cfg.total_bw_bytes
    memory_s = max(latency_s, bw_s)
    compute_s = ops / (cfg.total_units * cfg.freq_hz)
    time_s = max(memory_s, compute_s) if cfg.pipelined \
        else memory_s + compute_s

    # --- energy -----------------------------------------------------------
    br: Dict[str, float] = {}
    br["dram"] = total_bits * e.dram_pj_per_bit
    # every DRAM bit traverses an SRAM buffer (write+read) + accumulator I/O.
    br["sram"] = 2.0 * total_bits * e.sram_pj_per_bit + ops * 32 * e.sram_pj_per_bit
    if cfg.dataflow == "IS":
        br["pe"] = (ops * e.add16_pj + shifts * e.shift_pj
                    + quants * e.log2_quant_pj)
    else:
        br["pe"] = ops * e.mac16_pj
    # cross-vault partial-output reduction (IS) / local accumulate (OS).
    noc_bits = (cfg.vaults * layer.m * layer.n * 16.0
                if cfg.dataflow == "IS" else layer.m * layer.n * 16.0)
    br["noc"] = noc_bits * e.noc_pj_per_bit
    br["static"] = (cfg.vaults * e.static_mw_per_pe + e.dram_static_mw) \
        * 1e-3 * time_s * 1e12                       # mW * s -> pJ
    energy_pj = sum(br.values())

    return LayerResult(
        name=layer.name,
        dram_bits_weights=dram_w, dram_bits_acts=dram_a, dram_bits_out=dram_o,
        compute_s=compute_s, memory_s=memory_s, time_s=time_s,
        energy_j=energy_pj * 1e-12,
        energy_breakdown={k: v * 1e-12 for k, v in br.items()},
    )


def simulate(cfg: AcceleratorConfig, layers: Sequence[LayerWork],
             stats_per_layer: Sequence[ActStats] | ActStats) -> SimResult:
    if isinstance(stats_per_layer, ActStats):
        stats_per_layer = [stats_per_layer] * len(layers)
    results = [simulate_layer(cfg, l, s)
               for l, s in zip(layers, stats_per_layer, strict=True)]
    return SimResult(accel=cfg.name, layers=results)
