"""NDP accelerator simulator: Neurocube / NaHiD / QeiHaN (paper §V-§VI)."""

from repro.simulator.config import (ALL_ACCELERATORS, NAHID, NEUROCUBE,
                                    QEIHAN, AcceleratorConfig, EnergyModel,
                                    load_kernel_cost_table)
from repro.simulator.engine import LayerResult, SimResult, simulate, simulate_layer
from repro.simulator.stats import (ActStats, gaussian_stats, measure,
                                   paper_preset)
from repro.simulator.workload import (PAPER_WORKLOADS, LayerWork, alexnet,
                                      bert_base, bert_large, conv, fc, ptblm,
                                      transformer_base)

__all__ = [n for n in dir() if not n.startswith("_")]
