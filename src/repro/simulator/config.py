"""Accelerator configurations — paper Table II.

Three modeled systems:

* ``NEUROCUBE`` — OS dataflow, uniform 8b acts / 8b weights, 16 MACs/PE,
  no activation pruning (paper: "the efficiency of the activation pruning is
  limited in Neurocube due to its OS dataflow, so it is not implemented").
* ``NAHID``     — IS dataflow, LOG2 4b acts / 8b weights, 16 ADDs/PE,
  zero+small-activation pruning, **standard** weight layout (all 8 bits
  fetched for every live activation).
* ``QEIHAN``    — NaHiD plus the bit-plane weight layout: only the
  ``8-|e|`` MSB planes fetched for negative exponents.

Energy constants are 32 nm-class numbers with sources noted inline; the
paper's own evaluation is relative (normalized to Neurocube), so the model's
job is to get the *ratios* right, which are dominated by DRAM traffic.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class EnergyModel:
    """Per-event energies in picojoules."""

    dram_pj_per_bit: float = 3.7        # HMC internal access [Jeddeloh&Keeth'12]
    sram_pj_per_bit: float = 0.08       # ~2KB low-power SRAM @0.78V (CACTI-P class)
    noc_pj_per_bit: float = 0.35        # 2D-mesh hop, logic die
    mac16_pj: float = 1.3               # 16-bit MAC, 32nm (DesignWare class)
    add16_pj: float = 0.12              # 16-bit adder
    shift_pj: float = 0.03              # D&S barrel shift (append zeros)
    log2_quant_pj: float = 0.06         # comparator + int adder + mux (Fig. 5)
    static_mw_per_pe: float = 1.9       # leakage, logic die per tile
    dram_static_mw: float = 320.0       # HMC background/refresh


@dataclass(frozen=True)
class AcceleratorConfig:
    name: str
    dataflow: str                        # 'OS' | 'IS'
    vaults: int = 16                     # = PEs (Table II)
    units_per_pe: int = 16               # MACs (Neurocube) or ADDs (IS designs)
    freq_hz: float = 300e6               # logic die
    vault_bw_bytes: float = 10e9         # per-vault 3D memory bandwidth
    act_bits_dram: int = 8               # activation precision read from DRAM
    weight_bits: int = 8
    log2_activations: bool = False       # LOG2 4-bit exponent + sign datapath
    bitplane_weights: bool = False       # QeiHaN weight layout
    prune_activations: bool = False      # zero + clipped-small pruning
    out_bits_dram: int = 16              # partial/final output precision
    sram_bytes_per_pe: int = 2560
    energy: EnergyModel = field(default_factory=EnergyModel)
    # OS only: output neurons computed concurrently across the accelerator.
    # 16 PEs x 16 MACs; inputs are re-streamed once per output pass.
    os_concurrent_outputs: int = 256
    # Closed-page DRAM (paper §IV-B): each transaction moves `bus_bits` and
    # occupies a bank for tRC; bank-level parallelism overlaps transactions.
    # Effective per-vault bandwidth = bus_bits * banks / tRC (~1.4 GB/s),
    # far below the 10 GB/s TSV peak — this is why the paper's designs are
    # access-count-bound and speedup tracks Fig. 9.
    bus_bits: int = 32
    t_rc_s: float = 47e-9
    banks_per_vault: int = 16            # 4 banks/die x 4 dies (Table II)
    # QeiHaN/NaHiD overlap all dataflow stages in a deep pipeline (§IV-C);
    # the Neurocube baseline serializes compute and memory per §VI-B.
    pipelined: bool = True

    @property
    def total_bw_bytes(self) -> float:
        return self.vault_bw_bytes * self.vaults

    @property
    def total_units(self) -> int:
        return self.units_per_pe * self.vaults


NEUROCUBE = AcceleratorConfig(
    name="neurocube", dataflow="OS",
    act_bits_dram=8, log2_activations=False, bitplane_weights=False,
    prune_activations=False, pipelined=False,
)

NAHID = AcceleratorConfig(
    name="nahid", dataflow="IS",
    act_bits_dram=16,                    # paper: IB holds FP16 activations
    log2_activations=True, bitplane_weights=False, prune_activations=True,
    sram_bytes_per_pe=2112,              # 2KB OB + 64B IB + 64B WB
)

QEIHAN = AcceleratorConfig(
    name="qeihan", dataflow="IS",
    act_bits_dram=16,
    log2_activations=True, bitplane_weights=True, prune_activations=True,
    sram_bytes_per_pe=2112,
)

ALL_ACCELERATORS = (NEUROCUBE, NAHID, QEIHAN)


# ---------------------------------------------------------------------------
# serving-side cost table (static kernel audit -> simulator input)
# ---------------------------------------------------------------------------

KERNEL_COST_TABLE_PATH = "benchmarks/baselines/kernel_audit.json"


def load_kernel_cost_table(path: str = KERNEL_COST_TABLE_PATH):
    """Per-tick kernel cost table from the static kernel audit
    (``tools/audit.py --kernels``): ``{variant: {"tick_bytes_total",
    "kernels": {family: {"calls", "operand_bytes"}}}}``.

    The counts are compile-time facts (pallas_call census over the traced
    tick, scan trip counts multiplied through) and the bytes are the dense
    streaming upper bound per launch — what the energy model charges DRAM
    for before the paper's savings fractions (plane skip, page walk) are
    applied.  Raises ``FileNotFoundError`` if the audit baseline has not
    been generated (``tools/audit.py --kernels --update-baselines``).
    """
    import json

    with open(path) as f:
        doc = json.load(f)
    out = {}
    for name, rec in doc.get("per_tick", {}).items():
        out[name] = {
            "tick_bytes_total": int(rec["tick_bytes_total"]),
            "kernels": {k: {"calls": int(v["calls"]),
                            "operand_bytes": int(v["operand_bytes"])}
                        for k, v in rec["kernels"].items()},
        }
    return out
