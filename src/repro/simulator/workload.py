"""Workload descriptors: every FC/CONV-class layer as a GEMM.

A layer is ``(M, K, N)`` — M output rows (tokens / output pixels), K the
reduction (fan-in), N output features — plus ``unique_acts``, the number of
*distinct* input activations (for CONV, ``IH*IW*IC`` is smaller than ``M*K``
because of kernel overlap; the IS dataflow reads each distinct activation
from DRAM exactly once).

Workload builders for the paper's five DNNs (Table I) use the standard
published dimensions; per-model notes inline.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List


@dataclass(frozen=True)
class LayerWork:
    name: str
    m: int              # output rows (spatial x batch for conv, tokens for FC)
    k: int              # fan-in (IC*KH*KW for conv)
    n: int              # output features
    unique_acts: int    # distinct input activations feeding this layer
    kind: str = "fc"    # 'fc' | 'conv' | 'lstm' | 'attn_proj'

    @property
    def macs(self) -> int:
        return self.m * self.k * self.n

    @property
    def weights(self) -> int:
        return self.k * self.n


def conv(name: str, ih: int, iw: int, ic: int, oc: int, kh: int, kw: int,
         stride: int = 1, pad: int = 0) -> LayerWork:
    oh = (ih + 2 * pad - kh) // stride + 1
    ow = (iw + 2 * pad - kw) // stride + 1
    return LayerWork(name=name, m=oh * ow, k=ic * kh * kw, n=oc,
                     unique_acts=ih * iw * ic, kind="conv")


def fc(name: str, k: int, n: int, tokens: int = 1) -> LayerWork:
    return LayerWork(name=name, m=tokens, k=k, n=n,
                     unique_acts=tokens * k, kind="fc")


# ---------------------------------------------------------------------------
# Paper workloads (Table I)
# ---------------------------------------------------------------------------

def alexnet() -> List[LayerWork]:
    """AlexNet [Krizhevsky'12]: 5 CONV + 3 FC, ImageNet 227x227, batch 1."""
    return [
        conv("conv1", 227, 227, 3, 96, 11, 11, stride=4),
        conv("conv2", 27, 27, 96, 256, 5, 5, pad=2),
        conv("conv3", 13, 13, 256, 384, 3, 3, pad=1),
        conv("conv4", 13, 13, 384, 384, 3, 3, pad=1),
        conv("conv5", 13, 13, 384, 256, 3, 3, pad=1),
        fc("fc6", 9216, 4096),
        fc("fc7", 4096, 4096),
        fc("fc8", 4096, 1000),
    ]


def ptblm(seq: int = 35, hidden: int = 1500, vocab: int = 10000) -> List[LayerWork]:
    """PTBLM [Zaremba'14] 'large': 2 LSTM layers, h=1500, PTB vocab 10k.

    Each LSTM step runs 4 gate GEMMs over [x_t; h_{t-1}]; we fold the 4 gates
    into one (K = 2h, N = 4h) GEMM per layer per timestep, which is how the
    accelerator would schedule it.  Embedding lookup is not a GEMM; the
    softmax projection is.
    """
    layers: List[LayerWork] = []
    for t in range(seq):
        for l in range(2):
            layers.append(LayerWork(
                name=f"lstm{l}_t{t}", m=1, k=2 * hidden, n=4 * hidden,
                unique_acts=2 * hidden, kind="lstm"))
    layers.append(fc("softmax", hidden, vocab))
    return layers


def _encoder_block(name: str, d: int, ff: int, seq: int) -> List[LayerWork]:
    """Attention QKV/O projections + 2 FFN GEMMs for `seq` tokens.

    The paper quantizes only layers with *weights* — the QK^T / AV
    activation-activation products are excluded (see DESIGN.md
    §Arch-applicability) and are also excluded from its access counts.
    """
    return [
        fc(f"{name}.q", d, d, seq), fc(f"{name}.k", d, d, seq),
        fc(f"{name}.v", d, d, seq), fc(f"{name}.o", d, d, seq),
        fc(f"{name}.ff1", d, ff, seq), fc(f"{name}.ff2", ff, d, seq),
    ]


def transformer_base(seq: int = 128) -> List[LayerWork]:
    """Transformer [Vaswani'17] base: 6 enc + 6 dec, d=512, ff=2048.

    Decoder blocks carry an extra cross-attention projection set.
    """
    layers: List[LayerWork] = []
    for i in range(6):
        layers += _encoder_block(f"enc{i}", 512, 2048, seq)
    for i in range(6):
        layers += _encoder_block(f"dec{i}", 512, 2048, seq)
        layers += [fc(f"dec{i}.xq", 512, 512, seq),
                   fc(f"dec{i}.xk", 512, 512, seq),
                   fc(f"dec{i}.xv", 512, 512, seq),
                   fc(f"dec{i}.xo", 512, 512, seq)]
    layers.append(fc("generator", 512, 37000, seq))
    return layers


def bert(layers_n: int = 12, d: int = 768, ff: int = 3072,
         seq: int = 384) -> List[LayerWork]:
    """BERT-Base/Large [Devlin'18]; SQuAD uses seq 384."""
    layers: List[LayerWork] = []
    for i in range(layers_n):
        layers += _encoder_block(f"l{i}", d, ff, seq)
    layers.append(fc("qa_head", d, 2, seq))
    return layers


def bert_base(seq: int = 384) -> List[LayerWork]:
    return bert(12, 768, 3072, seq)


def bert_large(seq: int = 384) -> List[LayerWork]:
    return bert(24, 1024, 4096, seq)


PAPER_WORKLOADS = {
    "alexnet": alexnet,
    "ptblm": ptblm,
    "transformer": transformer_base,
    "bert-base": bert_base,
    "bert-large": bert_large,
}
