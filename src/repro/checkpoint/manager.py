"""Fault-tolerant checkpointing: atomic, keep-k, async, mesh-agnostic.

* **Atomic** — writes land in ``step_XXXXXXXX.tmp`` and are ``os.rename``d
  into place; a crash mid-write never corrupts the latest checkpoint.
* **Keep-k** — old steps garbage-collected after a successful save.
* **Async** — ``save_async`` snapshots to host memory synchronously (cheap)
  and writes on a background thread so the train loop isn't IO-bound.
* **Mesh-agnostic / elastic** — leaves are stored as full host arrays keyed
  by pytree path; ``restore`` re-shards onto whatever sharding tree the
  *current* mesh wants, so a job can restart on a different topology
  (elastic scaling) and resume bit-identically (data pipeline is keyed by
  step, not by worker).
"""

from __future__ import annotations

import json
import os
import re
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{8})$")


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = jax.tree_util.keystr(path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind == "V" or arr.dtype.name in (
                "bfloat16", "float8_e4m3fn", "float8_e5m2"):
            # npz cannot store ml_dtypes arrays — widen losslessly; restore
            # casts back to the template dtype
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _unflatten_like(template, data: Dict[str, np.ndarray]):
    leaves = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(template)[0]:
        key = jax.tree_util.keystr(path)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(arr)
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- writing -----------------------------------------------------------
    def save(self, step: int, tree: Any, metadata: Optional[dict] = None):
        flat = _flatten(tree)
        self._write(step, flat, metadata or {})

    def save_async(self, step: int, tree: Any, metadata: Optional[dict] = None):
        self.wait()                              # one outstanding write max
        flat = _flatten(tree)                    # snapshot now (synchronous)
        self._thread = threading.Thread(
            target=self._write_guarded, args=(step, flat, metadata or {}),
            daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write_guarded(self, step, flat, metadata):
        try:
            self._write(step, flat, metadata)
        except BaseException as e:               # surfaced on next wait()
            self._error = e

    def _write(self, step: int, flat: Dict[str, np.ndarray], metadata: dict):
        name = f"step_{step:08d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        final = os.path.join(self.dir, name)
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k: v for k, v in flat.items()})
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, **metadata}, f)
        if os.path.exists(final):
            raise FileExistsError(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: max(0, len(steps) - self.keep)]:
            path = os.path.join(self.dir, f"step_{s:08d}")
            for fn in os.listdir(path):
                os.remove(os.path.join(path, fn))
            os.rmdir(path)

    # -- reading -----------------------------------------------------------
    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            m = _STEP_RE.match(d)
            if m and os.path.isdir(os.path.join(self.dir, d)):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template: Any,
                shardings: Any = None) -> Any:
        """Restore into ``template``'s structure; optionally re-shard each
        leaf onto ``shardings`` (a matching tree of jax.sharding.Sharding) —
        this is the elastic-restart path."""
        path = os.path.join(self.dir, f"step_{step:08d}", "arrays.npz")
        with np.load(path) as z:
            data = {k: z[k] for k in z.files}
        tree = _unflatten_like(template, data)
        if shardings is not None:
            import jax.numpy as jnp
            tree = jax.tree.map(
                lambda arr, s, t: jax.device_put(
                    jnp.asarray(arr).astype(t.dtype)
                    if hasattr(t, "dtype") else arr, s),
                tree, shardings, template)
        return tree

    def metadata(self, step: int) -> dict:
        with open(os.path.join(self.dir, f"step_{step:08d}", "meta.json")) as f:
            return json.load(f)
