"""First-class QeiHaN integration: convert a model's projection weights to
the LOG2-activation / bit-plane-weight shift-add representation.

``quantize_model_params`` walks the param tree and, for every projection the
technique applies to (DESIGN.md §Arch-applicability: attention QKV/O,
dense/shared MLP, Mamba in/out projections), attaches a
``QuantizedLinearParams`` under ``<name>_q``.  Layers keep their float
weights too (used for anything the quant path doesn't cover and for
side-by-side evaluation).  Stacked (scan) leaves are quantized with vmap
over the repeat dim.

Routed MoE expert weights stay float (the EP shard_map path owns them);
routers/norms/rotaries are excluded per the paper (§II-A scopes LOG2 to
FC/CONV GEMMs).
"""

from __future__ import annotations

from typing import Any, Dict

import jax

from repro.core.shiftadd import quantized_linear_init
from repro.models.model import ModelConfig

# projection leaves eligible for the QeiHaN path, per block kind
_ATTN_PROJ = ("wq", "wk", "wv", "wo")
_MLP_PROJ = ("gate", "up", "down")
_MAMBA_PROJ = ("wz", "wx", "out_proj")


def _quantize_stacked(w, act_scale: float = 1.0, pack: bool = False):
    """w: (R, K, N) stacked over scan repeats -> stacked quant params."""
    from repro.core.bitplane import pack_planes

    def one(m):
        q = quantized_linear_init(m, act_scale=act_scale)
        if pack:
            q = q._replace(planes=pack_planes(q.planes, axis=0))
        return q
    return jax.vmap(one)(w)


def quantize_model_params(cfg: ModelConfig, params: Dict[str, Any],
                          act_scale: float = 1.0,
                          drop_float: bool = False,
                          pack: bool = False) -> Dict[str, Any]:
    """``drop_float=True`` replaces each quantized projection's float weight
    with a scalar placeholder — the deployment configuration where only the
    bit-plane representation is resident in HBM (the dry-run memory story)."""
    import jax.numpy as jnp

    def _maybe_drop(blk, name):
        if drop_float:
            # keep the scan's leading repeat dim on the placeholder
            blk[name] = jnp.zeros((cfg.repeats, 1), cfg.dtype)

    out = jax.tree.map(lambda x: x, params)        # shallow-ish copy
    blocks = []
    for i, kind in enumerate(cfg.pattern):
        blk = dict(out["blocks"][i])
        base = kind.split("_")[0]
        names = _ATTN_PROJ if base == "attn" else _MAMBA_PROJ
        for name in names:
            if name in blk:
                blk[name + "_q"] = _quantize_stacked(blk[name], act_scale, pack)
                _maybe_drop(blk, name)
        if "mlp" in blk:
            mlp = dict(blk["mlp"])
            if "experts" not in mlp:               # dense MLP
                for name in _MLP_PROJ:
                    mlp[name + "_q"] = _quantize_stacked(mlp[name], act_scale, pack)
                    _maybe_drop(mlp, name)
            if "shared" in mlp:
                sh = dict(mlp["shared"])
                for name in _MLP_PROJ:
                    sh[name + "_q"] = _quantize_stacked(sh[name], act_scale, pack)
                    _maybe_drop(sh, name)
                mlp["shared"] = sh
            blk["mlp"] = mlp
        blocks.append(blk)
    out["blocks"] = tuple(blocks)
    return out
