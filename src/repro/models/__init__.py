"""Model zoo: one DecoderModel machinery for all assigned architectures."""

from repro.models.model import (ModelConfig, forward, init_caches,
                                init_params, next_token_loss, param_count)

__all__ = ["ModelConfig", "forward", "init_caches", "init_params",
           "next_token_loss", "param_count"]
