"""GQA attention: flash-style chunked prefill/train + KV-cache decode.

Grouped formulation throughout — queries reshape to (B, S, G, R, D) with
G = kv heads and R = group size, so KV is *never* materialized repeated
(R x memory saving, and GSPMD keeps the cache sharding intact).

* ``flash_attention`` — online-softmax ``lax.scan`` over KV chunks; peak
  activation memory O(S * kv_chunk) per head instead of O(S^2): this is what
  lets 32k-prefill fit the dry-run memory budget.  Softmax statistics in f32.
* ``_decode_attention`` — single-token path: one masked einsum over the
  cache.  With the cache sequence-sharded on the TP axis the partial scores
  stay local and XLA inserts only tiny (B, G, R) softmax-stat collectives.
* ``_chunk_attention`` — chunked-prefill path (``attention(chunk_valid=)``):
  S chunk queries against the cache, same masked-einsum form as decode —
  mid-prompt chunks must see earlier chunks' K/V, which live in the cache,
  not in the fresh projections.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense, rms_norm
from repro.models.sharding import shard

NEG_INF = -1e30


def _grouped(q: jnp.ndarray, n_kv: int) -> jnp.ndarray:
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    q_positions: jnp.ndarray, kv_positions: jnp.ndarray,
                    causal: bool = True, kv_chunk: int = 1024,
                    kv_valid_len: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """q: (B, Sq, H, D); k/v: (B, Skv, G, D).  Returns (B, Sq, H, D).

    Custom VJP: the backward recomputes each chunk's P from the saved
    softmax statistics (O(S) residuals) — letting lax.scan's default VJP
    stack every chunk's (B,G,R,Sq,C) f32 probabilities measured +2 TB of
    HBM traffic per MoE train step (§Perf log).
    """
    b, sq, h, d = q.shape
    if sq == 1:
        return _decode_attention(q, k, v, q_positions, kv_positions,
                                 kv_valid_len)
    has_valid = kv_valid_len is not None
    fn = _make_flash(causal, min(kv_chunk, k.shape[1]), has_valid)
    valid = kv_valid_len if has_valid else jnp.zeros((b,), jnp.int32)
    return fn(q, k, v, q_positions, kv_positions, valid)


def _chunk_mask(pb, q_positions, ci, kv_chunk, valid, causal, has_valid):
    mask = jnp.ones((pb.shape[0], 1, 1, q_positions.shape[1],
                     pb.shape[1]), bool)
    if causal:
        mask = (pb[:, None, None, None, :]
                <= q_positions[:, None, None, :, None])
    if has_valid:
        idx = ci * kv_chunk + jnp.arange(kv_chunk)
        mask = mask & (idx[None, None, None, None, :]
                       < valid[:, None, None, None, None])
    return mask


import functools as _ft


@_ft.lru_cache(maxsize=None)
def _make_flash(causal: bool, kv_chunk: int, has_valid: bool):

    def _chunks(q, k, v, kv_positions):
        b, sq, h, d = q.shape
        skv, g = k.shape[1], k.shape[2]
        n_chunks = -(-skv // kv_chunk)
        pad = n_chunks * kv_chunk - skv
        if pad:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)),
                                   constant_values=2 ** 30)
        kc = k.reshape(b, n_chunks, kv_chunk, g, d).transpose(1, 0, 2, 3, 4)
        vc = v.reshape(b, n_chunks, kv_chunk, g, d).transpose(1, 0, 2, 3, 4)
        pc = kv_positions.reshape(b, n_chunks, kv_chunk).transpose(1, 0, 2)
        return kc, vc, pc, n_chunks, pad

    def _forward(q, k, v, q_positions, kv_positions, valid):
        b, sq, h, d = q.shape
        g = k.shape[2]
        r = h // g
        scale = 1.0 / jnp.sqrt(jnp.float32(d))
        kc, vc, pc, n_chunks, _ = _chunks(q, k, v, kv_positions)
        qg = _grouped(q, g)

        def body(carry, xs):
            m, l, acc = carry
            kb, vb, pb, ci = xs
            s = jnp.einsum("bqgrd,bkgd->bgrqk", qg, kb,
                           preferred_element_type=jnp.float32) * scale
            mask = _chunk_mask(pb, q_positions, ci, kv_chunk, valid,
                               causal, has_valid)
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bgrqk,bkgd->bgrqd", p.astype(q.dtype), vb,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, g, r, sq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, g, r, sq), jnp.float32)
        a0 = jnp.zeros((b, g, r, sq, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0), (kc, vc, pc, jnp.arange(n_chunks)))
        out_g = acc / jnp.maximum(l[..., None], 1e-30)   # (B,G,R,Sq,D) f32
        lse = m + jnp.log(jnp.maximum(l, 1e-30))         # (B,G,R,Sq)
        out = out_g.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d)
        return out.astype(q.dtype), out_g, lse

    @jax.custom_vjp
    def flash(q, k, v, q_positions, kv_positions, valid):
        return _forward(q, k, v, q_positions, kv_positions, valid)[0]

    def fwd(q, k, v, q_positions, kv_positions, valid):
        out, out_g, lse = _forward(q, k, v, q_positions, kv_positions, valid)
        return out, (q, k, v, q_positions, kv_positions, valid, out_g, lse)

    def bwd(res, dout):
        q, k, v, q_positions, kv_positions, valid, out_g, lse = res
        b, sq, h, d = q.shape
        g = k.shape[2]
        scale = 1.0 / jnp.sqrt(jnp.float32(d))
        kc, vc, pc, n_chunks, pad = _chunks(q, k, v, kv_positions)
        qg = _grouped(q, g)
        do = _grouped(dout, g).transpose(0, 2, 3, 1, 4)  # (B,G,R,Sq,D) f32?
        do = do.astype(jnp.float32)
        # D_i = rowsum(dO * O)
        delta = jnp.sum(do * out_g, axis=-1)             # (B,G,R,Sq)

        def body(dq_acc, xs):
            kb, vb, pb, ci = xs
            s = jnp.einsum("bqgrd,bkgd->bgrqk", qg, kb,
                           preferred_element_type=jnp.float32) * scale
            mask = _chunk_mask(pb, q_positions, ci, kv_chunk, valid,
                               causal, has_valid)
            s = jnp.where(mask, s, NEG_INF)
            p = jnp.exp(s - lse[..., None])              # (B,G,R,Sq,C)
            dv = jnp.einsum("bgrqk,bgrqd->bkgd", p.astype(q.dtype), do)
            dp = jnp.einsum("bgrqd,bkgd->bgrqk", do, vb,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - delta[..., None]) * scale
            dsq = ds.astype(q.dtype)
            dq_c = jnp.einsum("bgrqk,bkgd->bqgrd", dsq, kb,
                              preferred_element_type=jnp.float32)
            dk = jnp.einsum("bgrqk,bqgrd->bkgd", dsq, qg,
                            preferred_element_type=jnp.float32)
            return dq_acc + dq_c, (dk, dv)

        dq0 = jnp.zeros(qg.shape, jnp.float32)
        dq_g, (dkc, dvc) = jax.lax.scan(
            body, dq0, (kc, vc, pc, jnp.arange(n_chunks)))
        dq = dq_g.reshape(b, sq, h, d).astype(q.dtype)
        skv_p = n_chunks * kv_chunk
        dk = dkc.transpose(1, 0, 2, 3, 4).reshape(b, skv_p, g, d)
        dv = dvc.transpose(1, 0, 2, 3, 4).reshape(b, skv_p, g, d)
        if pad:
            dk = dk[:, : k.shape[1]]
            dv = dv[:, : v.shape[1]]
        return (dq, dk.astype(k.dtype), dv.astype(v.dtype), None, None, None)

    flash.defvjp(fwd, bwd)
    return flash


def _chunk_attention(q, k, v, q_positions, kv_positions, kv_valid_len):
    """q: (B, S, H, D) chunk queries against the full cache — the S-query
    generalization of :func:`_decode_attention`: one masked einsum + one
    softmax, no online chunking.

    Used by the chunked-prefill path, where queries must see *cache* rows
    (earlier chunks) and not just the fresh chunk K/V.  Like the decode path
    — and unlike ``flash_attention``, whose KV-chunk reshape would split the
    sequence axis (the documented CPU-SPMD hazard under a seq-sharded
    cache) — the scores stay shard-local and only softmax-normalization
    collectives cross shards.  Materializes (B, H, S, K) f32 scores: bounded
    by chunk_len x pool max_len, fine at serve-pool sizes (a flash-style
    online variant is the long-context follow-up).
    """
    b, sq, h, d = q.shape
    g = k.shape[2]
    qg = _grouped(q, g)                                  # (B, S, G, R, D)
    s = jnp.einsum("bsgrd,bkgd->bgrsk", qg, k,
                   preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(jnp.float32(d))
    mask = (kv_positions[:, None, None, None, :]
            <= q_positions[:, None, None, :, None])
    if kv_valid_len is not None:
        idx = jnp.arange(k.shape[1])
        mask = mask & (idx[None, None, None, None, :]
                       < kv_valid_len[:, None, None, None, None])
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrsk,bkgd->bsgrd", p.astype(q.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, d).astype(q.dtype)


def _decode_attention(q, k, v, q_positions, kv_positions, kv_valid_len):
    """q: (B, 1, H, D) against the full cache — single masked einsum."""
    b, _, h, d = q.shape
    g = k.shape[2]
    qg = _grouped(q, g)[:, 0]                            # (B, G, R, D)
    # bf16 cache reads with f32 accumulation — the cache is never copied
    s = jnp.einsum("bgrd,bkgd->bgrk", qg, k,
                   preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(jnp.float32(d))
    mask = kv_positions[:, None, None, :] <= q_positions[:, None, None, :1]
    if kv_valid_len is not None:
        idx = jnp.arange(k.shape[1])
        mask = mask & (idx[None, None, None, :]
                       < kv_valid_len[:, None, None, None])
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrk,bkgd->bgrd", p.astype(q.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, d).astype(q.dtype)


class KVCache(NamedTuple):
    k: jnp.ndarray            # (B, S_max, G, D)
    v: jnp.ndarray
    length: jnp.ndarray       # () int32 — tokens currently valid


class PagedKVCache(NamedTuple):
    """Paged slot-pool KV (``serving/scheduler.py`` ``paged=True``).

    The pool stores fixed-size pages shared by every slot; each slot's
    logical ``(max_len, G, D)`` cache is the run of pages named by its
    page-table row.  Entry 0 is the reserved trash page — junk writes
    (inactive rows, pad positions) are redirected there instead of the
    dense path's "write back own bytes" trick (``serving.kvpool``).
    """
    k: jnp.ndarray            # (P, page_len, G, D) page pool
    v: jnp.ndarray
    page_table: jnp.ndarray   # (B, n_blocks) int32 page ids, 0 = trash
    length: jnp.ndarray       # (B,) int32 per-slot valid lengths


class QuantPagedKVCache(NamedTuple):
    """Log2-quantized page pool (``ServeScheduler(kv_quant=True)``).

    Pages hold packed ``core.logquant`` wire codes plus a per-(page, head)
    power-of-two scale exponent; the D&S-unit image of the paper's §IV
    claim applied to serving state — only ``kv_bits + 1`` bits per cache
    element move on the streaming path.  The per-slot *tail ring* keeps
    each slot's newest two pages dense in the cache dtype, so
    decode-adjacent tokens read exactly what the dense pool would hold
    (DESIGN.md §Quantized KV pages).  Every write is idempotent: a row's
    codes are a pure function of (value, its page's first-row scale), so
    the scheduler's masked junk-write/rewrite pattern reproduces identical
    bytes.
    """
    k_codes: jnp.ndarray      # (P, page_len, G, D) packed codes
    v_codes: jnp.ndarray
    k_scale: jnp.ndarray      # (P, G) int32 power-of-two scale exponents
    v_scale: jnp.ndarray
    k_tail: jnp.ndarray       # (B, 2*page_len + 1, G, D) dense tail ring
    v_tail: jnp.ndarray       # (row 2*page_len = junk bin)
    page_table: jnp.ndarray   # (B, n_blocks) int32 page ids, 0 = trash
    length: jnp.ndarray       # (B,) int32 per-slot valid lengths


def _paged_write(pool: jnp.ndarray, table: jnp.ndarray, new: jnp.ndarray,
                 pos: jnp.ndarray, keep: jnp.ndarray) -> jnp.ndarray:
    """Scatter ``new`` (B, S, G, D) token rows into the page pool.

    ``pos`` (B, S) are absolute token positions (page = ``table[b,
    pos // page_len]``, offset = ``pos % page_len``); rows where ``keep``
    is False — chunk padding, positions past the slot's allocated pages —
    are redirected to the trash page.  Indexing stays in (page, offset)
    form end to end: no reshape ever merges the page axis with the
    in-page axis, so a page-sharded pool never sees a sharded-axis
    reshape (the documented CPU-SPMD hazard, models/sharding.py).
    """
    page_len = pool.shape[1]
    block = jnp.clip(pos // page_len, 0, table.shape[1] - 1)
    page = jnp.take_along_axis(table, block, axis=1)
    in_alloc = keep & (pos // page_len < table.shape[1])
    page = jnp.where(in_alloc, page, 0)
    off = jnp.where(in_alloc, pos % page_len, 0)
    flat_page = page.reshape(-1)
    flat_off = off.reshape(-1)
    vals = new.reshape((-1,) + new.shape[2:]).astype(pool.dtype)
    return pool.at[flat_page, flat_off].set(vals)


def _quant_paged_write(codes: jnp.ndarray, scale: jnp.ndarray,
                       tail: jnp.ndarray, table: jnp.ndarray,
                       new: jnp.ndarray, pos: jnp.ndarray, keep: jnp.ndarray,
                       start: jnp.ndarray, adv, n_bits: int):
    """Quantize-on-write into the compressed page pool + dense tail ring.

    ``codes (P, page_len, G, D)`` / ``scale (P, G)`` / ``tail (B,
    2*page_len + 1, G, D)``; ``new (B, S, G, D)`` rows at absolute
    positions ``pos (B, S)``; ``start (B,)`` is the pre-write length and
    ``adv`` the per-row advance.  Three scatters, all trash-redirected for
    masked rows exactly like :func:`_paged_write`:

    * codes — each row quantized under its page's scale.  A page whose
      first row sits inside this chunk takes its scale from that row; an
      older page reuses the pool's stored scale.  Appends only ever start
      a page at its offset-0 row, and the power-of-two scale makes
      requantization under the same scale lossless, so rewriting the same
      positions (the scheduler's junk-write pattern) reproduces identical
      bytes.
    * scale — only offset-0 rows own their page's scale entry; every other
      row's scale write is redirected to the trash page's entry.
    * tail ring — the row is also stored dense at ``pos % (2*page_len)``
      when it is within the newest two pages; older rows (and masked ones)
      hit the junk bin.  Two pages of ring mean a later write can only
      alias a position two pages back — one the overlay no longer reads.
    """
    from repro.core.logquant import quantize_page_codes, scale_exponent

    page_len = codes.shape[1]
    block = jnp.clip(pos // page_len, 0, table.shape[1] - 1)
    page = jnp.take_along_axis(table, block, axis=1)
    in_alloc = keep & (pos // page_len < table.shape[1])
    page = jnp.where(in_alloc, page, 0)
    off = jnp.where(in_alloc, pos % page_len, 0)

    b = pos.shape[0]
    startb = jnp.broadcast_to(start, (b,))
    p0 = pos - pos % page_len                     # each row's page start
    own = p0 >= startb[:, None]                   # page starts in this chunk
    j0 = jnp.clip(p0 - startb[:, None], 0, new.shape[1] - 1)
    row0 = jnp.take_along_axis(new, j0[..., None, None], axis=1)
    own_se = scale_exponent(row0, axis=-1)        # (B, S, G) int32
    pool_se = scale[page]                         # (B, S, G)
    se = jnp.where(own[..., None], own_se, pool_se)

    qcodes = quantize_page_codes(new, se[..., None], n_bits)
    codes = codes.at[page.reshape(-1), off.reshape(-1)].set(
        qcodes.reshape((-1,) + qcodes.shape[2:]).astype(codes.dtype))

    sp = jnp.where(in_alloc & (pos % page_len == 0), page, 0)
    scale = scale.at[sp.reshape(-1)].set(
        own_se.reshape((-1,) + own_se.shape[2:]))

    ring = 2 * page_len
    new_end = startb + adv
    in_ring = in_alloc & (pos >= new_end[:, None] - ring)
    toff = jnp.where(in_ring, pos % ring, ring)
    bidx = jnp.broadcast_to(jnp.arange(b, dtype=jnp.int32)[:, None],
                            pos.shape)
    tail = tail.at[bidx.reshape(-1), toff.reshape(-1)].set(
        new.reshape((-1,) + new.shape[2:]).astype(tail.dtype))
    return codes, scale, tail


def _quant_paged_gather(codes: jnp.ndarray, scale: jnp.ndarray,
                        tail: jnp.ndarray, table: jnp.ndarray,
                        lengths: jnp.ndarray, n_bits: int,
                        dtype) -> jnp.ndarray:
    """Dequant-fused gather of the compressed pool into the dense logical
    view, with the newest (possibly partial) page overlaid from the dense
    tail ring — so positions within two pages of the head are bit-equal to
    the dense pool's rows and older positions are their log2-quantized
    images.  Junk rows (trash pages, garbage scales) decode to finite
    values and are masked by the caller's ``kv_valid_len``."""
    from repro.core.logquant import dequantize_page_codes

    b, nb = table.shape
    page_len = codes.shape[1]
    deq = dequantize_page_codes(
        codes[table], scale[table][:, :, None, :, None], n_bits, dtype)
    tb = jnp.maximum(lengths - 1, 0) // page_len          # tail block
    half = (tb % 2) * page_len                            # ring half of tb
    j = jnp.arange(page_len, dtype=jnp.int32)
    tail_rows = jnp.take_along_axis(
        tail, (half[:, None] + j[None])[..., None, None], axis=1)
    use_tail = jnp.arange(nb, dtype=jnp.int32)[None] == tb[:, None]
    g = jnp.where(use_tail[:, :, None, None, None],
                  tail_rows[:, None].astype(dtype), deq)
    return g.reshape((b, nb * page_len) + codes.shape[2:])


def _paged_gather(pool: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """Gather each slot's pages into its dense logical view: ``(P,
    page_len, G, D)`` pool + ``(B, n_blocks)`` table -> ``(B, n_blocks *
    page_len, G, D)`` — bytes at valid positions identical to the dense
    slab's, junk (trash/unwritten) rows masked by the caller's
    ``kv_valid_len`` exactly like dense-path padding."""
    b, nb = table.shape
    g = pool[table]                          # (B, nb, page_len, G, D)
    return g.reshape((b, nb * pool.shape[1]) + pool.shape[2:])


def init_kv_cache(batch: int, max_len: int, n_kv: int, head_dim: int,
                  dtype=jnp.bfloat16, length: int = 0) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
        v=jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
        length=jnp.asarray(length, jnp.int32),
    )


def attention(p, x: jnp.ndarray, positions: jnp.ndarray, cfg,
              cache: Optional[KVCache] = None, quant=False,
              chunk_valid: Optional[jnp.ndarray] = None):
    """Full GQA block body (pre-norm residual handled by caller).

    Returns ``(attn_out, new_cache)``.  With ``cache`` given, ``x`` is the
    new-token slice (decode: S=1) appended at ``cache.length``.  ``quant``
    (bool | str | QuantCtx) routes QKV/O through the QeiHaN path.

    ``chunk_valid`` (``(B,)``, chunked prefill only) switches the
    prefill-with-cache path from "cache assumed empty" to *mid-prompt chunk*
    semantics: ``x`` is one right-padded chunk of a longer prompt whose
    earlier chunks already live in the cache.  Per row, only the first
    ``chunk_valid[b]`` slab positions are real — only those K/V rows are
    written (pad positions write back the cache's own bytes, an exact no-op)
    — and queries attend over the *cache* (earlier chunks + this one) under
    the causal mask with junk rows beyond ``length + chunk_valid`` masked by
    ``kv_valid_len``, instead of over the fresh chunk K/V alone.
    """
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    q = dense(p["wq"], x, p.get("bq"), p.get("wq_q") if quant else None,
              ctx=quant)
    k = dense(p["wk"], x, p.get("bk"), p.get("wk_q") if quant else None,
              ctx=quant)
    v = dense(p["wv"], x, p.get("bv"), p.get("wv_q") if quant else None,
              ctx=quant)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    # force=True pins head_dim (and any non-divisible heads dim) REPLICATED
    # before rope: rope splits/concats the head_dim axis, which GSPMD must
    # never see sharded (models/sharding.py::shard on the CPU-SPMD hazard)
    q = shard(q, "bthd", force=True)
    k = shard(k, "bthd", force=True)
    v = shard(v, "bthd", force=True)

    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)

    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        out = flash_attention(q, k, v, positions, positions, causal=True,
                              kv_chunk=cfg.kv_chunk)
        new_cache = None
    elif isinstance(cache, QuantPagedKVCache):
        # log2-quantized page pool: same (page, offset) addressing as the
        # PagedKVCache branch below, but rows quantize on write (packed
        # codes + per-page scale) and reads dequantize — fused into the
        # gather here, or into the Pallas kernel's per-page block loads.
        # The newest two pages stay dense in the tail ring, so
        # decode-adjacent tokens are bit-equal to the dense pool's
        # (DESIGN.md §Quantized KV pages).
        pos = cache.length[:, None] + jnp.arange(s, dtype=jnp.int32)[None]
        if chunk_valid is not None:
            keep = (jnp.arange(s, dtype=jnp.int32)[None]
                    < chunk_valid[:, None])
            adv = chunk_valid
        else:
            keep = jnp.ones((b, s), bool)
            adv = jnp.int32(s)
        n_bits = getattr(cfg, "kv_bits", 4)
        kcd, ksc, ktl = _quant_paged_write(
            cache.k_codes, cache.k_scale, cache.k_tail, cache.page_table,
            k, pos, keep, cache.length, adv, n_bits)
        vcd, vsc, vtl = _quant_paged_write(
            cache.v_codes, cache.v_scale, cache.v_tail, cache.page_table,
            v, pos, keep, cache.length, adv, n_bits)
        kcd = shard(kcd, "pool")
        vcd = shard(vcd, "pool")
        ktl = shard(ktl, "cache")
        vtl = shard(vtl, "cache")
        new_len = cache.length + adv
        if s == 1 and getattr(cfg, "paged_attn_kernel", "off") != "off":
            from repro.kernels.paged_attention.ops import \
                paged_decode_attention_quant
            out = paged_decode_attention_quant(
                q, kcd, ksc, vcd, vsc, ktl, vtl, cache.page_table, new_len,
                n_bits=n_bits, splits=getattr(cfg, "paged_attn_splits", 1))
        else:
            kg = _quant_paged_gather(kcd, ksc, ktl, cache.page_table,
                                     new_len, n_bits, ktl.dtype)
            vg = _quant_paged_gather(vcd, vsc, vtl, cache.page_table,
                                     new_len, n_bits, vtl.dtype)
            kv_pos = jnp.broadcast_to(
                jnp.arange(kg.shape[1], dtype=jnp.int32), (b, kg.shape[1]))
            if s == 1:
                out = _decode_attention(q, kg, vg, positions, kv_pos,
                                        kv_valid_len=new_len)
            else:
                out = _chunk_attention(q, kg, vg, positions, kv_pos,
                                       kv_valid_len=new_len)
        new_cache = QuantPagedKVCache(
            k_codes=kcd, v_codes=vcd, k_scale=ksc, v_scale=vsc,
            k_tail=ktl, v_tail=vtl, page_table=cache.page_table,
            length=new_len)
    elif isinstance(cache, PagedKVCache):
        # paged slot pool: per-page scatter writes + page-gathered reads.
        # Covers BOTH the decode step (S=1, every row appends at its own
        # length) and the chunked-prefill slab (chunk_valid real rows per
        # slot); the attention math is the same masked einsum as the dense
        # paths over the gathered view, so valid positions are bit-equal.
        # With cfg.paged_attn_kernel != "off" the S=1 decode read skips the
        # dense gather entirely: the Pallas kernel walks the page table
        # (token-equal on every tested seed; logits to f32-ULP softmax
        # reassociation — DESIGN.md §Paged attention kernel).
        pos = cache.length[:, None] + jnp.arange(s, dtype=jnp.int32)[None]
        if chunk_valid is not None:
            keep = (jnp.arange(s, dtype=jnp.int32)[None]
                    < chunk_valid[:, None])
            adv = chunk_valid
        else:
            keep = jnp.ones((b, s), bool)
            adv = jnp.int32(s)
        kc = _paged_write(cache.k, cache.page_table, k, pos, keep)
        vc = _paged_write(cache.v, cache.page_table, v, pos, keep)
        kc = shard(kc, "pool")
        vc = shard(vc, "pool")
        new_len = cache.length + adv
        if s == 1 and getattr(cfg, "paged_attn_kernel", "off") != "off":
            # fused table-walk kernel: per-page K/V loads + online softmax
            # straight off the pool — the dense gather below never runs.
            # Decode masking is the single `pos < new_len` predicate (the
            # causal mask is the same set at q_pos = new_len - 1); split-KV
            # partials merge outside the kernel (kernels/paged_attention).
            from repro.kernels.paged_attention.ops import \
                paged_decode_attention
            out = paged_decode_attention(
                q, kc, vc, cache.page_table, new_len,
                splits=getattr(cfg, "paged_attn_splits", 1))
        elif s == 1:
            kg = _paged_gather(kc, cache.page_table)
            vg = _paged_gather(vc, cache.page_table)
            kv_pos = jnp.broadcast_to(
                jnp.arange(kg.shape[1], dtype=jnp.int32), (b, kg.shape[1]))
            out = _decode_attention(q, kg, vg, positions, kv_pos,
                                    kv_valid_len=new_len)
        else:
            kg = _paged_gather(kc, cache.page_table)
            vg = _paged_gather(vc, cache.page_table)
            kv_pos = jnp.broadcast_to(
                jnp.arange(kg.shape[1], dtype=jnp.int32), (b, kg.shape[1]))
            out = _chunk_attention(q, kg, vg, positions, kv_pos,
                                   kv_valid_len=new_len)
        new_cache = PagedKVCache(k=kc, v=vc, page_table=cache.page_table,
                                 length=new_len)
    elif chunk_valid is not None:
        # chunked prefill: write ONLY the real slab rows (pad positions
        # write the cache's own bytes back — an exact no-op, so a decode /
        # free row riding along with chunk_valid == 0 leaves its cache
        # untouched), then attend over the cache: earlier chunks are already
        # resident and this chunk was just appended.  Cache row i holds the
        # token at position i, so the causal mask is plain kv_pos <= q_pos
        # and kv_valid_len hides junk rows beyond each row's new length.
        idx = jnp.broadcast_to(cache.length, (b,))

        def chunk_upd(c, n, i, keep_r):
            cur = jax.lax.dynamic_slice_in_dim(c, i, n.shape[0], axis=0)
            slab = jnp.where(keep_r[:, None, None], n, cur)
            return jax.lax.dynamic_update_slice_in_dim(c, slab, i, axis=0)

        keep = jnp.arange(s, dtype=jnp.int32)[None, :] < chunk_valid[:, None]
        row_upd = jax.vmap(chunk_upd)
        kc = row_upd(cache.k, k.astype(cache.k.dtype), idx, keep)
        vc = row_upd(cache.v, v.astype(cache.v.dtype), idx, keep)
        kc = shard(kc, "cache")
        vc = shard(vc, "cache")
        new_len = idx + chunk_valid
        kv_pos = jnp.broadcast_to(
            jnp.arange(kc.shape[1], dtype=jnp.int32), (b, kc.shape[1]))
        out = _chunk_attention(q, kc, vc, positions, kv_pos,
                               kv_valid_len=new_len)
        new_cache = KVCache(k=kc, v=vc, length=new_len)
    else:
        idx = cache.length
        if getattr(idx, "ndim", 0):
            # per-slot (B,) lengths: each row appends at its own offset —
            # vmapped dynamic-update keeps the write in-place per row
            row_upd = jax.vmap(
                lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(
                    c, n, i, axis=0))
            kc = row_upd(cache.k, k.astype(cache.k.dtype), idx)
            vc = row_upd(cache.v, v.astype(cache.v.dtype), idx)
        else:
            kc = jax.lax.dynamic_update_slice_in_dim(
                cache.k, k.astype(cache.k.dtype), idx, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(
                cache.v, v.astype(cache.v.dtype), idx, axis=1)
        kc = shard(kc, "cache")
        vc = shard(vc, "cache")
        new_len = idx + s
        if s == 1:
            kv_pos = jnp.broadcast_to(
                jnp.arange(kc.shape[1], dtype=jnp.int32), (b, kc.shape[1]))
            out = flash_attention(q, kc, vc, positions, kv_pos, causal=True,
                                  kv_chunk=cfg.kv_chunk,
                                  kv_valid_len=jnp.broadcast_to(new_len, (b,)))
        else:
            # prefill (cache assumed empty before this call): attend over the
            # fresh K/V — avoids streaming the seq-sharded cache back through
            # the chunk scan (the cache write above is the only cache access)
            out = flash_attention(q, k, v, positions, positions, causal=True,
                                  kv_chunk=cfg.kv_chunk)
        new_cache = KVCache(k=kc, v=vc, length=new_len)

    out = out.reshape(b, s, h * hd)
    y = dense(p["wo"], out, quant=p.get("wo_q") if quant else None, ctx=quant)
    return y, new_cache
