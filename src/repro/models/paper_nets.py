"""The paper's five workloads (Table I) as JAX forward passes that record
the *input activations of every FC/CONV GEMM* — exactly the tensors QeiHaN
LOG2-quantizes.  Used by benchmarks/fig2 (exponent histograms), fig3
(estimated memory savings) and as the measured-stats source for the
simulator (Figs. 9-12).

No pretrained weights are available offline; weights are random with
publication-standard initializers and inputs are synthetic.  Activation
*distributions* after normalization/ReLU are what matter for the paper's
observation (exponents concentrate below 0), and those are shape- and
normalizer-driven; EXPERIMENTS.md reports both these measured stats and the
paper-digitized presets (simulator/stats.paper_preset) side by side.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

Acts = List[Tuple[str, jnp.ndarray]]


def _dense(key, k, n, scale=None):
    scale = scale if scale is not None else 1.0 / jnp.sqrt(k)
    return jax.random.normal(key, (k, n), jnp.float32) * scale


def _layer_norm(x):
    mu = jnp.mean(x, -1, keepdims=True)
    v = jnp.var(x, -1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(v + 1e-5)


# ---------------------------------------------------------------------------
# AlexNet (5 CONV + 3 FC), batch 1, 227x227 ImageNet-style input
# ---------------------------------------------------------------------------

def alexnet_activations(key) -> Acts:
    ks = iter(jax.random.split(key, 16))
    x = jax.random.normal(next(ks), (1, 227, 227, 3), jnp.float32)
    acts: Acts = []

    def convrelu(name, x, oc, kh, stride, pad):
        ic = x.shape[-1]
        acts.append((name, x))
        w = jax.random.normal(next(ks), (kh, kh, ic, oc)) * jnp.sqrt(2.0 / (kh * kh * ic))
        y = jax.lax.conv_general_dilated(
            x, w, (stride, stride), [(pad, pad), (pad, pad)],
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        return jax.nn.relu(y)

    def maxpool(x):
        return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                     (1, 3, 3, 1), (1, 2, 2, 1), "VALID")

    x = convrelu("conv1", x, 96, 11, 4, 0); x = maxpool(x)
    x = convrelu("conv2", x, 256, 5, 1, 2); x = maxpool(x)
    x = convrelu("conv3", x, 384, 3, 1, 1)
    x = convrelu("conv4", x, 384, 3, 1, 1)
    x = convrelu("conv5", x, 256, 3, 1, 1); x = maxpool(x)
    x = x.reshape(1, -1)
    for name, n in [("fc6", 4096), ("fc7", 4096), ("fc8", 1000)]:
        acts.append((name, x))
        x = jax.nn.relu(x @ _dense(next(ks), x.shape[-1], n, jnp.sqrt(2.0 / x.shape[-1])))
    return acts


# ---------------------------------------------------------------------------
# PTBLM: 2-layer LSTM, hidden 1500 (Zaremba'14 "large")
# ---------------------------------------------------------------------------

def ptblm_activations(key, seq: int = 35, hidden: int = 1500) -> Acts:
    ks = iter(jax.random.split(key, 8))
    emb = jax.random.normal(next(ks), (seq, hidden)) * 0.1   # embedded tokens
    acts: Acts = []
    ws = [_dense(next(ks), 2 * hidden, 4 * hidden) for _ in range(2)]

    def lstm_layer(inputs, w, lname):
        h = jnp.zeros((hidden,))
        c = jnp.zeros((hidden,))
        outs = []
        gate_ins = []
        for t in range(seq):
            xin = jnp.concatenate([inputs[t], h])
            gate_ins.append(xin)
            g = xin @ w
            i, f, o, u = jnp.split(g, 4)
            c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(u)
            h = jax.nn.sigmoid(o) * jnp.tanh(c)
            outs.append(h)
        acts.append((lname, jnp.stack(gate_ins)))
        return jnp.stack(outs)

    x = lstm_layer(emb, ws[0], "lstm0")
    x = lstm_layer(x, ws[1], "lstm1")
    acts.append(("softmax_in", x))
    return acts


# ---------------------------------------------------------------------------
# Transformer / BERT encoders
# ---------------------------------------------------------------------------

def _encoder_activations(key, n_layers: int, d: int, ff: int, seq: int,
                         act_fn=jax.nn.gelu) -> Acts:
    ks = iter(jax.random.split(key, 6 * n_layers + 2))
    x = jax.random.normal(next(ks), (seq, d)) * 1.0
    x = _layer_norm(x)
    acts: Acts = []
    nh = max(d // 64, 1)
    for l in range(n_layers):
        h = _layer_norm(x)
        acts.append((f"l{l}.qkv_in", h))
        q = h @ _dense(next(ks), d, d)
        k = h @ _dense(next(ks), d, d)
        v = h @ _dense(next(ks), d, d)
        qh = q.reshape(seq, nh, -1).transpose(1, 0, 2)
        kh = k.reshape(seq, nh, -1).transpose(1, 0, 2)
        vh = v.reshape(seq, nh, -1).transpose(1, 0, 2)
        a = jax.nn.softmax(qh @ kh.transpose(0, 2, 1) / jnp.sqrt(d / nh), -1)
        o = (a @ vh).transpose(1, 0, 2).reshape(seq, d)
        acts.append((f"l{l}.o_in", o))
        x = x + o @ _dense(next(ks), d, d)
        h2 = _layer_norm(x)
        acts.append((f"l{l}.ff1_in", h2))
        u = act_fn(h2 @ _dense(next(ks), d, ff))
        acts.append((f"l{l}.ff2_in", u))
        x = x + u @ _dense(next(ks), ff, d)
    return acts


def transformer_activations(key, seq: int = 128) -> Acts:
    # 6 encoder + 6 decoder blocks, d=512, ff=2048, ReLU (Vaswani'17)
    k1, k2 = jax.random.split(key)
    enc = _encoder_activations(k1, 6, 512, 2048, seq, act_fn=jax.nn.relu)
    dec = _encoder_activations(k2, 6, 512, 2048, seq, act_fn=jax.nn.relu)
    return enc + [(f"dec_{n}", a) for n, a in dec]


def bert_base_activations(key, seq: int = 128) -> Acts:
    return _encoder_activations(key, 12, 768, 3072, seq)


def bert_large_activations(key, seq: int = 128) -> Acts:
    return _encoder_activations(key, 24, 1024, 4096, seq)


PAPER_ACTIVATIONS: Dict[str, Callable] = {
    "alexnet": alexnet_activations,
    "ptblm": ptblm_activations,
    "transformer": transformer_activations,
    "bert-base": bert_base_activations,
    "bert-large": bert_large_activations,
}
