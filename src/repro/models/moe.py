"""Mixture-of-Experts: sort-based capacity dispatch with two execution paths.

* **Local (pjit/single-device)** — the straightforward jnp formulation used
  for CPU smoke tests and small token counts (decode): top-k route, stable
  sort by expert, scatter into an (E, C, d) buffer, stacked-expert einsums,
  gather/combine.

* **Manual EP (shard_map)** — used under a mesh (``models.sharding.current``
  provides it) when experts divide the TP axis.  GSPMD cannot partition the
  dispatch scatter between token-sharded sources and expert-sharded buffers
  (it replicates — measured 9.5 TiB/chip all-reduce on deepseek-moe), so we
  do what production MoE systems do: each chip dispatches its *local* tokens
  into per-expert buffers, a tiled ``all_to_all`` over the EP axis regroups
  slots expert-major, local expert GEMMs run, and a second ``all_to_all``
  returns outputs to the token owners.  When tokens are replicated over the
  EP axis (decode without seq sharding) the combine is a ``psum`` instead.

Dense one-hot (GShard) dispatch is avoided entirely: its (tokens, E, C)
tensor is quadratic in tokens and infeasible at 1M-token train steps.

Shared experts (DeepSeekMoE) run densely on every token outside the routed
path.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import sharding as shctx
from repro.models.layers import swiglu
from repro.models.sharding import shard


def topk_routing(router_w, x2d: jnp.ndarray, n_experts: int, k: int):
    """x2d: (G, d) -> gates (G, k) f32, ids (G, k) int32."""
    logits = jnp.matmul(x2d.astype(jnp.float32),
                        router_w.astype(jnp.float32))       # (G, E)
    gates, ids = jax.lax.top_k(logits, k)
    gates = jax.nn.softmax(gates, axis=-1)
    return gates, ids


def _dispatch_tables(ids: jnp.ndarray, n_experts: int,
                     capacity: int) -> Tuple[jnp.ndarray, ...]:
    """Sort-based slot -> (expert, position) mapping with capacity drops.

    Returns (order, dest, keep): ``order`` sorts slots expert-major;
    ``dest`` is the row in the flattened (E*C) buffer (dropped -> E*C)."""
    gk = ids.shape[0]
    order = jnp.argsort(ids, stable=True)
    sorted_ids = ids[order]
    starts = jnp.searchsorted(sorted_ids, jnp.arange(n_experts), side="left")
    pos = jnp.arange(gk) - starts[sorted_ids]
    keep = pos < capacity
    dest = jnp.where(keep, sorted_ids * capacity + pos, n_experts * capacity)
    return order, dest, keep


def _expert_ffn(buf: jnp.ndarray, experts, dtype) -> jnp.ndarray:
    hg = jnp.einsum("ecd,edf->ecf", buf, experts["gate"].astype(dtype))
    hu = jnp.einsum("ecd,edf->ecf", buf, experts["up"].astype(dtype))
    h = jax.nn.silu(hg.astype(jnp.float32)).astype(dtype) * hu
    return jnp.einsum("ecf,efd->ecd", h, experts["down"].astype(dtype))


def _route_local(p, x2d: jnp.ndarray, cfg) -> jnp.ndarray:
    g, d = x2d.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    capacity = min(int(g * k / e * cfg.capacity_factor) + 1, g)

    gates, ids = topk_routing(p["router"], x2d, e, k)
    flat_ids = ids.reshape(-1)
    flat_gates = gates.reshape(-1)
    slot_token = jnp.repeat(jnp.arange(g), k)

    order, dest, keep = _dispatch_tables(flat_ids, e, capacity)
    xin = x2d[slot_token[order]]
    buf = jnp.zeros((e * capacity + 1, d), x2d.dtype).at[dest].set(xin)
    buf = shard(buf[:-1].reshape(e, capacity, d), "ecd")
    out_buf = shard(_expert_ffn(buf, p["experts"], x2d.dtype), "ecd")

    flat_out = out_buf.reshape(e * capacity, d)
    safe = jnp.clip(dest, 0, e * capacity - 1)
    slot_out = jnp.where(keep[:, None], flat_out[safe], 0.0)
    slot_out = slot_out * flat_gates[order][:, None].astype(x2d.dtype)
    return jnp.zeros((g, d), x2d.dtype).at[slot_token[order]].add(slot_out)


# ---------------------------------------------------------------------------
# manual EP via shard_map
# ---------------------------------------------------------------------------

def _route_ep_body(router, experts, x_loc, *, cfg, axis: str,
                   tokens_split: bool):
    """Runs per-chip inside shard_map.  x_loc: (b_loc, s_loc, d)."""
    m = shctx.axis_size(axis)
    col = jax.lax.axis_index(axis)
    b, s, d = x_loc.shape
    g = b * s
    e, k = cfg.n_experts, cfg.experts_per_token
    e_loc = e // m
    x2d = x_loc.reshape(g, d)
    gates, ids = topk_routing(router, x2d, e, k)
    flat_ids = ids.reshape(-1)
    flat_gates = gates.reshape(-1)
    slot_token = jnp.repeat(jnp.arange(g), k)

    if tokens_split:
        # per-chip buffers for ALL experts, then all_to_all expert-major
        capacity = min(int(g * k / e * cfg.capacity_factor) + 1, g)
        order, dest, keep = _dispatch_tables(flat_ids, e, capacity)
        xin = x2d[slot_token[order]]
        buf = jnp.zeros((e * capacity + 1, d), x_loc.dtype).at[dest].set(xin)
        buf = buf[:-1].reshape(e, capacity, d)
        buf = jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=1,
                                 tiled=True)          # (E_loc, M*C, d)
        out = _expert_ffn(buf, experts, x_loc.dtype)
        out = jax.lax.all_to_all(out, axis, split_axis=1, concat_axis=0,
                                 tiled=True)          # (E, C, d)
        flat_out = out.reshape(e * capacity, d)
        safe = jnp.clip(dest, 0, e * capacity - 1)
        slot_out = jnp.where(keep[:, None], flat_out[safe], 0.0)
        slot_out = slot_out * flat_gates[order][:, None].astype(x_loc.dtype)
        y2d = jnp.zeros((g, d), x_loc.dtype).at[slot_token[order]].add(slot_out)
    else:
        # tokens replicated across EP axis: keep only this chip's experts,
        # combine partial outputs with a psum
        capacity = min(int(g * k / e * cfg.capacity_factor) + 1, g)
        local = (flat_ids >= col * e_loc) & (flat_ids < (col + 1) * e_loc)
        rel_ids = jnp.where(local, flat_ids - col * e_loc, e_loc)
        order = jnp.argsort(rel_ids, stable=True)
        sorted_ids = rel_ids[order]
        starts = jnp.searchsorted(sorted_ids, jnp.arange(e_loc), side="left")
        pos = jnp.arange(rel_ids.shape[0]) - starts[jnp.clip(sorted_ids, 0, e_loc - 1)]
        keep = (sorted_ids < e_loc) & (pos < capacity)
        dest = jnp.where(keep, sorted_ids * capacity + pos, e_loc * capacity)
        xin = x2d[slot_token[order]]
        buf = jnp.zeros((e_loc * capacity + 1, d), x_loc.dtype).at[dest].set(xin)
        out = _expert_ffn(buf[:-1].reshape(e_loc, capacity, d), experts,
                          x_loc.dtype)
        flat_out = out.reshape(e_loc * capacity, d)
        safe = jnp.clip(dest, 0, e_loc * capacity - 1)
        slot_out = jnp.where(keep[:, None], flat_out[safe], 0.0)
        slot_out = slot_out * flat_gates[order][:, None].astype(x_loc.dtype)
        y2d = jnp.zeros((g, d), x_loc.dtype).at[slot_token[order]].add(slot_out)
        y2d = jax.lax.psum(y2d, axis)
    return y2d.reshape(b, s, d)


def _route_ep(p, x: jnp.ndarray, cfg, ctx) -> jnp.ndarray:
    import numpy as np
    mesh = ctx["mesh"]
    axis = ctx.get("ep_axis") or ctx["model"]
    sizes = dict(mesh.shape)
    m = sizes[axis]
    bax = ctx["batch"]
    b, s, d = x.shape
    # longest batch-axis prefix that divides B (long-context has B=1)
    use = list(bax)
    while use and b % int(np.prod([sizes[a] for a in use])):
        use.pop()
    bspec = tuple(use) if use else None
    batch_covers_ep = bspec is not None and axis in bspec
    split_seq = (not batch_covers_ep and ctx["seq_shard"]
                 and s % m == 0 and s >= m)
    # tokens distributed across the EP axis -> all_to_all regroup;
    # tokens replicated across it -> local experts + psum combine
    tokens_split = batch_covers_ep or split_seq
    x_spec = P(bspec, axis if split_seq else None, None)
    e_spec = P(axis, None, None)
    body = functools.partial(_route_ep_body, cfg=cfg, axis=axis,
                             tokens_split=tokens_split)
    fn = shctx.shard_map(body, mesh=mesh,
                         in_specs=(P(), e_spec, x_spec),
                         out_specs=x_spec, check_vma=False)
    return fn(p["router"], p["experts"], x)


def moe_apply(p, x: jnp.ndarray, cfg, quant: bool = False) -> jnp.ndarray:
    """p: router (d, E); experts {'gate','up','down'} stacked (E, ...);
    optional 'shared' swiglu params.  x: (B, S, d)."""
    b, s, d = x.shape
    ctx = shctx.current()
    ep_ax = (ctx or {}).get("ep_axis") or (ctx or {}).get("model")
    use_ep = (ctx is not None and ctx.get("mesh") is not None
              and ep_ax is not None
              and cfg.n_experts % dict(ctx["mesh"].shape)[ep_ax] == 0)
    if use_ep:
        y2d = _route_ep(p, x, cfg, ctx).reshape(b * s, d)
    else:
        y2d = _route_local(p, x.reshape(b * s, d), cfg)

    if "shared" in p:
        y2d = y2d + swiglu(p["shared"], x.reshape(b * s, d), quant=quant)
    return y2d.reshape(b, s, d)
