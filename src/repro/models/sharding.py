"""Activation-sharding context for the model zoo.

Models place ``shard(x, kind)`` hints at the canonical Megatron/SP points;
the launcher configures which mesh axes those hints bind to (and their
sizes).  When no axes are configured (unit tests, single-device smoke runs)
the hints are no-ops, so the same model code runs everywhere.

Every hint is **divisibility-checked**: a dim whose size doesn't divide the
bound axis size stays unsharded (e.g. smollm's 9 heads under 16-way TP) —
this mirrors the param-rule fallback and avoids GSPMD involuntary
rematerialization/replication.

Kinds:
  ``btd``   — residual stream (batch, seq, d): batch on DP axes, seq on the
              TP axis (sequence parallelism) so scan-carried remat residuals
              are distributed.
  ``btf``   — MLP hidden (batch, seq, ff): ff on TP.
  ``bthd``  — attention heads (batch, seq, heads, head_dim): heads on TP.
  ``btv``   — logits (batch, seq, vocab): vocab on TP.
  ``ecd``   — MoE expert buffers (experts, capacity, d): experts on TP (EP).
  ``cache`` — KV cache (batch, seq, kv_heads, hd): seq on DP for
              long-context decode (batch=1 there), else batch on DP.
  ``pool``  — paged-KV page pool (pages, page_len, kv_heads, hd): pages
              on DP (the batch role for paged serving).
  ``bshp``/``bchll``/``bchpn`` — SSD tensors: ssm-heads on TP.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()

_KIND_LAYOUT = {
    # kind -> list of (role) per dim; roles: 'b' batch, 's' seq (SP),
    # 'm' model, None replicate
    "btd": ("b", "s", None),
    "btf": ("b", None, "m"),
    "bthd": ("b", None, "m", None),
    "btv": ("b", None, "m"),
    "ecd": ("m", None, None),
    "bshp": ("b", None, "m", None),
    "bchll": ("b", None, "m", None, None),
    "bchpn": ("b", None, "m", None, None),
    "cache": ("b", "cs", None, None),
    # paged KV page pool (pages, page_len, kv_heads, hd): pages on the DP
    # axes — the page dim plays the batch role (every slot's rows live in
    # its pages), and the in-page token dim is never sharded so (page,
    # offset) indexing needs no sharded-axis reshape
    "pool": ("b", None, None, None),
    # split-KV flash-decode partials (kernels/paged_attention): acc
    # (B, G, split, R, D) and the (m, l) statistics (B, G, split, R).  The
    # split axis rides the model axis — each model shard owns a contiguous
    # run of KV pages and its own partial softmax, and the cross-split
    # merge (ops.merge_split_softmax) is the only collective: a tiny
    # (B, G, R)-sized statistic reduce instead of an all-gathered cache
    # (launch.shardings.split_kv_specs is the jit-boundary image)
    "kvsplit": ("b", None, "m", None, None),
    "kvsplit_stat": ("b", None, "m", None),
    # channels-REPLICATED (B, S, C): used with force=True to pin tensors
    # whose channel axis is about to be concat/split (the mamba conv window)
    "btc": ("b", None, None),
}


def _axes():
    return getattr(_state, "axes", None)


@contextlib.contextmanager
def mesh_axes(batch: Sequence[str] | str | None = ("data",),
              model: Optional[str] = "model",
              seq_shard: bool = True,
              cache_seq_axis: Optional[str] = None,
              sizes: Optional[Dict[str, int]] = None,
              mesh=None,
              ep_axis: Optional[str] = None):
    """Bind sharding hints to mesh axis names for the enclosed scope.

    ``sizes`` maps axis name -> size for divisibility checks (pass
    ``dict(mesh.shape)``); without it hints are applied unchecked.
    ``mesh`` (optional) enables shard_map-based blocks (manual-EP MoE);
    ``ep_axis`` names the expert-parallel axis (defaults to ``model``).
    """
    prev = _axes()
    batch_t = tuple(batch) if isinstance(batch, (tuple, list)) else (
        (batch,) if batch else ())
    if mesh is not None and sizes is None:
        sizes = dict(mesh.shape)
    _state.axes = dict(batch=batch_t, model=model, seq_shard=seq_shard,
                       cache_seq_axis=cache_seq_axis, sizes=sizes or {},
                       mesh=mesh, ep_axis=ep_axis if ep_axis else model)
    try:
        yield
    finally:
        _state.axes = prev


def current() -> Optional[dict]:
    """The active mesh-axes binding (None outside any mesh_axes scope)."""
    return _axes()


def _fits(dim_size: int, axis, sizes: Dict[str, int]) -> bool:
    if axis is None:
        return False
    names = axis if isinstance(axis, tuple) else (axis,)
    total = 1
    for n in names:
        total *= sizes.get(n, 1)
    if total <= 1:
        return False
    return dim_size % total == 0


def spec_for(kind: str, shape, force: bool = False) -> Optional[P]:
    ax = _axes()
    if ax is None:
        return None
    layout = _KIND_LAYOUT.get(kind)
    if layout is None:
        raise ValueError(f"unknown sharding kind {kind!r}")
    if len(layout) != len(shape):
        return None
    sizes = ax["sizes"]
    b = ax["batch"] if ax["batch"] else None
    m = ax["model"]
    entries = []
    for role, dim in zip(layout, shape):
        target = None
        if role == "b":
            target = b
        elif role == "m":
            target = m
        elif role == "s":
            target = m if ax["seq_shard"] else None
        elif role == "cs":
            # KV-cache sequence dim: explicit long-context axis, else the TP
            # axis (kv heads rarely divide 16-way TP; the seq dim always does)
            target = ax["cache_seq_axis"] or m
        if target is not None and (not sizes or _fits(dim, target, sizes)):
            entries.append(target)
        else:
            entries.append(None)
    if all(e is None for e in entries) and not force:
        return None
    return P(*entries)


def shard(x: jax.Array, kind: str, force: bool = False) -> jax.Array:
    """Sharding hint; a no-op outside a :func:`mesh_axes` scope.

    ``force=True`` applies the constraint even when every dim falls back to
    replicated — an all-``None`` spec is normally skipped as useless, but it
    is exactly what pins a tensor REPLICATED against GSPMD's propagation
    choices.  Rope inputs need this: jax 0.4.37's CPU SPMD backend
    miscompiles split/concat along a sharded axis (partially-replicated
    meshes only — see tests/test_serve_sharded.py), and head-dim replication
    before rope is the standard Megatron layout on TPU anyway.
    """
    s = spec_for(kind, x.shape, force=force)
    if s is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, s)
    except (ValueError, TypeError):
        return x


def replicate(x: jax.Array) -> jax.Array:
    """Force ``x`` fully replicated (any rank); a no-op outside a
    :func:`mesh_axes` scope.  The blunt instrument behind the CPU-SPMD
    hazard rule (see :func:`shard`): tensors about to be concatenated or
    split along an axis that param rules may have sharded — e.g. the mamba
    conv weights — get pinned replicated first."""
    ax = _axes()
    if ax is None or not ax["sizes"]:
        return x
    try:
        return jax.lax.with_sharding_constraint(
            x, P(*([None] * getattr(x, "ndim", 0))))
    except (ValueError, TypeError):
        return x


def axis_size(name: str) -> int:
    """Version-portable ``jax.lax.axis_size`` (absent before ~0.5): inside a
    collective scope ``psum(1, name)`` constant-folds to the static size."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """Version-portable ``shard_map``: jax >= 0.6 exposes ``jax.shard_map``
    (``check_vma``); older releases only have the experimental module
    (``check_rep``).  All repo call sites go through this wrapper."""
    if hasattr(jax, "shard_map"):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)
