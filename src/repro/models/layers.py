"""Shared layer primitives for the model zoo (pure-functional, pytree params)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.shiftadd import (QuantCtx, QuantizedLinearParams, as_quant_ctx,
                                 quantized_linear_apply, quantized_linear_init)

# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, k: int, n: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(k))
    return (jax.random.normal(key, (k, n), jnp.float32) * scale).astype(dtype)


def embed_init(key, v: int, d: int, dtype):
    return (jax.random.normal(key, (v, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * w


def layer_norm(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(dt) * w + b


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 1e4) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# dense projection (float or QeiHaN-quantized)
# ---------------------------------------------------------------------------

def dense(w, x: jnp.ndarray, bias=None,
          quant: Optional[QuantizedLinearParams] = None,
          ctx=None) -> jnp.ndarray:
    """Projection with optional QeiHaN path.

    ``w``: (K, N); ``x``: (..., K).  When ``quant`` is provided the GEMM runs
    through the LOG2-activation / bit-plane-weight shift-add path (the
    framework's first-class integration of the paper's technique).  ``ctx``
    (bool | str | QuantCtx) selects the backend ("xla" | "pallas") and
    optionally collects plane-traffic counts; see ``core.shiftadd.QuantCtx``.
    """
    if quant is not None:
        qc = as_quant_ctx(ctx) or QuantCtx()
        y = quantized_linear_apply(quant, x, n_bits=qc.n_bits,
                                   backend=qc.backend,
                                   collect=qc.collect).astype(x.dtype)
    else:
        y = jnp.matmul(x, w.astype(x.dtype))
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return y


def quantize_dense(w, bias=None, act_scale: float = 1.0) -> QuantizedLinearParams:
    return quantized_linear_init(jnp.asarray(w, jnp.float32), bias=bias,
                                 act_scale=act_scale)


def swiglu(p, x: jnp.ndarray, quant=False) -> jnp.ndarray:
    """p: {'gate': (d, ff), 'up': (d, ff), 'down': (ff, d)}.

    ``quant`` is the usual bool | str | QuantCtx flag (truthy enables the
    QeiHaN path and is forwarded to ``dense`` as the backend/stats context).
    """
    g = dense(p["gate"], x, quant=p.get("gate_q") if quant else None,
              ctx=quant)
    u = dense(p["up"], x, quant=p.get("up_q") if quant else None, ctx=quant)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    from repro.models.sharding import shard
    h = shard(h, "btf")
    return dense(p["down"], h, quant=p.get("down_q") if quant else None,
                 ctx=quant)
