"""DecoderModel: one machinery for all 10 assigned architectures.

A model is a periodic ``pattern`` of block kinds (length = period ``P``)
repeated ``n_layers / P`` times.  Parameters are stored *stacked over
repeats* (leading dim ``R``) and executed with ``lax.scan`` over repeats,
with the period unrolled inside the scan body — true layer order, small HLO,
fast 512-device SPMD compiles, and remat-at-period granularity.

Block kinds: ``attn`` | ``attn_moe`` | ``mamba`` | ``mamba_moe``.
Frontends (audio/vision) are stubs per the assignment: ``input_specs()``
supplies precomputed frame/patch embeddings.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.shiftadd import as_quant_ctx
from repro.models import moe as moe_lib
from repro.models import ssd as ssd_lib
from repro.models.attention import (KVCache, PagedKVCache,
                                    QuantPagedKVCache, attention)
from repro.models.layers import (dense, dense_init, embed_init, rms_norm,
                                 swiglu)
from repro.models.sharding import shard

Params = Dict[str, Any]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    d_ff: int
    vocab_size: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 128
    pattern: Tuple[str, ...] = ("attn",)
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # SSM (Mamba-2)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    conv_width: int = 4
    ssd_chunk: int = 256
    # frontends
    frontend: str = "none"            # none | audio_stub | vision_stub
    n_image_tokens: int = 0
    # execution
    dtype: Any = jnp.bfloat16
    cache_dtype: Any = None           # None -> io dtype; f8 halves KV residency
    kv_chunk: int = 1024
    remat: str = "full"               # none | full | dots
    # paged decode attention: "off" = dense pool[table] gather + masked
    # einsum; "pallas" = the fused table-walk kernel
    # (kernels/paged_attention) with paged_attn_splits-way split-KV
    # flash-decode.  Only consulted on the PagedKVCache decode path.
    paged_attn_kernel: str = "off"    # off | pallas
    paged_attn_splits: int = 1
    # log2-quantized KV pages (DESIGN.md §Quantized KV pages): the paged
    # pool stores packed core/logquant codes + per-page power-of-two scale
    # exponents instead of full-precision rows; a dense f32 tail ring keeps
    # the newest (partial) page exact.  Only consulted by init_paged_pool /
    # the PagedKVCache paths.
    kv_quant: bool = False
    kv_bits: int = 4
    # attention class: 'full' is quadratic -> long_500k is skipped for these
    # (DESIGN.md §Skips); SSM/hybrid run it.
    sub_quadratic: bool = False

    @property
    def repeats(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, \
            f"{self.n_layers} layers not divisible by period {len(self.pattern)}"
        return self.n_layers // len(self.pattern)

    @property
    def d_inner(self) -> int:
        return self.ssm_heads * self.ssm_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def _init_mlp(key, cfg: ModelConfig, moe: bool) -> Params:
    dt = cfg.dtype
    d = cfg.d_model
    if not moe:
        k1, k2, k3 = jax.random.split(key, 3)
        return {"gate": dense_init(k1, d, cfg.d_ff, dt),
                "up": dense_init(k2, d, cfg.d_ff, dt),
                "down": dense_init(k3, cfg.d_ff, d, dt)}
    ks = jax.random.split(key, 5)
    ffe = cfg.moe_d_ff or cfg.d_ff
    e = cfg.n_experts
    p: Params = {
        "router": dense_init(ks[0], d, e, jnp.float32, scale=0.02),
        "experts": {
            "gate": dense_init(ks[1], e * d, ffe, dt).reshape(e, d, ffe),
            "up": dense_init(ks[2], e * d, ffe, dt).reshape(e, d, ffe),
            "down": dense_init(ks[3], e * ffe, d, dt).reshape(e, ffe, d),
        },
    }
    if cfg.n_shared_experts:
        ffs = ffe * cfg.n_shared_experts
        s1, s2, s3 = jax.random.split(ks[4], 3)
        p["shared"] = {"gate": dense_init(s1, d, ffs, dt),
                       "up": dense_init(s2, d, ffs, dt),
                       "down": dense_init(s3, ffs, d, dt)}
    return p


def _init_attn(key, cfg: ModelConfig) -> Params:
    dt = cfg.dtype
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    p: Params = {
        "ln1": jnp.ones((d,), dt),
        "wq": dense_init(ks[0], d, h * hd, dt),
        "wk": dense_init(ks[1], d, hkv * hd, dt),
        "wv": dense_init(ks[2], d, hkv * hd, dt),
        "wo": dense_init(ks[3], h * hd, d, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((hkv * hd,), dt)
        p["bv"] = jnp.zeros((hkv * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def _init_mamba(key, cfg: ModelConfig) -> Params:
    dt = cfg.dtype
    d = cfg.d_model
    h, pdim, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    d_inner = h * pdim
    ks = jax.random.split(key, 9)
    w = cfg.conv_width

    def conv_init(k, c):
        return (jax.random.normal(k, (w, c), jnp.float32) * 0.2).astype(dt)

    # separate, shard-aligned projections (see models/ssd.py §Perf note)
    return {
        "ln1": jnp.ones((d,), dt),
        "wz": dense_init(ks[0], d, d_inner, dt),
        "wx": dense_init(ks[1], d, d_inner, dt),
        "wb": dense_init(ks[2], d, n, dt),
        "wc": dense_init(ks[3], d, n, dt),
        "wdt": dense_init(ks[4], d, h, dt),
        "conv_wx": conv_init(ks[5], d_inner),
        "conv_bx": jnp.zeros((d_inner,), dt),
        "conv_wb": conv_init(ks[6], n),
        "conv_bb": jnp.zeros((n,), dt),
        "conv_wc": conv_init(ks[7], n),
        "conv_bc": jnp.zeros((n,), dt),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "a_log": jnp.zeros((h,), jnp.float32),          # A = -1
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": jnp.ones((d_inner,), dt),
        "out_proj": dense_init(ks[8], d_inner, d, dt),
    }


def _init_block(key, cfg: ModelConfig, kind: str) -> Params:
    base, moe = (kind.split("_") + [""])[:2]
    k1, k2 = jax.random.split(key)
    if base == "attn":
        p = _init_attn(k1, cfg)
    elif base == "mamba":
        p = _init_mamba(k1, cfg)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    if moe == "moe":
        p["ln2"] = jnp.ones((cfg.d_model,), cfg.dtype)
        p["mlp"] = _init_mlp(k2, cfg, moe=True)
    elif base == "attn" or cfg.d_ff:
        p["ln2"] = jnp.ones((cfg.d_model,), cfg.dtype)
        p["mlp"] = _init_mlp(k2, cfg, moe=False)
    return p


def init_params(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, len(cfg.pattern) + 3)
    blocks = []
    for i, kind in enumerate(cfg.pattern):
        layer_keys = jax.random.split(keys[i], cfg.repeats)
        blocks.append(jax.vmap(
            lambda k, kind=kind: _init_block(k, cfg, kind))(layer_keys))
    params: Params = {
        "embed": embed_init(keys[-3], cfg.vocab_size, cfg.d_model, cfg.dtype),
        "blocks": tuple(blocks),
        "final_norm": jnp.ones((cfg.d_model,), cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[-2], cfg.d_model, cfg.vocab_size,
                                       cfg.dtype, scale=0.02)
    if cfg.frontend == "vision_stub":
        params["img_proj"] = dense_init(keys[-1], cfg.d_model, cfg.d_model,
                                        cfg.dtype)
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                dtype=None, per_slot: bool = False) -> Params:
    """Stacked (over repeats) per-period-position cache trees.

    ``per_slot=True`` makes ``length`` a ``(batch,)`` vector — one valid
    length per batch row — which is what the continuous-batching slot pool
    needs (``serving/scheduler.py``): every cache consumer accepts either the
    scalar or the per-row form.
    """
    if dtype is None:
        dtype = cfg.cache_dtype or cfg.dtype
    layers = []
    for kind in cfg.pattern:
        base = kind.split("_")[0]
        if base == "attn":
            c = {"k": jnp.zeros((cfg.repeats, batch, max_len, cfg.n_kv_heads,
                                 cfg.head_dim), dtype),
                 "v": jnp.zeros((cfg.repeats, batch, max_len, cfg.n_kv_heads,
                                 cfg.head_dim), dtype)}
        else:
            st = ssd_lib.mamba2_init_state(batch, cfg, dtype)
            c = {"ssm": jnp.broadcast_to(st.ssm, (cfg.repeats,) + st.ssm.shape),
                 "conv": jnp.broadcast_to(st.conv, (cfg.repeats,) + st.conv.shape)}
        layers.append(c)
    length = jnp.zeros((batch,) if per_slot else (), jnp.int32)
    return {"layers": tuple(layers), "length": length}


def init_paged_pool(cfg: ModelConfig, batch: int, max_len: int,
                    n_pages: int, page_len: int, dtype=None) -> Params:
    """Paged slot-pool caches (``serving/scheduler.py`` ``paged=True``).

    Attention KV lives in a shared page pool ``(R, n_pages, page_len, G,
    D)`` indexed through a host-side per-slot page table instead of a
    dense ``(R, B, max_len, ...)`` slab — page 0 is the reserved trash
    page (``serving.kvpool``).  SSM/conv recurrent state cannot be paged
    (a recurrence has no per-position rows to alias) and keeps the dense
    per-slot layout; ``length`` is per-slot like ``init_caches(per_slot=
    True)``.  ``max_len`` must be a multiple of ``page_len`` so the
    gathered per-slot view ``(B, blocks * page_len, ...)`` matches the
    dense slab shape exactly (the bit-equality bar).

    ``cfg.kv_quant=True`` swaps the full-precision K/V pools for the
    log2-compressed page format (DESIGN.md §Quantized KV pages): packed
    wire codes ``{k,v}_codes (R, n_pages, page_len, G, D)``
    (``core.logquant.code_dtype(cfg.kv_bits)``), per-page power-of-two
    scale exponents ``{k,v}_scale (R, n_pages, G)`` int32, and a dense
    per-slot tail ring ``{k,v}_tail (R, B, 2*page_len + 1, G, D)`` that
    holds each slot's newest two pages exactly (row ``2*page_len`` is the
    junk bin for masked writes).  Two pages — not one — so a page-boundary
    junk write from an inactive slot (frozen length ≡ 0 mod page_len)
    lands in the ring slot of a position two pages back, never clobbering
    a row the overlay still reads.
    """
    if max_len % page_len:
        raise ValueError(f"max_len={max_len} must be a multiple of "
                         f"page_len={page_len}")
    if dtype is None:
        dtype = cfg.cache_dtype or cfg.dtype
    layers = []
    for kind in cfg.pattern:
        base = kind.split("_")[0]
        if base == "attn" and cfg.kv_quant:
            from repro.core.logquant import code_dtype
            ct = code_dtype(cfg.kv_bits)
            kv_shape = (cfg.repeats, n_pages, page_len,
                        cfg.n_kv_heads, cfg.head_dim)
            tail_shape = (cfg.repeats, batch, 2 * page_len + 1,
                          cfg.n_kv_heads, cfg.head_dim)
            c = {"k_codes": jnp.zeros(kv_shape, ct),
                 "v_codes": jnp.zeros(kv_shape, ct),
                 "k_scale": jnp.zeros((cfg.repeats, n_pages,
                                       cfg.n_kv_heads), jnp.int32),
                 "v_scale": jnp.zeros((cfg.repeats, n_pages,
                                       cfg.n_kv_heads), jnp.int32),
                 "k_tail": jnp.zeros(tail_shape, dtype),
                 "v_tail": jnp.zeros(tail_shape, dtype)}
        elif base == "attn":
            c = {"k": jnp.zeros((cfg.repeats, n_pages, page_len,
                                 cfg.n_kv_heads, cfg.head_dim), dtype),
                 "v": jnp.zeros((cfg.repeats, n_pages, page_len,
                                 cfg.n_kv_heads, cfg.head_dim), dtype)}
        else:
            st = ssd_lib.mamba2_init_state(batch, cfg, dtype)
            c = {"ssm": jnp.broadcast_to(st.ssm, (cfg.repeats,) + st.ssm.shape),
                 "conv": jnp.broadcast_to(st.conv,
                                          (cfg.repeats,) + st.conv.shape)}
        layers.append(c)
    return {"layers": tuple(layers),
            "length": jnp.zeros((batch,), jnp.int32)}


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _apply_block(cfg: ModelConfig, kind: str, p: Params, x, positions,
                 cache, cache_len, quant, valid_len=None, chunk_valid=None,
                 page_table=None):
    base = kind.split("_")[0]
    is_moe = kind.endswith("_moe")
    x = shard(x, "btd")                     # keep the scan carry SP-sharded
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if base == "attn":
        if cache is None:
            kv = None
        elif page_table is not None and "k_codes" in cache:
            # log2-quantized page pool: packed codes + per-page scales +
            # dense tail ring (models/attention.py quantized paths)
            kv = QuantPagedKVCache(
                k_codes=cache["k_codes"], v_codes=cache["v_codes"],
                k_scale=cache["k_scale"], v_scale=cache["v_scale"],
                k_tail=cache["k_tail"], v_tail=cache["v_tail"],
                page_table=page_table, length=cache_len)
        elif page_table is not None:
            # paged slot pool: this layer's KV is a page pool indexed by
            # the shared host-built page table (models/attention.py)
            kv = PagedKVCache(k=cache["k"], v=cache["v"],
                              page_table=page_table, length=cache_len)
        else:
            kv = KVCache(k=cache["k"], v=cache["v"], length=cache_len)
        out, new_kv = attention(p, h, positions, cfg, cache=kv, quant=quant,
                                chunk_valid=chunk_valid)
        if new_kv is None:
            new_cache = None
        elif isinstance(new_kv, QuantPagedKVCache):
            new_cache = {"k_codes": new_kv.k_codes, "v_codes": new_kv.v_codes,
                         "k_scale": new_kv.k_scale, "v_scale": new_kv.v_scale,
                         "k_tail": new_kv.k_tail, "v_tail": new_kv.v_tail}
        else:
            new_cache = {"k": new_kv.k, "v": new_kv.v}
    else:
        st = None if cache is None else ssd_lib.SSMState(
            ssm=cache["ssm"], conv=cache["conv"])
        # a chunk's per-row valid count doubles as the SSM pad mask: pad
        # tokens get dt = 0 (state passes through untouched) and the rolling
        # conv window re-anchors at the real-token boundary — the same
        # masking bucketed prefill uses, applied mid-prompt
        out, new_st = ssd_lib.mamba2_block(
            p, h, cfg, state=st, quant=quant,
            valid_len=chunk_valid if chunk_valid is not None else valid_len)
        new_cache = None if new_st is None else {
            "ssm": new_st.ssm, "conv": new_st.conv}
    # hint the projection output to the residual sharding *before* the add so
    # GSPMD emits reduce-scatter (SP) rather than all-reduce + slice
    out = shard(out, "btd")
    x = x + out
    if "mlp" in p:
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        if is_moe:
            y = moe_lib.moe_apply(p["mlp"], h2, cfg, quant=quant)
        else:
            y = swiglu(p["mlp"], h2, quant=quant)
        y = shard(y, "btd")
        x = x + y
        x = shard(x, "btd")
    return x, new_cache


def forward(cfg: ModelConfig, params: Params, *,
            tokens: Optional[jnp.ndarray] = None,
            embeds: Optional[jnp.ndarray] = None,
            image_embeds: Optional[jnp.ndarray] = None,
            positions: Optional[jnp.ndarray] = None,
            caches: Optional[Params] = None,
            quant=False,
            return_stats: bool = False,
            valid_len: Optional[jnp.ndarray] = None,
            chunk_valid: Optional[jnp.ndarray] = None,
            page_table: Optional[jnp.ndarray] = None):
    """Returns (logits, new_caches). ``caches`` enables decode/prefill mode.

    ``quant`` (bool | str | QuantCtx) routes eligible projections through the
    QeiHaN shift-add path.  With ``return_stats=True`` a third element is
    returned: ``{"plane_fetched", "plane_total", "plane_traffic_fraction"}``,
    the weight-plane HBM-traffic accounting summed over every quantized
    projection of the call (the decode-time image of the paper's §VI
    memory-access savings; zeros when ``quant`` is falsy).

    ``caches["length"]`` may be a scalar (whole-batch, the classic path) or a
    ``(B,)`` vector (per-slot lengths, continuous batching): positions, KV
    writes and attention masking all honor the per-row form.  ``valid_len``
    (``(B,)``, bucketed prefill only) marks rows ``>= valid_len[b]`` of the
    input as right-padding: SSM state/conv updates are masked so pad tokens
    neither decay nor feed the recurrent state (attention needs no mask —
    pads sit at causal positions after every real token).

    ``chunk_valid`` (``(B,)``, chunked prefill) marks the input as one
    right-padded *mid-prompt chunk* per row: earlier chunks already live in
    the caches, so attention writes only the real slab rows and attends over
    the cache (``models.attention`` chunk path), the SSM path applies the
    same ``valid_len`` pad masking, and the cache ``length`` advances by
    ``chunk_valid`` — not by the padded slab width ``s``.  A row with
    ``chunk_valid[b] == 0`` passes through the call with its cache
    bit-identical (decode/free slots ride along in the serve scheduler's
    mixed tick).  Mutually exclusive with ``valid_len``.
    """
    if valid_len is not None and chunk_valid is not None:
        raise ValueError("pass either valid_len (bucketed prefill) or "
                         "chunk_valid (chunked prefill), not both")
    if chunk_valid is not None and caches is None:
        raise ValueError("chunk_valid requires caches: a chunk appends to "
                         "resident earlier chunks")
    ctx = as_quant_ctx(quant)
    if embeds is not None:                       # audio stub: direct embeddings
        x = embeds.astype(cfg.dtype)
    else:
        x = params["embed"][tokens]
    if image_embeds is not None:                 # vision stub: prepend patches
        img = dense(params["img_proj"], image_embeds.astype(cfg.dtype))
        x = jnp.concatenate([img, x], axis=1)
    b, s, _ = x.shape
    if positions is None:
        base = caches["length"] if caches is not None else 0
        if getattr(base, "ndim", 0):                 # per-slot (B,) lengths
            positions = base[:, None] + jnp.arange(s, dtype=jnp.int32)[None]
        else:
            positions = base + jnp.broadcast_to(
                jnp.arange(s, dtype=jnp.int32), (b, s))
    x = shard(x, "btd")
    cache_len = caches["length"] if caches is not None else None

    layer_caches = caches["layers"] if caches is not None else None

    def period_body(x, xs):
        lp, lc = xs
        # plane-traffic accounting: the collect list is created AND consumed
        # inside this body so its tracers never cross the scan boundary; the
        # per-period sums stream out as scan ys
        bctx = None if ctx is None else dataclasses.replace(
            ctx, collect=[] if return_stats else None)
        new_cs = []
        for i, kind in enumerate(cfg.pattern):
            c_i = None if lc is None else lc[i]
            x, nc = _apply_block(cfg, kind, lp[i], x, positions, c_i,
                                 cache_len, bctx, valid_len=valid_len,
                                 chunk_valid=chunk_valid,
                                 page_table=page_table)
            new_cs.append(nc)
        traffic = None
        if return_stats:
            coll = bctx.collect if bctx is not None else []
            zero = jnp.zeros((), jnp.float32)
            traffic = tuple(sum((c[j] for c in coll), zero) for j in range(4))
        return x, (tuple(new_cs), traffic)

    body = period_body
    if cfg.remat == "full":
        body = jax.checkpoint(period_body, prevent_cse=False)
    elif cfg.remat == "dots":
        body = jax.checkpoint(
            period_body, prevent_cse=False,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    if layer_caches is None:
        def scan_body(x, lp):
            x, (_, traffic) = body(x, (lp, None))
            return x, traffic
        x, traffic = jax.lax.scan(scan_body, x, params["blocks"])
        new_caches = None
    # NB (§Perf, refuted hypothesis): carrying the stacked caches as scan
    # carry + in-place update triggers XLA copy-insertion of the FULL cache
    # buffer per layer (the carry is both sliced and updated in one
    # iteration) — measured 6.5x worse than xs/ys streaming, which reads and
    # writes each layer's cache exactly once per step.
    else:
        def scan_body(x, xs):
            return body(x, xs)
        x, (new_layer_caches, traffic) = jax.lax.scan(
            scan_body, x, (params["blocks"], layer_caches))
        # a chunk advances each row by its REAL token count, not the padded
        # slab width (chunk_valid == 0 rows stay put entirely)
        new_caches = {"layers": new_layer_caches,
                      "length": cache_len + (s if chunk_valid is None
                                             else chunk_valid)}

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.matmul(x, head.astype(x.dtype))
    logits = shard(logits, "btv")
    if not return_stats:
        return logits, new_caches
    tile_f, tile_t, el_f, el_t = (jnp.sum(t) for t in traffic)
    stats = {"plane_fetched": tile_f, "plane_total": tile_t,
             "plane_traffic_fraction": tile_f / jnp.maximum(tile_t, 1.0),
             "element_traffic_fraction": el_f / jnp.maximum(el_t, 1.0)}
    return logits, new_caches, stats


# ---------------------------------------------------------------------------
# loss / accounting
# ---------------------------------------------------------------------------

def next_token_loss(cfg: ModelConfig, params: Params, batch: Dict[str, Any],
                    quant: bool = False) -> jnp.ndarray:
    """Causal LM loss.  batch: tokens/embeds (+image_embeds), labels, mask."""
    logits, _ = forward(cfg, params,
                        tokens=batch.get("tokens"),
                        embeds=batch.get("embeds"),
                        image_embeds=batch.get("image_embeds"),
                        quant=quant)
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:       # vision stub prepended tokens
        logits = logits[:, logits.shape[1] - labels.shape[1]:]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    lab = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1)[..., 0]
    nll = lse - lab
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(nll)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def param_count(cfg: ModelConfig) -> Dict[str, int]:
    """Analytic parameter counts (total & active) for roofline MODEL_FLOPS."""
    import math
    tree = jax.eval_shape(lambda k: init_params(k, cfg),
                          jax.random.PRNGKey(0))
    total = sum(math.prod(l.shape) for l in jax.tree.leaves(tree))
    expert = 0
    for i, kind in enumerate(cfg.pattern):
        if kind.endswith("_moe"):
            blk = tree["blocks"][i]
            expert += sum(math.prod(l.shape)
                          for l in jax.tree.leaves(blk["mlp"]["experts"]))
    if cfg.n_experts:
        active = total - expert * (1 - cfg.experts_per_token / cfg.n_experts)
    else:
        active = total
    return {"total": int(total), "active": int(active)}
