"""Mamba-2 SSD (state-space duality) block — chunked dual form + decode step.

Follows the SSD algorithm of Mamba-2 [arXiv:2405.21060]: the sequence is
split into chunks of ``L``; within-chunk terms use the quadratic (attention
-like) form, cross-chunk information flows through the recurrent state
``(B, H, P, N)`` with a ``lax.scan`` over chunks.  Single-token decode uses
the pure recurrence.  n_groups = 1 (B/C shared across heads).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense, rms_norm
from repro.models.sharding import replicate, shard


class SSMState(NamedTuple):
    ssm: jnp.ndarray        # (B, H, P, N)
    conv: jnp.ndarray       # (B, W-1, conv_dim) rolling conv window


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv; x: (B, S, C), w: (W, C).

    One ``lax.conv`` (not W padded shifts): under sequence sharding GSPMD
    exchanges only the (W-1)-row halo instead of permuting the full tensor
    per shift (§Perf iteration log).
    """
    width, c = w.shape
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32).reshape(width, 1, c),
        window_strides=(1,), padding=[(width - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=c)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """a: (..., L) -> (..., L, L) lower-triangular pairwise cumulative sums."""
    l = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    # sum_{j<k<=i} a_k  = cs[i] - cs[j]
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool), k=0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
                c: jnp.ndarray, chunk: int,
                init_state: Optional[jnp.ndarray] = None):
    """SSD scan.  x: (B, S, H, P); a: (B, S, H) log-decay (dt*A);
    b/c: (B, S, N).  Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk
    xc = x.reshape(bsz, nc, chunk, h, p)
    ac = a.reshape(bsz, nc, chunk, h).transpose(0, 1, 3, 2)   # (B,nc,H,L)
    bc = b.reshape(bsz, nc, chunk, n)
    cc = c.reshape(bsz, nc, chunk, n)

    a_cs = jnp.cumsum(ac, axis=-1)                            # (B,nc,H,L)
    # --- intra-chunk (quadratic) term ---
    lmat = jnp.exp(_segsum(ac))                               # (B,nc,H,L,L)
    lmat = shard(lmat, "bchll")
    scores = jnp.einsum("bcln,bcsn->bcls", cc, bc)            # (B,nc,L,L)
    y_diag = jnp.einsum("bcls,bchls,bcshp->bclhp",
                        scores, lmat, xc.astype(jnp.float32))

    # --- per-chunk input -> state ---
    decay_to_end = jnp.exp(a_cs[..., -1:] - a_cs)             # (B,nc,H,L)
    states = jnp.einsum("bcsn,bchs,bcshp->bchpn",
                        bc, decay_to_end, xc.astype(jnp.float32))
    states = shard(states, "bchpn")

    # --- inter-chunk recurrence ---
    chunk_decay = jnp.exp(a_cs[..., -1])                      # (B,nc,H)

    def step(carry, xs):
        st_in, dec, st_chunk = carry, xs[0], xs[1]
        new = st_in * dec[..., None, None] + st_chunk
        return new, st_in                                     # emit state *before* chunk

    st0 = init_state if init_state is not None else \
        jnp.zeros((bsz, h, p, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        step, st0,
        (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)        # (B,nc,H,P,N)

    # --- state -> output term ---
    in_decay = jnp.exp(a_cs)                                  # (B,nc,H,L)
    y_off = jnp.einsum("bcln,bchpn,bchl->bclhp", cc, prev_states, in_decay)

    y = (y_diag + y_off).reshape(bsz, nc * chunk, h, p)[:, :s]
    return y.astype(x.dtype), final


def mamba2_init_state(batch: int, cfg, dtype=jnp.float32) -> SSMState:
    d_inner = cfg.ssm_heads * cfg.ssm_head_dim
    conv_dim = d_inner + 2 * cfg.ssm_state
    return SSMState(
        ssm=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                      jnp.float32),
        conv=jnp.zeros((batch, cfg.conv_width - 1, conv_dim), dtype),
    )


def _window_at(window: jnp.ndarray, valid_len: jnp.ndarray,
               width: int) -> jnp.ndarray:
    """Per-row rolling conv state from a (B, W-1+S, C) window whose first
    W-1 rows are the incoming state and the rest the raw projections of a
    right-padded step: row ``b`` keeps rows ``valid_len[b] .. +W-2`` — the
    last W-1 *real* inputs (``dynamic_slice`` clamps in-range by
    construction since ``valid_len <= S``)."""
    return jax.vmap(
        lambda w, l: jax.lax.dynamic_slice_in_dim(w, l, width - 1, axis=0)
    )(window, valid_len)


def mamba2_block(p, x: jnp.ndarray, cfg,
                 state: Optional[SSMState] = None, quant: bool = False,
                 valid_len: Optional[jnp.ndarray] = None):
    """x: (B, S, d_model) -> (y, new_state).  Decode when ``state`` given.

    ``valid_len`` (B,) masks right-padding: pad tokens get ``dt = 0`` —
    decay ``exp(0) = 1`` and input contribution ``x * dt = 0``, so the
    recurrent state passes through them untouched — and the rolling conv
    window is sliced per row at the real-token boundary (``_window_at``).
    Two callers rely on it: bucketed prefill (one right-padded prompt into
    a fresh state) and chunked prefill (``forward(chunk_valid=...)``) —
    there the SAME masking runs mid-prompt, chunk by chunk, with the
    incoming state seeding the chunked dual form below; a ``valid_len[b]
    == 0`` row (a decode/free slot riding a chunk tick) passes through
    with state and conv window bit-identical."""
    bsz, s, _ = x.shape
    h, pdim, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    d_inner = h * pdim
    conv_dim = d_inner + 2 * n

    # separate projections (z | x | B | C | dt): identical math to the fused
    # in_proj, but every split boundary is shard-aligned — the fused layout
    # forced GSPMD to reshard (full-tensor collective-permutes, §Perf log)
    if "in_proj" in p:                    # legacy fused layout
        zxbcdt = dense(p["in_proj"], x,
                       quant=p.get("in_proj_q") if quant else None, ctx=quant)
        # pin channels replicated before the split (CPU-SPMD hazard:
        # split/concat must never run along a sharded axis)
        zxbcdt = shard(zxbcdt, "btc", force=True)
        z, xbc, dt = jnp.split(zxbcdt, [d_inner, d_inner + conv_dim], axis=-1)
        xs_r, b, c = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    else:
        z = dense(p["wz"], x, quant=p.get("wz_q") if quant else None,
                  ctx=quant)
        xs_r = dense(p["wx"], x, quant=p.get("wx_q") if quant else None,
                     ctx=quant)
        b = dense(p["wb"], x)
        c = dense(p["wc"], x)
        dt = dense(p["wdt"], x)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    if valid_len is not None:
        pad = jnp.arange(s, dtype=jnp.int32)[None, :] >= valid_len[:, None]
        dt = jnp.where(pad[..., None], 0.0, dt)
    a_log = -jnp.exp(p["a_log"].astype(jnp.float32))              # (H,) negative

    if state is None:
        xs_r = _causal_conv(xs_r, p["conv_wx"], p["conv_bx"])
        b = _causal_conv(b, p["conv_wb"], p["conv_bb"])
        c = _causal_conv(c, p["conv_wc"], p["conv_bc"])
        new_conv = None
    else:
        # the rolling window concats (xs|B|C) along channels and the conv
        # cache arrives model-sharded on that axis — pin every piece
        # replicated first: the channel axis must never be concat/split
        # while sharded (CPU-SPMD hazard, models/sharding.py::shard); the
        # window is (B, W-1+s, C)-tiny so replication costs nothing
        xs_r = shard(xs_r, "btc", force=True)
        b = shard(b, "btc", force=True)
        c = shard(c, "btc", force=True)
        conv_in = shard(state.conv, "btc", force=True)
        window = jnp.concatenate(
            [conv_in, jnp.concatenate([xs_r, b, c], -1).astype(
                conv_in.dtype)], axis=1)                          # (B, W-1+s, C)
        xbc_f = jnp.zeros((bsz, s, conv_dim), jnp.float32)
        # conv_wx/bx arrive channel-sharded from the param rules; their
        # concat with the replicated b/c conv weights runs along that axis
        # — same hazard, same cure (they're (W, C)-tiny)
        w = jnp.concatenate([replicate(p["conv_wx"]),
                             replicate(p["conv_wb"]),
                             replicate(p["conv_wc"])], -1)
        bias = jnp.concatenate([replicate(p["conv_bx"]),
                                replicate(p["conv_bb"]),
                                replicate(p["conv_bc"])], -1)
        w = w.astype(jnp.float32)
        width = w.shape[0]
        for i in range(width):
            xbc_f += window[:, i:i + s].astype(jnp.float32) * w[i]
        xbc = (xbc_f + bias.astype(jnp.float32)).astype(x.dtype)
        if valid_len is None:
            new_conv = window[:, s:s + cfg.conv_width - 1]
        else:
            new_conv = _window_at(window, valid_len, cfg.conv_width)
        # pin before the split: the downstream heads-sharding hint on xs
        # otherwise back-propagates through the reshape and re-shards this
        # very split (observed CPU-SPMD miscompile, tests/test_serve_sharded)
        xbc = shard(xbc, "btc", force=True)
        xs_r, b, c = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)

    xs = jax.nn.silu(xs_r.astype(jnp.float32)).astype(x.dtype)
    b = jax.nn.silu(b.astype(jnp.float32)).astype(x.dtype)
    c = jax.nn.silu(c.astype(jnp.float32)).astype(x.dtype)
    xs = xs.reshape(bsz, s, h, pdim)
    xs = shard(xs, "bshp")

    a = dt * a_log                                               # (B,S,H)
    dx = xs.astype(jnp.float32) * dt[..., None]                  # dt folded into x

    if state is None:
        y, final = ssd_chunked(dx, a, b.astype(jnp.float32),
                               c.astype(jnp.float32), cfg.ssd_chunk)
        new_state = None
    elif s > cfg.conv_width:
        # prefill with state: chunked dual form seeded with the incoming
        # state — NOT the token recurrence (which would serialize 32k steps
        # and stream 100x the tensor bytes; §Perf iteration log)
        y, final = ssd_chunked(dx, a, b.astype(jnp.float32),
                               c.astype(jnp.float32), cfg.ssd_chunk,
                               init_state=state.ssm)
        new_state = SSMState(ssm=final, conv=new_conv)
    else:
        # short-step decode: pure recurrence, UNROLLED (s <= conv_width
        # here, so at most W steps) — a lax.scan at this spot nests three
        # deep at serve time (scheduler tick scan -> layer scan -> this);
        # unrolling is the faster lowering for a <= 4-step loop and one
        # fewer nested-scan level for the SPMD partitioner to get wrong.
        def step(st, dx_t, a_t, b_t, c_t):
            st = st * jnp.exp(a_t)[..., None, None] \
                + jnp.einsum("bhp,bn->bhpn", dx_t, b_t)
            y_t = jnp.einsum("bhpn,bn->bhp", st, c_t)
            return st, y_t
        bf = b.astype(jnp.float32)
        cf = c.astype(jnp.float32)
        st = state.ssm
        ys = []
        for t in range(s):
            st, y_t = step(st, dx[:, t], a[:, t], bf[:, t], cf[:, t])
            ys.append(y_t)
        final = st
        y = jnp.stack(ys, axis=1)                                 # (B,S,H,P)
        new_state = SSMState(ssm=final, conv=new_conv)

    y = y + xs.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    if state is not None:
        # serving path: the (H, P) -> d_inner merge below runs on a heads-
        # sharded tensor — pin it replicated first (CPU-SPMD hazard; decode
        # tensors are tick-sized, so the gather is noise).  The training
        # path keeps GSPMD's layout freedom.
        y = replicate(y)
    # back to the block io dtype — the SSD math runs f32; letting f32 leak
    # into out_proj doubles its dot + TP-reduce traffic (§Perf log)
    y = y.reshape(bsz, s, d_inner).astype(x.dtype)
    z = z.astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 p["norm"], cfg.norm_eps)
    out = dense(p["out_proj"], y, quant=p.get("out_proj_q") if quant else None,
                ctx=quant)
    if state is None:
        return out, None
    return out, new_state
