"""The QeiHaN shift-add dot product — paper Eq. 5 — in three equal forms.

Paper mapping (arXiv 2310.18181; DESIGN.md "Paper ↔ code map"): this module
is the paper's §IV D&S (decode-and-shift) unit datapath — the compute side
of the *implicit bit-shift weight access*: Eq. 5's
``y = sum_i sign_i * ArithShift(w_i, e_i)`` over log2-quantized activations
(``core/logquant.py``, §II/Eqs. 2-4) and bit-plane-stored weights
(``core/bitplane.py``, §IV-B).  The plane-skipping Pallas kernel
(``kernels/bitplane_matmul/``) executes form (2) below with the skipped
fetches made explicit.

Semantics.  An activation quantizes to ``s * 2^e`` (``core.logquant``), a
weight to int8 ``w`` (``core.wquant``).  The D&S unit produces

* ``e >= 0``: ``w << e``  (exact product),
* ``e < 0``:  ``w >> |e|`` arithmetic  ==  ``floor(w / 2^|e|)``  — the LSBs
  of ``w`` shift out of the 16-bit datapath and are **never fetched** from
  memory.  This floor-truncation is the accuracy cost of the paper's memory
  saving, and we model it exactly.

Forms (all return the same int32 tensor, property-tested):

1. :func:`shift_product` / :func:`shiftadd_matmul_elementwise` — direct
   per-element oracle, O(K*N) temporaries; the specification.
2. :func:`shiftadd_matmul_bitplane` — the MXU-friendly regrouping used by
   the Pallas kernel: ``y = sum_b sgn_b * (a_b @ plane_b)`` with
   ``a_b[i] = s_i * 2^(b + e_i) * [b + e_i >= 0]`` (int32) and ``plane_b``
   the ``{0,1}`` bit-plane.  Plane ``b`` contributes nothing for activations
   with ``e_i < -b`` — the *compute* image of the paper's skipped fetches.
3. :func:`shiftadd_matmul_exact` — un-truncated ``sum s_i w_i 2^{e_i}``
   (what the NaHiD/full-fetch datapath computes, and the float reference for
   accuracy ablations).

`QuantizedLinear` wraps the whole path (calibrated activation pre-scale ->
LOG2 quant -> bit-plane matmul -> dequant) as the drop-in projection layer
used by the model zoo when ``QuantConfig.mode == "qeihan"``.
"""

from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Optional, Tuple, Union

import jax.numpy as jnp

from repro.core import bitplane as bp
from repro.core.logquant import (LogQuantized, log2_dequantize, log2_quantize,
                                 zero_sentinel)
from repro.core.wquant import QuantizedWeights, quantize_weights

__all__ = [
    "shift_product",
    "shiftadd_matmul_elementwise",
    "shiftadd_matmul_bitplane",
    "shiftadd_matmul_exact",
    "QuantizedLinearParams",
    "QuantCtx",
    "as_quant_ctx",
    "quantized_linear_init",
    "quantized_linear_apply",
    "calibrate_act_scale",
]


def shift_product(w: jnp.ndarray, q: LogQuantized, n_bits: int = 4) -> jnp.ndarray:
    """``sign * Bitshift(w, e)`` with arithmetic right shift; sentinel -> 0."""
    sentinel = zero_sentinel(n_bits)
    w32 = w.astype(jnp.int32)
    e = q.exp.astype(jnp.int32)
    left = w32 << jnp.maximum(e, 0)
    right = w32 >> jnp.maximum(-e, 0)        # arithmetic shift == floor div
    shifted = jnp.where(e >= 0, left, right)
    shifted = jnp.where(e == sentinel, 0, shifted)
    return q.sign.astype(jnp.int32) * shifted


def shiftadd_matmul_elementwise(q: LogQuantized, w: jnp.ndarray,
                                n_bits: int = 4) -> jnp.ndarray:
    """Oracle: ``y[..., n] = sum_k s_k * Bitshift(w[k, n], e_k)``.

    ``q.exp/q.sign``: ``(..., K)``;  ``w``: int8 ``(K, N)``.  O(K*N)
    temporaries — use only for validation / small layers.
    """
    prod = shift_product(w.astype(jnp.int32)[None], LogQuantized(
        exp=q.exp[..., None], sign=q.sign[..., None]), n_bits)
    return jnp.sum(prod, axis=-2)


def shiftadd_matmul_bitplane(q: LogQuantized, planes: jnp.ndarray,
                             n_bits: int = 4,
                             plane_dtype: jnp.dtype = jnp.int32) -> jnp.ndarray:
    """Bit-plane regrouping: 8 {0,1}-matmuls on the MXU.  Exact (int32).

    ``planes``: uint8 ``(bits, K, N)`` from :func:`bitplane.to_bitplanes`.
    Derivation: for ``e < 0``, ``floor(w/2^|e|) = sum_{b >= |e|} c_b 2^e
    plane_b(w)`` with ``c_b = 2^b`` (``-2^7`` for the sign plane), because
    two's-complement floor-shift simply discards low planes.  Folding
    ``c_b * 2^e = sgn_b * 2^(b+e)`` into the activation keeps everything
    integer: ``a_b[k] = s_k * 2^(b+e_k)`` when ``b + e_k >= 0`` else 0.
    """
    bits = planes.shape[0]
    sentinel = zero_sentinel(n_bits)
    e = q.exp.astype(jnp.int32)
    s = q.sign.astype(jnp.int32)
    alive = (e != sentinel)

    out = None
    for b in range(bits):
        sh = b + e
        contrib = alive & (sh >= 0)
        a_b = jnp.where(contrib, s << jnp.maximum(sh, 0), 0)
        term = jnp.matmul(a_b.astype(plane_dtype),
                          planes[b].astype(plane_dtype),
                          preferred_element_type=jnp.int32)
        if b == bits - 1:
            term = -term                      # two's-complement sign plane
        out = term if out is None else out + term
    return out


def shiftadd_matmul_exact(q: LogQuantized, w: jnp.ndarray,
                          n_bits: int = 4) -> jnp.ndarray:
    """Un-truncated ``sum_k s_k w_k 2^{e_k}`` (float32) — NaHiD datapath."""
    a = log2_dequantize(q, n_bits, dtype=jnp.float32)
    return jnp.matmul(a, w.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Framework-facing quantized projection layer
# ---------------------------------------------------------------------------

class QuantizedLinearParams(NamedTuple):
    planes: jnp.ndarray       # uint8 (8, K, N) bit-planes of the int8 weight
    w_scale: jnp.ndarray      # f32 per-output-channel scale (1, N)
    act_scale: jnp.ndarray    # f32 scalar pre-scale so acts fit [2^-7, 2^7]
    bias: Optional[jnp.ndarray]


@dataclasses.dataclass(frozen=True, eq=False)
class QuantCtx:
    """Runtime configuration of the QeiHaN quant path, threaded alongside the
    per-projection :class:`QuantizedLinearParams` down to every ``dense``.

    * ``backend`` — ``"xla"`` runs :func:`shiftadd_matmul_bitplane` (8
      unrolled {0,1}-matmuls, the portable form); ``"pallas"`` dispatches the
      plane-skipping TPU kernel (``kernels.bitplane_matmul``), interpret mode
      off-TPU.  Both compute the identical int32 result.
    * ``collect`` — trace-time accumulator: when set (a plain Python list),
      each quantized projection appends ``(tile_fetched, tile_total,
      elem_fetched, elem_total)`` weight-traffic counts — tile-granular
      (what the Pallas kernel's skip table actually DMAs) and
      element-granular (the ASIC bank model, paper Fig. 7) — the traffic
      image of the paper's §VI memory-access savings.  The list must be
      created and consumed within one trace scope (see
      ``models.model.forward``'s scan body).
    """

    backend: str = "xla"
    n_bits: int = 4
    collect: Optional[List[Tuple[jnp.ndarray, jnp.ndarray,
                                 jnp.ndarray, jnp.ndarray]]] = None


def as_quant_ctx(quant: Union[bool, str, QuantCtx, None],
                 default_backend: str = "xla") -> Optional[QuantCtx]:
    """Normalize the user-facing ``quant`` flag: False/None -> None (float
    path), True -> ``QuantCtx(backend=default_backend)``, a backend string or
    an explicit ``QuantCtx`` pass through."""
    if quant is None or quant is False:
        return None
    if isinstance(quant, QuantCtx):
        return quant
    if quant is True:
        return QuantCtx(backend=default_backend)
    if isinstance(quant, str):
        return QuantCtx(backend=quant)
    raise TypeError(f"quant must be bool, str or QuantCtx, got {quant!r}")


def calibrate_act_scale(x: jnp.ndarray, percentile: float = 99.9) -> jnp.ndarray:
    """Per-tensor activation scale: map the p99.9 magnitude to ~2^3.

    LOG2 codes cover [2^-7, 2^7]; centering the distribution's tail at 2^3
    leaves 4 octaves of headroom and 10 octaves below — matching the paper's
    observation that post-norm activations concentrate in (-1, 1).
    """
    mag = jnp.percentile(jnp.abs(x.astype(jnp.float32)), percentile)
    return jnp.maximum(mag, 1e-12) / 8.0


def quantized_linear_init(w: jnp.ndarray, bias: Optional[jnp.ndarray] = None,
                          act_scale: float | jnp.ndarray = 1.0,
                          bits: int = 8) -> QuantizedLinearParams:
    """Offline weight pre-arrangement (paper: 'weights are known statically
    so their organization can be pre-arranged offline')."""
    qw: QuantizedWeights = quantize_weights(w, bits=bits, channel_axis=-1)
    planes = bp.to_bitplanes(qw.q, bits=bits)
    return QuantizedLinearParams(
        planes=planes,
        w_scale=qw.scale.reshape(1, -1),
        act_scale=jnp.asarray(act_scale, jnp.float32),
        bias=bias,
    )


def quantized_linear_apply(p: QuantizedLinearParams, x: jnp.ndarray,
                           n_bits: int = 4,
                           truncated: bool = True,
                           backend: str = "xla",
                           collect: Optional[list] = None) -> jnp.ndarray:
    """x (..., K) -> y (..., N) through the full QeiHaN path.

    ``p.planes`` may be packed 8-to-a-byte along K (the HBM-resident deploy
    format: same footprint as plain INT8); unpacking happens on the fly —
    in-register on the TPU kernel, an explicit op here.

    ``backend="pallas"`` runs the plane-skipping Pallas kernel instead of the
    unrolled jnp bit-plane matmul (identical int32 result); ``collect``
    accumulates ``(fetched, total)`` plane-tile traffic counts (see
    :class:`QuantCtx`).
    """
    lead = x.shape[:-1]
    k = x.shape[-1]
    planes = p.planes
    if planes.shape[1] * 8 == k:                  # packed along K
        planes = bp.unpack_planes(planes, axis=0)
    xs = (x.astype(jnp.float32) / p.act_scale).reshape(-1, k)
    q = log2_quantize(xs, n_bits=n_bits)
    if collect is not None:
        from repro.core.access_model import needed_bits
        from repro.kernels.bitplane_matmul.ops import plane_traffic_counts
        # weight the per-GEMM fractions by the N extent so the accumulated
        # numbers reflect actual bytes, not tile-table cells
        n_scale = jnp.float32(planes.shape[-1])
        tile_f, tile_t = plane_traffic_counts(q.exp, n_bits=n_bits)
        nb = needed_bits(q.exp, n_bits=n_bits)
        alive = (q.exp != zero_sentinel(n_bits)).astype(jnp.float32)
        collect.append((tile_f * n_scale, tile_t * n_scale,
                        jnp.sum(nb.astype(jnp.float32)) * n_scale,
                        jnp.sum(alive) * 8.0 * n_scale))
    if truncated:
        if backend == "pallas":
            from repro.kernels.bitplane_matmul.ops import bitplane_matmul_pallas
            y_int = bitplane_matmul_pallas(q.exp, q.sign, planes,
                                           n_bits=n_bits)
        else:
            y_int = shiftadd_matmul_bitplane(q, planes, n_bits=n_bits)
        y = y_int.astype(jnp.float32)
    else:
        w = bp.from_bitplanes(planes).astype(jnp.float32)
        y = shiftadd_matmul_exact(q, w, n_bits=n_bits)
    y = y * p.w_scale * p.act_scale
    y = y.reshape(*lead, -1)
    if p.bias is not None:
        y = y + p.bias
    return y
