"""Bit-plane weight storage — QeiHaN paper §IV-B (Fig. 7).

Paper mapping (arXiv 2310.18181; DESIGN.md "Paper ↔ code map"): this module
is the paper's *implicit in-memory bit-shifting of the DNN weights* — the
§IV-B weight storage scheme.  The ASIC stores bit ``b`` of a group of
weights in DRAM bank ``b`` so the vault controller can fetch only the MSB
planes demanded by a negative activation exponent ("only the meaningful
bits of the weights required for the bit-shift operation are accessed");
the shift itself never executes — dropping low planes IS the shift (see
the semantics note below).  The TPU-native analogue implemented here:

* :func:`to_bitplanes` — two's-complement decomposition of an int8 weight
  tensor into 8 ``{0,1}`` planes, **plane-major** so each plane is a
  contiguous HBM region (the "bank").
* :func:`pack_planes` / :func:`unpack_planes` — pack each plane 8-to-a-byte
  along the reduction axis, giving the same total footprint as the original
  int8 tensor (8 planes x K/8 bytes) while keeping planes independently
  addressable — this is the layout the Pallas kernel DMAs tile-by-tile.
* :func:`from_bitplanes` — exact inverse (roundtrip-tested).

Semantics note: with two's complement, ``floor(w / 2^k)`` (the arithmetic
right shift the D&S unit performs for a negative exponent ``-k``) depends
only on planes ``b >= k``.  Dropping the low ``k`` planes is therefore *not
an approximation of the shift — it IS the shift*; this identity is what the
whole paper rides on and is property-tested in ``tests/test_core_quant.py``.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "to_bitplanes",
    "from_bitplanes",
    "pack_planes",
    "unpack_planes",
    "plane_coefficients",
]

WEIGHT_BITS = 8


def to_bitplanes(q: jnp.ndarray, bits: int = WEIGHT_BITS) -> jnp.ndarray:
    """int8 ``(...)`` -> uint8 ``(bits, ...)`` of {0,1}; plane b = bit b.

    Two's complement: ``q = -2^(bits-1) * plane[bits-1] + sum_{b<bits-1} 2^b
    * plane[b]``.
    """
    u = q.astype(jnp.uint8) if bits <= 8 else q.astype(jnp.uint32)
    planes = [(u >> b) & 1 for b in range(bits)]
    return jnp.stack(planes).astype(jnp.uint8)


def plane_coefficients(bits: int = WEIGHT_BITS) -> jnp.ndarray:
    """Signed weight of each plane: ``[1, 2, 4, ..., -2^(bits-1)]``."""
    c = [1 << b for b in range(bits - 1)] + [-(1 << (bits - 1))]
    return jnp.asarray(c, dtype=jnp.int32)


def from_bitplanes(planes: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`to_bitplanes` (returns int32 values)."""
    bits = planes.shape[0]
    coef = plane_coefficients(bits)
    coef = coef.reshape((bits,) + (1,) * (planes.ndim - 1))
    return jnp.sum(planes.astype(jnp.int32) * coef, axis=0)


def pack_planes(planes: jnp.ndarray, axis: int = 1) -> jnp.ndarray:
    """Pack a ``(bits, ..., K, ...)`` plane tensor 8x along ``axis``.

    ``axis`` is the index *within a single plane* (i.e. excluding the leading
    plane axis) of the dimension to pack; it must be divisible by 8.
    Bit ``j`` of packed byte ``g`` holds element ``8*g + j``.
    """
    axis = axis % (planes.ndim - 1)
    full_axis = axis + 1
    k = planes.shape[full_axis]
    if k % 8:
        raise ValueError(f"pack axis length {k} not divisible by 8")
    moved = jnp.moveaxis(planes, full_axis, -1)
    grouped = moved.reshape(moved.shape[:-1] + (k // 8, 8))
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    packed = jnp.sum(grouped.astype(jnp.uint8) * weights, axis=-1,
                     dtype=jnp.uint8)
    return jnp.moveaxis(packed, -1, full_axis)


def unpack_planes(packed: jnp.ndarray, axis: int = 1) -> jnp.ndarray:
    """Inverse of :func:`pack_planes`."""
    axis = axis % (packed.ndim - 1)
    full_axis = axis + 1
    moved = jnp.moveaxis(packed, full_axis, -1)
    bits = (moved[..., None] >> jnp.arange(8, dtype=jnp.uint8)) & 1
    flat = bits.reshape(moved.shape[:-1] + (moved.shape[-1] * 8,))
    return jnp.moveaxis(flat, -1, full_axis).astype(jnp.uint8)
