"""Memory-access accounting — paper §III (Fig. 3) and §VI-A (Fig. 9).

The *estimated memory savings* is "the percentage of bits from the weights
that can be ignored because the negative exponents of the base-2 activations
render those bits useless when performing the bit-shifting operation".
Pruned (zero/sentinel) activations are accounted separately, because the
paper prunes them "in both the baseline and our proposal".

Two fetch granularities:

* ``element`` — the ASIC's bank-level model: each activation ``i`` touches
  exactly ``needed(e_i) * M`` weight bits (paper Fig. 7).
* ``tile``    — the TPU adaptation: the Pallas kernel decides per
  ``(K-tile, plane)`` whether to DMA, so a plane is fetched for the whole
  tile iff *any* activation in the tile needs it.  This is the traffic the
  bit-plane kernel actually generates and is reported alongside the ASIC
  number in EXPERIMENTS.md.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.logquant import LogQuantized, zero_sentinel

__all__ = ["needed_bits", "AccessReport", "weight_access_report"]

WEIGHT_BITS = 8


def needed_bits(exp: jnp.ndarray, n_bits: int = 4,
                weight_bits: int = WEIGHT_BITS) -> jnp.ndarray:
    """Weight bits the D&S unit must fetch for one activation exponent.

    * sentinel (pruned)      -> 0
    * ``e < 0``              -> ``weight_bits - |e|``   (MSB planes only)
    * ``e >= 0``             -> ``weight_bits``
    """
    sentinel = zero_sentinel(n_bits)
    e = exp.astype(jnp.int32)
    nb = jnp.clip(weight_bits + jnp.minimum(e, 0), 0, weight_bits)
    return jnp.where(e == sentinel, 0, nb)


class AccessReport(NamedTuple):
    """All quantities are *per output-feature set of M weights per act*."""

    element_bits: jnp.ndarray      # bits fetched, ASIC bank-granularity
    tile_bits: jnp.ndarray         # bits fetched, TPU tile-granularity
    baseline_bits: jnp.ndarray     # NaHiD: full 8b for every live act
    savings_element: jnp.ndarray   # Fig. 3 number (live acts only)
    savings_tile: jnp.ndarray
    pruned_fraction: jnp.ndarray


def weight_access_report(q: LogQuantized, n_bits: int = 4,
                         weight_bits: int = WEIGHT_BITS,
                         tile_k: int = 256) -> AccessReport:
    """Traffic report for one layer's activation tensor ``q`` (flattened).

    Baseline (NaHiD) fetches ``weight_bits`` for every *live* activation —
    pruning is common to both designs, so the Fig. 3 savings ratio is
    measured over live activations only.
    """
    exp = q.exp.reshape(-1)
    sentinel = zero_sentinel(n_bits)
    live = exp != sentinel

    nb = needed_bits(exp, n_bits, weight_bits)
    element_bits = jnp.sum(nb)
    baseline_bits = jnp.sum(jnp.where(live, weight_bits, 0))

    # --- tile granularity: pad to a tile multiple, reduce per tile ---------
    k = exp.shape[0]
    pad = (-k) % tile_k
    nb_p = jnp.concatenate([nb, jnp.zeros((pad,), nb.dtype)])
    live_p = jnp.concatenate([live, jnp.zeros((pad,), bool)])
    tiles_nb = nb_p.reshape(-1, tile_k)
    tiles_live = live_p.reshape(-1, tile_k)
    planes_per_tile = jnp.max(tiles_nb, axis=1)          # planes DMA'd
    live_any = jnp.any(tiles_live, axis=1)
    tile_bits = jnp.sum(jnp.where(live_any, planes_per_tile, 0) * tile_k)
    # a tile-granular baseline DMAs all 8 planes of every live tile — the
    # apples-to-apples denominator for the kernel's skip savings.
    tile_baseline = jnp.sum(jnp.where(live_any, weight_bits, 0) * tile_k)

    denom = jnp.maximum(baseline_bits, 1)
    tdenom = jnp.maximum(tile_baseline, 1)
    return AccessReport(
        element_bits=element_bits,
        tile_bits=tile_bits,
        baseline_bits=baseline_bits,
        savings_element=1.0 - element_bits / denom,
        savings_tile=1.0 - tile_bits / tdenom,
        pruned_fraction=jnp.mean(1.0 - live.astype(jnp.float32)),
    )
