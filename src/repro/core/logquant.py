"""LOG2 (logarithmic base-2) activation quantization — QeiHaN paper Eqs. 2-4.

Paper mapping (arXiv 2310.18181; DESIGN.md "Paper ↔ code map"): this module
is the paper's *log2 activation quantization* — §II's observation that
FC/CONV activations concentrate in (-1, 1) and so quantize to powers of two
with mostly NEGATIVE exponents (Fig. 2), encoded via Eqs. 2-4 with the
Fig. 5 single-comparator rounding circuit.  Those negative exponents are
what the weight side (``core/bitplane.py``, §IV-B) turns into skipped
memory accesses; the Pallas realization of this quantizer is
``kernels/log2quant/``.

Implements two bit-identical paths:

* :func:`log2_quantize` — production path.  Extracts the IEEE-754 exponent
  field and applies the paper's single-comparator rounding trick (Fig. 5 /
  Eqs. 6-7): ``Round(log2|x|) = e + (m >= sqrt(2))`` with mantissa
  ``m in [1, 2)``.  Pure integer bit-twiddling; exact for every finite input.
* :func:`log2_quantize_naive` — direct ``Round(log2|x|))`` in floating point.
  Used only as a cross-check; may differ from the comparator path by 1 at
  values whose ``log2`` lands within float error of ``.5`` (measure-zero set;
  the comparator path is the specification).

Encoding (n-bit exponent, default n=4):

* exponents live in ``[-(2^(n-1)) + 1, 2^(n-1) - 1]`` (e.g. ``[-7, 7]``),
* the minimum code ``-(2^(n-1))`` (e.g. ``-8``) is the **zero sentinel**:
  exact zeros and activations whose rounded exponent clips below the range
  are pruned to it (paper: "all small activations are effectively pruned"),
* sign is carried separately (paper: "an extra bit for the sign").

A quantized activation therefore decodes as ``sign * 2^exp`` with the
sentinel decoding to ``0``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

__all__ = [
    "LogQuantized",
    "zero_sentinel",
    "code_dtype",
    "log2_quantize",
    "log2_quantize_naive",
    "log2_dequantize",
    "pack_codes",
    "unpack_codes",
    "scale_exponent",
    "quantize_page_codes",
    "dequantize_page_codes",
    "negative_fraction",
    "pruned_fraction",
]

# Mantissa-field threshold for the sqrt(2) comparator on a float32 mantissa
# (23 fraction bits).  m >= sqrt(2)  <=>  M >= _SQRT2_M_F32 where
# m = 1 + M / 2^23.  sqrt(2) is irrational so equality never occurs for a
# finite float; we use the first representable mantissa above sqrt(2).
_SQRT2_M_F32 = int(np.floor((np.sqrt(np.float64(2.0)) - 1.0) * (1 << 23))) + 1


class LogQuantized(NamedTuple):
    """LOG2-quantized activations: ``value = sign * 2^exp`` (sentinel -> 0)."""

    exp: jnp.ndarray   # int8 exponents in [-(2^(n-1)), 2^(n-1)-1]
    sign: jnp.ndarray  # int8 in {-1, +1}

    @property
    def n_bits(self) -> int:
        """Smallest encoding width whose exponent range (including the zero
        sentinel ``-(2^(n-1))``) holds every stored exponent."""
        if self.exp.size == 0:
            return 2
        lo = int(jnp.min(self.exp))
        hi = int(jnp.max(self.exp))
        n = 2
        while -(1 << (n - 1)) > lo or (1 << (n - 1)) - 1 < hi:
            n += 1
        return n


def zero_sentinel(n_bits: int = 4) -> int:
    """The exponent code that represents a pruned/zero activation."""
    return -(1 << (n_bits - 1))


def _exp_mantissa_fields(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Raw IEEE-754 exponent/mantissa fields of ``x`` viewed as float32.

    bf16/f16 inputs are first cast to float32 — an exact embedding, so the
    comparator semantics are unchanged.
    """
    xf = x.astype(jnp.float32)
    bits = jnp.asarray(xf).view(jnp.uint32)
    exp_field = ((bits >> 23) & jnp.uint32(0xFF)).astype(jnp.int32)
    man_field = (bits & jnp.uint32(0x7FFFFF)).astype(jnp.int32)
    return exp_field, man_field


def log2_quantize(x: jnp.ndarray, n_bits: int = 4) -> LogQuantized:
    """Paper Eqs. 2-4 via the Fig. 5 comparator circuit. Bit-exact.

    ``Round(log2|x|) = e + (m >= sqrt(2))`` where ``|x| = m * 2^e``,
    ``m in [1, 2)``; then clip to ``[-(2^(n-1)), 2^(n-1)-1]`` with the lower
    clip collapsing onto the zero sentinel (pruning).  Subnormals (exponent
    field 0) are far below any representable 4-bit exponent and prune; NaN is
    pruned; +/-Inf clips to the max exponent.
    """
    exp_field, man_field = _exp_mantissa_fields(x)
    sentinel = zero_sentinel(n_bits)
    emax = (1 << (n_bits - 1)) - 1

    unbiased = exp_field - 127
    rounded = unbiased + (man_field >= _SQRT2_M_F32).astype(jnp.int32)

    is_subnormal_or_zero = exp_field == 0
    is_nonfinite = exp_field == 0xFF
    is_nan = is_nonfinite & (man_field != 0)

    e = jnp.clip(rounded, sentinel, emax)
    # sentinel means "pruned to zero"; anything clipping to it from below,
    # plus exact zeros/subnormals/NaNs, prunes.  Note the clip already maps
    # rounded <= sentinel onto the sentinel; we only force the special cases.
    e = jnp.where(is_subnormal_or_zero | is_nan, sentinel, e)
    e = jnp.where(is_nonfinite & ~is_nan, emax, e)

    sign = jnp.where(x < 0, jnp.int8(-1), jnp.int8(1))
    return LogQuantized(exp=e.astype(jnp.int8), sign=sign)


def log2_quantize_naive(x: jnp.ndarray, n_bits: int = 4) -> LogQuantized:
    """Direct float evaluation of Eq. 3 (cross-check only, not the spec)."""
    sentinel = zero_sentinel(n_bits)
    emax = (1 << (n_bits - 1)) - 1
    absx = jnp.abs(x.astype(jnp.float32))
    # round-half-up on the log, matching `e + (m >= sqrt(2))`.
    raw = jnp.floor(jnp.log2(absx) + 0.5)
    e = jnp.clip(raw, sentinel, emax)
    e = jnp.where((absx == 0) | jnp.isnan(x.astype(jnp.float32)), sentinel, e)
    sign = jnp.where(x < 0, jnp.int8(-1), jnp.int8(1))
    return LogQuantized(exp=e.astype(jnp.int8), sign=sign)


def log2_dequantize(q: LogQuantized, n_bits: int = 4,
                    dtype: jnp.dtype = jnp.float32) -> jnp.ndarray:
    """``sign * 2^exp`` with the sentinel decoding to exactly 0."""
    sentinel = zero_sentinel(n_bits)
    mag = jnp.exp2(q.exp.astype(jnp.float32))
    val = q.sign.astype(jnp.float32) * mag
    return jnp.where(q.exp == sentinel, 0.0, val).astype(dtype)


def code_dtype(n_bits: int = 4):
    """Container dtype of the packed wire code.

    ``code = exp*2 + sign`` needs ``n_bits + 1`` bits, so int8 holds every
    width up to 7 exponent bits; the 8-bit encoding (exp in [-128, 127])
    widens to int16.
    """
    return jnp.int16 if n_bits >= 8 else jnp.int8


def pack_codes(q: LogQuantized, n_bits: int = 4) -> jnp.ndarray:
    """Pack (exp, sign) into a single code: ``code = exp*2 + (sign<0)``.

    This is the (n_bits+1)-bit (exponent + sign) wire format the PE sends to
    the D&S unit; used by the access model to count activation traffic.
    """
    ct = code_dtype(n_bits)
    neg = (q.sign < 0).astype(ct)
    return (q.exp.astype(ct) << 1) | neg


def unpack_codes(codes: jnp.ndarray, n_bits: int = 4) -> LogQuantized:
    # arithmetic shift keeps the exponent's sign; every width's exponent
    # range fits int8 (the widest, n_bits=8, spans [-128, 127])
    exp = (codes >> 1).astype(jnp.int8)
    sign = jnp.where((codes & 1) != 0, jnp.int8(-1), jnp.int8(1))
    return LogQuantized(exp=exp, sign=sign)


def scale_exponent(x: jnp.ndarray, axis=-1, keepdims: bool = False
                   ) -> jnp.ndarray:
    """Power-of-two row scale: ``floor(log2(max|x|))`` over ``axis`` (int32).

    Zero/subnormal rows scale by 2^0.  A power-of-two scale makes the
    scaled quantize *idempotent*: ``x / 2^se`` of an already-dequantized
    value is again an exact power of two, whose mantissa field is 0 — below
    the sqrt(2) comparator threshold — so requantizing under the same scale
    reproduces the codes bit-for-bit (the quantized KV pool's rewrite
    invariant, DESIGN.md §Quantized KV pages).
    """
    m = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=keepdims)
    exp_field, _ = _exp_mantissa_fields(m)
    return jnp.where(exp_field == 0, 0, exp_field - 127)


def quantize_page_codes(x: jnp.ndarray, scale_exp: jnp.ndarray,
                        n_bits: int = 4) -> jnp.ndarray:
    """LOG2-quantize ``x / 2^scale_exp`` and pack to wire codes.

    ``scale_exp`` (int32) broadcasts against ``x``; scaling by an exact
    power of two never perturbs the mantissa, so the comparator rounding is
    applied to the true scaled magnitudes.  Pruned values get the canonical
    positive-sign sentinel code — they decode to +0.0, and requantizing
    +0.0 must reproduce the same byte (the rewrite invariant).
    """
    scaled = x.astype(jnp.float32) * jnp.exp2(-scale_exp.astype(jnp.float32))
    q = log2_quantize(scaled, n_bits)
    sign = jnp.where(q.exp == zero_sentinel(n_bits), jnp.int8(1), q.sign)
    return pack_codes(LogQuantized(exp=q.exp, sign=sign), n_bits)


def dequantize_page_codes(codes: jnp.ndarray, scale_exp: jnp.ndarray,
                          n_bits: int = 4,
                          dtype: jnp.dtype = jnp.float32) -> jnp.ndarray:
    """``sign * 2^(exp + scale_exp)`` with the sentinel decoding to 0.

    The summed exponent is clamped to the f32 normal range so garbage
    scales (trash-page contents) decode to large-but-finite values —
    downstream masking then erases them without Inf/NaN contamination.
    """
    q = unpack_codes(codes, n_bits)
    sentinel = zero_sentinel(n_bits)
    e = jnp.clip(q.exp.astype(jnp.int32) + scale_exp.astype(jnp.int32),
                 -126, 127)
    val = q.sign.astype(jnp.float32) * jnp.exp2(e.astype(jnp.float32))
    return jnp.where(q.exp == sentinel, 0.0, val).astype(dtype)


def negative_fraction(q: LogQuantized, n_bits: int = 4) -> jnp.ndarray:
    """Fraction of *non-pruned* activations with negative exponent (Fig. 2)."""
    sentinel = zero_sentinel(n_bits)
    alive = q.exp != sentinel
    neg = alive & (q.exp < 0)
    denom = jnp.maximum(jnp.sum(alive), 1)
    return jnp.sum(neg) / denom


def pruned_fraction(q: LogQuantized, n_bits: int = 4) -> jnp.ndarray:
    """Fraction of activations pruned to zero (sentinel) — paper §VI-B."""
    sentinel = zero_sentinel(n_bits)
    return jnp.mean((q.exp == sentinel).astype(jnp.float32))
