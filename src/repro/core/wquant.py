"""INT8 uniform weight quantization — QeiHaN paper Eq. 1.

The paper quantizes weights with linear uniform quantization
``Q(r) = INT(r/s) - z``.  QeiHaN's shift-add datapath operates on
two's-complement integers, which requires a **symmetric** grid (``z = 0``);
we therefore use symmetric per-channel (or per-tensor) quantization, the
standard choice for weight-stationary integer GEMMs.  The asymmetric offset
in Eq. 1 is only exercised by the paper for activations in the Neurocube
baseline, which we model in ``simulator/``.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp

__all__ = ["QuantizedWeights", "quantize_weights", "dequantize_weights"]


class QuantizedWeights(NamedTuple):
    """Symmetric integer weights: ``w ~= q * scale``."""

    q: jnp.ndarray      # int8 (or int32 for >8-bit grids)
    scale: jnp.ndarray  # f32, broadcastable against q
    bits: int


def quantize_weights(w: jnp.ndarray, bits: int = 8,
                     channel_axis: Optional[int] = None) -> QuantizedWeights:
    """Symmetric uniform quantization to ``bits`` (default INT8).

    ``channel_axis`` selects per-channel scales (typically the output-feature
    axis of a ``(K, N)`` weight); ``None`` gives a per-tensor scale.
    The integer grid is ``[-(2^(b-1)-1), 2^(b-1)-1]`` (no -128, so the
    bit-plane decomposition and arithmetic shifts are symmetric in range).
    """
    w = w.astype(jnp.float32)
    qmax = (1 << (bits - 1)) - 1
    if channel_axis is None:
        absmax = jnp.max(jnp.abs(w))
    else:
        axes = tuple(a for a in range(w.ndim) if a != channel_axis % w.ndim)
        absmax = jnp.max(jnp.abs(w), axis=axes, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / qmax
    q = jnp.clip(jnp.round(w / scale), -qmax, qmax)
    dtype = jnp.int8 if bits <= 8 else jnp.int32
    return QuantizedWeights(q=q.astype(dtype), scale=scale, bits=bits)


def dequantize_weights(qw: QuantizedWeights) -> jnp.ndarray:
    return qw.q.astype(jnp.float32) * qw.scale
