"""QeiHaN core: LOG2 activation quantization + bit-plane shift-add GEMM."""

from repro.core.access_model import AccessReport, needed_bits, weight_access_report
from repro.core.bitplane import (from_bitplanes, pack_planes, plane_coefficients,
                                 to_bitplanes, unpack_planes)
from repro.core.logquant import (LogQuantized, code_dtype,
                                 dequantize_page_codes, log2_dequantize,
                                 log2_quantize, log2_quantize_naive,
                                 negative_fraction, pack_codes,
                                 pruned_fraction, quantize_page_codes,
                                 scale_exponent, unpack_codes, zero_sentinel)
from repro.core.shiftadd import (QuantCtx, QuantizedLinearParams,
                                 as_quant_ctx, calibrate_act_scale,
                                 quantized_linear_apply, quantized_linear_init,
                                 shift_product, shiftadd_matmul_bitplane,
                                 shiftadd_matmul_elementwise,
                                 shiftadd_matmul_exact)
from repro.core.wquant import (QuantizedWeights, dequantize_weights,
                               quantize_weights)

__all__ = [n for n in dir() if not n.startswith("_")]
