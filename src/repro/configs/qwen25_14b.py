"""qwen2.5-14b [dense]: 48L d=5120 40H (GQA kv=8) d_ff=13824 vocab=152064.

GQA + QKV bias (hf:Qwen/Qwen2.5 series).
"""
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    d_model=5120, n_layers=48, d_ff=13824, vocab_size=152064,
    n_heads=40, n_kv_heads=8, head_dim=128,
    qkv_bias=True, rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="qwen2.5-14b-smoke",
    d_model=64, n_layers=4, d_ff=128, vocab_size=256,
    n_heads=4, n_kv_heads=2, head_dim=16,
    qkv_bias=True, kv_chunk=32,
)
