"""musicgen-medium [audio]: 48L d=1536 24H (kv=24: MHA) d_ff=6144 vocab=2048.

Decoder-only over EnCodec tokens [arXiv:2306.05284].  The EnCodec frontend
is a STUB per the assignment: input_specs() supplies precomputed frame
embeddings (B, S, d); a single flattened-codebook head (vocab 2048).
"""
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    d_model=1536, n_layers=48, d_ff=6144, vocab_size=2048,
    n_heads=24, n_kv_heads=24, head_dim=64,
    frontend="audio_stub",
)

SMOKE = ModelConfig(
    name="musicgen-smoke",
    d_model=48, n_layers=3, d_ff=96, vocab_size=64,
    n_heads=3, n_kv_heads=3, head_dim=16,
    frontend="audio_stub", kv_chunk=32,
)
