"""Architecture registry: 10 assigned archs + the paper's 5 workloads.

``get_config(name)`` returns the full published configuration;
``get_smoke(name)`` returns a reduced same-family config for CPU tests
(small width/depth, few experts, tiny vocab) — the full configs are only
exercised via the dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.model import ModelConfig

ARCHS: List[str] = [
    "qwen3_32b",
    "qwen25_14b",
    "smollm_135m",
    "phi4_mini_3p8b",
    "musicgen_medium",
    "phi35_moe_42b",
    "deepseek_moe_16b",
    "jamba_v01_52b",
    "mamba2_780m",
    "internvl2_26b",
]

# canonical dashed ids from the assignment -> module names
ALIASES: Dict[str, str] = {
    "qwen3-32b": "qwen3_32b",
    "qwen2.5-14b": "qwen25_14b",
    "smollm-135m": "smollm_135m",
    "phi4-mini-3.8b": "phi4_mini_3p8b",
    "musicgen-medium": "musicgen_medium",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "mamba2-780m": "mamba2_780m",
    "internvl2-26b": "internvl2_26b",
}


def _module(name: str):
    mod = ALIASES.get(name, name.replace("-", "_").replace(".", ""))
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> ModelConfig:
    return _module(name).SMOKE


def list_archs() -> List[str]:
    return list(ARCHS)
