"""phi3.5-moe-42b-a6.6b [moe]: 32L d=4096 32H (GQA kv=8) expert_ff=6400,
vocab=32064, MoE 16e top-2 (hf:microsoft/Phi-3.5-MoE-instruct)."""
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b",
    d_model=4096, n_layers=32, d_ff=6400, vocab_size=32064,
    n_heads=32, n_kv_heads=8, head_dim=128,
    pattern=("attn_moe",),
    n_experts=16, experts_per_token=2, moe_d_ff=6400,
)

SMOKE = ModelConfig(
    name="phi3.5-moe-smoke",
    d_model=64, n_layers=3, d_ff=96, vocab_size=256,
    n_heads=4, n_kv_heads=2, head_dim=16,
    pattern=("attn_moe",),
    n_experts=4, experts_per_token=2, moe_d_ff=96, kv_chunk=32,
)
