"""jamba-v0.1-52b [hybrid]: 32L d=4096 32H (GQA kv=8) d_ff=14336,
vocab=65536, MoE 16e top-2; Mamba:attn 7:1 interleave [arXiv:2403.19887].

Period-8 pattern (1 attn per 8 layers, MoE every other layer), 4 repeats.
Mamba blocks use our SSD machinery (d_state=128 per the mamba2 adaptation
noted in DESIGN.md; Jamba's original uses Mamba-1 d_state=16).
"""
from repro.models.model import ModelConfig

_PATTERN = ("mamba", "mamba_moe", "mamba", "mamba_moe",
            "attn", "mamba_moe", "mamba", "mamba_moe")

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    d_model=4096, n_layers=32, d_ff=14336, vocab_size=65536,
    n_heads=32, n_kv_heads=8, head_dim=128,
    pattern=_PATTERN,
    n_experts=16, experts_per_token=2, moe_d_ff=14336,
    ssm_state=128, ssm_heads=128, ssm_head_dim=64,
    sub_quadratic=True,          # 1:7 attn ratio -> long-context capable
)

SMOKE = ModelConfig(
    name="jamba-smoke",
    d_model=64, n_layers=8, d_ff=96, vocab_size=256,
    n_heads=4, n_kv_heads=2, head_dim=16,
    pattern=_PATTERN,
    n_experts=4, experts_per_token=2, moe_d_ff=96,
    ssm_state=16, ssm_heads=4, ssm_head_dim=16,
    ssd_chunk=16, kv_chunk=32, sub_quadratic=True,
)
