"""deepseek-moe-16b [moe]: 28L d=2048 16H (kv=16: MHA) expert_ff=1408,
vocab=102400, 2 shared + 64 routed top-6 fine-grained [arXiv:2401.06066]."""
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    d_model=2048, n_layers=28, d_ff=1408, vocab_size=102400,
    n_heads=16, n_kv_heads=16, head_dim=128,
    pattern=("attn_moe",),
    n_experts=64, experts_per_token=6, n_shared_experts=2, moe_d_ff=1408,
)

SMOKE = ModelConfig(
    name="deepseek-moe-smoke",
    d_model=64, n_layers=3, d_ff=48, vocab_size=256,
    n_heads=4, n_kv_heads=4, head_dim=16,
    pattern=("attn_moe",),
    n_experts=8, experts_per_token=3, n_shared_experts=2, moe_d_ff=48,
    kv_chunk=32,
)
