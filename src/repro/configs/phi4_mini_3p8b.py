"""phi4-mini-3.8b [dense]: 32L d=3072 24H (GQA kv=8) d_ff=8192 vocab=200064.

RoPE + SwiGLU + GQA [arXiv:2412.08905].
"""
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    d_model=3072, n_layers=32, d_ff=8192, vocab_size=200064,
    n_heads=24, n_kv_heads=8, head_dim=128,
)

SMOKE = ModelConfig(
    name="phi4-mini-smoke",
    d_model=64, n_layers=4, d_ff=160, vocab_size=512,
    n_heads=4, n_kv_heads=2, head_dim=16, kv_chunk=32,
)
