"""qwen3-32b [dense]: 64L d=5120 64H (GQA kv=8) d_ff=25600 vocab=151936.

qk_norm + GQA, SwiGLU, RoPE (config family per hf:Qwen/Qwen3 series).
"""
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    d_model=5120, n_layers=64, d_ff=25600, vocab_size=151936,
    n_heads=64, n_kv_heads=8, head_dim=128,
    qk_norm=True, rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="qwen3-32b-smoke",
    d_model=64, n_layers=4, d_ff=160, vocab_size=256,
    n_heads=4, n_kv_heads=2, head_dim=16,
    qk_norm=True, rope_theta=1e6, kv_chunk=32,
)
