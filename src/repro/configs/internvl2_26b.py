"""internvl2-26b [vlm]: 48L d=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.

InternViT + InternLM2 [arXiv:2404.16821].  The InternViT frontend is a STUB
per the assignment: input_specs() supplies 256 precomputed patch embeddings
(B, 256, d) prepended to the text tokens.
"""
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    d_model=6144, n_layers=48, d_ff=16384, vocab_size=92553,
    n_heads=48, n_kv_heads=8, head_dim=128,
    frontend="vision_stub", n_image_tokens=256,
)

SMOKE = ModelConfig(
    name="internvl2-smoke",
    d_model=64, n_layers=3, d_ff=128, vocab_size=256,
    n_heads=4, n_kv_heads=2, head_dim=16,
    frontend="vision_stub", n_image_tokens=8, kv_chunk=32,
)
