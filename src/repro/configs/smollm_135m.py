"""smollm-135m [dense]: 30L d=576 9H (GQA kv=3) d_ff=1536 vocab=49152.

Llama-architecture small model (hf:HuggingFaceTB/SmolLM-135M), tied embeds.
"""
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    d_model=576, n_layers=30, d_ff=1536, vocab_size=49152,
    n_heads=9, n_kv_heads=3, head_dim=64,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="smollm-135m-smoke",
    d_model=48, n_layers=3, d_ff=128, vocab_size=256,
    n_heads=3, n_kv_heads=1, head_dim=16,
    tie_embeddings=True, kv_chunk=32,
)
