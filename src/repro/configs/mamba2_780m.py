"""mamba2-780m [ssm]: 48L d=1536 attn-free, vocab=50280, ssm_state=128.

SSD (state-space duality) [arXiv:2405.21060]; d_inner = 2*d, headdim 64
-> 48 SSD heads; no MLP (the mamba mixer is the whole layer).
"""
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    d_model=1536, n_layers=48, d_ff=0, vocab_size=50280,
    pattern=("mamba",),
    ssm_state=128, ssm_heads=48, ssm_head_dim=64,
    tie_embeddings=True,
    sub_quadratic=True,
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    d_model=64, n_layers=4, d_ff=0, vocab_size=256,
    pattern=("mamba",),
    ssm_state=16, ssm_heads=4, ssm_head_dim=32,
    ssd_chunk=16, tie_embeddings=True, sub_quadratic=True,
)
