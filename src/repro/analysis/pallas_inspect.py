"""Static introspection of Pallas kernel instantiations — no execution.

PR 7's program auditor proves properties of every serve program's jaxpr and
HLO but goes blind at the ``pallas_call`` boundary: BlockSpec index maps
are plain Python functions XLA never sees, and exactly those functions
decide the data movement the paper's claims are counted in (a
scalar-prefetched page-table index map that dereferences out of bounds is
*silent garbage* on TPU — the same failure class as the PR 3 CPU-SPMD
miscompiles).  This module is the machinery that makes the boundary
auditable:

* :class:`KernelInstantiation` — one concrete (grid, BlockSpecs, operand
  shapes/dtypes, scratch, scalar-prefetch values) tuple, built by each
  kernel's ``audit_specs()`` hook from the SAME spec-builder the shipped
  ``pallas_call`` uses, so the audited index maps are the shipped ones.
* :func:`check_bounds` — evaluates every index map over the full grid
  (grids are small and static: an exhaustive sweep IS a proof) and checks
  every block index lands inside its operand.
* :func:`vmem_footprint` — per-instantiation VMEM bytes: double-buffered
  in/out block buffers plus scratch, the number gated against
  ``benchmarks/baselines/kernel_audit.json``.
* :func:`block_traffic` — bytes moved per invocation from BlockSpecs x
  grid x dtype, with the pipeline's revisit elision (a block whose index
  does not change between consecutive grid steps is not re-fetched) and
  per-kernel refinement hooks (plane skipping, masked-dead blocks).
* :func:`extract_pallas_calls` — the jaxpr-side census: every
  ``pallas_call`` eqn in a traced serve program, with enclosing-scan trip
  counts multiplied through, so per-invocation statics compose into
  per-tick byte tables (the cost model ``simulator/`` consumes).

The rule families consuming this live in ``analysis.kernel_rules``.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

# Pallas pipelines double-buffer the in/out block windows (fetch block j+1
# while computing on block j); scratch is single-buffered, it never streams.
DOUBLE_BUFFER = 2


def _np_dtype(dt) -> np.dtype:
    """np.dtype for numpy/jnp dtypes AND jnp scalar types (bf16 included)."""
    return np.dtype(getattr(dt, "dtype", dt))


def dtype_bytes(dt) -> int:
    return _np_dtype(dt).itemsize


@dataclasses.dataclass(frozen=True)
class OperandSpec:
    """One operand's BlockSpec view: the shipped block shape + index map."""

    name: str
    shape: Tuple[int, ...]
    dtype: str
    block_shape: Tuple[int, ...]
    index_map: Callable

    @property
    def block_bytes(self) -> int:
        return int(np.prod(self.block_shape)) * dtype_bytes(self.dtype)

    @property
    def n_blocks(self) -> Tuple[int, ...]:
        """Blocks per dim: pallas requires block index ``b`` to satisfy
        ``0 <= b < ceil(extent / block)`` — anything else reads memory the
        operand does not own."""
        return tuple(-(-s // b) for s, b in zip(self.shape, self.block_shape))


@dataclasses.dataclass
class KernelInstantiation:
    """One concrete kernel configuration the verifier can sweep.

    ``scalars`` are the scalar-prefetch operand VALUES (page tables, skip
    tables, lengths) — small integer metadata, exactly the data that
    *decides* movement; evaluating index maps over them touches no tensor
    data and executes no kernel.  ``meta`` carries kernel-family facts the
    rule families interpret (page_len, lengths, trash page, exponents...).
    """

    kernel: str  # family: "paged_attention" | "bitplane_matmul" | "log2quant"
    case: str  # geometry id, e.g. "ragged512.s1"
    grid: Tuple[int, ...]
    inputs: Tuple[OperandSpec, ...]
    outputs: Tuple[OperandSpec, ...]
    scratch: Tuple[Tuple[Tuple[int, ...], str], ...] = ()
    scalars: Tuple[np.ndarray, ...] = ()
    meta: Dict = dataclasses.field(default_factory=dict)

    @property
    def name(self) -> str:
        return f"{self.kernel}/{self.case}"

    @property
    def operands(self) -> Tuple[OperandSpec, ...]:
        return self.inputs + self.outputs

    @property
    def grid_points(self) -> int:
        return int(np.prod(self.grid))


def make_operand(name: str, shape, dtype, block_spec) -> OperandSpec:
    """OperandSpec from a ``pl.BlockSpec`` — the object handed to
    ``pallas_call``, so audit and kernel share one index map."""
    return OperandSpec(
        name=name,
        shape=tuple(int(s) for s in shape),
        dtype=_np_dtype(dtype).name,
        block_shape=tuple(int(b) for b in block_spec.block_shape),
        index_map=block_spec.index_map,
    )


def scratch_entry(ref) -> Tuple[Tuple[int, ...], str]:
    """(shape, dtype name) from a ``pltpu.VMEM(...)`` MemoryRef."""
    return tuple(int(s) for s in ref.shape), _np_dtype(ref.dtype).name


def iter_grid(grid: Sequence[int]) -> Iterator[Tuple[int, ...]]:
    """Row-major sweep, last dim innermost — the TPU grid execution order
    (and the order pallas's revisit elision is defined over)."""
    return itertools.product(*(range(int(g)) for g in grid))


def eval_index_map(op: OperandSpec, gidx: Tuple[int, ...], scalars: Sequence[np.ndarray]):
    """Block indices the shipped index map produces for one grid point.

    Scalar-prefetch refs are passed as the real numpy arrays — ``tab[bi,
    j]`` works identically on a Ref and an ndarray.  Returns a tuple of
    ints, or raises whatever the index map raises (an out-of-range table
    read raises ``IndexError`` here instead of fetching garbage on TPU —
    the verifier treats both as bounds violations).
    """
    out = op.index_map(*gidx, *scalars)
    if not isinstance(out, tuple):
        out = (out,)
    return tuple(int(i) for i in out)


# ---------------------------------------------------------------------------
# bounds proofs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BoundsViolation:
    operand: str
    gidx: Tuple[int, ...]
    detail: str


def check_bounds(inst: KernelInstantiation) -> List[BoundsViolation]:
    """Exhaustively prove every block dereference in-bounds.

    Grids are small and static (a few hundred points across the whole
    audit matrix), so enumeration is a proof, not a sample.  A dimension's
    block index must satisfy ``0 <= b < ceil(extent / block)``; an index
    map that *raises* (numpy catches the out-of-range scalar read that TPU
    hardware would silently satisfy with garbage) is reported the same way.
    """
    out: List[BoundsViolation] = []
    for op in inst.operands:
        nb = op.n_blocks
        for gidx in iter_grid(inst.grid):
            try:
                bidx = eval_index_map(op, gidx, inst.scalars)
            except Exception as e:  # noqa: BLE001 — any raise is a violation
                out.append(
                    BoundsViolation(op.name, gidx, f"index map raised {type(e).__name__}: {e}")
                )
                continue
            if len(bidx) != len(op.block_shape):
                out.append(
                    BoundsViolation(
                        op.name,
                        gidx,
                        f"index map arity {len(bidx)} != block rank {len(op.block_shape)}",
                    )
                )
                continue
            for d, (b, n) in enumerate(zip(bidx, nb)):
                if not 0 <= b < n:
                    out.append(
                        BoundsViolation(
                            op.name,
                            gidx,
                            f"block index {b} outside [0, {n}) on dim {d} "
                            f"(operand {op.shape}, block {op.block_shape})",
                        )
                    )
    return out


# ---------------------------------------------------------------------------
# VMEM footprint
# ---------------------------------------------------------------------------


def vmem_footprint(inst: KernelInstantiation) -> Dict:
    """Resident VMEM bytes: 2x (double-buffered) in/out block windows plus
    single-buffered scratch.  ``n_buffers`` is structural (exact gate);
    ``vmem_bytes`` is gated at 10% rtol in ``kernel_rules``."""
    buffers: Dict[str, int] = {}
    for op in inst.operands:
        buffers[op.name] = DOUBLE_BUFFER * op.block_bytes
    for i, (shape, dtype) in enumerate(inst.scratch):
        buffers[f"scratch{i}"] = int(np.prod(shape)) * dtype_bytes(dtype)
    return {
        "n_buffers": len(buffers),
        "vmem_bytes": int(sum(buffers.values())),
        "buffers": buffers,
    }


# ---------------------------------------------------------------------------
# static byte-traffic model
# ---------------------------------------------------------------------------


def block_traffic(
    inst: KernelInstantiation,
    live: Optional[Callable[[str, Tuple[int, ...]], bool]] = None,
    refine_bytes: Optional[Callable[[str, Tuple[int, ...], float], float]] = None,
) -> Dict:
    """Bytes moved per invocation, derived from BlockSpecs x grid x dtype.

    Semantics:

    * **revisit elision** — pallas does not re-fetch a block whose index is
      unchanged from the previous grid step (the same contract that makes
      accumulator outputs work); consecutive identical indices count once.
    * ``live(name, gidx) -> bool`` — kernel-family hook: a block that is
      fully masked out of the result (every position past ``length``, for
      the paged kernel) moves no *useful* bytes and is excluded, mirroring
      the runtime counters (``ops.gather_traffic_counts`` counts only pages
      holding valid tokens).
    * ``refine_bytes(name, gidx, nominal) -> float`` — intra-block
      refinement: the bit-plane kernel's ``@pl.when`` plane skip fetches
      ``bits - min_plane`` of the 8 plane slabs of each block.

    Returns ``{"read": {name: bytes}, "written": {...}, "fetches": {name:
    count}}`` — fetches are post-elision, post-masking block counts.
    """
    read: Dict[str, float] = {op.name: 0.0 for op in inst.inputs}
    written: Dict[str, float] = {op.name: 0.0 for op in inst.outputs}
    fetches: Dict[str, int] = {op.name: 0 for op in inst.operands}
    prev: Dict[str, object] = {op.name: None for op in inst.operands}

    for gidx in iter_grid(inst.grid):
        for op in inst.inputs:
            bidx = eval_index_map(op, gidx, inst.scalars)
            if bidx == prev[op.name]:
                continue
            prev[op.name] = bidx
            if live is not None and not live(op.name, gidx):
                continue
            nominal = float(op.block_bytes)
            if refine_bytes is not None:
                nominal = refine_bytes(op.name, gidx, nominal)
            read[op.name] += nominal
            fetches[op.name] += 1
        for op in inst.outputs:
            bidx = eval_index_map(op, gidx, inst.scalars)
            if bidx == prev[op.name]:
                continue
            prev[op.name] = bidx
            written[op.name] += float(op.block_bytes)
            fetches[op.name] += 1
    return {"read": read, "written": written, "fetches": fetches}


# ---------------------------------------------------------------------------
# jaxpr-side census: pallas_call sites inside traced serve programs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PallasCallSite:
    """One ``pallas_call`` eqn in a traced program, loop-scaled."""

    kernel_name: str  # the kernel body's function name, e.g. "_paged_attn_kernel"
    multiplier: int  # product of enclosing scan trip counts
    grid: Tuple[int, ...]
    operand_shapes: Tuple[Tuple[int, ...], ...]
    operand_dtypes: Tuple[str, ...]
    block_shapes: Tuple[Tuple[int, ...], ...]

    @property
    def operand_bytes(self) -> int:
        """Bytes of every operand the call streams once (the dense upper
        bound; savings fractions come from the matching audit_specs case)."""
        return int(
            sum(
                int(np.prod(s)) * dtype_bytes(d)
                for s, d in zip(self.operand_shapes, self.operand_dtypes)
            )
        )


def _jaxprs_of(v):
    from jax import core

    if isinstance(v, core.ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, core.Jaxpr):
        yield v
    elif isinstance(v, (tuple, list)):
        for x in v:
            yield from _jaxprs_of(x)


def extract_pallas_calls(jaxpr, _mult: int = 1) -> List[PallasCallSite]:
    """Every ``pallas_call`` eqn in ``jaxpr`` and all sub-jaxprs, with
    enclosing ``scan`` trip counts multiplied through (``while`` bodies are
    scaled x1 — trip counts are data-dependent; the serve tick's loops are
    fixed-length scans, so the census is exact where it matters)."""
    from jax import core

    jaxpr = jaxpr.jaxpr if isinstance(jaxpr, core.ClosedJaxpr) else jaxpr
    out: List[PallasCallSite] = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            gm = eqn.params["grid_mapping"]
            name = str(eqn.params["name_and_src_info"].name)
            avals = [v.aval for v in eqn.invars]
            out.append(
                PallasCallSite(
                    kernel_name=name,
                    multiplier=_mult,
                    grid=tuple(int(g) for g in gm.grid),
                    operand_shapes=tuple(tuple(int(s) for s in a.shape) for a in avals),
                    operand_dtypes=tuple(_np_dtype(a.dtype).name for a in avals),
                    block_shapes=tuple(
                        tuple(int(b) for b in bm.block_shape) for bm in gm.block_mappings
                    ),
                )
            )
            continue
        sub_mult = _mult
        if eqn.primitive.name == "scan":
            sub_mult = _mult * int(eqn.params.get("length", 1))
        for sub in eqn.params.values():
            for j in _jaxprs_of(sub):
                out.extend(extract_pallas_calls(j, sub_mult))
    return out
