"""HLO budget gate (rule family 3): collective counts exact, bytes rtol.

Sharded tick programs run every serving tick; an accidental extra
all-gather per tick (a lost ``with_sharding_constraint``, a donation that
stopped engaging, a new op XLA chose to rematerialize across the mesh) is
invisible to the parity tests — the numbers stay right, the serve loop
just quietly ships more bytes.  This gate pins, per sharded variant and
program, the loop-scaled collective census (exact: counts are integers
XLA chooses deterministically for a fixed program + mesh) and the
roofline traffic estimate (rtol: byte totals wobble with fusion
decisions across jaxlib point releases), against the committed baseline
``benchmarks/baselines/program_audit.json``.
"""

from __future__ import annotations

import json
from typing import Dict, List, Optional

from repro.analysis.hlo import analyze_hlo, collective_census
from repro.analysis.report import Finding

# traffic estimates ride XLA fusion choices; counts do not
BYTES_RTOL = 0.10

BASELINE_PATH = "benchmarks/baselines/program_audit.json"


def program_budget(hlo: str) -> Dict:
    """The budget record for one compiled program's optimized-HLO text."""
    totals = analyze_hlo(hlo)
    census = collective_census(hlo)
    return {
        "collectives": {k: int(v["count"]) for k, v in sorted(census.items())},
        "collective_bytes": {k: float(v["bytes"]) for k, v in sorted(census.items())},
        "traffic_bytes": float(totals["traffic_bytes"]),
    }


def load_baseline(path: str = BASELINE_PATH) -> Dict[str, Dict]:
    with open(path) as f:
        return json.load(f).get("programs", {})


def save_baseline(
    budgets: Dict[str, Dict], path: str = BASELINE_PATH, note: Optional[str] = None
) -> None:
    doc = {
        "note": note
        or (
            "per-program collective/traffic budgets — "
            "regenerate with tools/audit.py --update-baselines"
        ),
        "programs": {k: budgets[k] for k in sorted(budgets)},
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")


def check_budgets(
    fresh: Dict[str, Dict], baseline: Dict[str, Dict], *, bytes_rtol: float = BYTES_RTOL
) -> List[Finding]:
    """Compare freshly-computed budgets against the committed baseline.

    * collective COUNTS: exact — one extra all-gather launch is a bug.
    * collective/traffic BYTES: relative tolerance ``bytes_rtol``.
    * a program missing from the baseline (or vice versa) is itself a
      finding: the baseline must be regenerated deliberately
      (``--update-baselines``), never drift silently.
    """
    out: List[Finding] = []

    def fi(key: str, detail: str) -> Finding:
        variant, _, program = key.partition("/")
        return Finding(rule="hlo-budget", variant=variant, program=program, detail=detail)

    for key in sorted(set(fresh) | set(baseline)):
        if key not in baseline:
            out.append(
                fi(key, "program has no committed budget — run tools/audit.py --update-baselines")
            )
            continue
        if key not in fresh:
            out.append(
                fi(
                    key,
                    "program in baseline but no longer audited — run tools/audit.py --update-baselines",
                )
            )
            continue
        got, want = fresh[key], baseline[key]
        gc, wc = got["collectives"], want["collectives"]
        for kind in sorted(set(gc) | set(wc)):
            g, w = int(gc.get(kind, 0)), int(wc.get(kind, 0))
            if g != w:
                out.append(
                    fi(
                        key,
                        f"{kind} count {g} != budget {w} "
                        f"(exact gate: every launch is "
                        f"per-tick serving cost)",
                    )
                )
        for field, gb in (("traffic_bytes", got["traffic_bytes"]),):
            wb = float(want.get(field, 0.0))
            if wb == 0.0 and gb == 0.0:
                continue
            rel = abs(gb - wb) / max(abs(wb), 1.0)
            if rel > bytes_rtol:
                out.append(
                    fi(
                        key,
                        f"{field} {gb:.3e} vs budget {wb:.3e} "
                        f"(rel {rel:.1%} > {bytes_rtol:.0%})",
                    )
                )
        gkb = got.get("collective_bytes", {})
        wkb = want.get("collective_bytes", {})
        for kind in sorted(set(gkb) | set(wkb)):
            g, w = float(gkb.get(kind, 0.0)), float(wkb.get(kind, 0.0))
            rel = abs(g - w) / max(abs(w), 1.0)
            if rel > bytes_rtol:
                out.append(
                    fi(
                        key,
                        f"{kind} bytes {g:.3e} vs budget "
                        f"{w:.3e} (rel {rel:.1%} > "
                        f"{bytes_rtol:.0%})",
                    )
                )
    return out
