"""Jaxpr rule family: structural invariants of the traced serve programs.

These rules run on ``jax.make_jaxpr`` output — pure tracing, no lowering,
no execution — and encode invariants that runtime parity tests can only
check *after* a regression ships (DESIGN.md §Program audit):

* :func:`rule_no_dense_pool_gather` — with ``attn_kernel=True`` the whole
  point of the Pallas paged-attention kernel (PR 6) is that the dense
  ``pool[table]`` gather never materializes; a ``gather`` reading the KV
  page pool inside a kernel-enabled tick program means the dispatch
  silently fell back to the dense path.
* :func:`rule_no_host_callback` — a host callback (``debug_callback`` left
  behind from debugging, ``pure_callback``/``io_callback``, infeed/outfeed)
  inside a tick program forces a device->host sync every tick and breaks
  the "one jitted program per tick" contract from PR 1.
* :func:`rule_no_double_precision` / :func:`rule_no_integer_upcast` — the
  shift-add path is integer (int32 planes/accumulators) by design (PAPER
  §IV); an f64/c128 value anywhere in a tick program, or an i64/u64 value
  in a quant program, is a silent upcast that doubles traffic on exactly
  the path whose claim is *fewer* bytes touched.

Every helper works on ``Jaxpr`` or ``ClosedJaxpr`` and recurses into every
sub-jaxpr (pjit / scan / while / cond / custom calls), so rules see through
the jitted wrappers and the tick's ``lax.scan``.
"""

from __future__ import annotations

from typing import Iterator, List, Optional

import jax
import numpy as np
from jax import core

from repro.analysis.report import Finding

# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------


def _as_jaxpr(j) -> core.Jaxpr:
    return j.jaxpr if isinstance(j, core.ClosedJaxpr) else j


def _jaxprs_in(v) -> Iterator[core.Jaxpr]:
    if isinstance(v, core.ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, core.Jaxpr):
        yield v
    elif isinstance(v, (tuple, list)):
        for x in v:
            yield from _jaxprs_in(x)


def sub_jaxprs(eqn) -> Iterator[core.Jaxpr]:
    """Every jaxpr referenced by an eqn's params (pjit ``jaxpr``, scan
    ``jaxpr``, while ``cond_jaxpr``/``body_jaxpr``, cond ``branches``...)."""
    for v in eqn.params.values():
        yield from _jaxprs_in(v)


def iter_eqns(jaxpr) -> Iterator:
    """Depth-first over every eqn in the jaxpr and all nested sub-jaxprs."""
    for eqn in _as_jaxpr(jaxpr).eqns:
        yield eqn
        for sub in sub_jaxprs(eqn):
            yield from iter_eqns(sub)


def _avals(eqn):
    for v in list(eqn.invars) + list(eqn.outvars):
        aval = getattr(v, "aval", None)
        if aval is not None and hasattr(aval, "dtype"):
            yield aval


# ---------------------------------------------------------------------------
# rules
# ---------------------------------------------------------------------------

CALLBACK_PRIMS = frozenset(
    {
        "pure_callback",
        "io_callback",
        "debug_callback",
        "outside_call",
        "host_callback_call",
        "infeed",
        "outfeed",
    }
)


def rule_no_host_callback(jaxpr, variant: str, program: str) -> List[Finding]:
    """Tick programs must be host-silent: no callback / infeed / outfeed
    primitive anywhere in the traced program."""
    out = []
    for eqn in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if name in CALLBACK_PRIMS or "callback" in name:
            out.append(
                Finding(
                    rule="no-host-callback",
                    variant=variant,
                    program=program,
                    detail=f"host-syncing primitive {name!r} in the program",
                )
            )
    return out


_WIDE_FLOAT = ("float64", "complex128")
_WIDE_INT = ("int64", "uint64")


def rule_no_double_precision(jaxpr, variant: str, program: str) -> List[Finding]:
    """No f64/c128 value may appear anywhere in a serve program — CPU smoke
    silently tolerates them; accelerators pay double bandwidth (or trap)."""
    out = []
    for eqn in iter_eqns(jaxpr):
        for aval in _avals(eqn):
            if str(aval.dtype) in _WIDE_FLOAT:
                out.append(
                    Finding(
                        rule="no-double-precision",
                        variant=variant,
                        program=program,
                        detail=(
                            f"{aval.dtype} value of shape "
                            f"{tuple(aval.shape)} at primitive "
                            f"{eqn.primitive.name!r}"
                        ),
                    )
                )
                break  # one finding per eqn is enough
    return out


def rule_no_integer_upcast(jaxpr, variant: str, program: str) -> List[Finding]:
    """Quant programs: the shift-add path accumulates in int32 (PAPER §IV)
    — an i64/u64 value means numpy-int leakage or an XLA promotion widened
    the integer path, silently doubling plane-traffic bytes."""
    out = []
    for eqn in iter_eqns(jaxpr):
        for aval in _avals(eqn):
            if str(aval.dtype) in _WIDE_INT:
                out.append(
                    Finding(
                        rule="no-integer-upcast",
                        variant=variant,
                        program=program,
                        detail=(
                            f"{aval.dtype} value of shape "
                            f"{tuple(aval.shape)} at primitive "
                            f"{eqn.primitive.name!r}"
                        ),
                    )
                )
                break
    return out


def rule_no_dense_pool_gather(jaxpr, variant: str, program: str, *, n_pages: int) -> List[Finding]:
    """Kernel-enabled tick programs must never gather the KV page pool.

    The dense fallback is ``pool[table]`` (``models.attention._paged_gather``)
    — a ``gather`` whose operand is a *floating* array carrying the pool's
    page axis (``n_pages``).  Page-table index arithmetic (int32 gathers)
    passes; any float gather off the pool is the exact dense read the PR 6
    kernel exists to eliminate.  ``n_pages`` should be sized distinctively
    by the caller (``analysis.programs`` picks a value no other dimension
    uses) so the page axis is unambiguous."""
    out = []
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name != "gather":
            continue
        aval = getattr(eqn.invars[0], "aval", None)
        if aval is None or not hasattr(aval, "dtype"):
            continue
        if not np.issubdtype(np.dtype(aval.dtype), np.floating):
            continue
        if n_pages in tuple(aval.shape):
            gathered = getattr(eqn.outvars[0], "aval", None)
            out.append(
                Finding(
                    rule="no-dense-pool-gather",
                    variant=variant,
                    program=program,
                    detail=(
                        f"float gather reads the page pool: operand "
                        f"{tuple(aval.shape)} ({aval.dtype}) -> "
                        f"{tuple(gathered.shape) if gathered is not None else '?'}"
                        f" — dense pool[table] fallback while the paged-"
                        f"attention kernel is enabled"
                    ),
                )
            )
    return out


def make_program_jaxpr(fn, args) -> core.ClosedJaxpr:
    """Trace ``fn`` (a scheduler program: plain jit OR an
    ``engine.jit_sharded`` wrapper) to a jaxpr without executing it.

    Sharded wrappers expose ``trace_context`` (the mesh + ``mesh_axes``
    binding their calls enter) and ``jitted``; plain jits trace directly.
    """
    import contextlib

    ctx = getattr(fn, "trace_context", None)
    target = getattr(fn, "jitted", fn)
    with ctx() if ctx is not None else contextlib.nullcontext():
        return jax.make_jaxpr(target)(*args)
