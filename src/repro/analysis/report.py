"""Audit findings, waivers, and the machine-readable report.

A :class:`Finding` is one rule violation in one lowered program.  The
auditor (``tools/audit.py``) collects findings from every rule family,
applies the committed waiver file (``tools/audit_waivers.json``), and
fails on whatever is left — a waiver is an explicit, reviewed decision
with a reason string, never a silent default (DESIGN.md §Program audit).
"""

from __future__ import annotations

import fnmatch
import json
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Sequence


@dataclass
class Finding:
    rule: str  # e.g. "no-dense-pool-gather"
    variant: str  # e.g. "paged_kernel-quant@2x2"
    program: str  # e.g. "tick"
    detail: str  # human-readable evidence (primitive, shapes, dim)
    waived: bool = False
    waive_reason: Optional[str] = None

    def key(self) -> str:
        return f"{self.rule}:{self.variant}/{self.program}"


@dataclass
class Waiver:
    """One committed exception: rule + variant/program glob + reason."""

    rule: str
    match: str  # fnmatch glob over "variant/program"
    reason: str

    def covers(self, f: Finding) -> bool:
        return self.rule == f.rule and fnmatch.fnmatch(f"{f.variant}/{f.program}", self.match)


def load_waivers(path: str, known_rules: Optional[Sequence[str]] = None) -> List[Waiver]:
    """Read ``tools/audit_waivers.json``: ``{"waivers": [{"rule": ...,
    "match": ..., "reason": ...}, ...]}``.  Entries without a non-empty
    reason string are rejected — the reason IS the point.  When
    ``known_rules`` is given, a waiver naming a rule outside the live
    registry is rejected too: a typo'd rule id would otherwise sit
    silently inert while the finding it meant to cover keeps failing."""
    with open(path) as f:
        data = json.load(f)
    out = []
    for w in data.get("waivers", []):
        reason = w.get("reason", "")
        if not isinstance(reason, str) or not reason.strip():
            raise ValueError(f"waiver {w!r} has no reason string")
        if known_rules is not None and w["rule"] not in known_rules:
            raise ValueError(
                f"waiver {w!r} names unknown rule {w['rule']!r} — "
                f"known rules: {', '.join(known_rules)}"
            )
        out.append(Waiver(rule=w["rule"], match=w["match"], reason=reason))
    return out


def apply_waivers(findings: List[Finding], waivers: List[Waiver]) -> List[Finding]:
    """Mark waived findings in place; returns the still-failing rest."""
    live = []
    for f in findings:
        for w in waivers:
            if w.covers(f):
                f.waived = True
                f.waive_reason = w.reason
                break
        if not f.waived:
            live.append(f)
    return live


@dataclass
class AuditReport:
    """Everything one ``tools/audit.py`` run produced, JSON-serializable
    (CI uploads it as a workflow artifact next to the bench JSONs)."""

    variants: List[str] = field(default_factory=list)
    programs_audited: int = 0
    rules_run: List[str] = field(default_factory=list)
    findings: List[Finding] = field(default_factory=list)
    budgets: Dict[str, dict] = field(default_factory=dict)
    census: Dict[str, dict] = field(default_factory=dict)
    kernels: Dict[str, dict] = field(default_factory=dict)

    @property
    def failures(self) -> List[Finding]:
        return [f for f in self.findings if not f.waived]

    def to_json(self) -> str:
        return (
            json.dumps(
                {
                    "version": 1,
                    "variants": self.variants,
                    "programs_audited": self.programs_audited,
                    "rules_run": self.rules_run,
                    "findings": [asdict(f) for f in self.findings],
                    "budgets": self.budgets,
                    "census": self.census,
                    "kernels": self.kernels,
                    "n_failures": len(self.failures),
                },
                indent=2,
                sort_keys=True,
            )
            + "\n"
        )
