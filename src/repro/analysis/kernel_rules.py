"""Kernel rule family: the static Pallas verifier (rule family 5).

Four rules over every registered kernel instantiation (the per-kernel
``audit_specs()`` hooks in ``kernels/*/kernel.py``), none of which execute
a kernel:

* ``kernel-index-bounds`` — exhaustive index-map bounds proof
  (:func:`pallas_inspect.check_bounds`) plus the paged-attention validity
  half: a LIVE page-table column (one holding valid tokens) must map to a
  real page, never the reserved trash page — a trash entry in the live
  zone makes valid tokens unreachable and the softmax silently wrong.
* ``kernel-vmem-budget`` — double-buffered block windows + scratch gated
  against ``benchmarks/baselines/kernel_audit.json`` (buffer counts
  exact, bytes at 10% rtol) and an absolute per-core cap.
* ``kernel-unmasked-tail`` — a grid dimension that does not divide its
  operand extent must carry a masked-tail declaration, and the PR 6
  trash-column idiom is enforced: every DEAD page-table column (past the
  last valid page) must point at the trash page, not a stale real page.
* ``kernel-traffic-model`` — bytes moved derived from BlockSpecs x grid x
  dtype (:func:`pallas_inspect.block_traffic`), refined by the plane-skip
  table and the live-page mask, cross-checked EXACTLY against the runtime
  counters (``ops.gather_traffic_counts``, ``ops.plane_traffic_counts``,
  ``core.access_model.needed_bits``) and the committed baselines.  The
  paper's savings numbers become compile-time facts: the static model
  must reproduce the measured ``gather_saved_frac`` bit-for-bit, and the
  per-tick pallas_call census (via the PR 7 program registry) prices a
  whole serve tick in bytes — the cost table ``simulator/`` loads.

Baselines live in ``benchmarks/baselines/kernel_audit.json``; regenerate
with ``tools/audit.py --kernels --update-baselines``.
"""

from __future__ import annotations

import json
from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.analysis.pallas_inspect import (
    KernelInstantiation,
    block_traffic,
    check_bounds,
    extract_pallas_calls,
    vmem_footprint,
)
from repro.analysis.report import Finding

KERNEL_RULES = (
    "kernel-index-bounds",
    "kernel-vmem-budget",
    "kernel-unmasked-tail",
    "kernel-traffic-model",
)

KERNEL_BASELINE_PATH = "benchmarks/baselines/kernel_audit.json"
PAGED_ATTN_BENCH_BASELINE = "benchmarks/baselines/paged_attn.json"

# one TPU core's VMEM; an instantiation above this cannot be resident even
# once, let alone double-buffered
VMEM_LIMIT_BYTES = 16 * 2**20
VMEM_BYTES_RTOL = 0.10
TICK_BYTES_RTOL = 0.10

# kernel-body function name (as pallas records it in name_and_src_info)
# -> audit family; the per-tick census keys sites by this
KERNEL_FN_FAMILY = {
    "_paged_attn_kernel": "paged_attention",
    "_paged_attn_quant_kernel": "paged_attention",
    "_bitplane_matmul_kernel": "bitplane_matmul",
    "_log2quant_kernel": "log2quant",
}

# the serve variants whose tick dispatches pallas kernels (PR 7 matrix)
TICK_VARIANTS = ("paged_kernel", "paged_kernel-quant")

MAX_BOUNDS_FINDINGS = 8  # per instantiation: first few violations suffice


def registered_instantiations() -> List[KernelInstantiation]:
    """Every instantiation the kernel packages register — all three
    kernels across their audit matrices (dtypes, tilings, geometries)."""
    from repro.kernels.bitplane_matmul import kernel as bitplane
    from repro.kernels.log2quant import kernel as log2quant
    from repro.kernels.paged_attention import kernel as paged

    out: List[KernelInstantiation] = []
    for mod in (paged, bitplane, log2quant):
        out.extend(mod.audit_specs())
    return out


def _finding(rule: str, inst: KernelInstantiation, detail: str) -> Finding:
    return Finding(rule=rule, variant=inst.kernel, program=inst.case, detail=detail)


# ---------------------------------------------------------------------------
# rule 1: index-map bounds proofs
# ---------------------------------------------------------------------------


def rule_index_bounds(inst: KernelInstantiation) -> List[Finding]:
    out: List[Finding] = []
    violations = check_bounds(inst)
    for v in violations[:MAX_BOUNDS_FINDINGS]:
        out.append(
            _finding("kernel-index-bounds", inst, f"{v.operand} at grid{v.gidx}: {v.detail}")
        )
    if len(violations) > MAX_BOUNDS_FINDINGS:
        out.append(
            _finding(
                "kernel-index-bounds",
                inst,
                f"... and {len(violations) - MAX_BOUNDS_FINDINGS} more " f"bounds violations",
            )
        )

    # validity half for the paged kernel: live columns must be real pages
    if inst.kernel == "paged_attention":
        meta = inst.meta
        table = np.asarray(meta["table"])
        lens = np.asarray(meta["lengths"])
        page_len, trash = int(meta["page_len"]), int(meta["trash_page"])
        for bi in range(table.shape[0]):
            n_live = -(-int(lens[bi]) // page_len)
            for j in range(n_live):
                if int(table[bi, j]) == trash:
                    out.append(
                        _finding(
                            "kernel-index-bounds",
                            inst,
                            f"slot {bi} column {j} holds valid tokens but maps "
                            f"to the trash page {trash} — those tokens are "
                            f"unreachable",
                        )
                    )
    return out


# ---------------------------------------------------------------------------
# rule 3: padding/divisibility lints (the trash-column idiom, checked)
# ---------------------------------------------------------------------------


def rule_unmasked_tail(inst: KernelInstantiation) -> List[Finding]:
    out: List[Finding] = []
    masked = inst.meta.get("masked_dims", {})
    for op in inst.operands:
        declared = set(masked.get(op.name, ()))
        for d, (extent, blk) in enumerate(zip(op.shape, op.block_shape)):
            if extent % blk and d not in declared:
                out.append(
                    _finding(
                        "kernel-unmasked-tail",
                        inst,
                        f"{op.name} dim {d}: block {blk} does not divide "
                        f"extent {extent} and no masked-tail declaration — "
                        f"the last block streams {blk - extent % blk} padding "
                        f"elements into the kernel unmasked",
                    )
                )

    # paged kernel: dead table columns must be trash (PR 6 idiom) — a stale
    # real page there is fetched, masked late, and billed as traffic
    if inst.kernel == "paged_attention":
        meta = inst.meta
        table = np.asarray(meta["table"])
        lens = np.asarray(meta["lengths"])
        page_len, trash = int(meta["page_len"]), int(meta["trash_page"])
        for bi in range(table.shape[0]):
            n_live = -(-int(lens[bi]) // page_len)
            for j in range(n_live, table.shape[1]):
                if int(table[bi, j]) != trash:
                    out.append(
                        _finding(
                            "kernel-unmasked-tail",
                            inst,
                            f"slot {bi} column {j} is past the last valid page "
                            f"({n_live}) but maps to page {int(table[bi, j])} "
                            f"instead of the trash page — stale mapping, "
                            f"unmasked tail traffic",
                        )
                    )
    return out


# ---------------------------------------------------------------------------
# rule 4: static byte-traffic model (+ exact runtime agreement)
# ---------------------------------------------------------------------------


def _traffic_paged(inst: KernelInstantiation) -> Tuple[Dict, List[Finding]]:
    meta = inst.meta
    table = np.asarray(meta["table"])
    lens = np.asarray(meta["lengths"])
    page_len, bps = int(meta["page_len"]), int(meta["bps"])
    g = inst.inputs[0].shape[1]

    def live(name: str, gidx: Tuple[int, ...]) -> bool:
        if name not in ("k_pool", "v_pool", "k_scale", "v_scale"):
            return True
        bi, _, si, ji = gidx
        return si * bps + ji < -(-int(lens[bi]) // page_len)

    tr = block_traffic(inst, live=live)

    # the static gather fraction: pages the table walk touches, per slot
    # (each group re-reads the same pages — divide the g multiplicity out)
    assert tr["fetches"]["k_pool"] % g == 0
    static_touched = tr["fetches"]["k_pool"] // g
    total = table.shape[0] * table.shape[1]
    saved_frac = 1.0 - static_touched / total

    findings: List[Finding] = []
    from repro.kernels.paged_attention.ops import gather_traffic_counts

    rt_touched, rt_total = gather_traffic_counts(table, lens, page_len)
    if (float(static_touched), float(total)) != (rt_touched, rt_total):
        findings.append(
            _finding(
                "kernel-traffic-model",
                inst,
                f"static page walk touches {static_touched}/{total} pages but "
                f"the runtime counter says {rt_touched:.0f}/{rt_total:.0f} — "
                f"one of the two models is wrong",
            )
        )
    if tr["fetches"]["v_pool"] != tr["fetches"]["k_pool"]:
        findings.append(
            _finding(
                "kernel-traffic-model",
                inst,
                f"k_pool and v_pool disagree on fetches "
                f"({tr['fetches']['k_pool']} vs {tr['fetches']['v_pool']}) — "
                f"their index maps must walk the same pages",
            )
        )

    record = {
        "bytes_read": int(sum(tr["read"].values())),
        "bytes_written": int(sum(tr["written"].values())),
        "fetches": {k: int(v) for k, v in sorted(tr["fetches"].items())},
        "gather_saved_frac": saved_frac,
    }
    if "k_scale" in tr["fetches"]:
        # quantized pool: page bytes actually streamed (packed codes +
        # per-page scales) vs the same page walk over a dense f32 pool —
        # the compressed-page traffic saving, as a gated exact number.
        kp = next(op for op in inst.inputs if op.name == "k_pool")
        itemsize = np.dtype(kp.dtype).itemsize
        q_bytes = sum(tr["read"][n]
                      for n in ("k_pool", "v_pool", "k_scale", "v_scale"))
        dense_bytes = (tr["read"]["k_pool"] + tr["read"]["v_pool"]) * (
            4.0 / itemsize)
        record["page_read_saved_frac"] = 1.0 - q_bytes / dense_bytes
    return record, findings


def _traffic_bitplane(inst: KernelInstantiation) -> Tuple[Dict, List[Finding]]:
    meta = inst.meta
    exp = np.asarray(meta["exp"], np.int64)
    bits, n_bits = int(meta["bits"]), int(meta["n_bits"])
    bm, bk = int(meta["block_m"]), int(meta["block_k"])
    prefetched = np.asarray(meta["min_plane"])
    findings: List[Finding] = []

    # independent numpy recompute of the skip table — the scalar operand
    # the kernel will actually prefetch must agree with it
    sentinel = -(1 << (n_bits - 1))
    m, k = exp.shape
    e4 = exp.reshape(m // bm, bm, k // bk, bk).swapaxes(1, 2)
    alive4 = e4 != sentinel
    max_e = np.max(np.where(alive4, e4, -128), axis=(2, 3))
    table = np.where(np.any(alive4, axis=(2, 3)), np.clip(-max_e, 0, bits), bits).astype(np.int64)
    if not np.array_equal(table, prefetched):
        findings.append(
            _finding(
                "kernel-traffic-model",
                inst,
                "scalar-prefetch min_plane table disagrees with the numpy "
                "recompute from the exponents — skip accounting is broken",
            )
        )

    # tile-granular plane traffic: what the kernel's @pl.when skip fetches
    fetched_tiles = int(np.sum(bits - table))
    total_tiles = int(bits * table.size)
    frac_tile = fetched_tiles / total_tiles

    import jax.numpy as jnp

    from repro.kernels.bitplane_matmul.ops import plane_traffic_counts

    rt_f, rt_t = plane_traffic_counts(
        jnp.asarray(exp, jnp.int8), n_bits=n_bits, block_m=bm, block_k=bk, bits=bits
    )
    if (float(fetched_tiles), float(total_tiles)) != (float(rt_f), float(rt_t)):
        findings.append(
            _finding(
                "kernel-traffic-model",
                inst,
                f"static tile count {fetched_tiles}/{total_tiles} != runtime "
                f"plane_traffic_counts {float(rt_f):.0f}/{float(rt_t):.0f}",
            )
        )

    # element-granular bits: the paper's per-activation needed-bits sum,
    # recomputed in numpy and cross-checked against core.access_model
    alive = exp != sentinel
    nb_elem = np.clip(bits + np.minimum(exp, 0), 0, bits)
    element_bits = int(np.sum(np.where(alive, nb_elem, 0)))
    dense_bits = int(np.sum(alive)) * bits

    from repro.core.access_model import needed_bits

    rt_bits = int(
        jnp.sum(needed_bits(jnp.asarray(exp, jnp.int8), n_bits=n_bits, weight_bits=bits))
    )
    if element_bits != rt_bits:
        findings.append(
            _finding(
                "kernel-traffic-model",
                inst,
                f"static element bits {element_bits} != access_model "
                f"needed_bits sum {rt_bits}",
            )
        )

    def refine(name: str, gidx: Tuple[int, ...], nominal: float) -> float:
        if name != "planes":
            return nominal
        mi, _, ki = gidx
        return nominal * (bits - int(table[mi, ki])) / bits

    tr = block_traffic(inst, refine_bytes=refine)
    record = {
        "bytes_read": int(sum(tr["read"].values())),
        "bytes_written": int(sum(tr["written"].values())),
        "fetches": {k: int(v) for k, v in sorted(tr["fetches"].items())},
        "plane_traffic_fraction_tile": frac_tile,
        "element_bits": element_bits,
        "dense_element_bits": dense_bits,
    }
    return record, findings


def _traffic_log2quant(inst: KernelInstantiation) -> Tuple[Dict, List[Finding]]:
    tr = block_traffic(inst)
    record = {
        "bytes_read": int(sum(tr["read"].values())),
        "bytes_written": int(sum(tr["written"].values())),
        "fetches": {k: int(v) for k, v in sorted(tr["fetches"].items())},
    }
    return record, []


_TRAFFIC_BY_FAMILY: Dict[str, Callable] = {
    "paged_attention": _traffic_paged,
    "bitplane_matmul": _traffic_bitplane,
    "log2quant": _traffic_log2quant,
}


def static_traffic(inst: KernelInstantiation) -> Tuple[Dict, List[Finding]]:
    """(record, agreement findings) for one instantiation."""
    return _TRAFFIC_BY_FAMILY[inst.kernel](inst)


# ---------------------------------------------------------------------------
# per-tick census: compose statics over the serve programs (PR 7 registry)
# ---------------------------------------------------------------------------


def per_tick_census(log=lambda msg: None) -> Dict[str, Dict]:
    """Every pallas_call a kernel-enabled serve tick dispatches, with scan
    trip counts multiplied through: ``{variant: {"kernels": {family:
    {"calls", "operand_bytes"}}, "tick_bytes_total"}}`` — calls are the
    exact per-tick launch bill, bytes the dense streaming upper bound the
    simulator prices (savings fractions come from the matching audit
    case)."""
    from repro.analysis.jaxpr_rules import make_program_jaxpr
    from repro.analysis.programs import Variant, audit_model, build_scheduler

    cfg, params = audit_model()
    out: Dict[str, Dict] = {}
    for quant in (False, True):
        variant = Variant("paged_kernel", quant, None)
        log(f"  tracing {variant.name}/tick for the kernel census...")
        sched = build_scheduler(variant, cfg=cfg, params=params)
        fn, args = sched.audit_programs()["tick"]
        sites = extract_pallas_calls(make_program_jaxpr(fn, args))
        kernels: Dict[str, Dict[str, int]] = {}
        for site in sites:
            family = KERNEL_FN_FAMILY.get(site.kernel_name, site.kernel_name)
            rec = kernels.setdefault(family, {"calls": 0, "operand_bytes": 0})
            rec["calls"] += site.multiplier
            rec["operand_bytes"] += site.multiplier * site.operand_bytes
        out[variant.name] = {
            "kernels": {k: kernels[k] for k in sorted(kernels)},
            "tick_bytes_total": int(sum(r["operand_bytes"] for r in kernels.values())),
        }
    return out


# ---------------------------------------------------------------------------
# baseline I/O + gates
# ---------------------------------------------------------------------------


def load_kernel_baseline(path: str = KERNEL_BASELINE_PATH) -> Dict:
    with open(path) as f:
        return json.load(f)


def save_kernel_baseline(records: Dict, path: str = KERNEL_BASELINE_PATH) -> None:
    doc = {
        "note": (
            "static kernel-audit budgets (VMEM + byte-traffic model) "
            "— regenerate with tools/audit.py --kernels "
            "--update-baselines"
        ),
        "kernels": {k: records["kernels"][k] for k in sorted(records["kernels"])},
        "per_tick": records.get("per_tick", {}),
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")


def _f(rule: str, key: str, detail: str) -> Finding:
    variant, _, program = key.partition("/")
    return Finding(rule=rule, variant=variant, program=program, detail=detail)


def check_kernel_budgets(
    fresh: Dict,
    baseline: Dict,
    *,
    vmem_rtol: float = VMEM_BYTES_RTOL,
    tick_rtol: float = TICK_BYTES_RTOL,
) -> List[Finding]:
    """Gate fresh records against the committed baseline.

    * VMEM: buffer counts exact, bytes at ``vmem_rtol`` (block shapes are
      deliberate choices; byte totals may shift with dtype swaps).
    * traffic: EXACT — fetch counts, byte totals, and the savings metrics
      are deterministic integer arithmetic; any drift is a model change
      that must be recommitted deliberately.
    * per-tick census: calls exact, bytes at ``tick_rtol`` (operand
      shapes ride the smoke-model config).
    * a case missing from either side is itself a finding.
    """
    out: List[Finding] = []
    fresh_k = fresh.get("kernels", {})
    base_k = baseline.get("kernels", {})
    for key in sorted(set(fresh_k) | set(base_k)):
        if key not in base_k:
            out.append(
                _f(
                    "kernel-vmem-budget",
                    key,
                    "instantiation has no committed budget — run "
                    "tools/audit.py --kernels --update-baselines",
                )
            )
            continue
        if key not in fresh_k:
            out.append(
                _f(
                    "kernel-vmem-budget",
                    key,
                    "instantiation in baseline but no longer "
                    "registered — run tools/audit.py --kernels "
                    "--update-baselines",
                )
            )
            continue
        got, want = fresh_k[key], base_k[key]
        if int(got["n_buffers"]) != int(want["n_buffers"]):
            out.append(
                _f(
                    "kernel-vmem-budget",
                    key,
                    f"n_buffers {got['n_buffers']} != budget " f"{want['n_buffers']} (exact gate)",
                )
            )
        gb, wb = float(got["vmem_bytes"]), float(want["vmem_bytes"])
        rel = abs(gb - wb) / max(abs(wb), 1.0)
        if rel > vmem_rtol:
            out.append(
                _f(
                    "kernel-vmem-budget",
                    key,
                    f"vmem_bytes {gb:.0f} vs budget {wb:.0f} " f"(rel {rel:.1%} > {vmem_rtol:.0%})",
                )
            )
        for field in sorted(set(got) | set(want)):
            if field in ("n_buffers", "vmem_bytes"):
                continue
            if got.get(field) != want.get(field):
                out.append(
                    _f(
                        "kernel-traffic-model",
                        key,
                        f"{field} {got.get(field)!r} != committed "
                        f"{want.get(field)!r} (exact gate: the "
                        f"static model is deterministic)",
                    )
                )

    fresh_t = fresh.get("per_tick", {})
    base_t = baseline.get("per_tick", {})
    for name in sorted(set(fresh_t) | set(base_t)):
        key = f"{name}/tick"
        if name not in base_t or name not in fresh_t:
            out.append(
                _f(
                    "kernel-traffic-model",
                    key,
                    "per-tick census missing on one side — run "
                    "tools/audit.py --kernels --update-baselines",
                )
            )
            continue
        got, want = fresh_t[name], base_t[name]
        gk, wk = got["kernels"], want["kernels"]
        for fam in sorted(set(gk) | set(wk)):
            g = int(gk.get(fam, {}).get("calls", 0))
            w = int(wk.get(fam, {}).get("calls", 0))
            if g != w:
                out.append(
                    _f(
                        "kernel-traffic-model",
                        key,
                        f"{fam} launches {g} != budget {w} per tick "
                        f"(exact gate: every launch is per-tick "
                        f"serving cost)",
                    )
                )
            gb = float(gk.get(fam, {}).get("operand_bytes", 0))
            wb = float(wk.get(fam, {}).get("operand_bytes", 0))
            rel = abs(gb - wb) / max(abs(wb), 1.0)
            if rel > tick_rtol:
                out.append(
                    _f(
                        "kernel-traffic-model",
                        key,
                        f"{fam} operand bytes {gb:.3e} vs budget "
                        f"{wb:.3e} (rel {rel:.1%} > "
                        f"{tick_rtol:.0%})",
                    )
                )
    return out


def check_bench_agreement(
    fresh: Dict, *, bench_path: str = PAGED_ATTN_BENCH_BASELINE
) -> List[Finding]:
    """The cross-file exact gate: the static model's ragged512 gather
    fraction must reproduce the MEASURED bench baseline bit-for-bit —
    this is the acceptance criterion that makes the paper's access-saving
    claim a compile-time fact."""
    key = "paged_attention/ragged512.s1"
    rec = fresh.get("kernels", {}).get(key)
    if rec is None:
        return [
            _f(
                "kernel-traffic-model",
                key,
                "ragged512.s1 not registered — the bench-agreement " "gate has nothing to check",
            )
        ]
    try:
        with open(bench_path) as f:
            measured = json.load(f)["rows"]["gather_saved_frac"]
    except (FileNotFoundError, KeyError):
        return [
            _f(
                "kernel-traffic-model",
                key,
                f"no measured gather_saved_frac in {bench_path} — "
                f"run benchmarks/kernel_bench.py first",
            )
        ]
    static = rec["gather_saved_frac"]
    if float(static) != float(measured):
        return [
            _f(
                "kernel-traffic-model",
                key,
                f"static gather_saved_frac {static!r} != measured "
                f"{measured!r} in {bench_path} (exact gate: static "
                f"and runtime must agree)",
            )
        ]
    return []


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def run_kernel_audit(
    baseline_path: str = KERNEL_BASELINE_PATH,
    *,
    update_baselines: bool = False,
    with_per_tick: bool = True,
    log=lambda msg: None,
) -> Tuple[List[Finding], Dict]:
    """The kernel rule family end to end: sweep every registered
    instantiation, run rules 1-4, gate (or rewrite) the baselines.
    Returns ``(findings, records)``; ``records`` is what the report embeds
    and ``save_kernel_baseline`` writes."""
    findings: List[Finding] = []
    records: Dict = {"kernels": {}, "per_tick": {}}

    for inst in registered_instantiations():
        log(f"  kernel-audit {inst.name} (grid {inst.grid}, " f"{inst.grid_points} points)...")
        findings += rule_index_bounds(inst)
        findings += rule_unmasked_tail(inst)
        fp = vmem_footprint(inst)
        if fp["vmem_bytes"] > VMEM_LIMIT_BYTES:
            findings.append(
                _finding(
                    "kernel-vmem-budget",
                    inst,
                    f"vmem_bytes {fp['vmem_bytes']} exceeds the "
                    f"{VMEM_LIMIT_BYTES} per-core cap — the kernel cannot be "
                    f"resident",
                )
            )
        traffic_rec, agree = static_traffic(inst)
        findings += agree
        records["kernels"][inst.name] = {
            "n_buffers": fp["n_buffers"],
            "vmem_bytes": fp["vmem_bytes"],
            **traffic_rec,
        }

    if with_per_tick:
        records["per_tick"] = per_tick_census(log=log)

    if update_baselines:
        save_kernel_baseline(records, baseline_path)
        log(f"wrote {len(records['kernels'])} kernel budgets -> " f"{baseline_path}")
    else:
        baseline = load_kernel_baseline(baseline_path)
        if not with_per_tick:
            # partial run: gate only what was computed, as run_audit does
            # for budget-skipping device-limited runs
            baseline = {**baseline, "per_tick": {}}
        findings += check_kernel_budgets(records, baseline)
        findings += check_bench_agreement(records)
    return findings, records


# ---------------------------------------------------------------------------
# the simulator-facing cost table
# ---------------------------------------------------------------------------


def kernel_cost_table(records: Dict) -> Dict[str, Dict]:
    """Flatten per-tick records into the shape
    ``simulator.config.load_kernel_cost_table`` returns: per variant, the
    per-tick launch counts and dense byte bill per kernel family."""
    out: Dict[str, Dict] = {}
    for name, rec in records.get("per_tick", {}).items():
        out[name] = {
            "tick_bytes_total": int(rec["tick_bytes_total"]),
            "kernels": {
                k: {"calls": int(v["calls"]), "operand_bytes": int(v["operand_bytes"])}
                for k, v in rec["kernels"].items()
            },
        }
    return out
