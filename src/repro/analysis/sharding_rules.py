"""Sharded-rearrange hazard detector (rule family 2).

PR 3 hit two real jax-0.4.37 CPU-SPMD miscompiles on partially-replicated
meshes; the one this rule encodes: **split / concatenate / reshape along an
axis that carries the ``model`` mesh axis returns garbage**.  The codebase's
discipline (DESIGN.md §Sharded serving) is to pin the rearranged axis
REPLICATED immediately before the rearrangement (``models.sharding.shard(...,
force=True)`` / ``replicate()``) — rope inputs, the mamba conv window, the
SSD channel split all do this.  Until now that discipline lived in comments
and runtime bit-equality tests; this rule machine-checks it at trace time.

Mechanics: walk the traced program's eqns tracking, per jaxpr variable, the
``PartitionSpec`` most recently *pinned* on it — seeded from explicit
``sharding_constraint`` eqns and from the jit boundary's ``in_shardings``
— propagated only through spec-preserving ops (convert / copy).  A
``concatenate`` / ``slice`` / ``split`` / ``reshape`` whose operand carries
the model axis on a dimension the op rearranges, with no replication pin in
between, is exactly the documented hazard and is flagged.  Tensors with no
adjacent pin are *untracked* (GSPMD may or may not shard them — the rule
stays quiet rather than guessing), which is also why the pin discipline
matters: a pin is both the fix and the auditor's evidence.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from jax import core

from repro.analysis.jaxpr_rules import _as_jaxpr, sub_jaxprs
from repro.analysis.report import Finding

# ops that rearrange data along axes (the miscompile surface)
REARRANGE_PRIMS = ("concatenate", "slice", "split", "reshape")
# ops a pinned spec survives unchanged (same shape, same layout)
_TRANSPARENT_PRIMS = ("convert_element_type", "copy", "stop_gradient", "sharding_constraint")


def _lookup(pinned: Dict[object, Tuple[tuple, str]], v):
    """pinned.get guarded against ``core.Literal`` invars (unhashable)."""
    return pinned.get(v) if isinstance(v, core.Var) else None


def _spec_of(sharding) -> Optional[tuple]:
    """PartitionSpec entries of a NamedSharding (None for GSPMD/opaque)."""
    spec = getattr(sharding, "spec", None)
    if spec is None:
        return None
    return tuple(spec)


def _model_dims(spec: tuple, model_axis: str) -> List[int]:
    out = []
    for i, entry in enumerate(spec):
        names = entry if isinstance(entry, tuple) else (entry,)
        if model_axis in [n for n in names if n is not None]:
            out.append(i)
    return out


def _rearranged_dims(eqn) -> List[int]:
    """Dims an eqn rearranges: concat dim, sliced dims, reshaped dims."""
    name = eqn.primitive.name
    if name == "concatenate":
        return [int(eqn.params["dimension"])]
    if name == "split":
        return [int(eqn.params["axis"])]
    if name == "slice":
        aval = getattr(eqn.invars[0], "aval", None)
        if aval is None:
            return []
        starts = eqn.params.get("start_indices", ())
        limits = eqn.params.get("limit_indices", ())
        return [
            i
            for i, (s, l, n) in enumerate(zip(starts, limits, aval.shape))
            if not (int(s) == 0 and int(l) == int(n))
        ]
    if name == "reshape":
        aval = getattr(eqn.invars[0], "aval", None)
        out = getattr(eqn.outvars[0], "aval", None)
        if aval is None or out is None:
            return []
        old, new = tuple(aval.shape), tuple(out.shape)
        # dims in the preserved common prefix/suffix are untouched; the
        # middle (merged/split) region is the rearranged part
        pre = 0
        while pre < len(old) and pre < len(new) and old[pre] == new[pre]:
            pre += 1
        suf = 0
        while suf < len(old) - pre and suf < len(new) - pre and old[-1 - suf] == new[-1 - suf]:
            suf += 1
        return list(range(pre, len(old) - suf))
    return []


def rule_sharded_rearrange(
    jaxpr, variant: str, program: str, *, model_axis: str = "model"
) -> List[Finding]:
    """Flag rearrange ops whose operand is pinned ``model``-sharded on a
    rearranged dim (see module docstring).  Works on ``Jaxpr`` /
    ``ClosedJaxpr``; recurses into every sub-jaxpr, seeding inner tracking
    from pjit ``in_shardings`` where present."""
    findings: List[Finding] = []

    def walk(j: core.Jaxpr, seed: Dict[object, Tuple[tuple, str]]) -> None:
        # var -> (spec entries, where the pin came from)
        pinned: Dict[object, Tuple[tuple, str]] = dict(seed)
        for eqn in j.eqns:
            name = eqn.primitive.name
            if name == "sharding_constraint":
                spec = _spec_of(eqn.params.get("sharding"))
                if spec is not None:
                    pinned[eqn.outvars[0]] = (spec, "sharding_constraint")
                continue
            if name in REARRANGE_PRIMS:
                dims = _rearranged_dims(eqn)
                for v in eqn.invars:
                    entry = _lookup(pinned, v)
                    if entry is None:
                        continue
                    spec, src = entry
                    hot = sorted(set(dims) & set(_model_dims(spec, model_axis)))
                    if hot:
                        aval = getattr(v, "aval", None)
                        findings.append(
                            Finding(
                                rule="sharded-rearrange",
                                variant=variant,
                                program=program,
                                detail=(
                                    f"{name} rearranges dim(s) {hot} of a "
                                    f"tensor pinned {spec} (via {src}, "
                                    f"shape {tuple(aval.shape) if aval is not None else '?'}"
                                    f") — {model_axis}-sharded axis must be "
                                    f"pinned replicated before "
                                    f"split/concat/reshape (jax-0.4.37 "
                                    f"CPU-SPMD miscompile, DESIGN.md "
                                    f"§Sharded serving)"
                                ),
                            )
                        )
                # rearranged output loses the pin
            elif name in _TRANSPARENT_PRIMS:
                entry = _lookup(pinned, eqn.invars[0]) if eqn.invars else None
                if entry is not None and eqn.outvars:
                    pinned[eqn.outvars[0]] = entry

            # recurse with seeds mapped through the call boundary
            for sub in sub_jaxprs(eqn):
                inner_seed: Dict[object, Tuple[tuple, str]] = {}
                # positional: pjit/scan pass eqn.invars -> sub.invars
                # (best-effort — lengths differ for scan carries; zip stops)
                for outer_v, inner_v in zip(eqn.invars, sub.invars):
                    entry = _lookup(pinned, outer_v)
                    if entry is not None:
                        outer_aval = getattr(outer_v, "aval", None)
                        inner_aval = getattr(inner_v, "aval", None)
                        if (
                            outer_aval is not None
                            and inner_aval is not None
                            and tuple(getattr(outer_aval, "shape", ()))
                            == tuple(getattr(inner_aval, "shape", ()))
                        ):
                            inner_seed[inner_v] = entry
                if name == "pjit":
                    in_sh = eqn.params.get("in_shardings", ())
                    for sh, inner_v in zip(in_sh, sub.invars):
                        spec = _spec_of(sh)
                        if spec is not None:
                            inner_seed.setdefault(inner_v, (spec, "in_shardings"))
                walk(sub, inner_seed)

    walk(_as_jaxpr(jaxpr), {})
    return findings
