"""Shared HLO-text parsing: computations, loop-scaled walks, analyses.

One home for everything that reads ``compiled.as_text()`` — the roofline
analysis (:func:`analyze_hlo`, historically ``launch/hlo_analysis.py``,
which now re-exports from here), the debug CLIs (``tools/top_collectives``
/ ``tools/top_traffic``), and the program auditor's HLO budget gate
(``analysis/budgets.py``).

Why text parsing at all: XLA's ``compiled.cost_analysis()`` counts a
``while`` body **once**, but our models are ``lax.scan``-over-layers —
everything interesting sits inside a while loop with a static trip count.
Everything here re-derives its numbers from the HLO text with loop
multipliers:

* **FLOPs** — from ``dot``/``convolution`` ops: 2 * prod(result_dims) *
  contracted_size (operand types resolved through a per-computation symbol
  table; dots inside fusions included).
* **Collective bytes / counts** — result bytes and loop-scaled instruction
  counts of all-reduce / all-gather / reduce-scatter / all-to-all /
  collective-permute, per kind (async pairs counted at the ``-done``).
* **HBM traffic estimate** — 2x the result bytes of top-level (non-fused)
  instructions: fusion boundaries are materialization points, and each
  materialized buffer is written once and read ~once downstream.  Counting
  results only (not operands) avoids double-counting shared inputs.

Trip counts come from the ``known_trip_count`` backend_config XLA attaches
to while ops (fallback: the comparison constant in the loop condition).
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1,
    "s4": 0.5,
    "u4": 0.5,
    "s8": 1,
    "u8": 1,
    "s16": 2,
    "u16": 2,
    "s32": 4,
    "u32": 4,
    "s64": 8,
    "u64": 8,
    "f16": 2,
    "bf16": 2,
    "f32": 4,
    "f64": 8,
    "c64": 8,
    "c128": 16,
    "f8e4m3fn": 1,
    "f8e5m2": 1,
    "f4e2m1fn": 0.5,
    "token": 0,
    "opaque": 0,
}

SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
HEADER_RE = re.compile(r"^\s*(ENTRY\s+)?%([\w\.\-]+)\s*\(")
OP_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
OPERAND_RE = re.compile(r"%([\w\.\-]+)")
TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
CONST_RE = re.compile(r"constant\((\d+)\)")
COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")

SKIP_TRAFFIC = {
    "parameter",
    "constant",
    "tuple",
    "get-tuple-element",
    "bitcast",
    "copy-start",
    "copy-done",
    "after-all",
    "partition-id",
    "replica-id",
    "iota",
}


def type_bytes(type_str: str) -> float:
    """Total byte size of every array shape named in an HLO type string."""
    total = 0.0
    for dt, dims in SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1.0
        for d in [int(x) for x in dims.split(",") if x]:
            n *= d
        total += n * DTYPE_BYTES[dt]
    return total


def first_array_dims(type_str: str) -> List[int]:
    m = SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(x) for x in m.group(2).split(",") if x]


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    line: str


@dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: List[Instr] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)


def split_computations(hlo: str) -> Dict[str, Computation]:
    """Parse HLO text into named computations (``__entry__`` aliases the
    ENTRY computation)."""
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        stripped = raw.strip()
        if cur is None:
            m = HEADER_RE.match(raw)
            if m and raw.rstrip().endswith("{"):
                cur = Computation(name=m.group(2), is_entry=bool(m.group(1)))
                comps[cur.name] = cur
                if cur.is_entry:
                    comps["__entry__"] = cur
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        om = OP_RE.match(stripped)
        if om:
            ins = Instr(
                name=om.group(1), type_str=om.group(2).strip(), op=om.group(3), line=stripped
            )
            cur.instrs.append(ins)
            cur.symbols[ins.name] = ins.type_str
    return comps


def operand_names(line: str) -> List[str]:
    try:
        start = line.index("(")
    except ValueError:
        return []
    # stop at attribute section (", key=") to avoid called-computation refs
    body = line[start:]
    cut = re.search(r"\),\s*\w+=", body)
    if cut:
        body = body[: cut.start() + 1]
    return OPERAND_RE.findall(body)


def called_computations(line: str) -> List[str]:
    out = []
    for key in ("body", "condition", "calls", "to_apply", "branch_computations"):
        m = re.search(key + r"=\{?([^,}\s]+(?:,\s*[^,}\s]+)*)\}?", line)
        if m:
            for c in m.group(1).split(","):
                c = c.strip().lstrip("%")
                if c:
                    out.append(c)
    return out


def trip_count(ins: Instr, comps: Dict[str, Computation]) -> Optional[int]:
    """Static trip count of a ``while`` instruction, or None if unknown."""
    m = TRIP_RE.search(ins.line)
    if m:
        return int(m.group(1))
    cm = re.search(r"condition=%?([\w\.\-]+)", ins.line)
    if cm and cm.group(1) in comps:
        consts = [int(c) for i in comps[cm.group(1)].instrs for c in CONST_RE.findall(i.line)]
        consts = [c for c in consts if c > 0]
        if consts:
            return max(consts)
    return None


def collective_base(op: str) -> Optional[str]:
    """Collective kind for an op name (``all-reduce-done`` ->
    ``all-reduce``), or None for non-collectives."""
    base = op
    for suf in ("-start", "-done"):
        if base.endswith(suf):
            base = base[: -len(suf)]
    return base if base in COLLECTIVES else None


def scaled_instructions(
    comps: Dict[str, Computation],
    entry: Optional[str] = None,
) -> Iterator[Tuple[Instr, int]]:
    """Yield ``(instr, multiplier)`` for every *top-level* instruction
    reachable from the entry, loop-scaled: instructions inside a ``while``
    body carry the loop's static trip count (nested loops multiply),
    ``call`` / ``conditional`` / ``async-start`` bodies are walked at the
    caller's multiplier.  Fusion interiors are NOT entered — a fusion is
    one materialization point (the basis of both debug CLIs and the
    collective census)."""
    if entry is None:
        ec = comps.get("__entry__")
        if ec is None:
            raise ValueError("no ENTRY computation found")
        entry = ec.name

    def walk(name: str, mult: int) -> Iterator[Tuple[Instr, int]]:
        comp = comps.get(name)
        if comp is None:
            return
        for ins in comp.instrs:
            if ins.op == "while":
                m = TRIP_RE.search(ins.line)
                trips = int(m.group(1)) if m else 1
                bm = re.search(r"body=%?([\w\.\-]+)", ins.line)
                if bm:
                    yield from walk(bm.group(1), mult * trips)
                continue
            if ins.op in ("call", "conditional", "async-start"):
                for key in ("calls", "to_apply", "branch_computations"):
                    mm = re.search(key + r"=\{?([^,}\s]+)", ins.line)
                    if mm:
                        yield from walk(mm.group(1).strip().lstrip("%"), mult)
                continue
            yield ins, mult

    yield from walk(entry, 1)


def collective_census(hlo: str) -> Dict[str, Dict[str, float]]:
    """Loop-scaled collective counts AND bytes per kind.

    ``{"all-reduce": {"count": 12, "bytes": 1.5e6}, ...}`` — the count is
    the number of collective *launches* the program performs end to end
    (while bodies multiplied by their trip counts), the quantity the
    program-audit budget gate pins exactly; bytes are the loop-scaled
    result bytes (async pairs counted once, at the ``-done``)."""
    out: Dict[str, Dict[str, float]] = {}
    comps = split_computations(hlo)
    for ins, mult in scaled_instructions(comps):
        base = collective_base(ins.op)
        if base is None or ins.op.endswith("-start"):
            continue
        d = out.setdefault(base, {"count": 0, "bytes": 0.0})
        d["count"] += mult
        d["bytes"] += type_bytes(ins.type_str) * mult
    return out


def dot_flops(ins: Instr, symbols: Dict[str, str]) -> float:
    out_elems = 1.0
    for d in first_array_dims(ins.type_str):
        out_elems *= d
    opnds = operand_names(ins.line)
    if not opnds:
        return 0.0
    lhs_type = symbols.get(opnds[0], "")
    lhs_dims = first_array_dims(lhs_type)
    contract = 1.0
    if ins.op == "dot":
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
        if m and m.group(1):
            for ci in m.group(1).split(","):
                ci = int(ci)
                if ci < len(lhs_dims):
                    contract *= lhs_dims[ci]
    elif ins.op == "convolution":
        # contracted size = kernel spatial x input features (approx: rhs
        # elements / output features)
        rhs_dims = first_array_dims(symbols.get(opnds[1], "")) if len(opnds) > 1 else []
        out_dims = first_array_dims(ins.type_str)
        if rhs_dims and out_dims:
            contract = max(
                1.0, float(int(__import__("numpy").prod(rhs_dims))) / max(out_dims[-1], 1)
            )
    return 2.0 * out_elems * contract


@dataclass
class Totals:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    unknown_trip_loops: int = 0

    def scaled(self, k: float) -> "Totals":
        t = Totals(
            flops=self.flops * k,
            traffic_bytes=self.traffic_bytes * k,
            unknown_trip_loops=self.unknown_trip_loops,
        )
        for kk, v in self.collective_bytes.items():
            t.collective_bytes[kk] = v * k
        return t

    def add(self, o: "Totals"):
        self.flops += o.flops
        self.traffic_bytes += o.traffic_bytes
        self.unknown_trip_loops += o.unknown_trip_loops
        for k, v in o.collective_bytes.items():
            self.collective_bytes[k] += v


def _dus_update_bytes(comps, called_names) -> Optional[float]:
    """If a fused computation performs an in-place buffer update (contains a
    dynamic-update-slice whose buffer spans the fusion result, possibly
    behind converts), return the update-operand bytes; else None."""
    for c in called_names:
        comp = comps.get(c)
        if comp is None or not comp.instrs:
            continue
        for ins in comp.instrs:
            if ins.op == "dynamic-update-slice":
                ops_ = operand_names(ins.line)
                if len(ops_) > 1:
                    ub = type_bytes(comp.symbols.get(ops_[1], ""))
                    if ub:
                        return ub
    return None


def analyze_hlo(hlo: str) -> Dict[str, float]:
    """Loop-aware roofline inputs (flops / traffic / collective bytes) from
    optimized-HLO text — see the module docstring for the model."""
    comps = split_computations(hlo)
    entry = comps.get("__entry__")
    if entry is None:
        raise ValueError("no ENTRY computation found")
    memo: Dict[Tuple[str, bool], Totals] = {}

    def walk(name: str, top_level: bool) -> Totals:
        key = (name, top_level)
        if key in memo:
            return memo[key]
        memo[key] = Totals()  # cycle guard
        comp = comps.get(name)
        if comp is None:
            return memo[key]
        t = Totals()
        for ins in comp.instrs:
            rb = type_bytes(ins.type_str)
            if ins.op == "while":
                trips = trip_count(ins, comps)
                if trips is None:
                    trips = 1
                    t.unknown_trip_loops += 1
                bm = re.search(r"body=%?([\w\.\-]+)", ins.line)
                if bm:
                    t.add(walk(bm.group(1), True).scaled(trips))
                continue
            if ins.op in ("call", "conditional", "async-start"):
                for c in called_computations(ins.line):
                    t.add(walk(c, True))
                continue
            if ins.op == "fusion":
                inner = Totals()
                called = called_computations(ins.line)
                for c in called:
                    inner.add(walk(c, False))
                t.flops += inner.flops
                for k, v in inner.collective_bytes.items():
                    t.collective_bytes[k] += v
                if top_level:
                    # in-place update fusions (root = dynamic-update-slice)
                    # write only the update slice, not the whole buffer
                    ub = _dus_update_bytes(comps, called)
                    t.traffic_bytes += 2.0 * (ub if ub is not None else rb)
                continue
            if ins.op == "dynamic-update-slice":
                if top_level:
                    ops_ = operand_names(ins.line)
                    ub = type_bytes(comp.symbols.get(ops_[1], "")) if len(ops_) > 1 else rb
                    t.traffic_bytes += 2.0 * ub
                continue

            base = collective_base(ins.op)
            if base is not None:
                if not ins.op.endswith("-start"):
                    t.collective_bytes[base] += rb
                    if top_level:
                        t.traffic_bytes += 2.0 * rb
                continue
            if ins.op in ("dot", "convolution"):
                t.flops += dot_flops(ins, comp.symbols)
            if ins.op in ("reduce", "reduce-window"):
                # flops ~ input elements (one accumulate op per element)
                for o in operand_names(ins.line)[:1]:
                    ob = type_bytes(comp.symbols.get(o, ""))
                    t.flops += ob / 4.0
            if top_level and ins.op not in SKIP_TRAFFIC:
                t.traffic_bytes += 2.0 * rb
        memo[key] = t
        return t

    total = walk(entry.name, True)
    # entry parameters (weights/caches) are materialized buffers no op
    # produces — count one read of each (loop xs slicing reads each element
    # once per step; FSDP re-gathers already appear as all-gather results)
    param_bytes = sum(type_bytes(i.type_str) for i in entry.instrs if i.op == "parameter")
    return {
        "flops": total.flops,
        "traffic_bytes": total.traffic_bytes + param_bytes,
        "param_bytes": param_bytes,
        "collective_bytes": dict(total.collective_bytes),
        "collective_bytes_total": float(sum(total.collective_bytes.values())),
        "unknown_trip_loops": total.unknown_trip_loops,
    }
