"""The audit variant matrix: every serve-program configuration we ship.

One :class:`Variant` = (serving mode, numerics, mesh).  The four modes
cover the scheduler's compiled-program surface end to end:

* ``bucketed``  — monolithic bucketed prefill + fused decode tick (PR 1/2)
* ``chunked``   — chunked prefill interleaved with decode (PR 4)
* ``paged``     — paged KV pool + radix prefix cache (PR 5)
* ``paged_kernel`` — ditto with the Pallas paged-attention kernel (PR 6)

crossed with float vs quant (the shift-add integer path) and single-device
vs a 2x2 data×model mesh.  Every variant builds a REAL ``ServeScheduler``
on the smollm smoke config — the auditor then traces/lowers the exact
programs the serve loop would dispatch (``ServeScheduler.audit_programs``)
without executing any of them.

Sizing notes: ``AUDIT_N_PAGES = 34`` is deliberately a value no other
dimension of the smoke model takes, so :func:`jaxpr_rules.
rule_no_dense_pool_gather` can identify the pool's page axis unambiguously
(and 34 is even, so the pages-on-data sharding engages on a 2-way data
axis).  The model is tiny; building all 16 variants takes seconds.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

AUDIT_ARCH = "smollm-135m"
AUDIT_BUCKETS: Tuple[int, ...] = (8, 16)
AUDIT_MAX_LEN = 32
AUDIT_SLOTS = 4
AUDIT_TICK_STEPS = 2
AUDIT_CHUNK_LEN = 8
AUDIT_PAGE_LEN = 4
AUDIT_N_PAGES = 34  # distinctive page-axis size — see module docstring

MODES = ("bucketed", "chunked", "paged", "paged_kernel")


@dataclasses.dataclass(frozen=True)
class Variant:
    mode: str  # one of MODES
    quant: bool  # shift-add integer path
    mesh_spec: Optional[str]  # None (single device) or "DxM" e.g. "2x2"

    @property
    def name(self) -> str:
        return (
            self.mode
            + ("-quant" if self.quant else "")
            + (f"@{self.mesh_spec}" if self.mesh_spec else "")
        )

    @property
    def paged(self) -> bool:
        return self.mode in ("paged", "paged_kernel")

    @property
    def attn_kernel(self) -> bool:
        return self.mode == "paged_kernel"

    @property
    def n_devices(self) -> int:
        if not self.mesh_spec:
            return 1
        d, m = self.mesh_spec.split("x")
        return int(d) * int(m)

    def serve_config(self):
        """This variant's scheduler knobs as ONE ``ServeConfig`` — the
        single source of truth the auditor builds from (the hand-kept
        per-mode kwarg dicts this replaced could silently drift from
        what production construction validates)."""
        from repro.serving.config import ServeConfig

        kw = dict(
            max_slots=AUDIT_SLOTS,
            max_len=AUDIT_MAX_LEN,
            buckets=AUDIT_BUCKETS,
            quant=self.quant,
            tick_steps=AUDIT_TICK_STEPS,
            mesh_spec=self.mesh_spec,
        )
        if self.mode == "chunked":
            kw.update(chunked="always", chunk_len=AUDIT_CHUNK_LEN)
        elif self.paged:
            kw.update(
                paged=True,
                page_len=AUDIT_PAGE_LEN,
                n_pages=AUDIT_N_PAGES,
                prefix_cache=True,
                chunked="auto",
                chunk_len=AUDIT_CHUNK_LEN,
                attn_kernel=self.attn_kernel,
            )
        return ServeConfig(**kw)


def variant_matrix(mesh_specs: Sequence[Optional[str]] = (None, "2x2")) -> List[Variant]:
    """The full registry, single-device variants first (cheapest to trace)."""
    return [
        Variant(mode, quant, ms) for ms in mesh_specs for mode in MODES for quant in (False, True)
    ]


def audit_model():
    """(cfg, float params) for the audit scheduler — smoke smollm in f32
    (bf16 smoke numerics are irrelevant to STRUCTURAL rules, and f32 keeps
    the f64-upcast rule's negative space clean)."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke
    from repro.models.model import init_params

    cfg = get_smoke(AUDIT_ARCH).replace(dtype=jnp.float32)
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


def build_scheduler(variant: Variant, cfg=None, params=None):
    """A live ``ServeScheduler`` configured exactly as the variant says.

    Pass ``cfg``/``params`` to reuse one smoke model across the matrix
    (params are re-quantized per quant variant, never mutated)."""
    from repro.models.quantize import quantize_model_params
    from repro.serving.scheduler import ServeScheduler

    if cfg is None or params is None:
        cfg, params = audit_model()
    if variant.quant:
        params = quantize_model_params(cfg, params)
    # the config carries the mesh BY SPEC; the scheduler resolves it to
    # live devices in this process (ServeConfig.make_mesh)
    return ServeScheduler(cfg, params, variant.serve_config())


# ---------------------------------------------------------------------------
# trace / lower — never execute
# ---------------------------------------------------------------------------


def program_lowered(fn, args):
    """``fn.lower(*args)`` under the program's own mesh context (sharded
    wrappers expose ``lower``; plain jits have jax's)."""
    lower = getattr(fn, "lower", None)
    if lower is None:
        raise TypeError(f"{fn!r} has no .lower — not a jitted program")
    return lower(*args)


def program_hlo(fn, args) -> str:
    """Optimized HLO text of the compiled program (compile != execute:
    nothing runs, XLA just emits the module the serve loop would launch)."""
    return program_lowered(fn, args).compile().as_text()
