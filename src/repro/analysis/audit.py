"""The audit driver: variants x programs x rule families -> AuditReport.

``run_audit`` is what ``tools/audit.py`` (and the CI ``audit`` job) call.
It never executes a serve program — rule families 1-2 run on jaxprs
(trace only), family 3 on optimized HLO (compile only), and family 4
(the recompile census) is the one deliberate exception: it drives a tiny
scripted sweep because caching behavior is not a property of any single
traced program (see ``analysis/recompile.py``).  Family 5 (the static
Pallas kernel verifier, ``analysis/kernel_rules.py``) covers the one
boundary families 1-4 cannot see through: ``pallas_call``.

Rule applicability is part of the contract, not an optimization:

* ``no-host-callback`` / ``no-double-precision`` — every program, every
  variant (nothing in the serve path may sync the host or touch f64).
* ``no-integer-upcast`` — quant variants only (the rule pins the
  shift-add integer path; float programs have no integer path to widen).
* ``no-dense-pool-gather`` — kernel variants, ``tick`` only.  The Pallas
  kernel is a *decode* kernel: chunk ingestion (``chunk``/``mixed``)
  reads the pool densely BY DESIGN for S>1 slabs, so flagging those
  would just force a permanent waiver (DESIGN.md §Program audit).
* ``sharded-rearrange`` — mesh variants, every program.
* HLO budgets — mesh variants, per-tick programs (``tick``/``mixed``):
  those run every serving tick, so their collective census IS the
  steady-state interconnect bill.
* kernel rules — device-count independent (``--kernels``): they sweep the
  registered kernel instantiations, not the variant matrix.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

from repro.analysis import budgets as budgets_mod
from repro.analysis import jaxpr_rules, kernel_rules, sharding_rules
from repro.analysis.programs import (
    AUDIT_N_PAGES,
    Variant,
    audit_model,
    build_scheduler,
    program_hlo,
    variant_matrix,
)
from repro.analysis.report import AuditReport, Finding

RULES = (
    "no-host-callback",
    "no-double-precision",
    "no-integer-upcast",
    "no-dense-pool-gather",
    "sharded-rearrange",
    "hlo-budget",
    "recompile-census",
)

# every rule id any family can emit — the waiver loader validates against
# this so a typo'd waiver fails loudly instead of sitting inert
ALL_RULES = RULES + kernel_rules.KERNEL_RULES

BUDGET_PROGRAMS = ("tick", "mixed")


def audit_variant(
    variant: Variant,
    report: AuditReport,
    *,
    cfg=None,
    params=None,
    with_budgets: bool = True,
    log=lambda msg: None,
) -> None:
    """Trace/lower every program of one variant and run the static rules,
    appending findings and budget records to ``report`` in place."""
    sched = build_scheduler(variant, cfg=cfg, params=params)
    programs = sched.audit_programs()
    name = variant.name
    for prog, (fn, args) in programs.items():
        jaxpr = jaxpr_rules.make_program_jaxpr(fn, args)
        fnd: List[Finding] = []
        fnd += jaxpr_rules.rule_no_host_callback(jaxpr, name, prog)
        fnd += jaxpr_rules.rule_no_double_precision(jaxpr, name, prog)
        if variant.quant:
            fnd += jaxpr_rules.rule_no_integer_upcast(jaxpr, name, prog)
        if variant.attn_kernel and prog == "tick":
            fnd += jaxpr_rules.rule_no_dense_pool_gather(jaxpr, name, prog, n_pages=AUDIT_N_PAGES)
        if variant.mesh_spec:
            fnd += sharding_rules.rule_sharded_rearrange(jaxpr, name, prog)
        report.findings.extend(fnd)
        report.programs_audited += 1
        if with_budgets and variant.mesh_spec and prog in BUDGET_PROGRAMS:
            key = f"{name}/{prog}"
            log(f"  lowering {key} for budgets...")
            report.budgets[key] = budgets_mod.program_budget(program_hlo(fn, args))
    report.variants.append(name)


def run_audit(
    mesh_specs: Optional[Sequence[Optional[str]]] = None,
    *,
    baseline_path: str = budgets_mod.BASELINE_PATH,
    kernel_baseline_path: str = kernel_rules.KERNEL_BASELINE_PATH,
    update_baselines: bool = False,
    with_budgets: bool = True,
    with_recompile: bool = True,
    with_kernels: bool = False,
    log=lambda msg: None,
) -> AuditReport:
    """Audit every variant the device count allows.

    Mesh variants needing more devices than are visible are skipped with a
    log line (the CI ``audit`` job forces 8 host devices so nothing skips
    there); ``update_baselines=True`` rewrites the committed budget file
    instead of gating against it.
    """
    import jax

    report = AuditReport(rules_run=list(RULES))
    n_dev = len(jax.devices())
    if mesh_specs is None:
        mesh_specs = (None, "2x2")
    cfg, params = audit_model()
    skipped = 0
    for variant in variant_matrix(mesh_specs):
        if variant.n_devices > n_dev:
            log(
                f"SKIP {variant.name}: needs {variant.n_devices} devices, "
                f"have {n_dev} (use --host-devices)"
            )
            skipped += 1
            continue
        log(f"auditing {variant.name}...")
        audit_variant(variant, report, cfg=cfg, params=params, with_budgets=with_budgets, log=log)

    if with_budgets and report.budgets:
        if update_baselines:
            budgets_mod.save_baseline(report.budgets, baseline_path)
            log(f"wrote {len(report.budgets)} budgets -> {baseline_path}")
        else:
            baseline = budgets_mod.load_baseline(baseline_path)
            if skipped:
                # partial run (too few devices): gate only what was audited
                # — do not flag baselines this run could not recompute
                baseline = {k: v for k, v in baseline.items() if k in report.budgets}
            report.findings.extend(budgets_mod.check_budgets(report.budgets, baseline))

    if with_recompile:
        log("recompile audit (scripted sweep)...")
        from repro.analysis.recompile import run_recompile_audit

        fnd, census = run_recompile_audit()
        report.findings.extend(fnd)
        report.census = {k: int(v) for k, v in census.items()}

    if with_kernels:
        log("kernel audit (static pallas verifier)...")
        report.rules_run.extend(kernel_rules.KERNEL_RULES)
        fnd, records = kernel_rules.run_kernel_audit(
            kernel_baseline_path, update_baselines=update_baselines, log=log
        )
        report.findings.extend(fnd)
        report.kernels = records

    return report
