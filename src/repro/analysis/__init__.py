"""Static program analysis: the serve-program auditor + shared HLO parsing.

Sub-modules:

* :mod:`repro.analysis.hlo` — HLO-text parsing shared by the roofline
  (``launch.hlo_analysis`` re-exports it), the debug CLIs, and the budget
  gate.
* :mod:`repro.analysis.jaxpr_rules` / :mod:`~.sharding_rules` — trace-time
  rules over serve-program jaxprs.
* :mod:`repro.analysis.programs` — the audited variant matrix.
* :mod:`repro.analysis.budgets` — per-program collective/traffic budgets.
* :mod:`repro.analysis.recompile` — the compiled-program census sweep.
* :mod:`repro.analysis.audit` — the driver (``tools/audit.py`` front-end).
* :mod:`repro.analysis.report` — findings, waivers, the JSON report.
"""

from repro.analysis.report import AuditReport, Finding, Waiver  # noqa: F401
