"""Recompile audit (rule family 4): the compiled-program census, enforced.

The other three rule families are strictly static (trace/lower, nothing
runs).  This one cannot be: whether the scheduler RE-compiles under real
traffic is a property of its caching behavior, not of any single traced
program.  So this family drives a scripted traffic sweep through a live
``ServeScheduler`` on the tiny smoke model and asserts the census:

* ``prefill`` compiles once per *bucket used*, never per prompt length;
* ``tick`` / ``write`` / ``chunk`` / ``mixed`` compile exactly once —
  chunked ingestion is ONE slab shape regardless of prompt length;
* replaying the same traffic shapes leaves every count unchanged
  (zero warm-path recompiles);
* the generate-program LRU keys on the mesh fingerprint — an unsharded
  and a sharded build of the SAME configuration must occupy two distinct
  entries (a collision silently reuses the other variant's program).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.analysis.report import Finding

VARIANT = "recompile-sweep"


def check_census(
    census: Dict[str, int],
    expect: Dict[str, int],
    variant: str = VARIANT,
    *,
    stage: str = "census",
) -> List[Finding]:
    """Compare an observed compile census against the expected one —
    exact, including a probe-unavailable (-1) guard."""
    out: List[Finding] = []
    for prog in sorted(set(census) | set(expect)):
        got = census.get(prog)
        want = expect.get(prog)
        if got is None or want is None:
            out.append(
                Finding(
                    rule="recompile-census",
                    variant=variant,
                    program=str(prog),
                    detail=(
                        f"{stage}: program present on one side only "
                        f"(got={got}, want={want})"
                    ),
                )
            )
        elif got == -1:
            out.append(
                Finding(
                    rule="recompile-census",
                    variant=variant,
                    program=str(prog),
                    detail=(
                        f"{stage}: compiled-program probe unavailable "
                        f"(jax dropped _cache_size?)"
                    ),
                )
            )
        elif got != want:
            out.append(
                Finding(
                    rule="recompile-census",
                    variant=variant,
                    program=str(prog),
                    detail=(
                        f"{stage}: {got} compiled programs, expected {want} "
                        f"(shape-keyed retrace leak)"
                    ),
                )
            )
    return out


def _sweep(sched, prompts: List[Tuple[int, int]]) -> None:
    """Submit (length, max_new) prompts and drain the scheduler."""
    rng = np.random.default_rng(0)
    for length, max_new in prompts:
        sched.submit(rng.integers(0, sched.cfg.vocab_size, size=length, dtype=np.int32), max_new)
    sched.run()


def run_recompile_audit() -> Tuple[List[Finding], Dict[str, int]]:
    """The scripted traffic sweep (see module docstring).  Returns
    (findings, final census) — an empty findings list is the pass."""
    from repro.analysis.programs import (
        AUDIT_BUCKETS,
        AUDIT_CHUNK_LEN,
        AUDIT_MAX_LEN,
        AUDIT_SLOTS,
        AUDIT_TICK_STEPS,
        audit_model,
    )
    from repro.serving import engine
    from repro.serving.config import ServeConfig
    from repro.serving.scheduler import ServeScheduler

    cfg, params = audit_model()
    sched = ServeScheduler(
        cfg,
        params,
        ServeConfig(
            max_slots=AUDIT_SLOTS,
            max_len=AUDIT_MAX_LEN,
            buckets=AUDIT_BUCKETS,
            tick_steps=AUDIT_TICK_STEPS,
            chunked="auto",
            chunk_len=AUDIT_CHUNK_LEN,
        ),
    )
    findings: List[Finding] = []

    # phase 1: one over-bucket prompt ALONE — its ingestion runs chunk-only
    # ticks (no decode rows live yet), so the chunk program compiles here
    _sweep(sched, [(20, 4)])
    # phase 2: mixed traffic — both buckets, plus an over-bucket prompt
    # ingesting WHILE others decode (compiles the mixed program)
    _sweep(sched, [(5, 6), (12, 6), (24, 6), (7, 4)])
    expect = {"prefill": len(AUDIT_BUCKETS), "tick": 1, "write_slot": 1, "chunk": 1, "mixed": 1}
    findings += check_census(sched.compile_stats(), expect, stage="cold")

    # phase 3: REPLAY different lengths hitting the same buckets/chunks —
    # the warm path must not compile anything new
    _sweep(sched, [(6, 4), (11, 5), (26, 4), (3, 3)])
    findings += check_census(sched.compile_stats(), expect, stage="warm")

    # mesh-fingerprint collision check: same configuration, unsharded vs a
    # degenerate 1x1 mesh — two distinct generate-LRU entries (building the
    # jitted wrappers compiles nothing)
    import jax

    fp_none = engine.mesh_fingerprint(None)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    fp_mesh = engine.mesh_fingerprint(mesh)
    if fp_none == fp_mesh:
        findings.append(
            Finding(
                rule="recompile-census",
                variant=VARIANT,
                program="generate_fn",
                detail=(
                    "mesh_fingerprint(None) == mesh_fingerprint(1x1 mesh): "
                    "sharded/unsharded programs would collide in the LRU"
                ),
            )
        )
    before = len(engine.generate_fn)
    fn_plain = engine.generate_fn(cfg, 4, 0.0, False, None, False, mesh=None)
    fn_mesh = engine.generate_fn(cfg, 4, 0.0, False, None, False, mesh=mesh)
    grew = len(engine.generate_fn) - before
    if fn_plain is fn_mesh or grew < 2:
        findings.append(
            Finding(
                rule="recompile-census",
                variant=VARIANT,
                program="generate_fn",
                detail=(
                    f"mesh-fingerprint cache collision: unsharded and 1x1-"
                    f"mesh builds share a program (cache grew {grew}, "
                    f"expected 2)"
                ),
            )
        )

    return findings, sched.compile_stats()
