"""Train-step builders: pjit path (DP/TP/EP/SP via sharding constraints),
microbatch gradient accumulation, straggler watchdog, resume-able loop."""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.model import ModelConfig, next_token_loss
from repro.optim import adamw


@dataclass(frozen=True)
class TrainConfig:
    optimizer: adamw.AdamWConfig = field(default_factory=adamw.AdamWConfig)
    micro_batches: int = 1              # gradient accumulation factor
    quant: bool = False                 # QeiHaN-quantized projections


def make_loss_fn(cfg: ModelConfig, quant: bool = False) -> Callable:
    def loss_fn(params, batch):
        return next_token_loss(cfg, params, batch, quant=quant)
    return loss_fn


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig) -> Callable:
    """Returns ``train_step(params, opt_state, batch) -> (params, opt, metrics)``.

    With ``micro_batches > 1`` the batch's leading dim is split and grads are
    accumulated in f32 via ``lax.scan`` (compute/memory trade controlled by
    the caller); loss is the microbatch mean.
    """
    loss_fn = make_loss_fn(cfg, tcfg.quant)
    grad_fn = jax.value_and_grad(loss_fn)

    def train_step(params, opt_state, batch):
        if tcfg.micro_batches <= 1:
            loss, grads = grad_fn(params, batch)
        else:
            n = tcfg.micro_batches

            def split(x):
                b = x.shape[0]
                return x.reshape(n, b // n, *x.shape[1:])

            micro = jax.tree.map(split, batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc(carry, mb):
                tot_loss, tot_g = carry
                loss, g = grad_fn(params, mb)
                tot_g = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), tot_g, g)
                return (tot_loss + loss, tot_g), None

            (loss, grads), _ = jax.lax.scan(acc, (0.0, zeros), micro)
            loss = loss / n
            grads = jax.tree.map(lambda g: g / n, grads)

        params, opt_state, metrics = adamw.update(
            tcfg.optimizer, grads, opt_state, params)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


class StragglerWatchdog:
    """Tracks per-step wall time; flags steps slower than ``factor`` x the
    running median.  At cluster scale the flag feeds the orchestration layer
    (preempt/replace the slow host); here it logs and counts."""

    def __init__(self, factor: float = 3.0, warmup: int = 3):
        self.factor = factor
        self.warmup = warmup
        self.times = []
        self.flagged = 0

    def observe(self, seconds: float) -> bool:
        self.times.append(seconds)
        if len(self.times) <= self.warmup:
            return False
        hist = sorted(self.times[:-1])
        median = hist[len(hist) // 2]
        slow = seconds > self.factor * median
        self.flagged += int(slow)
        return slow


def train_loop(cfg: ModelConfig, tcfg: TrainConfig, params, opt_state,
               batches, *, step0: int = 0, jit: bool = True,
               hook: Optional[Callable[[int, Dict[str, Any]], None]] = None):
    """Generic host loop used by examples and tests (single-process path;
    the production launcher in launch/train.py adds mesh + checkpointing)."""
    step_fn = make_train_step(cfg, tcfg)
    if jit:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
    watchdog = StragglerWatchdog()
    metrics = {}
    for step, batch in enumerate(batches, start=step0):
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        slow = watchdog.observe(dt)
        if hook:
            hook(step, {**metrics, "step_time_s": dt, "straggler": slow})
    return params, opt_state, metrics
