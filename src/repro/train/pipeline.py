"""GPipe-style pipeline parallelism via shard_map + collective_permute.

For configurations whose per-stage footprint exceeds HBM even after TP+FSDP
(e.g. >16 GB/chip at small meshes) the launcher can add a ``pipe`` mesh axis.
Stages hold disjoint layer groups; microbatches stream through with the
classic (n_micro + n_stages - 1)-slot schedule.

``pipeline_forward`` is the building block (forward pass), validated against
sequential execution in tests/test_distributed.py on 8 host devices.  For
training, the same schedule applies to the VJP (run the pipeline over the
cotangent stream in reverse) — wired through ``jax.linear_transpose`` is out
of scope for the default 512-chip DP x TP dry-run mesh (see DESIGN.md).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def _stage_step(stage_fn, stage_params, x):
    return stage_fn(stage_params, x)


def pipeline_forward(stage_fn: Callable, params_stacked, x_micro,
                     *, axis: str = "pipe"):
    """Run inside shard_map over ``axis``.

    ``params_stacked``: per-stage params (leading dim sharded over ``axis``
    outside; inside, each stage sees its own slice with leading dim 1).
    ``x_micro``: (n_micro, mb, ...) — meaningful on stage 0.
    Returns (n_micro, mb, ...) outputs — meaningful on the last stage.
    """
    from repro.models.sharding import axis_size
    n_stages = axis_size(axis)
    stage = jax.lax.axis_index(axis)
    n_micro = x_micro.shape[0]
    total_ticks = n_micro + n_stages - 1
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    my_params = jax.tree.map(lambda p: p[0], params_stacked)
    carry = jnp.zeros_like(x_micro[0])
    outputs = jnp.zeros_like(x_micro)

    for t in range(total_ticks):                       # static schedule
        inject = x_micro[jnp.minimum(t, n_micro - 1)]
        cur = jnp.where(stage == 0, inject, carry)
        y = _stage_step(stage_fn, my_params, cur)
        # last stage emits micro t - (n_stages - 1)
        out_idx = t - (n_stages - 1)
        valid = jnp.logical_and(stage == n_stages - 1, out_idx >= 0)
        outputs = jax.lax.cond(
            valid,
            lambda o: jax.lax.dynamic_update_index_in_dim(
                o, y, jnp.maximum(out_idx, 0), 0),
            lambda o: o, outputs)
        carry = jax.lax.ppermute(y, axis, perm)
    # only the last stage wrote anything; psum makes the result replicated
    # so out_specs=P() is well-defined on every shard
    return jax.lax.psum(outputs, axis)


def make_pipelined_fn(mesh: Mesh, stage_fn: Callable, *, axis: str = "pipe"):
    """Wrap ``pipeline_forward`` in shard_map on ``mesh`` (params stacked on
    the stage axis; activations enter on stage 0 and leave on the last)."""
    fn = functools.partial(pipeline_forward, stage_fn, axis=axis)
    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False,
    )
