"""Int8 error-feedback gradient compression for the DP all-reduce.

Same spirit as the paper — quantize what moves through the bottleneck.
For cross-replica gradient reduction the bottleneck is ICI/DCN, so the
all-reduce payload is quantized to int8 with a shared (pmax'd) scale and
the per-replica quantization residual is carried to the next step
(error feedback keeps the optimizer unbiased over time).

Used inside a ``shard_map`` over the DP axes (see train/trainer.py's
``compressed`` mode); plain pjit training lets XLA all-reduce in bf16.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compressed_psum_mean(tree, axis_name, error_tree=None) -> Tuple[Any, Any]:
    """All-reduce-mean `tree` across `axis_name` with int8 payloads.

    Returns (reduced_tree_f32, new_error_tree).  ``error_tree`` carries the
    error-feedback residual (zeros on first use).
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, err):
        g32 = g.astype(jnp.float32)
        if err is not None:
            g32 = g32 + err
        amax = jnp.max(jnp.abs(g32))
        scale = jax.lax.pmax(amax, axis_name) / 127.0
        scale = jnp.maximum(scale, 1e-30)
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        new_err = g32 - q.astype(jnp.float32) * scale
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return summed.astype(jnp.float32) * scale / n, new_err

    flat_g, treedef = jax.tree.flatten(tree)
    flat_e = (treedef.flatten_up_to(error_tree) if error_tree is not None
              else [None] * len(flat_g))
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
