from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.optim.compress import compressed_psum_mean, init_error
__all__ = ["adamw", "AdamWConfig", "compressed_psum_mean", "init_error"]
