"""Layout policy: which mesh axes carry DP/FSDP/TP/EP per (arch x step).

Derived from first-principles traffic math; EXPERIMENTS.md §Perf records the
measurements behind each choice:

* **Dense training (fsdp)** — batch shards over *all* axes; params shard
  FSDP over all axes.  Per-chip collective traffic ~3x params (gather
  fwd/remat/bwd) + grad reduce-scatter — measured 6x less than Megatron
  TP=16+SP at 32B/256 chips (activation gathers dwarf weights).
* **MoE training (ep)** — same FSDP layout for attention/dense/embeddings,
  plus **EP**: expert weights live un-gathered on the ``model`` axis and the
  token buffers move through two tiled all_to_alls inside the MoE shard_map
  (models/moe.py).  Attention TP hints stay OFF — mixing head-TP with EP
  measured 1.5 TiB/chip of flash-backward all-gathers.
* **Serving (tp)** — TP on model for every arch: weights must be resident
  (per-token FSDP gathers would melt the ICI), batch on pod x data, KV cache
  sequence-sharded on model.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

from repro.models.model import ModelConfig


@dataclass(frozen=True)
class LayoutPolicy:
    batch_axes: Tuple[str, ...]
    model_axis: Optional[str]          # TP hint axis (None = no TP hints)
    ep_axis: Optional[str]             # expert-parallel shard_map axis
    fsdp_axes: Tuple[str, ...]         # param-sharding axes
    seq_shard: bool                    # SP for scan-carried residuals
    fsdp: bool = True
    tp_scope: str = "all"              # 'all' | 'experts': which param rules
                                       # bind the model axis

    def describe(self) -> str:
        return (f"batch={self.batch_axes} tp={self.model_axis} "
                f"ep={self.ep_axis} "
                f"fsdp={self.fsdp_axes if self.fsdp else None} "
                f"sp={self.seq_shard} scope={self.tp_scope}")


def for_cell(cfg: ModelConfig, step: str, mesh,
             override: Optional[str] = None,
             global_batch: Optional[int] = None) -> LayoutPolicy:
    """Baseline policy per (arch, step); ``override`` forces a named layout
    (used by the §Perf hillclimb: 'tp', 'fsdp', 'ep', 'ep_dp').

    Divisibility fallback: pure-FSDP needs the batch to shard over the whole
    mesh; when it cannot (e.g. batch 256 on the 512-chip 2-pod mesh), an
    idle model axis makes GSPMD bounce activations (measured 22 TiB/chip on
    qwen3 train) — fall back to DP x TP (dense) / DP x EP + SP (MoE)."""
    axes = tuple(mesh.axis_names)
    pods = tuple(a for a in axes if a == "pod")
    name = override or ("ep" if cfg.n_experts and step == "train" else
                        "fsdp" if step == "train" else "tp")
    covers = (global_batch is None or global_batch % mesh.size == 0)
    if name == "fsdp" and not covers:
        name = "tp"
    if name == "ep" and not covers:
        name = "ep_dp"

    if name == "fsdp":                     # dense training default
        return LayoutPolicy(batch_axes=axes, model_axis=None, ep_axis=None,
                            fsdp_axes=axes, seq_shard=False)
    if name == "ep":                       # MoE training default
        return LayoutPolicy(batch_axes=axes, model_axis=None,
                            ep_axis="model", fsdp_axes=axes,
                            seq_shard=False, tp_scope="experts")
    if name == "ep_dp":                    # MoE train, batch < mesh: DP over
        return LayoutPolicy(                # pod x data, EP + seq-split MoE
            batch_axes=pods + ("data",), model_axis=None, ep_axis="model",
            fsdp_axes=pods + ("data",), seq_shard=True, tp_scope="experts")
    if name == "tp":                       # serving default / megatron train
        return LayoutPolicy(batch_axes=pods + ("data",), model_axis="model",
                            ep_axis="model",
                            fsdp_axes=pods + ("data",) if step == "train" else (),
                            seq_shard=step != "serve", fsdp=step == "train")
    raise ValueError(f"unknown layout {name!r}")
