"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Hardware model (TPU v5e, per chip):
  peak bf16 compute : 197 TFLOP/s
  HBM bandwidth     : 819 GB/s
  ICI link bandwidth: ~50 GB/s per link

Terms (seconds, per chip, per step):
  compute    = HLO_FLOPs / 197e12
  memory     = HLO_bytes / 819e9
  collective = collective_bytes / 50e9

HLO_FLOPs / HLO_bytes / collective_bytes are per-chip values from the
loop-aware analyzer (launch.hlo_analysis) over the SPMD-partitioned module;
the global value is chips x per-chip, so these terms equal the assignment's
``global / (chips * peak)`` formulation.

MODEL_FLOPS = 6*N*D (train; dense N or active N for MoE) or 2*N*D
(inference) — the "useful math" floor.  ``useful_fraction`` =
MODEL_FLOPS-ideal-time / max(term): how close the step is to running the
useful math at the roofline.
"""

from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops: float
    useful_ratio: float          # MODEL_FLOPS / (HLO_FLOPs * chips)
    useful_fraction: float       # model-ideal-time / bound
    bound_s: float
    collective_breakdown: Dict[str, float]
    note: str = ""

    @property
    def key(self):
        return (self.arch, self.shape, self.mesh)


def analyze_cell(res: dict) -> Optional[RooflineRow]:
    if res.get("skipped") or res.get("error"):
        return None
    hlo = res["hlo"]
    chips = res["chips"]
    compute_s = hlo["flops"] / PEAK_FLOPS
    memory_s = hlo["traffic_bytes"] / HBM_BW
    collective_s = hlo["collective_bytes_total"] / ICI_BW

    n = res["params_active"]
    d = res["tokens_per_step"]
    model_flops = (6.0 if res["step"] == "train" else 2.0) * n * d
    hlo_global = hlo["flops"] * chips
    bound = max(compute_s, memory_s, collective_s)
    ideal = model_flops / chips / PEAK_FLOPS
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    return RooflineRow(
        arch=res["arch"], shape=res["shape"],
        mesh="x".join(map(str, res["mesh"])), chips=chips,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=model_flops, hlo_flops=hlo_global,
        useful_ratio=model_flops / max(hlo_global, 1.0),
        useful_fraction=ideal / max(bound, 1e-30), bound_s=bound,
        collective_breakdown={k: v / ICI_BW
                              for k, v in hlo["collective_bytes"].items()},
    )


def load_rows(directory: str) -> List[RooflineRow]:
    rows = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            res = json.load(f)
        row = analyze_cell(res)
        if row:
            rows.append(row)
    return rows


def format_table(rows: List[RooflineRow]) -> str:
    hdr = (f"{'arch':<20} {'shape':<12} {'mesh':<9} "
           f"{'compute_s':>10} {'memory_s':>10} {'collect_s':>10} "
           f"{'bound':<10} {'useful':>7} {'frac':>6}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:<20} {r.shape:<12} {r.mesh:<9} "
            f"{r.compute_s:>10.4f} {r.memory_s:>10.4f} {r.collective_s:>10.4f} "
            f"{r.dominant:<10} {r.useful_ratio:>7.3f} {r.useful_fraction:>6.3f}")
    return "\n".join(lines)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    args = ap.parse_args()
    rows = load_rows(args.dir)
    print(format_table(rows))


if __name__ == "__main__":
    main()
