"""Production training driver: mesh + shardings + checkpoint/restart +
straggler watchdog + deterministic data.  Scales from single-CPU smoke runs
(``--smoke --mesh host``) to the 512-chip dry-run mesh unchanged.

Fault tolerance: `--resume auto` restarts from the newest valid checkpoint;
checkpoints are mesh-agnostic, so restarting on a different mesh (elastic
scaling, e.g. after losing a pod) re-shards on load and — because the data
pipeline is keyed by (seed, step, shard) — replays the exact token stream.

Example (CPU smoke):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \
      --mesh host --steps 10 --global-batch 8 --seq-len 64
"""

from __future__ import annotations

import argparse
import json
import time

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke
from repro.data import DataConfig, SyntheticLM
from repro.launch.mesh import batch_axes, make_host_mesh, make_production_mesh
from repro.launch.shardings import (batch_shardings, opt_shardings,
                                    params_shardings)
from repro.models.model import init_params
from repro.models.sharding import mesh_axes
from repro.optim import adamw
from repro.train.trainer import StragglerWatchdog, TrainConfig, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="host", choices=["host", "pod", "pod2"])
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--micro-batches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--quant", action="store_true",
                    help="QeiHaN-quantized projections")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=10)
    ap.add_argument("--resume", default="none", choices=["none", "auto"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if args.mesh == "host":
        mesh = make_host_mesh(args.model_parallel)
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "pod2")
    bax = batch_axes(mesh)

    dcfg = DataConfig(global_batch=args.global_batch, seq_len=args.seq_len,
                      vocab_size=cfg.vocab_size, seed=args.seed)
    data = SyntheticLM(dcfg)
    tcfg = TrainConfig(
        optimizer=adamw.AdamWConfig(lr=args.lr, warmup_steps=5,
                                    total_steps=max(args.steps, 10)),
        micro_batches=args.micro_batches, quant=args.quant)

    with mesh, mesh_axes(batch=bax, model="model",
                         seq_shard=True, sizes=dict(mesh.shape), mesh=mesh):
        params = init_params(jax.random.PRNGKey(args.seed), cfg)
        psh = params_shardings(mesh, params)
        params = jax.device_put(params, psh)
        opt_state = adamw.init(params)
        osh = opt_shardings(mesh, opt_state, psh)
        opt_state = jax.device_put(opt_state, osh)

        step0 = 0
        mgr = None
        if args.checkpoint_dir:
            mgr = CheckpointManager(args.checkpoint_dir, keep=3)
            if args.resume == "auto" and mgr.latest_step() is not None:
                step0 = mgr.latest_step()
                state = mgr.restore(step0, {"params": params, "opt": opt_state},
                                    {"params": psh, "opt": osh})
                params, opt_state = state["params"], state["opt"]
                print(f"[train] resumed from step {step0}")

        example = data.batch(0)
        bsh = batch_shardings(mesh, example)
        rep = NamedSharding(mesh, P())
        msh = {"loss": rep, "grad_norm": rep, "lr": rep}
        step_fn = jax.jit(make_train_step(cfg, tcfg),
                          in_shardings=(psh, osh, bsh),
                          out_shardings=(psh, osh, msh),
                          donate_argnums=(0, 1))

        watchdog = StragglerWatchdog()
        for step in range(step0, args.steps):
            host = data.batch(step)
            batch = jax.device_put(host, bsh)
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            slow = watchdog.observe(dt)
            if step % args.log_every == 0:
                print(json.dumps({"step": step, "loss": round(loss, 4),
                                  "grad_norm": round(float(metrics["grad_norm"]), 3),
                                  "sec": round(dt, 3),
                                  "straggler": bool(slow)}))
            if mgr and (step + 1) % args.checkpoint_every == 0:
                mgr.save_async(step + 1, {"params": params, "opt": opt_state})
        if mgr:
            mgr.wait()
    print("[train] done")


if __name__ == "__main__":
    main()
