"""Assigned input shapes and ShapeDtypeStruct builders per (arch x shape).

Shapes (assignment):
  train_4k     seq 4096,   global_batch 256   -> train_step
  prefill_32k  seq 32768,  global_batch 32    -> prefill_step
  decode_32k   seq 32768,  global_batch 128   -> serve_step (1 new token)
  long_500k    seq 524288, global_batch 1     -> serve_step; SSM/hybrid only

``input_specs()`` returns weak-type-correct ShapeDtypeStructs only — no
device allocation; the dry-run lowers against them directly.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.model import ModelConfig, init_caches


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    step: str                   # train | prefill | serve


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "serve"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "serve"),
}


def shape_applicable(cfg: ModelConfig, shape: str) -> bool:
    """long_500k only for sub-quadratic archs (DESIGN.md §Skips)."""
    if shape == "long_500k":
        return cfg.sub_quadratic
    return True


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def batch_specs(cfg: ModelConfig, sp: ShapeSpec, *,
                with_labels: bool) -> Dict[str, Any]:
    b, s = sp.global_batch, sp.seq_len
    out: Dict[str, Any] = {}
    if cfg.frontend == "audio_stub":
        out["embeds"] = _sds((b, s, cfg.d_model), jnp.bfloat16)
        if with_labels:
            out["labels"] = _sds((b, s), jnp.int32)
        return out
    s_text = s - cfg.n_image_tokens if cfg.frontend == "vision_stub" else s
    out["tokens"] = _sds((b, s_text), jnp.int32)
    if cfg.frontend == "vision_stub":
        out["image_embeds"] = _sds((b, cfg.n_image_tokens, cfg.d_model),
                                   jnp.bfloat16)
    if with_labels:
        out["labels"] = _sds((b, s_text), jnp.int32)
    return out


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> Any:
    return jax.eval_shape(lambda: init_caches(cfg, batch, max_len))


def token_specs(cfg: ModelConfig, batch: int) -> Any:
    if cfg.frontend == "audio_stub":
        return _sds((batch, 1, cfg.d_model), jnp.bfloat16)
    return _sds((batch, 1), jnp.int32)


def input_specs(cfg: ModelConfig, shape: str) -> Dict[str, Any]:
    """All step inputs (excluding params/opt) for the given shape."""
    sp = SHAPES[shape]
    if sp.step == "train":
        return {"batch": batch_specs(cfg, sp, with_labels=True)}
    if sp.step == "prefill":
        return {"batch": batch_specs(cfg, sp, with_labels=False),
                "caches": cache_specs(cfg, sp.global_batch, sp.seq_len)}
    # serve: one token against a cache holding seq_len tokens
    return {"caches": cache_specs(cfg, sp.global_batch, sp.seq_len),
            "token": token_specs(cfg, sp.global_batch)}
