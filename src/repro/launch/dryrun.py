import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes and extract memory/cost/collective analyses (EXPERIMENTS.md §Dry-run).

The two lines above MUST precede any jax import: jax locks the device count
at first init, and only the dry-run wants 512 placeholder devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --multi-pod
Outputs one JSON per cell under --out (default results/dryrun).
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ALIASES, get_config, get_smoke, list_archs
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch import policy
from repro.launch.shapes import SHAPES, input_specs, shape_applicable
from repro.launch.shardings import (batch_shardings, cache_shardings,
                                    opt_shardings, params_shardings)
from repro.models.model import init_params, param_count
from repro.models.sharding import mesh_axes
from repro.optim import adamw
from repro.serving.engine import make_prefill_step, make_serve_step
from repro.train.trainer import TrainConfig, make_train_step


def _param_specs(cfg):
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def _memory_analysis_dict(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in dir(ma):
        if k.startswith("_"):
            continue
        v = getattr(ma, k)
        if isinstance(v, (int, float)):
            out[k] = v
    return out


def _cost_analysis_dict(compiled):
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and len(k) < 40}


def run_cell(arch: str, shape: str, *, multi_pod: bool = False,
             smoke: bool = False, remat: str = None,
             layout: str = None, save_hlo: str = None,
             quant: bool = False, cache_dtype: str = None) -> dict:
    cfg = get_smoke(arch) if smoke else get_config(arch)
    if remat:
        cfg = cfg.replace(remat=remat)
    if cache_dtype:
        cfg = cfg.replace(cache_dtype={
            "f8": jnp.float8_e4m3fn, "bf16": jnp.bfloat16,
            "int8": jnp.int8}[cache_dtype])
    sp = SHAPES[shape]
    if not shape_applicable(cfg, shape):
        return {"arch": arch, "shape": shape, "skipped": True,
                "reason": "long_500k requires sub-quadratic attention "
                          "(DESIGN.md §Skips)"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    pol = policy.for_cell(cfg, sp.step, mesh, override=layout,
                          global_batch=sp.global_batch)
    specs = input_specs(cfg, shape)
    rep = NamedSharding(mesh, P())
    long_ctx = shape == "long_500k"

    t0 = time.perf_counter()
    with mesh:
        if quant and sp.step != "train":
            # QeiHaN deployment: packed bit-plane weights resident, float
            # projections dropped (paper technique as the serving format)
            from repro.models.quantize import quantize_model_params
            pspecs = jax.eval_shape(
                lambda: quantize_model_params(
                    cfg, init_params(jax.random.PRNGKey(0), cfg),
                    drop_float=True, pack=True))
        else:
            pspecs = _param_specs(cfg)
        psh = params_shardings(mesh, pspecs, fsdp=pol.fsdp,
                               model_axis=pol.model_axis,
                               fsdp_axes=pol.fsdp_axes,
                               tp_scope=pol.tp_scope, ep_axis=pol.ep_axis)
        if sp.step == "train":
            ospecs = jax.eval_shape(adamw.init, pspecs)
            osh = opt_shardings(mesh, ospecs, psh,
                                extra_axes=tuple(a for a in mesh.axis_names
                                                 if a != pol.ep_axis))
            bsh = batch_shardings(mesh, specs["batch"], axes=pol.batch_axes)
            step = make_train_step(cfg, TrainConfig())
            msh = {"loss": rep, "grad_norm": rep, "lr": rep}
            jitted = jax.jit(step, in_shardings=(psh, osh, bsh),
                             out_shardings=(psh, osh, msh),
                             donate_argnums=(0, 1))
            args = (pspecs, ospecs, specs["batch"])
        else:
            csh = cache_shardings(mesh, specs["caches"],
                                  batch=sp.global_batch,
                                  long_context=long_ctx,
                                  axes=pol.batch_axes,
                                  model_axis=pol.model_axis)
            if sp.step == "prefill":
                bsh = batch_shardings(mesh, specs["batch"],
                                      axes=pol.batch_axes)
                step = make_prefill_step(cfg, quant=quant)
                jitted = jax.jit(step, in_shardings=(psh, bsh, csh),
                                 donate_argnums=(2,))
                args = (pspecs, specs["batch"], specs["caches"])
            else:
                tsh = batch_shardings(mesh, specs["token"],
                                      axes=pol.batch_axes)
                # "xla" backend: the dry-run lowers under GSPMD on the 512-
                # device placeholder mesh, which cannot partition a pallas
                # interpret call; the bit-plane math is identical either way
                step = make_serve_step(cfg, quant="xla" if quant else False)
                jitted = jax.jit(step, in_shardings=(psh, csh, tsh),
                                 donate_argnums=(1,))
                args = (pspecs, specs["caches"], specs["token"])

        with mesh_axes(batch=pol.batch_axes, model=pol.model_axis,
                       seq_shard=pol.seq_shard and sp.step != "serve",
                       cache_seq_axis="data" if long_ctx else None,
                       sizes=dict(mesh.shape), mesh=mesh,
                       ep_axis=pol.ep_axis):
            lowered = jitted.lower(*args)
        lower_s = time.perf_counter() - t0
        t1 = time.perf_counter()
        compiled = lowered.compile()
        compile_s = time.perf_counter() - t1

    mem = _memory_analysis_dict(compiled)
    cost = _cost_analysis_dict(compiled)
    hlo_text = compiled.as_text()
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo_text)
    hlo = analyze_hlo(hlo_text)
    pc = param_count(cfg)
    tokens = sp.global_batch * (sp.seq_len if sp.step != "serve" else 1)

    result = {
        "arch": arch, "shape": shape, "step": sp.step,
        "mesh": list(mesh.devices.shape), "axes": list(mesh.axis_names),
        "chips": int(mesh.size), "smoke": smoke,
        "seq_len": sp.seq_len, "global_batch": sp.global_batch,
        "tokens_per_step": tokens,
        "params_total": pc["total"], "params_active": pc["active"],
        "lower_s": round(lower_s, 2), "compile_s": round(compile_s, 2),
        "memory_analysis": mem, "cost_analysis": cost, "hlo": hlo,
        "options": {"remat": cfg.remat, "layout": pol.describe(),
                    "quant": quant},
    }
    print(f"[dryrun] {arch} x {shape} mesh={result['mesh']} "
          f"lower={lower_s:.1f}s compile={compile_s:.1f}s "
          f"temp={mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
          f"flops/chip={hlo['flops']:.3e} "
          f"coll/chip={hlo['collective_bytes_total']/2**20:.1f}MiB")
    print("memory_analysis:", json.dumps(mem))          # proves it fits
    print("cost_analysis:", json.dumps(cost))           # FLOPs/bytes source
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--layout", default=None,
                    choices=[None, "fsdp", "ep", "tp"])
    ap.add_argument("--quant", action="store_true",
                    help="serve with QeiHaN packed bit-plane weights")
    ap.add_argument("--cache-dtype", default=None,
                    choices=[None, "f8", "bf16", "int8"],
                    help="KV-cache storage dtype (beyond-paper: LOG2-style "
                         "quantization applied to the cache)")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    os.makedirs(args.out, exist_ok=True)

    failures = 0
    for arch in archs:
        for shape in shapes:
            mesh_tag = "pod2" if args.multi_pod else "pod1"
            name = ALIASES.get(arch, arch).replace("-", "_")
            out_path = os.path.join(
                args.out, f"{name}__{shape}__{mesh_tag}{args.tag}.json")
            try:
                res = run_cell(arch, shape, multi_pod=args.multi_pod,
                               smoke=args.smoke, remat=args.remat,
                               layout=args.layout,
                               save_hlo=args.save_hlo, quant=args.quant,
                               cache_dtype=args.cache_dtype)
            except Exception as e:                      # noqa: BLE001
                traceback.print_exc()
                res = {"arch": arch, "shape": shape, "error": str(e)}
                failures += 1
            with open(out_path, "w") as f:
                json.dump(res, f, indent=1)
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
