"""Serving driver: prefill a batch of prompts, decode new tokens, report
tokens/s.  Mesh-aware (TP sharding of params and caches); the decode phase is
the FUSED ``lax.scan`` loop — one XLA program for all new tokens, no
per-token dispatch.  CPU smoke:

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
      --batch 4 --prompt-len 32 --new-tokens 16

``--continuous`` switches to the continuous-batching slot scheduler
(``serving/scheduler.py``): a queued trace of variable-length prompts is
admitted into a persistent slot pool, stepped in fused multi-token ticks,
and retired/re-filled on EOS or length — decode never drains:

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
      --continuous --requests 16 --max-slots 4 --new-tokens 16 --quant

``--mesh DxM`` (e.g. ``2x2``, ``4x1``) runs either mode tensor/data-parallel
over a ``data x model`` host mesh: params get the TP rules (incl. packed bit
-planes), the slot pool shards batch-on-data, and the token stream is
bit-equal to the single-device run (tests/test_serve_sharded.py).  On a CPU
box add ``--host-devices N`` (must be the FIRST jax knob to take effect — it
sets ``XLA_FLAGS=--xla_force_host_platform_device_count`` before jax init):

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
      --continuous --mesh 2x2 --host-devices 4
"""

from __future__ import annotations

import argparse
import math
import sys
import time

# must precede the first jax import: jax locks the device count at init
# (repro.launch.host_devices is deliberately jax-free)
if __name__ == "__main__":
    from repro.launch.host_devices import force_host_devices
    force_host_devices(sys.argv)

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke
from repro.launch.mesh import batch_axes, make_serve_mesh
from repro.launch.shardings import cache_shardings, params_shardings
from repro.models.model import init_caches, init_params
from repro.models.quantize import quantize_model_params
from repro.models.sharding import mesh_axes
from repro.serving.engine import make_decode_loop, make_prefill_step


def build_serve_config(args):
    """Pure flags -> :class:`~repro.serving.config.ServeConfig` mapping
    for ``--continuous`` serving.  No jax state is touched: the same
    flags always produce the same config, and ``--dump-config`` commits
    exactly what this returns (round-trip tested).  The mesh is the one
    deliberate exclusion — device binding is process-local, so the
    launcher resolves ``--mesh`` itself and passes the live mesh
    alongside the config (``ServeConfig.mesh_spec`` stays for configs
    authored by hand)."""
    from repro.serving.config import ServeConfig
    from repro.serving.scheduler import round_pool_len

    buckets = tuple(sorted({8, 16, max(8, args.prompt_len)}))
    chunked = args.chunked or "off"
    chunk_len = args.chunk_len or 8
    long_max = (3 * args.prompt_len) if chunked != "off" else args.prompt_len
    pool = max(long_max, max(buckets)) + args.new_tokens + args.tick_steps
    # ONE rounding to the lcm: sequential round-ups could undo each other
    # (e.g. chunk 12 then page 16 yields 112, not a multiple of 12)
    quantum = 1
    if chunked != "off" or args.prefix_cache:
        quantum = chunk_len
    kv_quant = args.kv_quant is not None
    paged = bool(args.paged or args.prefix_cache or args.attn_kernel
                 or kv_quant)
    if paged:
        quantum = math.lcm(quantum, args.page_len)
    if quantum > 1:
        pool = round_pool_len(pool, quantum)
    return ServeConfig(
        max_slots=args.max_slots, max_len=pool, buckets=buckets,
        quant=args.quant_backend if args.quant else False,
        with_stats=args.quant, tick_steps=args.tick_steps,
        chunked=chunked, chunk_len=chunk_len, paged=paged,
        page_len=args.page_len, prefix_cache=args.prefix_cache,
        attn_kernel="pallas" if args.attn_kernel else "off",
        attn_splits=args.attn_splits,
        kv_quant=kv_quant, kv_bits=args.kv_quant or 4)


def _load_serve_config(args):
    """The serving config for this invocation: ``--config path.json`` if
    given (the committed-file workflow), else derived from the flags."""
    from repro.serving.config import ServeConfig

    if args.config is None:
        return build_serve_config(args)
    with open(args.config) as fh:
        return ServeConfig.from_json(fh.read())


def _serve_continuous(cfg, params, args, mesh):
    """Queued-trace continuous batching: submit everything, drain, report
    sustained tok/s + per-request latency + plane traffic.

    With ``--chunked`` the trace includes LONG prompts (up to 3x
    ``--prompt-len``, past every prefill bucket) — rejected outright without
    chunking — ingested ``--chunk-len`` tokens per tick, interleaved with
    decode.  ``--disaggregate`` serves the same trace through the
    prefill/decode router (``serving/router.py``) instead of the combined
    scheduler — identical tokens, isolated decode ticks."""
    import numpy as np

    from repro.serving.router import Router
    from repro.serving.scheduler import ServeScheduler

    config = _load_serve_config(args)
    buckets = config.buckets
    chunked = config.chunked
    long_max = ((3 * args.prompt_len) if chunked != "off"
                else args.prompt_len)
    live_mesh = mesh if mesh is not None and mesh.size > 1 else None
    if args.disaggregate:
        if not config.paged:
            raise SystemExit("--disaggregate requires a paged config "
                             "(add --paged, or paged=true in --config)")
        sched = Router(cfg, params, config, mesh=live_mesh)
    else:
        sched = ServeScheduler(cfg, params, config, mesh=live_mesh)
    rng = np.random.default_rng(args.seed)
    # with a prefix cache, draw a shared-system-prompt workload (half the
    # prompt is a common prefix) so the radix tree has something to hit
    prefix = (rng.integers(0, cfg.vocab_size, size=max(args.prompt_len // 2,
                                                       config.page_len))
              .astype(np.int32) if config.prefix_cache else None)
    for _ in range(args.requests):
        n = int(rng.integers(2, long_max + 1))
        p = rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
        if prefix is not None and rng.random() < 0.75:
            p = np.concatenate([prefix, p])[:max(long_max, len(prefix) + 2)]
        sched.submit(p, max_new=args.new_tokens, eos_id=args.eos_id)
    t0 = time.perf_counter()
    results = sched.run()
    dt = time.perf_counter() - t0
    total = sum(len(r.tokens) for r in results)
    mesh_tag = ("1-device" if live_mesh is None else
                "x".join(str(s) for s in live_mesh.devices.shape) + " mesh")
    chunk_tag = ("" if chunked == "off"
                 else f", chunked={chunked}/{config.chunk_len}")
    if config.paged:
        chunk_tag += (f", paged/{config.page_len}"
                      + ("+prefix" if config.prefix_cache else "")
                      + (f"+kernel/s{config.attn_splits}"
                         if config.attn_kernel != "off" else "")
                      + (f"+kvq/{config.kv_bits}b" if config.kv_quant
                         else ""))
    if args.disaggregate:
        mode_tag = "disaggregated"
        compile_stats = {"prefill": sched.prefill.scheduler.compile_stats(),
                         "decode": sched.decode.scheduler.compile_stats()}
        stats_sched = sched.prefill.scheduler
    else:
        mode_tag = "continuous batching"
        compile_stats = sched.compile_stats()
        stats_sched = sched
    print(f"[serve] {cfg.name}: {mode_tag} ({mesh_tag}{chunk_tag}) "
          f"— {len(results)} requests, {config.max_slots} slots, "
          f"tick={config.tick_steps}: "
          f"{total} tokens in {dt:.3f}s ({total / max(dt, 1e-9):.1f} tok/s "
          f"incl. compile); programs: {compile_stats}")
    if args.disaggregate and sched.decode_tick_times:
        tt = np.asarray(sched.decode_tick_times) * 1e3
        print(f"[serve] decode fleet: {len(tt)} isolated ticks, p50/p95 "
              f"{np.percentile(tt, 50):.1f}/{np.percentile(tt, 95):.1f} ms "
              f"(prefill work excluded by construction)")
    if not results:
        return
    served = [r for r in results if r.finish_reason != "rejected"]
    ttft = [r.first_token_time - r.submit_time for r in served
            if np.isfinite(r.first_token_time)]
    e2e = [r.finish_time - r.submit_time for r in served
           if np.isfinite(r.finish_time)]
    if ttft:
        print(f"[serve] latency (incl. compile): ttft p50/p95 "
              f"{np.percentile(ttft, 50) * 1e3:.1f}/"
              f"{np.percentile(ttft, 95) * 1e3:.1f} ms, e2e p50/p95 "
              f"{np.percentile(e2e, 50) * 1e3:.1f}/"
              f"{np.percentile(e2e, 95) * 1e3:.1f} ms; "
              f"{len(served)}/{len(results)} served, longest prompt "
              f"{max(r.prompt_len for r in served)} tokens "
              f"(buckets cap {max(buckets)})")
    if args.quant:
        tile = float(np.mean([r.plane_traffic_fraction for r in served]))
        elem = float(np.mean([r.element_traffic_fraction for r in served]))
        print(f"[serve] per-request plane_traffic_fraction: {tile:.3f} "
              f"tile-granular, {elem:.3f} element-granular")
    if config.prefix_cache:
        st = stats_sched.prefix_cache_stats()
        print(f"[serve] prefix cache: hit_rate {st['hit_rate']:.3f} "
              f"({int(st['cached_tokens'])}/{int(st['prompt_tokens'])} "
              f"prompt tokens from shared pages, "
              f"{int(st['lookup_hits'])}/{int(st['lookups'])} lookups hit; "
              f"pages {int(st['pages_in_use'])} in use / "
              f"{int(st['pages_free'])} free)")
    r0 = results[0]
    print(f"sample request 0 ({r0.finish_reason}):", r0.tokens[:8])


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="host",
                    help="'host', 'pod', 'pod2', or an explicit DxM "
                         "data x model grid (e.g. '2x2', '4x1')")
    ap.add_argument("--host-devices", type=int, default=None,
                    help="force N host (CPU) devices for a local mesh smoke "
                         "run (consumed before jax init; see module "
                         "docstring)")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--quant", action="store_true")
    ap.add_argument("--quant-backend", default="pallas",
                    choices=["pallas", "xla"])
    ap.add_argument("--pack", action="store_true",
                    help="serve packed bit-planes (int8-footprint deploy "
                         "format)")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="enable while_loop early stop on this token id")
    ap.add_argument("--seed", type=int, default=0)
    # continuous-batching mode
    ap.add_argument("--continuous", action="store_true",
                    help="serve a queued request trace through the slot "
                         "scheduler instead of one rectangular batch")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--tick-steps", type=int, default=8)
    ap.add_argument("--chunked", nargs="?", const="auto", default=None,
                    choices=["off", "auto", "always"],
                    help="chunked prefill (continuous mode): ingest prompts "
                         "chunk-by-chunk interleaved with decode; lifts the "
                         "bucket ceiling on prompt length, and the trace "
                         "draws prompts up to 3x --prompt-len.  Bare "
                         "--chunked means 'auto' (only over-bucket prompts "
                         "chunk); 'always' chunks every prompt")
    ap.add_argument("--chunk-len", type=int, default=None,
                    help="tokens ingested per chunk per tick (default 8, "
                         "the smallest bucket)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV pool (continuous mode): slots share a "
                         "pool of fixed-size pages through per-slot page "
                         "tables instead of owning dense cache slabs")
    ap.add_argument("--page-len", type=int, default=16,
                    help="tokens per KV page (paged mode)")
    ap.add_argument("--attn-kernel", action="store_true",
                    help="fused paged-attention decode kernel (implies "
                         "--paged): walks the page tables directly instead "
                         "of gathering pool[table] into the dense view "
                         "(DESIGN.md §Paged attention kernel)")
    ap.add_argument("--attn-splits", type=int, default=1,
                    help="split-KV flash-decode: partition the KV page axis "
                         "into this many independent softmax partials, "
                         "merged at the end (rides the model mesh axis "
                         "when it divides)")
    ap.add_argument("--kv-quant", nargs="?", const=4, type=int,
                    default=None, metavar="BITS",
                    help="log2-quantize completed KV pages at BITS wire "
                         "exponent bits (default 4; implies --paged — "
                         "newest pages stay f32 in the per-slot tail ring)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix prefix cache over the paged pool (implies "
                         "--paged): requests re-use the cached KV of their "
                         "longest shared prompt prefix and prefill only "
                         "the suffix; the trace draws shared-prefix "
                         "prompts to show hits")
    ap.add_argument("--config", default=None, metavar="PATH",
                    help="load the continuous-mode ServeConfig from this "
                         "JSON file instead of deriving it from the flags "
                         "(--dump-config writes the derived form)")
    ap.add_argument("--dump-config", nargs="?", const="-", default=None,
                    metavar="PATH",
                    help="print (or write to PATH) the ServeConfig JSON "
                         "this flag combination derives, then exit — the "
                         "committed-config workflow's authoring step")
    ap.add_argument("--disaggregate", action="store_true",
                    help="continuous mode through the disaggregated "
                         "prefill/decode router (serving/router.py) "
                         "instead of the combined scheduler: identical "
                         "tokens, decode ticks isolated from prompt "
                         "ingestion (requires a paged config)")
    args = ap.parse_args(argv)

    if args.dump_config is not None:
        text = _load_serve_config(args).to_json(indent=2)
        if args.dump_config == "-":
            print(text)
        else:
            with open(args.dump_config, "w") as fh:
                fh.write(text + "\n")
        return

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if cfg.frontend == "audio_stub":
        raise SystemExit("use examples/serve_decode.py for the audio stub")
    mesh = make_serve_mesh(args.mesh, args.model_parallel)
    bax = batch_axes(mesh)
    max_len = args.prompt_len + args.new_tokens

    quant = args.quant_backend if args.quant else False
    with mesh, mesh_axes(batch=bax, model="model", seq_shard=False,
                         sizes=dict(mesh.shape), mesh=mesh):
        params = init_params(jax.random.PRNGKey(args.seed), cfg)
        if args.quant:
            params = quantize_model_params(cfg, params, pack=args.pack)
        psh = params_shardings(mesh, params, fsdp=False)
        params = jax.device_put(params, psh)
        if args.continuous:
            return _serve_continuous(cfg, params, args, mesh)
        caches = init_caches(cfg, args.batch, max_len, dtype=cfg.dtype)
        # ssm_model=False: this path EXECUTES decode — a model-sharded SSM
        # recurrent carry is the documented CPU-SPMD miscompile (DESIGN.md
        # §Sharded serving); only lowering-only consumers keep it
        csh = cache_shardings(mesh, caches, batch=args.batch,
                              ssm_model=False)
        caches = jax.device_put(caches, csh)

        key = jax.random.PRNGKey(args.seed)
        prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                    cfg.vocab_size)
        if cfg.frontend == "vision_stub":
            n_img = cfg.n_image_tokens
            img = jax.random.normal(key, (args.batch, n_img, cfg.d_model),
                                    jnp.bfloat16)
            batch = {"tokens": prompt, "image_embeds": img}
        else:
            batch = {"tokens": prompt}

        prefill = jax.jit(make_prefill_step(cfg, quant),
                          donate_argnums=(2,))
        decode = jax.jit(make_decode_loop(cfg, args.new_tokens, quant=quant,
                                          eos_id=args.eos_id,
                                          with_stats=args.quant),
                         donate_argnums=(1,))

        t0 = time.perf_counter()
        logits, caches = prefill(params, batch, caches)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0

        t1 = time.perf_counter()
        toks, stats = decode(params, caches, logits, key)
        jax.block_until_ready(toks)
        t_decode = time.perf_counter() - t1

    import numpy as np
    toks_h = np.asarray(toks)
    if args.eos_id is None:
        total_new = toks_h.size
        steps = args.new_tokens
    else:
        # early stop: count per-row tokens up to (and including) the first
        # EOS, and only the while_loop iterations that actually executed —
        # trailing slots are EOS padding / zeroed stats
        hits = toks_h == args.eos_id
        first = np.where(hits.any(1), hits.argmax(1) + 1, args.new_tokens)
        total_new = int(first.sum())
        steps = int(first.max()) if args.new_tokens else 0
    print(f"[serve] {cfg.name}: prefill {args.batch}x{args.prompt_len} "
          f"in {t_prefill:.3f}s; {total_new} tokens decoded in "
          f"{t_decode:.3f}s ({total_new / max(t_decode, 1e-9):.1f} tok/s, "
          f"fused scan incl. compile)")
    if stats is not None and steps:
        # average over executed forwards only: the terminal while_loop
        # iteration no longer steps the model (its logits were dead) and
        # reports exact-zero traffic for that slot
        tile_all = np.asarray(stats["plane_traffic_fraction"][:steps])
        ran = tile_all > 0
        tile = float(tile_all[ran].mean()) if ran.any() else 0.0
        elem_all = np.asarray(stats["element_traffic_fraction"][:steps])
        elem = float(elem_all[ran].mean()) if ran.any() else 0.0
        print(f"[serve] plane_traffic_fraction: {tile:.3f} tile-granular "
              f"(kernel DMA), {elem:.3f} element-granular (ASIC model)")
    print("sample tokens:", toks_h[0, :8].tolist())


if __name__ == "__main__":
    main()
