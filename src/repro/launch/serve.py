"""Serving driver: prefill a batch of prompts, decode new tokens, report
tokens/s.  Mesh-aware (TP sharding of params and caches); CPU smoke:

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
      --batch 4 --prompt-len 32 --new-tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke
from repro.launch.mesh import batch_axes, make_host_mesh, make_production_mesh
from repro.launch.shardings import cache_shardings, params_shardings
from repro.models.model import init_caches, init_params
from repro.models.sharding import mesh_axes
from repro.serving.engine import make_prefill_step, make_serve_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="host", choices=["host", "pod", "pod2"])
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--quant", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if cfg.frontend == "audio_stub":
        raise SystemExit("use examples/serve_decode.py for the audio stub")
    if args.mesh == "host":
        mesh = make_host_mesh(args.model_parallel)
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "pod2")
    bax = batch_axes(mesh)
    max_len = args.prompt_len + args.new_tokens

    with mesh, mesh_axes(batch=bax, model="model", seq_shard=False,
                         sizes=dict(mesh.shape), mesh=mesh):
        params = init_params(jax.random.PRNGKey(args.seed), cfg)
        psh = params_shardings(mesh, params, fsdp=False)
        params = jax.device_put(params, psh)
        caches = init_caches(cfg, args.batch, max_len, dtype=cfg.dtype)
        csh = cache_shardings(mesh, caches, batch=args.batch)
        caches = jax.device_put(caches, csh)

        key = jax.random.PRNGKey(args.seed)
        prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                    cfg.vocab_size)
        if cfg.frontend == "vision_stub":
            n_img = cfg.n_image_tokens
            img = jax.random.normal(key, (args.batch, n_img, cfg.d_model),
                                    jnp.bfloat16)
            batch = {"tokens": prompt, "image_embeds": img}
        else:
            batch = {"tokens": prompt}

        prefill = jax.jit(make_prefill_step(cfg, args.quant),
                          donate_argnums=(2,))
        step = jax.jit(make_serve_step(cfg, args.quant), donate_argnums=(1,))

        t0 = time.perf_counter()
        logits, caches = prefill(params, batch, caches)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0

        cur = jnp.argmax(logits, axis=-1)
        toks = [cur]
        t1 = time.perf_counter()
        for _ in range(args.new_tokens - 1):
            logits, caches = step(params, caches, cur[:, None])
            cur = jnp.argmax(logits, axis=-1)
            toks.append(cur)
        jax.block_until_ready(cur)
        t_decode = time.perf_counter() - t1

    total_new = args.batch * args.new_tokens
    print(f"[serve] {cfg.name}: prefill {args.batch}x{args.prompt_len} "
          f"in {t_prefill:.3f}s; {total_new} tokens decoded in "
          f"{t_decode:.3f}s ({total_new / max(t_decode, 1e-9):.1f} tok/s)")
    print("sample tokens:", jnp.stack(toks, axis=1)[0, :8].tolist())


if __name__ == "__main__":
    main()
