"""Production mesh builders.

Functions (not module-level constants) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* first jax
init; smoke tests see the real single device.
"""

from __future__ import annotations

import re
import warnings

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: 16x16 = 256 chips per pod; 2 pods = 512 chips.

    Axes: ``data`` (DP/FSDP), ``model`` (TP/EP/SP); ``pod`` composes with
    ``data`` for cross-pod data parallelism (DCN-friendly: only gradient
    all-reduces cross pods).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """``data x model`` mesh over whatever devices exist (CPU smoke runs).

    A single-device process silently falls back to ``model_parallel=1``
    (with a warning) so the same CLI invocation works on a laptop and under
    ``--xla_force_host_platform_device_count``; any other indivisibility is
    a real configuration error and raises (a ``ValueError``, not an assert —
    asserts vanish under ``python -O``).
    """
    n = len(jax.devices())
    if model_parallel != 1 and n == 1:
        warnings.warn(f"make_host_mesh: only 1 device visible; falling back "
                      f"to model_parallel=1 (requested {model_parallel})",
                      stacklevel=2)
        model_parallel = 1
    if model_parallel < 1 or n % model_parallel != 0:
        raise ValueError(
            f"make_host_mesh: model_parallel={model_parallel} must be >= 1 "
            f"and divide the visible device count ({n} devices)")
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))


_MESH_SPEC_RE = re.compile(r"^(\d+)x(\d+)$")


def make_serve_mesh(spec: str, model_parallel: int = 1):
    """Mesh from a CLI spec: ``host`` (all devices / ``model_parallel``),
    ``pod`` / ``pod2`` (production v5e meshes), or an explicit ``DxM``
    (``2x2``, ``4x1``, ...) ``data x model`` grid over the visible devices.
    """
    if spec == "host":
        return make_host_mesh(model_parallel)
    if spec in ("pod", "pod2"):
        return make_production_mesh(multi_pod=spec == "pod2")
    m = _MESH_SPEC_RE.match(spec)
    if not m:
        raise ValueError(f"mesh spec {spec!r}: expected 'host', 'pod', "
                         f"'pod2', or 'DxM' (e.g. '2x2')")
    d, t = int(m.group(1)), int(m.group(2))
    n = len(jax.devices())
    if d * t > n:
        raise ValueError(f"mesh spec {spec!r} needs {d * t} devices but only "
                         f"{n} are visible (set XLA_FLAGS="
                         f"--xla_force_host_platform_device_count={d * t} "
                         f"for a CPU smoke run)")
    return jax.make_mesh((d, t), ("data", "model"))


def batch_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
