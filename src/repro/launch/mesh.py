"""Production mesh builders.

Functions (not module-level constants) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* first jax
init; smoke tests see the real single device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: 16x16 = 256 chips per pod; 2 pods = 512 chips.

    Axes: ``data`` (DP/FSDP), ``model`` (TP/EP/SP); ``pod`` composes with
    ``data`` for cross-pod data parallelism (DCN-friendly: only gradient
    all-reduces cross pods).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Degenerate mesh over whatever devices exist (CPU smoke runs)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))


def batch_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
