"""Loop-aware HLO analysis for the roofline — compatibility shim.

The implementation moved to :mod:`repro.analysis.hlo` (the program
auditor, the debug CLIs, and the roofline all share one HLO-text parsing
layer now); this module keeps the historical import path
(``repro.launch.hlo_analysis.analyze_hlo``) and the old private-underscore
names working.
"""

from __future__ import annotations

from repro.analysis.hlo import (
    COLLECTIVES,
    DTYPE_BYTES,
    SKIP_TRAFFIC,
    TRIP_RE,
    Computation,
    Instr,
    Totals,
    analyze_hlo,
    collective_census,
    dot_flops,
    scaled_instructions,
    split_computations,
    trip_count,
    type_bytes,
)

# historical private names (pre-refactor callers imported these directly)
_COLLECTIVES = COLLECTIVES
_DTYPE_BYTES = DTYPE_BYTES
_SKIP_TRAFFIC = SKIP_TRAFFIC
_TRIP_RE = TRIP_RE
_dot_flops = dot_flops
_split_computations = split_computations
_trip_count = trip_count
_type_bytes = type_bytes

__all__ = [
    "COLLECTIVES", "DTYPE_BYTES", "SKIP_TRAFFIC", "TRIP_RE", "Computation",
    "Instr", "Totals", "analyze_hlo", "collective_census", "dot_flops",
    "scaled_instructions", "split_computations", "trip_count", "type_bytes",
]
