"""Loop-aware HLO analysis for the roofline.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body **once**, but our
models are ``lax.scan``-over-layers — everything interesting sits inside a
while loop with a static trip count.  This module re-derives roofline inputs
from ``compiled.as_text()`` with loop multipliers:

* **FLOPs** — from ``dot``/``convolution`` ops: 2 * prod(result_dims) *
  contracted_size (operand types resolved through a per-computation symbol
  table; dots inside fusions included).
* **Collective bytes** — result bytes of all-reduce / all-gather /
  reduce-scatter / all-to-all / collective-permute, per kind (async pairs
  counted at the ``-done``).
* **HBM traffic estimate** — 2x the result bytes of top-level (non-fused)
  instructions: fusion boundaries are materialization points, and each
  materialized buffer is written once and read ~once downstream.  Counting
  results only (not operands) avoids double-counting shared inputs.

Trip counts come from the ``known_trip_count`` backend_config XLA attaches
to while ops (fallback: the comparison constant in the loop condition).
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "f4e2m1fn": 0.5, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_HEADER_RE = re.compile(r"^\s*(ENTRY\s+)?%([\w\.\-]+)\s*\(")
_OP_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONST_RE = re.compile(r"constant\((\d+)\)")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _type_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1.0
        for d in [int(x) for x in dims.split(",") if x]:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_array_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(x) for x in m.group(2).split(",") if x]


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    line: str


@dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: List[Instr] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)


def _split_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        stripped = raw.strip()
        if cur is None:
            m = _HEADER_RE.match(raw)
            if m and raw.rstrip().endswith("{"):
                cur = Computation(name=m.group(2), is_entry=bool(m.group(1)))
                comps[cur.name] = cur
                if cur.is_entry:
                    comps["__entry__"] = cur
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        om = _OP_RE.match(stripped)
        if om:
            ins = Instr(name=om.group(1), type_str=om.group(2).strip(),
                        op=om.group(3), line=stripped)
            cur.instrs.append(ins)
            cur.symbols[ins.name] = ins.type_str
    return comps


def _operand_names(line: str) -> List[str]:
    try:
        start = line.index("(")
    except ValueError:
        return []
    # stop at attribute section (", key=") to avoid called-computation refs
    body = line[start:]
    cut = re.search(r"\),\s*\w+=", body)
    if cut:
        body = body[: cut.start() + 1]
    return _OPERAND_RE.findall(body)


def _called_computations(line: str) -> List[str]:
    out = []
    for key in ("body", "condition", "calls", "to_apply",
                "branch_computations"):
        m = re.search(key + r"=\{?([^,}\s]+(?:,\s*[^,}\s]+)*)\}?", line)
        if m:
            for c in m.group(1).split(","):
                c = c.strip().lstrip("%")
                if c:
                    out.append(c)
    return out


def _dot_flops(ins: Instr, symbols: Dict[str, str]) -> float:
    out_elems = 1.0
    for d in _first_array_dims(ins.type_str):
        out_elems *= d
    opnds = _operand_names(ins.line)
    if not opnds:
        return 0.0
    lhs_type = symbols.get(opnds[0], "")
    lhs_dims = _first_array_dims(lhs_type)
    contract = 1.0
    if ins.op == "dot":
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
        if m and m.group(1):
            for ci in m.group(1).split(","):
                ci = int(ci)
                if ci < len(lhs_dims):
                    contract *= lhs_dims[ci]
    elif ins.op == "convolution":
        # contracted size = kernel spatial x input features (approx: rhs
        # elements / output features)
        rhs_dims = _first_array_dims(symbols.get(opnds[1], "")) if len(opnds) > 1 else []
        out_dims = _first_array_dims(ins.type_str)
        if rhs_dims and out_dims:
            contract = max(1.0, float(int(
                __import__("numpy").prod(rhs_dims))) / max(out_dims[-1], 1))
    return 2.0 * out_elems * contract


@dataclass
class Totals:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(
        default_factory=lambda: defaultdict(float))
    unknown_trip_loops: int = 0

    def scaled(self, k: float) -> "Totals":
        t = Totals(flops=self.flops * k, traffic_bytes=self.traffic_bytes * k,
                   unknown_trip_loops=self.unknown_trip_loops)
        for kk, v in self.collective_bytes.items():
            t.collective_bytes[kk] = v * k
        return t

    def add(self, o: "Totals"):
        self.flops += o.flops
        self.traffic_bytes += o.traffic_bytes
        self.unknown_trip_loops += o.unknown_trip_loops
        for k, v in o.collective_bytes.items():
            self.collective_bytes[k] += v


def _trip_count(ins: Instr, comps: Dict[str, Computation]) -> Optional[int]:
    m = _TRIP_RE.search(ins.line)
    if m:
        return int(m.group(1))
    cm = re.search(r"condition=%?([\w\.\-]+)", ins.line)
    if cm and cm.group(1) in comps:
        consts = [int(c) for i in comps[cm.group(1)].instrs
                  for c in _CONST_RE.findall(i.line)]
        consts = [c for c in consts if c > 0]
        if consts:
            return max(consts)
    return None


_SKIP_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "copy-start", "copy-done", "after-all",
                 "partition-id", "replica-id", "iota"}


def _dus_update_bytes(comps, called_names) -> Optional[float]:
    """If a fused computation performs an in-place buffer update (contains a
    dynamic-update-slice whose buffer spans the fusion result, possibly
    behind converts), return the update-operand bytes; else None."""
    for c in called_names:
        comp = comps.get(c)
        if comp is None or not comp.instrs:
            continue
        for ins in comp.instrs:
            if ins.op == "dynamic-update-slice":
                ops_ = _operand_names(ins.line)
                if len(ops_) > 1:
                    ub = _type_bytes(comp.symbols.get(ops_[1], ""))
                    if ub:
                        return ub
    return None


def analyze_hlo(hlo: str) -> Dict[str, float]:
    comps = _split_computations(hlo)
    entry = comps.get("__entry__")
    if entry is None:
        raise ValueError("no ENTRY computation found")
    memo: Dict[Tuple[str, bool], Totals] = {}

    def walk(name: str, top_level: bool) -> Totals:
        key = (name, top_level)
        if key in memo:
            return memo[key]
        memo[key] = Totals()                                  # cycle guard
        comp = comps.get(name)
        if comp is None:
            return memo[key]
        t = Totals()
        for ins in comp.instrs:
            rb = _type_bytes(ins.type_str)
            if ins.op == "while":
                trips = _trip_count(ins, comps)
                if trips is None:
                    trips = 1
                    t.unknown_trip_loops += 1
                bm = re.search(r"body=%?([\w\.\-]+)", ins.line)
                if bm:
                    t.add(walk(bm.group(1), True).scaled(trips))
                continue
            if ins.op in ("call", "conditional", "async-start"):
                for c in _called_computations(ins.line):
                    t.add(walk(c, True))
                continue
            if ins.op == "fusion":
                inner = Totals()
                called = _called_computations(ins.line)
                for c in called:
                    inner.add(walk(c, False))
                t.flops += inner.flops
                for k, v in inner.collective_bytes.items():
                    t.collective_bytes[k] += v
                if top_level:
                    # in-place update fusions (root = dynamic-update-slice)
                    # write only the update slice, not the whole buffer
                    ub = _dus_update_bytes(comps, called)
                    t.traffic_bytes += 2.0 * (ub if ub is not None else rb)
                continue
            if ins.op == "dynamic-update-slice":
                if top_level:
                    ops_ = _operand_names(ins.line)
                    ub = (_type_bytes(comp.symbols.get(ops_[1], ""))
                          if len(ops_) > 1 else rb)
                    t.traffic_bytes += 2.0 * ub
                continue

            base = ins.op
            for suf in ("-start", "-done"):
                if base.endswith(suf):
                    base = base[: -len(suf)]
            if base in _COLLECTIVES:
                if not ins.op.endswith("-start"):
                    t.collective_bytes[base] += rb
                    if top_level:
                        t.traffic_bytes += 2.0 * rb
                continue
            if ins.op in ("dot", "convolution"):
                t.flops += _dot_flops(ins, comp.symbols)
            if ins.op in ("reduce", "reduce-window"):
                # flops ~ input elements (one accumulate op per element)
                for o in _operand_names(ins.line)[:1]:
                    ob = _type_bytes(comp.symbols.get(o, ""))
                    t.flops += ob / 4.0
            if top_level and ins.op not in _SKIP_TRAFFIC:
                t.traffic_bytes += 2.0 * rb
        memo[key] = t
        return t

    total = walk(entry.name, True)
    # entry parameters (weights/caches) are materialized buffers no op
    # produces — count one read of each (loop xs slicing reads each element
    # once per step; FSDP re-gathers already appear as all-gather results)
    param_bytes = sum(_type_bytes(i.type_str) for i in entry.instrs
                      if i.op == "parameter")
    return {
        "flops": total.flops,
        "traffic_bytes": total.traffic_bytes + param_bytes,
        "param_bytes": param_bytes,
        "collective_bytes": dict(total.collective_bytes),
        "collective_bytes_total": float(sum(total.collective_bytes.values())),
        "unknown_trip_loops": total.unknown_trip_loops,
    }
