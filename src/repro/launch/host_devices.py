"""``--host-devices N`` bootstrap for CLI entry points.

jax locks the host device count at first init, so this must run before the
first ``import jax`` — which is why this module is deliberately jax-free
(and ``repro``/``repro.launch`` are namespace packages, so importing it
pulls in nothing else).  Shared by ``repro.launch.serve`` and
``examples/serve_continuous.py``.
"""

from __future__ import annotations

import os
import re
import warnings

_COUNT_RE = re.compile(r"--xla_force_host_platform_device_count=(\d+)")


def force_host_devices(argv, environ=os.environ):
    """Apply ``--host-devices N`` / ``--host-devices=N`` from ``argv`` to
    ``XLA_FLAGS``.  Appends to a pre-set ``XLA_FLAGS`` rather than being
    swallowed by it; an existing forced count is *replaced* (with a warning
    when it differs) — the explicitly passed knob always wins.  Returns the
    requested count, or None if the flag is absent."""
    n = None
    for i, a in enumerate(argv):
        if a == "--host-devices" and i + 1 < len(argv):
            n = int(argv[i + 1])
        elif a.startswith("--host-devices="):
            n = int(a.split("=", 1)[1])
    if n is None:
        return None
    flag = f"--xla_force_host_platform_device_count={n}"
    prev = environ.get("XLA_FLAGS", "")
    m = _COUNT_RE.search(prev)
    if m:
        if int(m.group(1)) != n:
            warnings.warn(
                f"--host-devices {n} replaces the existing "
                f"xla_force_host_platform_device_count={m.group(1)} "
                f"in XLA_FLAGS", stacklevel=2)
        environ["XLA_FLAGS"] = _COUNT_RE.sub(flag, prev)
    else:
        environ["XLA_FLAGS"] = f"{prev} {flag}".strip()
    return n
