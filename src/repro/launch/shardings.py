"""Parameter/cache/batch partition rules with divisibility fallback.

TP rule per leaf (by pytree path name), FSDP rule on top (largest remaining
dim sharded on the data axis for leaves above a size threshold), and every
rule checks divisibility against the mesh — non-divisible dims stay
replicated (e.g. smollm's 9 heads under 16-way TP).
"""

from __future__ import annotations

import re
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# leaf-name -> index of the dim to shard on the model axis (negative = from
# the right; None = replicate).  Stacked layer leaves carry a leading repeat
# dim, hence counting from the right.
_TP_RULES = [
    # QeiHaN bit-plane weights (R, 8, K[, /8], N): same relative dims as
    # their float counterparts (packing only shrinks K)
    (r"\['(wq_q|wk_q|wv_q|gate_q|up_q|in_proj_q)'\]\.planes$", -1),
    (r"\['(wo_q|down_q|out_proj_q)'\]\.planes$", -2),
    (r"\.(w_scale|act_scale)$", None),
    (r"\['embed'\]$", 0),              # (V, d): vocab
    (r"\['lm_head'\]$", -1),           # (d, V): vocab
    (r"\['img_proj'\]$", -1),
    (r"\['(wq|wk|wv)'\]$", -1),        # (R, d, H*hd): heads
    (r"\['(bq|bk|bv)'\]$", -1),
    (r"\['wo'\]$", -2),                # (R, H*hd, d): input/head dim
    (r"\['experts'\]\['(gate|up|down)'\]$", -3),   # (R, E, ..): experts (EP)
    (r"\['(gate|up)'\]$", -1),         # (R, d, ff): ff
    (r"\['down'\]$", -2),              # (R, ff, d): ff
    (r"\['router'\]$", None),
    (r"\['in_proj'\]$", -1),           # (R, d, Z): inner (legacy fused)
    (r"\['(wz|wx)'\]$", -1),           # (R, d, d_inner)
    (r"\['(wb|wc|wdt)'\]$", None),     # small B/C/dt heads: replicate
    (r"\['out_proj'\]$", -2),          # (R, d_inner, d)
    (r"\['conv_w(x)?'\]$", -1),
    (r"\['conv_b(x)?'\]$", -1),
    (r"\['conv_[wb][bc]'\]$", None),
    (r"\['(dt_bias|a_log|d_skip)'\]$", -1),
    (r"\['norm'\]$", -1),              # (R, d_inner): gated-norm weight
    (r"\['(ln1|ln2|q_norm|k_norm|final_norm)'\]$", None),
]


def _axis_size(mesh: Mesh, axis: Optional[str]) -> int:
    if axis is None:
        return 1
    return mesh.shape[axis]


def _tp_dim(path: str, ndim: int) -> Optional[int]:
    if ndim == 0:                       # scalar placeholder (dropped weight)
        return None
    for pat, dim in _TP_RULES:
        if re.search(pat, path):
            if dim is None:
                return None
            return dim % ndim
    return None


_EXPERT_RE = re.compile(r"\['experts'\]\['(gate|up|down)'\]$")


def param_spec(path: str, shape: tuple, mesh: Mesh, *,
               model_axis: Optional[str] = "model",
               fsdp_axes: tuple = (),
               fsdp_threshold: int = 1 << 20,
               tp_scope: str = "all") -> P:
    ndim = len(shape)
    entries: list = [None] * ndim
    is_expert = bool(_EXPERT_RE.search(path))
    use_tp = model_axis is not None and (tp_scope == "all" or is_expert)
    msize = _axis_size(mesh, model_axis) if use_tp else 1
    if use_tp and msize > 1:
        tp = _tp_dim(path, ndim)
        if tp is not None and shape[tp] % msize == 0:
            entries[tp] = model_axis
    # FSDP: shard the largest remaining divisible dim on the given axes.
    # Expert weights under EP stay resident (shard_map owns them 1:1).
    if fsdp_axes and not (is_expert and tp_scope == "experts") \
            and int(np.prod(shape)) >= fsdp_threshold:
        fsize = int(np.prod([_axis_size(mesh, a) for a in fsdp_axes]))
        cands = sorted(range(ndim), key=lambda d: -shape[d])
        for d in cands:
            if entries[d] is None and shape[d] % fsize == 0:
                entries[d] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
                break
    return P(*entries)


def params_shardings(mesh: Mesh, params_tree: Any, *, fsdp: bool = True,
                     model_axis: Optional[str] = "model",
                     fsdp_axes: Optional[tuple] = None,
                     fsdp_threshold: int = 1 << 20,
                     tp_scope: str = "all",
                     ep_axis: Optional[str] = None) -> Any:
    from repro.launch.mesh import batch_axes
    if fsdp_axes is None:
        fax = batch_axes(mesh) if fsdp else ()
    else:
        fax = fsdp_axes if fsdp else ()
    # under EP-only scope, experts bind the EP axis
    eff_model = model_axis if model_axis is not None else ep_axis
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_tree)
    out = []
    for path, leaf in flat:
        spec = param_spec(jax.tree_util.keystr(path), leaf.shape, mesh,
                          model_axis=eff_model,
                          fsdp_axes=fax, fsdp_threshold=fsdp_threshold,
                          tp_scope=tp_scope)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def opt_shardings(mesh: Mesh, opt_tree: Any, param_shardings_tree: Any,
                  extra_axes: tuple = ()) -> Any:
    """Moments follow their parameters; scalars replicate.

    ``extra_axes``: additionally shard each moment's largest free dim on
    these axes — f32 m/v are the optimizer-memory hog, and since the update
    is elementwise any sharding is valid (EP expert weights keep their
    weights resident but spread their moments)."""
    rep = NamedSharding(mesh, P())

    def widen(sh, leaf):
        if not extra_axes:
            return sh
        spec = list(sh.spec) + [None] * (len(leaf.shape) - len(sh.spec))
        used = set()
        for e in spec:
            for a in (e if isinstance(e, tuple) else (e,)):
                if a:
                    used.add(a)
        free = tuple(a for a in extra_axes if a not in used)
        if not free:
            return sh
        fsize = int(np.prod([_axis_size(mesh, a) for a in free]))
        for d in sorted(range(len(leaf.shape)), key=lambda i: -leaf.shape[i]):
            if spec[d] is None and leaf.shape[d] % fsize == 0:
                spec[d] = free if len(free) > 1 else free[0]
                break
        return NamedSharding(mesh, P(*spec))

    m_sh = jax.tree.map(widen, param_shardings_tree, opt_tree["m"])
    return {
        "m": m_sh,
        "v": jax.tree.map(lambda s: s, m_sh),
        "step": rep,
    }


def batch_shardings(mesh: Mesh, batch_tree: Any,
                    axes: Optional[tuple] = None) -> Any:
    from repro.launch.mesh import batch_axes
    bax = tuple(axes) if axes is not None else batch_axes(mesh)

    def one(leaf):
        b = leaf.shape[0]
        # use the longest prefix of batch axes that divides the batch
        use = list(bax)
        while use and b % int(np.prod([mesh.shape[a] for a in use])):
            use.pop()
        if use:
            return NamedSharding(mesh, P(tuple(use)))
        return NamedSharding(mesh, P())
    return jax.tree.map(one, batch_tree)


def cache_shardings(mesh: Mesh, caches_tree: Any, *, batch: int,
                    long_context: bool = False,
                    axes: Optional[tuple] = None,
                    model_axis: Optional[str] = "model",
                    ssm_model: bool = True,
                    paged: bool = False) -> Any:
    """KV caches (R, B, S, Hkv, D) / SSM states (R, B, H, P, N).

    decode: batch on the data axes; long-context (batch=1): KV sequence dim
    on data instead.  Model-axis sharding: kv-heads / ssm-heads when
    divisible.  The per-slot ``length`` vector (``init_caches(per_slot=
    True)``, shape (B,)) follows the batch axes like every other per-row
    cache leaf — the scalar whole-batch ``length`` replicates.

    ``ssm_model=False`` keeps the SSM/conv state leaves batch-only: a
    model-sharded recurrent state carried through the serve tick's scan is
    miscompiled by the jax 0.4.37 CPU SPMD pipeline (partially-replicated
    meshes; tests/test_serve_sharded.py), so the *executing* serve path
    (``serve_shardings``) opts out while lowering-only consumers (the
    dry-run) keep the full TP image.

    ``paged=True`` reads the KV leaves as page pools (R, P, page_len, Hkv,
    D): the page dim takes the batch role (pages on data — every slot's
    rows live in its pages, scattered/gathered through the page table) and
    the in-page token dim is NEVER sharded, so the (page, offset) indexing
    of ``models.attention._paged_write`` touches no sharded-axis reshape.
    """
    from repro.launch.mesh import batch_axes
    bax = tuple(axes) if axes is not None else batch_axes(mesh)
    nb = int(np.prod([mesh.shape[a] for a in bax]))
    msz = (mesh.shape[model_axis]
           if model_axis and model_axis in mesh.axis_names else 1)

    def one_path(path, leaf):
        name = jax.tree_util.keystr(path)
        shape = leaf.shape
        if name.endswith("['length']"):
            if len(shape) == 1 and nb > 1 and shape[0] % nb == 0:
                return NamedSharding(mesh, P(bax if len(bax) > 1 else bax[0]))
            return NamedSharding(mesh, P())
        entries = [None] * len(shape)
        if paged and re.search(r"'[kv]_(codes|scale)'", name):
            # log2-quantized page pool: codes (R, P, page_len, Hkv, D) and
            # per-page scales (R, P, Hkv) — pages on data, like the dense
            # pool; a page's codes and its scale land on the same shard so
            # dequant (codes + scale -> rows) stays local
            if shape[1] % nb == 0 and nb > 1:
                entries[1] = bax if len(bax) > 1 else bax[0]
            return NamedSharding(mesh, P(*entries))
        if paged and re.search(r"'[kv]_tail'", name):
            # f32 tail ring (R, B, 2*page_len+1, Hkv, D): per-slot rows,
            # batch on data like every other per-row cache leaf
            if shape[1] % nb == 0 and nb > 1:
                entries[1] = bax if len(bax) > 1 else bax[0]
            return NamedSharding(mesh, P(*entries))
        if paged and ("'k'" in name or "'v'" in name):
            # page pool (R, P, page_len, Hkv, D): pages on data only
            if shape[1] % nb == 0 and nb > 1:
                entries[1] = bax if len(bax) > 1 else bax[0]
            return NamedSharding(mesh, P(*entries))
        if "'k'" in name or "'v'" in name:          # (R, B, S, Hkv, D)
            if long_context:
                if shape[2] % nb == 0 and nb > 1:
                    entries[2] = bax if len(bax) > 1 else bax[0]
            else:
                if shape[1] % nb == 0 and nb > 1:
                    entries[1] = bax if len(bax) > 1 else bax[0]
                # kv heads rarely divide the TP axis; the seq dim always does
                if msz > 1 and shape[2] % msz == 0:
                    entries[2] = model_axis
        elif "'ssm'" in name:                       # (R, B, H, P, N)
            if shape[1] % nb == 0 and nb > 1:
                entries[1] = bax if len(bax) > 1 else bax[0]
            if ssm_model and msz > 1 and shape[2] % msz == 0:
                entries[2] = model_axis
        elif "'conv'" in name:                      # (R, B, W-1, C)
            if shape[1] % nb == 0 and nb > 1:
                entries[1] = bax if len(bax) > 1 else bax[0]
            if ssm_model and msz > 1 and shape[3] % msz == 0:
                entries[3] = model_axis
        return NamedSharding(mesh, P(*entries))

    flat, treedef = jax.tree_util.tree_flatten_with_path(caches_tree)
    return jax.tree_util.tree_unflatten(
        treedef, [one_path(p, l) for p, l in flat])


def split_kv_specs(mesh: Mesh, *, splits: int, batch: int,
                   model_axis: Optional[str] = "model",
                   axes: Optional[tuple] = None) -> dict:
    """Split-KV flash-decode partial-reduce rule (kernels/paged_attention).

    The paged-attention kernel partitions the KV page axis into ``splits``
    contiguous runs; each run emits partial online-softmax state — ``acc``
    (B, G, split, R, D) plus the (m, l) statistics (B, G, split, R) — and
    the cross-split merge (``ops.merge_split_softmax``) is the only
    reduction that crosses runs.  Under a mesh the split axis rides the
    model axis (each model shard owns its page run and reads nothing
    else), the batch axis rides data like every per-slot tensor, and the
    merge ships one (B, G, R)-sized triple per shard instead of
    all-gathering cache pages.

    Returns ``{"partial": P, "stat": P}`` — the jit-boundary image of the
    ``models.sharding`` ``"kvsplit"`` / ``"kvsplit_stat"`` hint kinds
    (same divisibility fallback: a non-divisible axis stays replicated).
    """
    from repro.launch.mesh import batch_axes
    bax = tuple(axes) if axes is not None else batch_axes(mesh)
    nb = int(np.prod([mesh.shape[a] for a in bax]))
    msz = (mesh.shape[model_axis]
           if model_axis and model_axis in mesh.axis_names else 1)
    b_entry = (bax if len(bax) > 1 else bax[0]) \
        if nb > 1 and batch % nb == 0 else None
    s_entry = model_axis if msz > 1 and splits % msz == 0 else None
    return {
        "partial": P(b_entry, None, s_entry, None, None),
        "stat": P(b_entry, None, s_entry, None),
    }


def serve_shardings(mesh: Mesh, params_tree: Any, caches_tree: Any, *,
                    batch: int,
                    model_axis: Optional[str] = "model",
                    axes: Optional[tuple] = None,
                    paged: bool = False) -> dict:
    """Everything the mesh-native serving stack pins at jit boundaries.

    One bundle so ``serving/engine.py`` / ``serving/scheduler.py`` consume a
    single object instead of re-deriving rules leaf by leaf:

    * ``params``  — TP rules (``_TP_RULES``: float weights AND packed
      bit-planes — the plane leaves shard on the same relative dims as their
      float counterparts, the decode-time image of the paper's §IV-B
      vault-level parallelism), no FSDP (serving wants weights resident).
    * ``caches``  — KV/SSM slot pool: batch on ``data``, kv-seq on ``model``
      when divisible, per-slot (B,) ``length`` on ``data``.  SSM/conv state
      stays batch-only (``ssm_model=False`` — the executing CPU SPMD
      pipeline miscompiles a model-sharded recurrent carry; see
      ``cache_shardings``).
    * ``logits``  — (B, V) decode carry: batch on ``data``, vocab replicated
      (the greedy argmax stays a local per-row reduction).
    * ``tokens`` / ``active`` — per-slot (B, ...) arrays on ``data``.
      ``tokens`` is any per-slot token slab — the (B, 1) decode token AND
      the chunked-prefill (B, chunk_len) chunk slab (the slab's row lands
      on the device holding that slot's cache rows, so the chunk write
      stays local); ``active`` likewise covers every (B,) host-built flag
      vector (the decode-active mask and the chunked ``chunk_valid`` /
      ``fresh`` / ``finishing`` vectors).
    * ``replicated`` — the catch-all for host-supplied scalars.

    ``paged=True`` (the paged slot pool, ISSUE 5): KV leaves are page
    pools sharded pages-on-data (see ``cache_shardings``); the host-built
    page table rides the ``tokens`` sharding — its rows follow the slots.
    With the paged-attention kernel enabled (``attn_kernel=``, ISSUE 6)
    the in-tick split-KV partials follow :func:`split_kv_specs` via the
    ``models.sharding`` hint kinds — no extra jit-boundary entry needed.
    """
    from repro.launch.mesh import batch_axes
    bax = tuple(axes) if axes is not None else batch_axes(mesh)
    nb = int(np.prod([mesh.shape[a] for a in bax]))
    row = (P(bax if len(bax) > 1 else bax[0])
           if nb > 1 and batch % nb == 0 else P())
    return {
        "params": params_shardings(mesh, params_tree, fsdp=False,
                                   model_axis=model_axis),
        "caches": cache_shardings(mesh, caches_tree, batch=batch,
                                  axes=bax, model_axis=model_axis,
                                  ssm_model=False, paged=paged),
        "logits": NamedSharding(mesh, P(*row, None)),
        "tokens": NamedSharding(mesh, P(*row, None)),
        "active": NamedSharding(mesh, row),
        "replicated": NamedSharding(mesh, P()),
    }
