"""Pallas kernel: QeiHaN bit-plane shift-add matmul with plane skipping.

Paper mapping (arXiv 2310.18181; DESIGN.md "Paper ↔ code map"): TPU-native
realization of the paper's §IV — the D&S unit's Eq. 5 shift-add
(``core/shiftadd.py``) fused with the §IV-B *implicit bit-shift weight
access*: the scalar-prefetched skip table below is the vault controller
deciding, per tile, which weight bit-planes a negative log2 activation
exponent (§II, Eqs. 2-4) makes it skip.  Computes, exactly in integers,

    y[m, n] = sum_k  sign[m,k] * ArithShift(w[k,n], exp[m,k])

where ``ArithShift(w, e) = w << e`` for ``e >= 0`` and the *truncating*
``floor(w / 2^|e|)`` for ``e < 0`` (the sentinel exponent contributes 0).

Regrouping (see ``core.shiftadd``): ``y = sum_b sgn_b * (A_b @ P_b)`` with
``P_b`` the {0,1} bit-plane of the int8 weights and
``A_b[m,k] = sign * 2^(b + exp)`` wherever ``b + exp >= 0``, else 0.  Each
per-plane, per-K-block partial product is bounded by ``bk * 2^14 < 2^24`` so
an f32 MXU matmul is exact; accumulation across planes/K-blocks happens in an
int32 VMEM scratch.

The paper's memory-access saving appears here as **plane skipping**: a
scalar-prefetched table ``min_plane[mi, ki]`` holds the smallest plane index
any activation in tile ``(mi, ki)`` can touch (``max(0, -max_e)``, or 8 if
the tile is fully pruned).  Planes ``b < min_plane`` are skipped with
``@pl.when`` — on hardware the corresponding weight-plane tiles are never
read out of VMEM and the MXU issues nothing; the HBM-traffic image of the
skip is accounted by ``core.access_model.weight_access_report`` (granularity
='tile') and, for the ASIC, by ``simulator/``.

Grid: ``(M/bm, N/bn, K/bk)``, K innermost (accumulator-friendly).
VMEM at defaults (bm=bk=bn=128): planes block 8*128*128 B = 128 KiB,
exp/sign blocks 2*16 KiB, acc 64 KiB, A_b temporaries ~64 KiB -> ~0.3 MiB,
leaving headroom to raise bn/bk to 512 on real v5e.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

WEIGHT_BITS = 8

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def _bitplane_matmul_kernel(min_plane_ref,          # scalar prefetch (Mb, Kb)
                            exp_ref, sign_ref,       # (bm, bk) int8
                            planes_ref,              # (8, bk, bn) uint8
                            out_ref,                 # (bm, bn) int32
                            acc_ref,                 # VMEM scratch (bm, bn) int32
                            *, bits: int, n_bits: int, k_blocks: int):
    mi = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    sentinel = -(1 << (n_bits - 1))
    e = exp_ref[...].astype(jnp.int32)
    s = sign_ref[...].astype(jnp.int32)
    alive = e != sentinel

    min_plane = min_plane_ref[mi, ki]

    for b in range(bits):                          # static unroll: 8 planes
        @pl.when(b >= min_plane)
        def _plane(b=b):
            sh = b + e
            # A_b = sign * 2^(b+e) where contributing; exact powers of two in f32.
            a_b = jnp.where(alive & (sh >= 0),
                            (s << jnp.clip(sh, 0, 14)).astype(jnp.float32),
                            0.0)
            p_b = planes_ref[b].astype(jnp.float32)
            term = jax.lax.dot_general(
                a_b, p_b, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            ti = term.astype(jnp.int32)
            if b == bits - 1:
                ti = -ti                            # two's-complement sign plane
            acc_ref[...] += ti

    @pl.when(ki == k_blocks - 1)
    def _flush():
        out_ref[...] = acc_ref[...]


def bitplane_specs(m: int, k: int, n: int, bits: int,
                   block_m: int, block_n: int, block_k: int):
    """Grid + BlockSpecs shared by :func:`bitplane_matmul_kernel` and the
    static verifier's ``audit_specs()``."""
    grid = (m // block_m, n // block_n, k // block_k)
    in_specs = [
        # index maps receive the scalar-prefetch ref as a trailing arg
        pl.BlockSpec((block_m, block_k), lambda mi, ni, ki, mp: (mi, ki)),
        pl.BlockSpec((block_m, block_k), lambda mi, ni, ki, mp: (mi, ki)),
        pl.BlockSpec((bits, block_k, block_n),
                     lambda mi, ni, ki, mp: (0, ki, ni)),
    ]
    out_specs = pl.BlockSpec((block_m, block_n),
                             lambda mi, ni, ki, mp: (mi, ni))
    scratch_shapes = [pltpu.VMEM((block_m, block_n), jnp.int32)]
    return grid, in_specs, out_specs, scratch_shapes


def bitplane_matmul_kernel(exp: jnp.ndarray, sign: jnp.ndarray,
                           planes: jnp.ndarray, min_plane: jnp.ndarray,
                           *, n_bits: int = 4,
                           block_m: int = 128, block_n: int = 128,
                           block_k: int = 128,
                           interpret: bool = False) -> jnp.ndarray:
    """Pre-padded inputs: exp/sign (M, K) int8, planes (8, K, N) uint8,
    min_plane (M/bm, K/bk) int32. Returns int32 (M, N)."""
    m, k = exp.shape
    bits, k2, n = planes.shape
    assert k2 == k, (k2, k)
    grid, in_specs, out_specs, scratch_shapes = bitplane_specs(
        m, k, n, bits, block_m, block_n, block_k)

    kern = functools.partial(_bitplane_matmul_kernel, bits=bits,
                             n_bits=n_bits, k_blocks=grid[2])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=scratch_shapes,
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(min_plane, exp, sign, planes)


# ---------------------------------------------------------------------------
# static-verifier registration (analysis.kernel_rules)
# ---------------------------------------------------------------------------


def audit_specs():
    """Registered instantiations for the static kernel verifier.

    Three geometries: the canonical sigma-1.0 activation stream at the
    default 128 blocks (the traffic-model gate), the same stream re-tiled
    at 64 blocks (VMEM scaling), and a half-pruned stream exercising the
    fully-skipped ``min_plane == bits`` branch.  The ``min_plane`` skip
    table is built by the SAME ``ops._skip_table`` the runtime wrapper
    uses, so static and measured plane traffic share one source of truth.
    """
    import numpy as np

    from repro.analysis.pallas_inspect import (KernelInstantiation,
                                               make_operand, scratch_entry)
    from repro.kernels.bitplane_matmul.ops import (_skip_table,
                                                  canonical_logquant)

    n_bits = 4
    sentinel = -(1 << (n_bits - 1))
    cases = []

    exp_c, sign_c = canonical_logquant((256, 4096), sigma=1.0, seed=2,
                                       n_bits=n_bits)
    cases.append(("canon_s1.b128", exp_c, sign_c, 512, 128, 128, 128))
    cases.append(("canon_s1.b64", exp_c, sign_c, 512, 64, 64, 64))

    exp_p, sign_p = canonical_logquant((128, 512), sigma=0.25, seed=3,
                                       n_bits=n_bits)
    exp_p = np.array(exp_p)
    exp_p[:, :256] = sentinel              # half the K range fully pruned
    cases.append(("pruned_half.b128", exp_p, sign_p, 256, 128, 128, 128))

    out = []
    for name, exp, sign, n, bm, bn, bk in cases:
        m, k = exp.shape
        table = np.asarray(_skip_table(jnp.asarray(exp, jnp.int8), bm, bk,
                                       n_bits, WEIGHT_BITS))
        grid, in_specs, out_specs, scratch = bitplane_specs(
            m, k, n, WEIGHT_BITS, bm, bn, bk)
        inputs = (
            make_operand("exp", (m, k), jnp.int8, in_specs[0]),
            make_operand("sign", (m, k), jnp.int8, in_specs[1]),
            make_operand("planes", (WEIGHT_BITS, k, n), jnp.uint8,
                         in_specs[2]),
        )
        outputs = (
            make_operand("out", (m, n), jnp.int32, out_specs),
        )
        out.append(KernelInstantiation(
            kernel="bitplane_matmul", case=name, grid=grid,
            inputs=inputs, outputs=outputs,
            scratch=tuple(scratch_entry(s) for s in scratch),
            scalars=(table,),
            meta=dict(exp=np.asarray(exp), n_bits=n_bits, bits=WEIGHT_BITS,
                      block_m=bm, block_k=bk, min_plane=table),
        ))
    return out
