"""Pure-jnp oracle for the bit-plane matmul: direct per-element shifts.

Deliberately the most literal transcription of paper Eq. 5 + the D&S unit's
arithmetic-shift semantics — no bit-plane regrouping, no tiling — so the
kernel and oracle share neither algorithm nor layout.
"""

from __future__ import annotations

import jax.numpy as jnp


def bitplane_matmul_ref(exp: jnp.ndarray, sign: jnp.ndarray,
                        w_int8: jnp.ndarray, n_bits: int = 4) -> jnp.ndarray:
    """exp/sign: (M, K) int8; w_int8: (K, N) int8 -> (M, N) int32."""
    sentinel = -(1 << (n_bits - 1))
    e = exp.astype(jnp.int32)[:, :, None]           # (M, K, 1)
    s = sign.astype(jnp.int32)[:, :, None]
    w = w_int8.astype(jnp.int32)[None, :, :]        # (1, K, N)
    left = w << jnp.maximum(e, 0)
    right = w >> jnp.maximum(-e, 0)                 # arithmetic: floor(w/2^|e|)
    prod = jnp.where(e >= 0, left, right)
    prod = jnp.where(e == sentinel, 0, prod)
    return jnp.sum(s * prod, axis=1)
