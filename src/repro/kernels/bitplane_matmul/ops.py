"""Public jit'd wrapper for the bit-plane matmul Pallas kernel.

Responsibilities: pad (M, K, N) to block multiples, build the scalar-prefetch
``min_plane`` skip table from the activation exponents, invoke the kernel,
unpad.  Also exposes :func:`plane_traffic_fraction`, the HBM-traffic image of
the skip table used by benchmarks (granularity-matched to the kernel tiles).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.bitplane_matmul.kernel import (WEIGHT_BITS,
                                                  bitplane_matmul_kernel)


def _skip_table(exp: jnp.ndarray, block_m: int, block_k: int,
                n_bits: int, bits: int) -> jnp.ndarray:
    """min_plane[mi, ki] = max(0, -max_exp_tile); 'bits' if tile fully pruned."""
    sentinel = -(1 << (n_bits - 1))
    m, k = exp.shape
    e = exp.astype(jnp.int32).reshape(m // block_m, block_m,
                                      k // block_k, block_k)
    e = jnp.swapaxes(e, 1, 2)                        # (Mb, Kb, bm, bk)
    alive = e != sentinel
    neg_inf = jnp.int32(-128)
    max_e = jnp.max(jnp.where(alive, e, neg_inf), axis=(2, 3))
    min_plane = jnp.clip(-max_e, 0, bits)
    return jnp.where(jnp.any(alive, axis=(2, 3)), min_plane, bits).astype(
        jnp.int32)


@functools.partial(jax.jit, static_argnames=("n_bits", "block_m", "block_n",
                                             "block_k", "interpret"))
def bitplane_matmul_pallas(exp: jnp.ndarray, sign: jnp.ndarray,
                           planes: jnp.ndarray, n_bits: int = 4,
                           block_m: int = 128, block_n: int = 128,
                           block_k: int = 128,
                           interpret: bool | None = None) -> jnp.ndarray:
    """exp/sign int8 (M, K), planes uint8 (8, K, N) -> int32 (M, N)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, k = exp.shape
    bits, _, n = planes.shape

    pm, pk, pn = (-m) % block_m, (-k) % block_k, (-n) % block_n
    sentinel = -(1 << (n_bits - 1))
    # pad activations with the sentinel (contributes nothing), weights with 0.
    exp_p = jnp.pad(exp, ((0, pm), (0, pk)), constant_values=sentinel)
    sign_p = jnp.pad(sign, ((0, pm), (0, pk)), constant_values=1)
    planes_p = jnp.pad(planes, ((0, 0), (0, pk), (0, pn)))

    table = _skip_table(exp_p, block_m, block_k, n_bits, bits)
    out = bitplane_matmul_kernel(exp_p, sign_p, planes_p, table,
                                 n_bits=n_bits, block_m=block_m,
                                 block_n=block_n, block_k=block_k,
                                 interpret=interpret)
    return out[:m, :n]


def canonical_logquant(shape, sigma: float = 1.0, seed: int = 2,
                       n_bits: int = 4):
    """Deterministic (exp, sign) int8 stream for benches and the static
    kernel audit: N(0, sigma) activations from a fixed numpy generator,
    pushed through the paper's log2 quantizer.  Returned as numpy arrays
    so audit instantiations carry concrete scalar operands."""
    import numpy as np

    from repro.core.logquant import log2_quantize

    rng = np.random.default_rng(seed)
    x = rng.normal(0.0, sigma, shape).astype(np.float32)
    q = log2_quantize(jnp.asarray(x), n_bits=n_bits)
    return np.asarray(q.exp, np.int8), np.asarray(q.sign, np.int8)


def plane_traffic_counts(exp: jnp.ndarray, n_bits: int = 4,
                         block_m: int = 128, block_k: int = 128,
                         bits: int = WEIGHT_BITS):
    """(fetched, total) weight-plane tile counts, as f32 scalars.

    ``total`` is all ``bits`` planes of every (m-tile, k-tile) cell — what a
    standard int8 layout streams; ``fetched`` mirrors the kernel's skip rule
    exactly (same table).  Returned as a pair so callers accumulating over
    many projections (the serving engine's per-step stats) can weight each
    GEMM by its tile count before taking the fraction.
    """
    m, k = exp.shape
    pm, pk = (-m) % block_m, (-k) % block_k
    sentinel = -(1 << (n_bits - 1))
    exp_p = jnp.pad(exp, ((0, pm), (0, pk)), constant_values=sentinel)
    table = _skip_table(exp_p, block_m, block_k, n_bits, bits)
    fetched = jnp.sum(bits - table).astype(jnp.float32)
    total = jnp.asarray(bits * table.size, jnp.float32)
    return fetched, total


def plane_traffic_fraction(exp: jnp.ndarray, n_bits: int = 4,
                           block_m: int = 128, block_k: int = 128,
                           bits: int = WEIGHT_BITS) -> jnp.ndarray:
    """Fraction of weight-plane tiles the kernel actually touches (0..1)."""
    fetched, total = plane_traffic_counts(exp, n_bits, block_m, block_k, bits)
    return fetched / total
