"""Pallas kernel: paged-attention decode with split-KV flash-decode.

Paper mapping (arXiv 2310.18181; DESIGN.md §Paged attention kernel): the
paper's §IV thesis is that DNN inference is bounded by *memory accesses*,
and its in-memory scheme wins by touching only the rows a computation
actually needs.  The serving-side image of that is this kernel: instead of
gathering ``pool[table]`` into a dense padded ``(B, max_len, G, D)`` view
every decode tick (reading ALL allocated pages of every slot, valid or
not), the BlockSpec index maps below dereference the scalar-prefetched
page table themselves — block ``j`` of slot ``b`` loads pool page
``table[b, j]`` directly, so only resident pages ever stream into VMEM and
nothing is ever re-laid-out densely.

Per (slot, kv-head, split) the kernel walks that split's pages in order
with the standard online-softmax recurrence (running max ``m``, running
normalizer ``l``, rescaled accumulator ``acc`` — the same f32 statistics
``models.attention.flash_attention`` carries over KV chunks):

    s_j  = (q @ k_j^T) / sqrt(D),  masked to  pos < length  with the
           finite NEG_INF = -1e30 (never -inf: all-masked blocks then
           yield exp(0)=1 "uniform junk" instead of inf-inf NaNs, and the
           junk is *exactly* erased later — see below)
    m'   = max(m, max_k s_j)
    p    = exp(s_j - m');  corr = exp(m - m')
    l    = l * corr + sum_k p;   acc = acc * corr + p @ v_j

**Split-KV ("flash-decode", SNIPPETS.md flashdecode idiom)**: the page
axis is additionally partitioned into ``splits`` contiguous runs mapped to
a parallel grid axis; each run emits partial ``(acc, m, l)`` and the tiny
cross-split merge happens outside the kernel
(``ops.merge_split_softmax``).  A split that holds no valid token
accumulates uniform junk at ``m = NEG_INF``; the merge weights it by
``exp(NEG_INF - m_real) == 0.0`` exactly (f32 underflow), so junk splits
— and trash-page contents in general — are *bitwise* absent from the
output.  Under a mesh the split axis can ride the ``model`` axis
(``launch.shardings.split_kv_specs``), so each shard reads only its own
pages and ships one (B, G, R)-sized statistic triple.

Masking is the single ``pos < length`` predicate: decode queries sit at
position ``length - 1``, so the dense path's causal mask (``kv_pos <=
q_pos``) and validity mask (``kv_pos < length``) are the same set.

Grid: ``(B, G, splits, blocks_per_split)``, pages innermost
(accumulator-friendly, "arbitrary"); q/out blocks are whole (R, D) tiles —
R and D are small (<= head_dim) so VMEM residency is a few KiB per step.
On this CPU container the kernel runs in interpret mode (the wrapper
auto-selects), which lowers to plain traced lax ops — jittable, scannable
inside the serve tick, and partitionable by GSPMD.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

# The long-context ragged decode tick used by BOTH the paged_attn kernel
# microbench (benchmarks/kernel_bench.py) and the static kernel verifier
# (analysis.kernel_rules) — one geometry, one gather_saved_frac number,
# EXACT-gated in benchmarks/baselines/{paged_attn,kernel_audit}.json.
RAGGED512 = dict(b=4, page_len=16, nb=32, g=2, r=2, d=16,
                 lengths=(512, 300, 64, 17))


def paged_attn_specs(b: int, g: int, r: int, d: int, page_len: int,
                     nb: int, splits: int):
    """Grid + BlockSpecs + scratch of one kernel instantiation.

    ONE source of truth: :func:`paged_attention_kernel` assembles its
    ``PrefetchScalarGridSpec`` from exactly this, and each ``audit_specs``
    instantiation hands the same objects to the static verifier
    (``analysis.pallas_inspect``) — so the index maps the verifier proves
    in-bounds are the index maps the kernel ships, not a re-statement.
    """
    assert nb % splits == 0, (nb, splits)
    bps = nb // splits
    grid = (b, g, splits, bps)
    in_specs = [
        pl.BlockSpec((1, 1, r, d),
                     lambda bi, gi, si, ji, tab, lens: (bi, gi, 0, 0)),
        # the table walk: block index maps dereference the prefetched
        # page table — page (tab[b, split*bps + j]) streams in, nothing
        # else; the dense gather never happens
        pl.BlockSpec((1, page_len, 1, d),
                     lambda bi, gi, si, ji, tab, lens:
                     (tab[bi, si * bps + ji], 0, gi, 0)),
        pl.BlockSpec((1, page_len, 1, d),
                     lambda bi, gi, si, ji, tab, lens:
                     (tab[bi, si * bps + ji], 0, gi, 0)),
    ]
    out_specs = [
        pl.BlockSpec((1, 1, 1, r, d),
                     lambda bi, gi, si, ji, tab, lens:
                     (bi, gi, si, 0, 0)),
        pl.BlockSpec((1, 1, 1, r),
                     lambda bi, gi, si, ji, tab, lens: (bi, gi, si, 0)),
        pl.BlockSpec((1, 1, 1, r),
                     lambda bi, gi, si, ji, tab, lens: (bi, gi, si, 0)),
    ]
    scratch_shapes = [pltpu.VMEM((r, 1), jnp.float32),
                      pltpu.VMEM((r, 1), jnp.float32),
                      pltpu.VMEM((r, d), jnp.float32)]
    return grid, in_specs, out_specs, scratch_shapes, bps


def paged_attn_quant_specs(b: int, g: int, r: int, d: int, page_len: int,
                           nb: int, splits: int):
    """Quantized-pool variant of :func:`paged_attn_specs`.

    Same grid/out/scratch; the K/V operands are packed log2 code pools
    (same (P, page_len, G, D) geometry, int8/int16 elements — the §IV
    traffic saving is the dtype shrink on exactly these block loads) plus
    one (P, G) int32 scale-exponent pool each, block (1, 1) dereferenced
    through the same page-table walk.
    """
    grid, in_specs, out_specs, scratch_shapes, bps = paged_attn_specs(
        b, g, r, d, page_len, nb, splits)
    scale_spec = pl.BlockSpec(
        (1, 1),
        lambda bi, gi, si, ji, tab, lens: (tab[bi, si * bps + ji], gi))
    in_specs = [in_specs[0], in_specs[1], scale_spec, in_specs[2],
                scale_spec]
    return grid, in_specs, out_specs, scratch_shapes, bps


def _dequant_block(codes, se, n_bits: int):
    """In-kernel log2 dequant of one page block: ``sign * 2^(exp + se)``
    with the zero sentinel -> 0.  The summed exponent clamps to the f32
    normal range so garbage codes/scales (trash-page contents) decode to
    large-but-finite values the position mask then erases — never Inf/NaN
    (mirrors ``core.logquant.dequantize_page_codes``)."""
    sentinel = -(1 << (n_bits - 1))
    e = (codes >> 1).astype(jnp.int32)
    ee = jnp.clip(e + se, -126, 127)
    mag = jnp.exp2(ee.astype(jnp.float32))
    val = jnp.where((codes & 1) != 0, -mag, mag)
    return jnp.where(e == sentinel, 0.0, val)


def _paged_attn_kernel(table_ref, lens_ref,      # scalar prefetch
                       q_ref,                    # (1, 1, R, D)
                       k_ref, v_ref,             # (1, page_len, 1, D)
                       o_ref,                    # (1, 1, 1, R, D) f32
                       m_ref, l_ref,             # (1, 1, 1, R) f32
                       m_s, l_s, acc_s,          # VMEM scratch
                       *, page_len: int, bps: int):
    b = pl.program_id(0)
    si = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0]                               # (R, D)
    k = k_ref[0, :, 0, :]                         # (page_len, D)
    v = v_ref[0, :, 0, :]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(jnp.float32(q.shape[-1]))    # (R, page_len)
    base = (si * bps + j) * page_len
    pos = base + jax.lax.broadcasted_iota(jnp.int32, (1, page_len), 1)
    s = jnp.where(pos < lens_ref[b], s, NEG_INF)

    m_prev = m_s[...]                             # (R, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    # p is cast to the cache dtype before the PV product, mirroring the
    # dense path's `p.astype(q.dtype)` — keeps kernel-vs-dense drift to
    # the softmax reassociation alone
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_s[...] = acc_s[...] * corr + pv
    m_s[...] = m_new

    @pl.when(j == bps - 1)
    def _flush():
        o_ref[0, 0, 0] = acc_s[...]
        m_ref[0, 0, 0] = m_s[..., 0]
        l_ref[0, 0, 0] = l_s[..., 0]


def paged_attention_kernel(qg: jnp.ndarray, k_pool: jnp.ndarray,
                           v_pool: jnp.ndarray, page_table: jnp.ndarray,
                           lengths: jnp.ndarray, *, splits: int = 1,
                           interpret: bool = False):
    """qg (B, G, R, D) grouped decode queries; k/v pool (P, page_len, G,
    D); page_table (B, NB) int32 with NB divisible by ``splits``; lengths
    (B,) int32.  Returns partial ``(o, m, l)``: o (B, G, splits, R, D)
    f32, m/l (B, G, splits, R) f32 — merge with
    :func:`ops.merge_split_softmax`."""
    b, g, r, d = qg.shape
    page_len = k_pool.shape[1]
    nb = page_table.shape[1]
    grid, in_specs, out_specs, scratch_shapes, bps = paged_attn_specs(
        b, g, r, d, page_len, nb, splits)

    kern = functools.partial(_paged_attn_kernel, page_len=page_len, bps=bps)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=scratch_shapes,
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((b, g, splits, r, d), jnp.float32),
                   jax.ShapeDtypeStruct((b, g, splits, r), jnp.float32),
                   jax.ShapeDtypeStruct((b, g, splits, r), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(page_table, lengths, qg, k_pool, v_pool)


def _paged_attn_quant_kernel(table_ref, lens_ref,  # scalar prefetch
                             q_ref,                # (1, 1, R, D)
                             k_ref, ks_ref,        # (1, page_len, 1, D) codes
                             v_ref, vs_ref,        # + (1, 1) int32 scale
                             o_ref, m_ref, l_ref,
                             m_s, l_s, acc_s,
                             *, page_len: int, bps: int, n_bits: int):
    """Quantized-pool body: identical online-softmax walk to
    :func:`_paged_attn_kernel`, but each page block streams in as packed
    log2 codes + one scale exponent and dequantizes in-register — the
    wire format never round-trips through a dense pool.  The caller masks
    to *full* pages only (``lengths`` floored to a page multiple); the
    newest partial page merges as one extra dense-tail split outside
    (``ops.paged_decode_attention_quant``)."""
    b = pl.program_id(0)
    si = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0]                               # (R, D)
    k = _dequant_block(k_ref[0, :, 0, :], ks_ref[0, 0], n_bits)
    v = _dequant_block(v_ref[0, :, 0, :], vs_ref[0, 0], n_bits)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(jnp.float32(q.shape[-1]))    # (R, page_len)
    base = (si * bps + j) * page_len
    pos = base + jax.lax.broadcasted_iota(jnp.int32, (1, page_len), 1)
    s = jnp.where(pos < lens_ref[b], s, NEG_INF)

    m_prev = m_s[...]                             # (R, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    # all-masked-so-far blocks keep m_new = NEG_INF, so masked p would be
    # exp(0) = 1 against dequantized garbage of magnitude up to 2^127 —
    # large enough for the junk accumulator to overflow to inf and turn
    # the merge's zero weight into 0 * inf = NaN.  Zero the masked p
    # explicitly: bitwise no-op for any block holding a valid token
    # (there masked p already underflowed to exact 0.0)
    p = jnp.where(pos < lens_ref[b], p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_s[...] = acc_s[...] * corr + pv
    m_s[...] = m_new

    @pl.when(j == bps - 1)
    def _flush():
        o_ref[0, 0, 0] = acc_s[...]
        m_ref[0, 0, 0] = m_s[..., 0]
        l_ref[0, 0, 0] = l_s[..., 0]


def paged_attention_quant_kernel(qg: jnp.ndarray, k_codes: jnp.ndarray,
                                 k_scale: jnp.ndarray, v_codes: jnp.ndarray,
                                 v_scale: jnp.ndarray,
                                 page_table: jnp.ndarray,
                                 lengths: jnp.ndarray, *, n_bits: int = 4,
                                 splits: int = 1, interpret: bool = False):
    """qg (B, G, R, D); code pools (P, page_len, G, D) packed log2 codes;
    scale pools (P, G) int32; ``lengths`` must already be floored to full
    pages (the dense tail merges outside).  Returns partial ``(o, m, l)``
    like :func:`paged_attention_kernel`."""
    b, g, r, d = qg.shape
    page_len = k_codes.shape[1]
    nb = page_table.shape[1]
    grid, in_specs, out_specs, scratch_shapes, bps = paged_attn_quant_specs(
        b, g, r, d, page_len, nb, splits)

    kern = functools.partial(_paged_attn_quant_kernel, page_len=page_len,
                             bps=bps, n_bits=n_bits)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=scratch_shapes,
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((b, g, splits, r, d), jnp.float32),
                   jax.ShapeDtypeStruct((b, g, splits, r), jnp.float32),
                   jax.ShapeDtypeStruct((b, g, splits, r), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(page_table, lengths, qg, k_codes, k_scale, v_codes, v_scale)


# ---------------------------------------------------------------------------
# static-verifier registration (analysis.kernel_rules)
# ---------------------------------------------------------------------------


def make_page_table(lengths, nb: int, page_len: int):
    """The canonical page table of a decode tick: each slot's pages are
    allocated sequentially from page 1 (page 0 is the PR 5 reserved trash
    page), columns past ``ceil(length / page_len)`` stay trash.  Shared by
    the kernel microbench and the audit instantiations so the traffic
    numbers can't drift apart."""
    import numpy as np

    lens = np.asarray(lengths, np.int32)
    table = np.zeros((len(lens), nb), np.int32)
    nxt = 1
    for i, ln in enumerate(lens):
        for j in range(-(-int(ln) // page_len)):
            table[i, j] = nxt
            nxt += 1
    return table


def audit_specs():
    """Registered instantiations for the static kernel verifier.

    Enumerates the audit matrix — the ragged512 bench geometry (the
    gather_saved_frac gate), the serve-smoke geometry the scheduler's tick
    actually compiles (page_len 4, the distinctive 34-page pool), and a
    GQA edge case — across splits and pool dtypes.  Each instantiation
    hands the verifier the SAME BlockSpecs :func:`paged_attn_specs` gives
    ``pallas_call``, plus the concrete scalar-prefetch operands (table,
    lengths) the index maps dereference.
    """
    import numpy as np

    from repro.analysis.pallas_inspect import (KernelInstantiation,
                                               make_operand, scratch_entry)

    cases = [
        # (case name, geometry, splits, pool/q dtype, n_pages)
        ("ragged512.s1", RAGGED512, 1, jnp.float32, None),
        ("ragged512.s4", RAGGED512, 4, jnp.float32, None),
        ("serve_smoke.s1",
         dict(b=4, page_len=4, nb=8, g=1, r=3, d=16,
              lengths=(0, 1, 31, 32)), 1, jnp.float32, 34),
        ("serve_smoke.s2",
         dict(b=4, page_len=4, nb=8, g=1, r=3, d=16,
              lengths=(32, 5, 3, 9)), 2, jnp.bfloat16, 34),
        ("gqa_edge.s2",
         dict(b=2, page_len=8, nb=4, g=3, r=4, d=8,
              lengths=(7, 32)), 2, jnp.bfloat16, None),
    ]
    out = []
    for name, geo, splits, dtype, n_pages in cases:
        b, pl_, nb = geo["b"], geo["page_len"], geo["nb"]
        g, r, d = geo["g"], geo["r"], geo["d"]
        lens = np.asarray(geo["lengths"], np.int32)
        if n_pages is None:
            n_pages = 1 + b * nb
        table = make_page_table(lens, nb, pl_)
        grid, in_specs, out_specs, scratch, bps = paged_attn_specs(
            b, g, r, d, pl_, nb, splits)
        pool_shape = (n_pages, pl_, g, d)
        inputs = (
            make_operand("q", (b, g, r, d), dtype, in_specs[0]),
            make_operand("k_pool", pool_shape, dtype, in_specs[1]),
            make_operand("v_pool", pool_shape, dtype, in_specs[2]),
        )
        outputs = (
            make_operand("o", (b, g, splits, r, d), jnp.float32,
                         out_specs[0]),
            make_operand("m", (b, g, splits, r), jnp.float32, out_specs[1]),
            make_operand("l", (b, g, splits, r), jnp.float32, out_specs[2]),
        )
        out.append(KernelInstantiation(
            kernel="paged_attention", case=name, grid=grid,
            inputs=inputs, outputs=outputs,
            scratch=tuple(scratch_entry(s) for s in scratch),
            scalars=(table, lens),
            meta=dict(page_len=pl_, bps=bps, splits=splits, n_pages=n_pages,
                      trash_page=0, table=table, lengths=lens),
        ))

    # quantized-pool variants (ServeScheduler kv_quant=True): same table
    # walk, but the K/V operands are packed log2 code pools + (P, G) scale
    # pools — the audit's byte model makes the compressed-page traffic
    # saving a gated number (page_read_saved_frac).  The kernel is masked
    # to full pages (lengths floored; the dense tail merges outside), but
    # the allocated tail page still streams, so liveness/table rules use
    # the ORIGINAL lengths.
    from repro.core.logquant import code_dtype
    quant_cases = [
        ("ragged512.q4.s2", RAGGED512, 2, 4, None),
        ("serve_smoke.q4.s1",
         dict(b=4, page_len=4, nb=8, g=1, r=3, d=16,
              lengths=(0, 1, 31, 32)), 1, 4, 34),
        ("gqa_edge.q8.s2",
         dict(b=2, page_len=8, nb=4, g=3, r=4, d=8,
              lengths=(7, 32)), 2, 8, None),
    ]
    for name, geo, splits, kv_bits, n_pages in quant_cases:
        b, pl_, nb = geo["b"], geo["page_len"], geo["nb"]
        g, r, d = geo["g"], geo["r"], geo["d"]
        lens = np.asarray(geo["lengths"], np.int32)
        if n_pages is None:
            n_pages = 1 + b * nb
        table = make_page_table(lens, nb, pl_)
        kern_lens = (np.maximum(lens - 1, 0) // pl_ * pl_).astype(np.int32)
        grid, in_specs, out_specs, scratch, bps = paged_attn_quant_specs(
            b, g, r, d, pl_, nb, splits)
        ct = code_dtype(kv_bits)
        pool_shape = (n_pages, pl_, g, d)
        inputs = (
            make_operand("q", (b, g, r, d), jnp.float32, in_specs[0]),
            make_operand("k_pool", pool_shape, ct, in_specs[1]),
            make_operand("k_scale", (n_pages, g), jnp.int32, in_specs[2]),
            make_operand("v_pool", pool_shape, ct, in_specs[3]),
            make_operand("v_scale", (n_pages, g), jnp.int32, in_specs[4]),
        )
        outputs = (
            make_operand("o", (b, g, splits, r, d), jnp.float32,
                         out_specs[0]),
            make_operand("m", (b, g, splits, r), jnp.float32, out_specs[1]),
            make_operand("l", (b, g, splits, r), jnp.float32, out_specs[2]),
        )
        out.append(KernelInstantiation(
            kernel="paged_attention", case=name, grid=grid,
            inputs=inputs, outputs=outputs,
            scratch=tuple(scratch_entry(s) for s in scratch),
            scalars=(table, kern_lens),
            meta=dict(page_len=pl_, bps=bps, splits=splits, n_pages=n_pages,
                      trash_page=0, table=table, lengths=lens,
                      kv_bits=kv_bits),
        ))
    return out
