"""Pallas kernel: paged-attention decode with split-KV flash-decode.

Paper mapping (arXiv 2310.18181; DESIGN.md §Paged attention kernel): the
paper's §IV thesis is that DNN inference is bounded by *memory accesses*,
and its in-memory scheme wins by touching only the rows a computation
actually needs.  The serving-side image of that is this kernel: instead of
gathering ``pool[table]`` into a dense padded ``(B, max_len, G, D)`` view
every decode tick (reading ALL allocated pages of every slot, valid or
not), the BlockSpec index maps below dereference the scalar-prefetched
page table themselves — block ``j`` of slot ``b`` loads pool page
``table[b, j]`` directly, so only resident pages ever stream into VMEM and
nothing is ever re-laid-out densely.

Per (slot, kv-head, split) the kernel walks that split's pages in order
with the standard online-softmax recurrence (running max ``m``, running
normalizer ``l``, rescaled accumulator ``acc`` — the same f32 statistics
``models.attention.flash_attention`` carries over KV chunks):

    s_j  = (q @ k_j^T) / sqrt(D),  masked to  pos < length  with the
           finite NEG_INF = -1e30 (never -inf: all-masked blocks then
           yield exp(0)=1 "uniform junk" instead of inf-inf NaNs, and the
           junk is *exactly* erased later — see below)
    m'   = max(m, max_k s_j)
    p    = exp(s_j - m');  corr = exp(m - m')
    l    = l * corr + sum_k p;   acc = acc * corr + p @ v_j

**Split-KV ("flash-decode", SNIPPETS.md flashdecode idiom)**: the page
axis is additionally partitioned into ``splits`` contiguous runs mapped to
a parallel grid axis; each run emits partial ``(acc, m, l)`` and the tiny
cross-split merge happens outside the kernel
(``ops.merge_split_softmax``).  A split that holds no valid token
accumulates uniform junk at ``m = NEG_INF``; the merge weights it by
``exp(NEG_INF - m_real) == 0.0`` exactly (f32 underflow), so junk splits
— and trash-page contents in general — are *bitwise* absent from the
output.  Under a mesh the split axis can ride the ``model`` axis
(``launch.shardings.split_kv_specs``), so each shard reads only its own
pages and ships one (B, G, R)-sized statistic triple.

Masking is the single ``pos < length`` predicate: decode queries sit at
position ``length - 1``, so the dense path's causal mask (``kv_pos <=
q_pos``) and validity mask (``kv_pos < length``) are the same set.

Grid: ``(B, G, splits, blocks_per_split)``, pages innermost
(accumulator-friendly, "arbitrary"); q/out blocks are whole (R, D) tiles —
R and D are small (<= head_dim) so VMEM residency is a few KiB per step.
On this CPU container the kernel runs in interpret mode (the wrapper
auto-selects), which lowers to plain traced lax ops — jittable, scannable
inside the serve tick, and partitionable by GSPMD.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def _paged_attn_kernel(table_ref, lens_ref,      # scalar prefetch
                       q_ref,                    # (1, 1, R, D)
                       k_ref, v_ref,             # (1, page_len, 1, D)
                       o_ref,                    # (1, 1, 1, R, D) f32
                       m_ref, l_ref,             # (1, 1, 1, R) f32
                       m_s, l_s, acc_s,          # VMEM scratch
                       *, page_len: int, bps: int):
    b = pl.program_id(0)
    si = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0]                               # (R, D)
    k = k_ref[0, :, 0, :]                         # (page_len, D)
    v = v_ref[0, :, 0, :]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(jnp.float32(q.shape[-1]))    # (R, page_len)
    base = (si * bps + j) * page_len
    pos = base + jax.lax.broadcasted_iota(jnp.int32, (1, page_len), 1)
    s = jnp.where(pos < lens_ref[b], s, NEG_INF)

    m_prev = m_s[...]                             # (R, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    # p is cast to the cache dtype before the PV product, mirroring the
    # dense path's `p.astype(q.dtype)` — keeps kernel-vs-dense drift to
    # the softmax reassociation alone
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_s[...] = acc_s[...] * corr + pv
    m_s[...] = m_new

    @pl.when(j == bps - 1)
    def _flush():
        o_ref[0, 0, 0] = acc_s[...]
        m_ref[0, 0, 0] = m_s[..., 0]
        l_ref[0, 0, 0] = l_s[..., 0]


def paged_attention_kernel(qg: jnp.ndarray, k_pool: jnp.ndarray,
                           v_pool: jnp.ndarray, page_table: jnp.ndarray,
                           lengths: jnp.ndarray, *, splits: int = 1,
                           interpret: bool = False):
    """qg (B, G, R, D) grouped decode queries; k/v pool (P, page_len, G,
    D); page_table (B, NB) int32 with NB divisible by ``splits``; lengths
    (B,) int32.  Returns partial ``(o, m, l)``: o (B, G, splits, R, D)
    f32, m/l (B, G, splits, R) f32 — merge with
    :func:`ops.merge_split_softmax`."""
    b, g, r, d = qg.shape
    page_len = k_pool.shape[1]
    nb = page_table.shape[1]
    assert nb % splits == 0, (nb, splits)
    bps = nb // splits
    grid = (b, g, splits, bps)

    kern = functools.partial(_paged_attn_kernel, page_len=page_len, bps=bps)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, r, d),
                         lambda bi, gi, si, ji, tab, lens: (bi, gi, 0, 0)),
            # the table walk: block index maps dereference the prefetched
            # page table — page (tab[b, split*bps + j]) streams in, nothing
            # else; the dense gather never happens
            pl.BlockSpec((1, page_len, 1, d),
                         lambda bi, gi, si, ji, tab, lens:
                         (tab[bi, si * bps + ji], 0, gi, 0)),
            pl.BlockSpec((1, page_len, 1, d),
                         lambda bi, gi, si, ji, tab, lens:
                         (tab[bi, si * bps + ji], 0, gi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, r, d),
                         lambda bi, gi, si, ji, tab, lens:
                         (bi, gi, si, 0, 0)),
            pl.BlockSpec((1, 1, 1, r),
                         lambda bi, gi, si, ji, tab, lens: (bi, gi, si, 0)),
            pl.BlockSpec((1, 1, 1, r),
                         lambda bi, gi, si, ji, tab, lens: (bi, gi, si, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((r, 1), jnp.float32),
                        pltpu.VMEM((r, 1), jnp.float32),
                        pltpu.VMEM((r, d), jnp.float32)],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((b, g, splits, r, d), jnp.float32),
                   jax.ShapeDtypeStruct((b, g, splits, r), jnp.float32),
                   jax.ShapeDtypeStruct((b, g, splits, r), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(page_table, lengths, qg, k_pool, v_pool)
