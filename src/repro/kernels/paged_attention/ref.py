"""Dense-gather oracle for the paged-attention decode kernel.

This is, op for op, the scheduler's historical dense path
(``models.attention._paged_gather`` + ``_decode_attention``) specialized to
decode: gather every page the table names into the padded logical view,
run ONE masked einsum + monolithic softmax over it.  The kernel is tested
against this — same inputs, same masking semantics — so "kernel vs ref"
parity IS "kernel vs dense-gather scheduler" parity at the math level.

Masking: decode queries sit at position ``lengths - 1`` and the dense path
masks both causally (``kv_pos <= q_pos``) and by validity (``kv_pos <
lengths``).  For decode the two are the same set — ``kv_pos <= lengths - 1``
iff ``kv_pos < lengths`` — so the oracle (and the kernel) carry the single
``kv_pos < lengths`` mask.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_reference(q: jnp.ndarray, k_pool: jnp.ndarray,
                              v_pool: jnp.ndarray, page_table: jnp.ndarray,
                              lengths: jnp.ndarray) -> jnp.ndarray:
    """q (B, 1, H, D); k/v pool (P, page_len, G, D); page_table (B, NB)
    int32; lengths (B,) int32 valid tokens per row.  Returns (B, 1, H, D).
    """
    b, _, h, d = q.shape
    page_len, g = k_pool.shape[1], k_pool.shape[2]
    nb = page_table.shape[1]
    kg = k_pool[page_table].reshape(b, nb * page_len, g, d)
    vg = v_pool[page_table].reshape(b, nb * page_len, g, d)
    qg = q.reshape(b, 1, g, h // g, d)[:, 0]             # (B, G, R, D)
    s = jnp.einsum("bgrd,bkgd->bgrk", qg, kg,
                   preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(jnp.float32(d))
    idx = jnp.arange(nb * page_len)
    mask = idx[None, None, None, :] < lengths[:, None, None, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrk,bkgd->bgrd", p.astype(q.dtype), vg,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, d).astype(q.dtype)
