"""Fused paged-attention decode kernel (split-KV flash-decode).

kernel.py — Pallas kernel whose BlockSpec index maps walk the page table
            directly (scalar-prefetched): per-page K/V block loads +
            online-softmax (m, l) accumulation, no dense ``pool[table]``
            gather.
ops.py    — jit'd wrapper: grouped-query reshape, split-KV padding, the
            partial-softmax merge, and the gather-traffic accounting.
ref.py    — dense-gather masked-softmax oracle (the exact math of the
            scheduler's dense path) the kernel is parity-tested against.
"""

from repro.kernels.paged_attention.ops import (gather_traffic_counts,
                                               merge_split_softmax,
                                               paged_decode_attention)
from repro.kernels.paged_attention.ref import paged_attention_reference

__all__ = ["paged_decode_attention", "merge_split_softmax",
           "paged_attention_reference", "gather_traffic_counts"]
