"""Public jit'd wrapper for the paged-attention decode kernel.

Responsibilities: grouped-query reshape, split-KV table padding (trailing
trash-page columns make the block count divisible by ``splits`` — padded
blocks sit past every valid position, so they mask to exact zeros), the
cross-split partial-softmax merge, the split-KV sharding hints, and the
gather-traffic accounting benchmarks report (the paper-§IV "avoided
accesses" image of the kernel, like ``bitplane_matmul.ops
.plane_traffic_fraction`` for weight planes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.paged_attention.kernel import (NEG_INF,
                                                  paged_attention_kernel,
                                                  paged_attention_quant_kernel)
from repro.models.sharding import shard


def merge_split_softmax(m: jnp.ndarray, l: jnp.ndarray, acc: jnp.ndarray,
                        axis: int = -1) -> jnp.ndarray:
    """Reduce per-split online-softmax partials into the full softmax.

    ``m`` / ``l`` carry a split axis at ``axis``; ``acc`` carries the same
    axis plus a trailing feature dim.  With the global max ``M`` over
    splits, each split reweights by ``exp(m - M)`` — for a split that saw
    no valid token ``m == NEG_INF`` (finite, -1e30) and the weight
    underflows to exactly 0.0 in f32, so its junk partials are *bitwise*
    absent from the sum.  A row with no valid token anywhere keeps
    ``l_tot`` positive (every split contributes its uniform-junk ``l``),
    so the output is finite garbage — never NaN — exactly like the dense
    path's softmax over an all-NEG_INF row; such rows are inactive slots
    whose outputs the serve tick discards.
    """
    axis = axis % m.ndim          # acc has a trailing extra dim, so resolve
    m_max = jnp.max(m, axis=axis, keepdims=True)  # negative axes against m
    w = jnp.exp(m - m_max)
    l_tot = jnp.sum(l * w, axis=axis)
    num = jnp.sum(acc * jnp.expand_dims(w, -1), axis=axis)
    return num / jnp.maximum(l_tot, 1e-30)[..., None]


@functools.partial(jax.jit, static_argnames=("splits", "interpret"))
def paged_decode_attention(q: jnp.ndarray, k_pool: jnp.ndarray,
                           v_pool: jnp.ndarray, page_table: jnp.ndarray,
                           lengths: jnp.ndarray, *, splits: int = 1,
                           interpret: bool | None = None) -> jnp.ndarray:
    """Decode attention straight off the page pool — no dense gather.

    q (B, 1, H, D); k/v pool (P, page_len, G, D); page_table (B, NB)
    int32 (entry 0 = trash page); lengths (B,) int32 valid tokens per row.
    Returns (B, 1, H, D) in q's dtype — the drop-in replacement for
    ``_paged_gather`` + ``_decode_attention`` (token-equal on every tested
    seed/arch; logits agree to f32-ULP softmax reassociation, see
    tests/test_paged_attention.py for the exact bar).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, _, h, d = q.shape
    g = k_pool.shape[2]
    nb = page_table.shape[1]
    pad = (-nb) % splits
    if pad:
        # trash-page columns: their positions sit past any valid length,
        # so the kernel masks them to exact zeros like any junk tail
        page_table = jnp.pad(page_table, ((0, 0), (0, pad)))
    qg = q.reshape(b, 1, g, h // g, d)[:, 0]             # (B, G, R, D)
    o, m, l = paged_attention_kernel(qg, k_pool, v_pool,
                                     page_table.astype(jnp.int32),
                                     lengths.astype(jnp.int32),
                                     splits=splits, interpret=interpret)
    # split-KV partial-reduce rule: the split axis may ride the model mesh
    # axis (models.sharding "kvsplit" kinds; launch.shardings
    # .split_kv_specs documents the layout) — each shard owns a contiguous
    # page run, the merge below is the only cross-shard reduction
    o = shard(o, "kvsplit")
    m = shard(m, "kvsplit_stat")
    l = shard(l, "kvsplit_stat")
    out = merge_split_softmax(m, l, o, axis=2)           # (B, G, R, D)
    return out.reshape(b, 1, h, d).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("n_bits", "splits", "interpret"))
def paged_decode_attention_quant(q: jnp.ndarray, k_codes: jnp.ndarray,
                                 k_scale: jnp.ndarray, v_codes: jnp.ndarray,
                                 v_scale: jnp.ndarray, k_tail: jnp.ndarray,
                                 v_tail: jnp.ndarray, page_table: jnp.ndarray,
                                 lengths: jnp.ndarray, *, n_bits: int = 4,
                                 splits: int = 1,
                                 interpret: bool | None = None) -> jnp.ndarray:
    """Decode attention off the log2-quantized page pool.

    q (B, 1, H, D); code pools (P, page_len, G, D) packed wire codes;
    scale pools (P, G) int32; tail rings (B, 2*page_len + 1, G, D) dense
    cache-dtype (row 2*page_len = junk bin); page_table (B, NB) int32;
    lengths (B,) int32.  The kernel walks *full* pages only (lengths
    floored to a page multiple — the newest partial page's codes are
    still being rewritten every tick); the partial page is computed here
    as one extra dense flash-decode split over the tail ring and merged
    through the same :func:`merge_split_softmax`, so its tokens read
    exactly the bytes the dense pool would hold.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, _, h, d = q.shape
    page_len = k_codes.shape[1]
    g = k_codes.shape[2]
    nb = page_table.shape[1]
    pad = (-nb) % splits
    if pad:
        page_table = jnp.pad(page_table, ((0, 0), (0, pad)))
    qg = q.reshape(b, 1, g, h // g, d)[:, 0]             # (B, G, R, D)
    lengths = lengths.astype(jnp.int32)
    tb = jnp.maximum(lengths - 1, 0) // page_len         # tail-page block
    kern_lens = tb * page_len                            # full pages only
    o, m, l = paged_attention_quant_kernel(qg, k_codes, k_scale, v_codes,
                                           v_scale,
                                           page_table.astype(jnp.int32),
                                           kern_lens, n_bits=n_bits,
                                           splits=splits, interpret=interpret)
    o = shard(o, "kvsplit")
    m = shard(m, "kvsplit_stat")
    l = shard(l, "kvsplit_stat")

    # the tail-page partial: ring half (tb % 2) * page_len holds positions
    # [tb*page_len, (tb+1)*page_len) — a flash-decode block over dense rows
    half = (tb % 2) * page_len
    j = jnp.arange(page_len, dtype=jnp.int32)
    idx = (half[:, None] + j[None, :])[:, :, None, None]
    kt = jnp.take_along_axis(k_tail, idx, axis=1)        # (B, pl, G, D)
    vt = jnp.take_along_axis(v_tail, idx, axis=1)
    pos = tb[:, None] * page_len + j[None, :]            # (B, pl) absolute
    s_t = jnp.einsum("bgrd,bkgd->bgrk", qg.astype(jnp.float32),
                     kt.astype(jnp.float32)) / jnp.sqrt(jnp.float32(d))
    s_t = jnp.where(pos[:, None, None, :] < lengths[:, None, None, None],
                    s_t, NEG_INF)
    m_t = jnp.max(s_t, axis=-1, keepdims=True)           # (B, G, R, 1)
    # p casts to the cache dtype before PV, mirroring the dense decode
    # path — the tail tokens must read exactly like the dense pool's
    p = jnp.exp(s_t - m_t)
    l_t = jnp.sum(p, axis=-1)                            # (B, G, R)
    acc_t = jnp.einsum("bgrk,bkgd->bgrd", p.astype(vt.dtype), vt,
                       preferred_element_type=jnp.float32)

    # append the tail as one extra split: kernel partials are UNNORMALIZED
    # accumulators, so the tail block composes through the same merge
    o = jnp.concatenate([o, acc_t[:, :, None]], axis=2)
    m = jnp.concatenate([m, m_t[..., 0][:, :, None]], axis=2)
    l = jnp.concatenate([l, l_t[:, :, None]], axis=2)
    out = merge_split_softmax(m, l, o, axis=2)           # (B, G, R, D)
    return out.reshape(b, 1, h, d).astype(q.dtype)


def gather_traffic_counts(page_table: np.ndarray, lengths: np.ndarray,
                          page_len: int):
    """(touched, total) page-read counts per decode tick, as floats.

    ``total`` is what the dense ``pool[table]`` gather streams — every
    allocated table column of every slot, valid or not; ``touched`` is
    what the kernel's table walk reads — only pages holding at least one
    valid token (``ceil(length / page_len)``).  The ratio is the paged
    analogue of ``plane_traffic_fraction``: deterministic, exact, gated
    by the ``paged_attn`` bench baseline.
    """
    table = np.asarray(page_table)
    lens = np.asarray(lengths)
    total = float(table.shape[0] * table.shape[1])
    touched = float(np.sum(-(-lens // int(page_len))))
    return touched, total
