"""Public jit'd wrapper for the paged-attention decode kernel.

Responsibilities: grouped-query reshape, split-KV table padding (trailing
trash-page columns make the block count divisible by ``splits`` — padded
blocks sit past every valid position, so they mask to exact zeros), the
cross-split partial-softmax merge, the split-KV sharding hints, and the
gather-traffic accounting benchmarks report (the paper-§IV "avoided
accesses" image of the kernel, like ``bitplane_matmul.ops
.plane_traffic_fraction`` for weight planes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.paged_attention.kernel import paged_attention_kernel
from repro.models.sharding import shard


def merge_split_softmax(m: jnp.ndarray, l: jnp.ndarray, acc: jnp.ndarray,
                        axis: int = -1) -> jnp.ndarray:
    """Reduce per-split online-softmax partials into the full softmax.

    ``m`` / ``l`` carry a split axis at ``axis``; ``acc`` carries the same
    axis plus a trailing feature dim.  With the global max ``M`` over
    splits, each split reweights by ``exp(m - M)`` — for a split that saw
    no valid token ``m == NEG_INF`` (finite, -1e30) and the weight
    underflows to exactly 0.0 in f32, so its junk partials are *bitwise*
    absent from the sum.  A row with no valid token anywhere keeps
    ``l_tot`` positive (every split contributes its uniform-junk ``l``),
    so the output is finite garbage — never NaN — exactly like the dense
    path's softmax over an all-NEG_INF row; such rows are inactive slots
    whose outputs the serve tick discards.
    """
    axis = axis % m.ndim          # acc has a trailing extra dim, so resolve
    m_max = jnp.max(m, axis=axis, keepdims=True)  # negative axes against m
    w = jnp.exp(m - m_max)
    l_tot = jnp.sum(l * w, axis=axis)
    num = jnp.sum(acc * jnp.expand_dims(w, -1), axis=axis)
    return num / jnp.maximum(l_tot, 1e-30)[..., None]


@functools.partial(jax.jit, static_argnames=("splits", "interpret"))
def paged_decode_attention(q: jnp.ndarray, k_pool: jnp.ndarray,
                           v_pool: jnp.ndarray, page_table: jnp.ndarray,
                           lengths: jnp.ndarray, *, splits: int = 1,
                           interpret: bool | None = None) -> jnp.ndarray:
    """Decode attention straight off the page pool — no dense gather.

    q (B, 1, H, D); k/v pool (P, page_len, G, D); page_table (B, NB)
    int32 (entry 0 = trash page); lengths (B,) int32 valid tokens per row.
    Returns (B, 1, H, D) in q's dtype — the drop-in replacement for
    ``_paged_gather`` + ``_decode_attention`` (token-equal on every tested
    seed/arch; logits agree to f32-ULP softmax reassociation, see
    tests/test_paged_attention.py for the exact bar).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, _, h, d = q.shape
    g = k_pool.shape[2]
    nb = page_table.shape[1]
    pad = (-nb) % splits
    if pad:
        # trash-page columns: their positions sit past any valid length,
        # so the kernel masks them to exact zeros like any junk tail
        page_table = jnp.pad(page_table, ((0, 0), (0, pad)))
    qg = q.reshape(b, 1, g, h // g, d)[:, 0]             # (B, G, R, D)
    o, m, l = paged_attention_kernel(qg, k_pool, v_pool,
                                     page_table.astype(jnp.int32),
                                     lengths.astype(jnp.int32),
                                     splits=splits, interpret=interpret)
    # split-KV partial-reduce rule: the split axis may ride the model mesh
    # axis (models.sharding "kvsplit" kinds; launch.shardings
    # .split_kv_specs documents the layout) — each shard owns a contiguous
    # page run, the merge below is the only cross-shard reduction
    o = shard(o, "kvsplit")
    m = shard(m, "kvsplit_stat")
    l = shard(l, "kvsplit_stat")
    out = merge_split_softmax(m, l, o, axis=2)           # (B, G, R, D)
    return out.reshape(b, 1, h, d).astype(q.dtype)


def gather_traffic_counts(page_table: np.ndarray, lengths: np.ndarray,
                          page_len: int):
    """(touched, total) page-read counts per decode tick, as floats.

    ``total`` is what the dense ``pool[table]`` gather streams — every
    allocated table column of every slot, valid or not; ``touched`` is
    what the kernel's table walk reads — only pages holding at least one
    valid token (``ceil(length / page_len)``).  The ratio is the paged
    analogue of ``plane_traffic_fraction``: deterministic, exact, gated
    by the ``paged_attn`` bench baseline.
    """
    table = np.asarray(page_table)
    lens = np.asarray(lengths)
    total = float(table.shape[0] * table.shape[1])
    touched = float(np.sum(-(-lens // int(page_len))))
    return touched, total
