"""Pallas kernel: LOG2 activation quantization (paper Fig. 5, Eqs. 6-7).

Paper mapping (arXiv 2310.18181; DESIGN.md "Paper ↔ code map"): the
kernel-side twin of ``core/logquant.py`` — the paper's §II log2 activation
quantization, evaluated as the Fig. 5 comparator circuit (Eqs. 6-7 fold
the Eq. 3 rounding into one exponent-field add + mantissa compare).

Elementwise over a 2D tensor, tiled ``(block_m, block_n)`` in VMEM.  The body
is the same comparator circuit as ``core.logquant.log2_quantize``: IEEE-754
exponent-field extraction plus one mantissa-vs-sqrt(2) compare — no
transcendental evaluation, so VPU-only, fully vectorized, and bit-exact.

VMEM budget at the default (256, 512) f32 block: in 512 KiB + two int8 outs
128 KiB each -> well under a v5e core's ~16 MiB VMEM with double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_SQRT2_M_F32 = 3474676  # floor((sqrt(2)-1) * 2^23) + 1, see core.logquant


def _log2quant_kernel(x_ref, exp_ref, sign_ref, *, n_bits: int):
    x = x_ref[...].astype(jnp.float32)
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    exp_field = ((bits >> 23) & jnp.uint32(0xFF)).astype(jnp.int32)
    man_field = (bits & jnp.uint32(0x7FFFFF)).astype(jnp.int32)

    sentinel = -(1 << (n_bits - 1))
    emax = (1 << (n_bits - 1)) - 1

    rounded = exp_field - 127 + (man_field >= _SQRT2_M_F32).astype(jnp.int32)
    e = jnp.clip(rounded, sentinel, emax)

    is_sub_or_zero = exp_field == 0
    is_nonfinite = exp_field == 0xFF
    is_nan = is_nonfinite & (man_field != 0)
    e = jnp.where(is_sub_or_zero | is_nan, sentinel, e)
    e = jnp.where(is_nonfinite & ~is_nan, emax, e)

    exp_ref[...] = e.astype(jnp.int8)
    sign_ref[...] = jnp.where(x < 0, jnp.int8(-1), jnp.int8(1))


def log2quant_specs(m: int, n: int, block_m: int, block_n: int):
    """Grid + BlockSpec shared by :func:`log2_quantize_kernel` and the
    static verifier's ``audit_specs()`` (one spec serves input and both
    outputs — the quantizer is a pure elementwise map)."""
    grid = (m // block_m, n // block_n)
    spec = pl.BlockSpec((block_m, block_n), lambda i, j: (i, j))
    return grid, spec


def log2_quantize_kernel(x: jnp.ndarray, *, n_bits: int = 4,
                         block_m: int = 256, block_n: int = 512,
                         interpret: bool = False):
    """x: f32/bf16 ``(M, N)`` (pre-padded to block multiples) -> (exp, sign)."""
    m, n = x.shape
    grid, spec = log2quant_specs(m, n, block_m, block_n)
    return pl.pallas_call(
        functools.partial(_log2quant_kernel, n_bits=n_bits),
        grid=grid,
        in_specs=[spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.int8),
            jax.ShapeDtypeStruct((m, n), jnp.int8),
        ],
        interpret=interpret,
    )(x)


# ---------------------------------------------------------------------------
# static-verifier registration (analysis.kernel_rules)
# ---------------------------------------------------------------------------


def audit_specs():
    """Registered instantiations for the static kernel verifier: the
    default decode-path tiling in f32 and bf16, plus a single-block edge
    case.  No scalar prefetch, no scratch — the audit mostly proves the
    tiling divides and prices the VMEM/HBM footprint."""
    from repro.analysis.pallas_inspect import (KernelInstantiation,
                                               make_operand)

    cases = [
        ("decode_f32.b256x512", 512, 1024, 256, 512, jnp.float32),
        ("decode_bf16.b256x512", 1024, 512, 256, 512, jnp.bfloat16),
        ("single_block.b128x128", 128, 128, 128, 128, jnp.float32),
    ]
    out = []
    for name, m, n, bm, bn, dtype in cases:
        grid, spec = log2quant_specs(m, n, bm, bn)
        out.append(KernelInstantiation(
            kernel="log2quant", case=name, grid=grid,
            inputs=(make_operand("x", (m, n), dtype, spec),),
            outputs=(
                make_operand("exp", (m, n), jnp.int8, spec),
                make_operand("sign", (m, n), jnp.int8, spec),
            ),
            scratch=(),
            scalars=(),
            meta={},
        ))
    return out
