"""Pure-jnp oracle for the log2quant kernel (independent of core.logquant).

Uses ``jnp.frexp`` — mathematically exact mantissa/exponent split — rather
than bit extraction, so the kernel and oracle share no code path.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def log2_quantize_ref(x: jnp.ndarray, n_bits: int = 4):
    sentinel = -(1 << (n_bits - 1))
    emax = (1 << (n_bits - 1)) - 1

    xf = x.astype(jnp.float32)
    mant, expo = jnp.frexp(jnp.abs(xf))           # |x| = mant * 2^expo, mant in [0.5, 1)
    # Round(log2|x|) = (expo - 1) + (2*mant >= sqrt(2)); mantissa in [1,2) is 2*mant.
    # float32(sqrt(2)) rounds BELOW the true sqrt(2), and no float32 mantissa
    # lies between them, so the exact predicate "m >= sqrt(2)" over float32
    # inputs is the *strict* compare against the rounded constant.
    half_sqrt2 = np.float32(np.sqrt(np.float64(2.0)) / 2.0)
    rounded = (expo - 1) + (mant > half_sqrt2).astype(jnp.int32)

    e = jnp.clip(rounded, sentinel, emax)
    e = jnp.where((xf == 0) | jnp.isnan(xf), sentinel, e)
    e = jnp.where(jnp.isinf(xf), emax, e)
    sign = jnp.where(xf < 0, jnp.int8(-1), jnp.int8(1))
    return e.astype(jnp.int8), sign
