"""Public jit'd wrapper for the log2quant Pallas kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.log2quant.kernel import log2_quantize_kernel


@functools.partial(jax.jit, static_argnames=("n_bits", "block_m", "block_n",
                                             "interpret"))
def log2_quantize_pallas(x: jnp.ndarray, n_bits: int = 4,
                         block_m: int = 256, block_n: int = 512,
                         interpret: bool | None = None):
    """LOG2-quantize an arbitrary-rank tensor via the Pallas kernel.

    Flattens to 2D, pads to block multiples, unpads/reshapes the outputs.
    Returns ``(exp int8, sign int8)`` with the same shape as ``x``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    shape = x.shape
    total = 1
    for s in shape:
        total *= s
    n = min(block_n, max(128, total))
    # choose an (M, N) factorization: lanes = block_n when possible
    n = block_n if total >= block_n else total
    m = -(-total // n)
    pad = m * n - total
    flat = jnp.pad(x.reshape(-1).astype(jnp.float32), (0, pad)).reshape(m, n)

    pm = (-m) % block_m
    pn = (-n) % block_n
    flat = jnp.pad(flat, ((0, pm), (0, pn)))
    exp, sign = log2_quantize_kernel(flat, n_bits=n_bits,
                                     block_m=min(block_m, flat.shape[0]),
                                     block_n=min(block_n, flat.shape[1]),
                                     interpret=interpret)
    exp = exp[:m, :n].reshape(-1)[:total].reshape(shape)
    sign = sign[:m, :n].reshape(-1)[:total].reshape(shape)
    return exp, sign
