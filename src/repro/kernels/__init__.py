"""Pallas TPU kernels for the QeiHaN hot paths.

Each kernel directory carries:
  kernel.py — ``pl.pallas_call`` + explicit ``BlockSpec`` VMEM tiling
  ops.py    — jit'd public wrapper (padding, scalar-prefetch tables)
  ref.py    — pure-jnp oracle the kernel is exact/allclose-tested against

Kernels target TPU v5e; on this CPU container they are validated with
``interpret=True`` (the wrappers auto-select based on backend).
"""

from repro.kernels.bitplane_matmul.ops import bitplane_matmul_pallas
from repro.kernels.log2quant.ops import log2_quantize_pallas
from repro.kernels.paged_attention.ops import (merge_split_softmax,
                                               paged_decode_attention)

__all__ = ["log2_quantize_pallas", "bitplane_matmul_pallas",
           "paged_decode_attention", "merge_split_softmax"]
