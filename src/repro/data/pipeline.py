"""Deterministic synthetic LM data pipeline.

Counter-based (Philox) generation keyed by ``(seed, step, shard)`` — any
worker can materialize any batch independently, which is what makes
checkpoint/restart and *elastic* restarts replay identical data without a
data-service dependency.  Token stream is Zipf-distributed (vocab-realistic
marginals) with a short-range Markov flavor so losses move during smoke
training runs.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    vocab_size: int
    seed: int = 0
    zipf_a: float = 1.2


class SyntheticLM:
    """``batch(step, shard, n_shards)`` -> host numpy batch for that shard."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.global_batch % 1:
            raise ValueError
        # stationary Zipf marginal over the vocab
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._probs = p / p.sum()

    def batch(self, step: int, shard: int = 0, n_shards: int = 1) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        if cfg.global_batch % n_shards:
            raise ValueError(f"batch {cfg.global_batch} % shards {n_shards}")
        local = cfg.global_batch // n_shards
        rng = np.random.Generator(np.random.Philox(
            key=cfg.seed, counter=[step, shard, 0, 0]))
        toks = rng.choice(cfg.vocab_size, size=(local, cfg.seq_len + 1),
                          p=self._probs).astype(np.int32)
        # short-range structure: every other position repeats its neighbor
        # with p=0.25 so next-token prediction is learnable
        rep = rng.random((local, cfg.seq_len)) < 0.25
        toks[:, 1:][rep] = toks[:, :-1][rep]
        return {"tokens": toks[:, :-1],
                "labels": toks[:, 1:].astype(np.int32)}
