"""Disaggregated serving workers: prefill and decode engines over one
serialized ``PageSpan`` hand-off.

The paper's thesis is co-location — move computation to the data instead
of stalling a shared engine (PAPER §III).  Serving-side, the shared
engine is the combined scheduler: a long prompt's chunk ingestion rides
the same jitted mixed tick as every in-flight decode, so prefill floods
inflate decode latency.  Disaggregation splits the roles (ROADMAP open
item 2):

* :class:`PrefillEngine` ingests ONE prompt at a time into its paged KV
  pool through the scheduler's existing chunked/bucketed admission paths
  (prefix-cache hits included — the radix tree lives prefill-side), with
  decode *held off* (``ServeScheduler._defer_decode``): the cut point is
  post-chunk, pre-decode, i.e. prompt KV pages + first-token logits and
  not a single generated token.  The filled slot is exported as a
  :class:`PageSpan` and immediately released (donating its pages to the
  prefix cache exactly like a retiring request).
* :class:`DecodeEngine` imports a span into its OWN pool — fresh pages
  from its allocator, scatter of the span's page contents, table row,
  logits row, SSM state, kv_quant tail ring — and ticks it with the
  unmodified fused decode program until EOS/length retirement.

Both engines are built from the same :class:`~repro.serving.config.
ServeConfig`, so every compiled program has the same shape as the
combined scheduler's — and because per-slot decode is masked independent
of the other rows (the property the whole serve test suite asserts),
the disaggregated token stream is **bit-equal** to the single-process
paged scheduler on the same trace (tests/test_disagg.py).

``PageSpan.to_bytes()`` / ``from_bytes()`` is the wire format (framed
magic + versioned JSON header + raw array payload + CRC32), used by the
two-process router transport (``serving/router.py``).
"""

from __future__ import annotations

import dataclasses
import json
import struct
import time
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving.config import ServeConfig
from repro.serving.kvpool import TRASH_PAGE, blocks_for_tokens
from repro.serving.scheduler import (Request, RequestResult, ServeScheduler,
                                     _Slot)

_MAGIC = b"RPSPAN"
_SPAN_VERSION = 1
_U32 = struct.Struct("<I")


def _np_dtype(name: str) -> np.dtype:
    """Resolve a dtype by NAME (``arr.dtype.name``): numpy builtins, with
    the ml_dtypes extension types (bfloat16, ...) as fallback — jax array
    dtypes round-trip through their names, never through raw descriptors
    (which are endianness/registration dependent)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


@dataclasses.dataclass
class PageSpan:
    """One prefilled request, serialized: everything the decode engine
    needs to resume the request in its own pool.

    ``layers`` mirrors the pool's per-layer-group structure: attention
    groups carry the slot's page CONTENTS gathered out of the prefill
    pool (``k``/``v`` of shape ``(R, n_blocks, page_len, G, D)`` — or
    ``k_codes``/``v_codes`` + per-page ``*_scale`` + the slot's dense
    ``*_tail`` ring under ``kv_quant``), SSM groups carry the slot's
    recurrent state slice (the snapshot equivalent at the full prompt
    boundary).  ``hit_len``/``shared_pages`` are the radix metadata of
    the prefill-side admission (observability — the pages themselves are
    materialized into the span either way).
    """

    prompt: np.ndarray                      # (L,) int32 token ids
    length: int                             # tokens resident in the pages
    max_new: int
    eos_id: Optional[int]
    page_len: int
    kv_quant: bool
    kv_bits: int
    hit_len: int                            # prefix-cache hit at admission
    shared_pages: int                       # whole pages aliased at admission
    logits: np.ndarray                      # (V,) first-token logits row
    layers: Tuple[Dict[str, np.ndarray], ...]

    # ------------------------------------------------------------- wire
    def _arrays(self) -> List[Tuple[str, np.ndarray]]:
        out = [("prompt", np.ascontiguousarray(self.prompt)),
               ("logits", np.ascontiguousarray(self.logits))]
        for li, group in enumerate(self.layers):
            for key in sorted(group):
                out.append((f"layer{li}.{key}",
                            np.ascontiguousarray(group[key])))
        return out

    def to_bytes(self) -> bytes:
        arrays = self._arrays()
        header = {
            "version": _SPAN_VERSION,
            "length": int(self.length),
            "max_new": int(self.max_new),
            "eos_id": None if self.eos_id is None else int(self.eos_id),
            "page_len": int(self.page_len),
            "kv_quant": bool(self.kv_quant),
            "kv_bits": int(self.kv_bits),
            "hit_len": int(self.hit_len),
            "shared_pages": int(self.shared_pages),
            "n_groups": len(self.layers),
            "arrays": [{"name": name, "shape": list(a.shape),
                        "dtype": a.dtype.name, "nbytes": int(a.nbytes)}
                       for name, a in arrays],
        }
        hdr = json.dumps(header, sort_keys=True).encode("utf-8")
        payload = b"".join(a.tobytes() for _, a in arrays)
        body = _MAGIC + _U32.pack(_SPAN_VERSION) + _U32.pack(len(hdr)) + hdr
        return body + payload + _U32.pack(zlib.crc32(hdr + payload))

    @classmethod
    def from_bytes(cls, blob: bytes) -> "PageSpan":
        fixed = len(_MAGIC) + 2 * _U32.size
        if len(blob) < fixed + _U32.size:
            raise ValueError(f"truncated PageSpan: {len(blob)} bytes is "
                             f"shorter than the fixed frame")
        if blob[:len(_MAGIC)] != _MAGIC:
            raise ValueError("not a PageSpan (bad magic)")
        version, = _U32.unpack_from(blob, len(_MAGIC))
        if version != _SPAN_VERSION:
            raise ValueError(f"PageSpan wire version {version} (this build "
                             f"reads version {_SPAN_VERSION})")
        hdr_len, = _U32.unpack_from(blob, len(_MAGIC) + _U32.size)
        if len(blob) < fixed + hdr_len + _U32.size:
            raise ValueError(f"truncated PageSpan: header claims "
                             f"{hdr_len} bytes, frame is short")
        hdr = blob[fixed:fixed + hdr_len]
        payload = blob[fixed + hdr_len:-_U32.size]
        crc, = _U32.unpack_from(blob, len(blob) - _U32.size)
        if zlib.crc32(hdr + payload) != crc:
            raise ValueError("PageSpan corrupt: CRC32 mismatch")
        header = json.loads(hdr.decode("utf-8"))
        want = sum(int(d["nbytes"]) for d in header["arrays"])
        if len(payload) != want:
            raise ValueError(f"truncated PageSpan: payload {len(payload)} "
                             f"bytes, manifest claims {want}")
        arrays: Dict[str, np.ndarray] = {}
        off = 0
        for d in header["arrays"]:
            dt = _np_dtype(d["dtype"])
            n = int(d["nbytes"])
            a = np.frombuffer(payload, dtype=dt, count=n // dt.itemsize,
                              offset=off)
            arrays[d["name"]] = a.reshape(d["shape"]).copy()
            off += n
        layers: List[Dict[str, np.ndarray]] = [
            {} for _ in range(int(header["n_groups"]))]
        for name, a in arrays.items():
            if name.startswith("layer"):
                li, key = name.split(".", 1)
                layers[int(li[len("layer"):])][key] = a
        return cls(prompt=arrays["prompt"], length=int(header["length"]),
                   max_new=int(header["max_new"]), eos_id=header["eos_id"],
                   page_len=int(header["page_len"]),
                   kv_quant=bool(header["kv_quant"]),
                   kv_bits=int(header["kv_bits"]),
                   hit_len=int(header["hit_len"]),
                   shared_pages=int(header["shared_pages"]),
                   logits=arrays["logits"], layers=tuple(layers))

    @property
    def n_blocks(self) -> int:
        return blocks_for_tokens(self.length, self.page_len)


def _require_paged(config: ServeConfig, who: str) -> None:
    if not config.paged:
        raise ValueError(f"{who} requires a paged ServeConfig (the page "
                         f"pool is the prefill->decode transfer unit)")


class PrefillEngine:
    """Prompt-ingestion half of the disaggregated pair.

    Wraps a full :class:`ServeScheduler` (same config, same compiled
    programs as the combined scheduler — the shape identity the
    bit-equality guarantee rests on) with decode held off: ``prefill``
    runs the admission + chunk ticks for ONE request in slot 0, exports
    the filled slot as a :class:`PageSpan`, and releases it — donating
    the prompt's pages to the prefill-side radix tree, so later prompts
    hit their shared prefixes exactly like in the combined scheduler.
    """

    def __init__(self, cfg, params, config: ServeConfig, *, mesh=None):
        _require_paged(config, "PrefillEngine")
        self._sched = ServeScheduler(cfg, params, config, mesh=mesh)
        self._sched._defer_decode = True

    @property
    def scheduler(self) -> ServeScheduler:
        return self._sched

    def prefill(self, prompt, max_new: int, eos_id: Optional[int] = None):
        """Ingest one prompt; returns ``(span, None)`` on success or
        ``(None, RequestResult)`` when the oversize policy rejected it
        (``oversize="truncate"`` spans the truncated prompt;
        ``"raise"`` raises, exactly like scheduler submission)."""
        s = self._sched
        rid = s.submit(prompt, max_new=max_new, eos_id=eos_id)
        if rid in s._results:              # rejected at submission
            return None, s._results.pop(rid)
        req = s._queue.popleft()           # possibly truncated
        status = s._admit(0, req)
        if status == "drop":
            return None, s._results.pop(req.rid)
        assert status == "ok", status      # "wait" needs other live slots
        # chunk-only ticks until ingestion completes; _defer_decode holds
        # the finishing row out of the same-tick decode scan, so the slot
        # lands at phase "decode" with first-token logits and ZERO tokens
        # (bucketed admissions arrive there with zero ticks)
        while s._slots[0] is not None and s._slots[0].phase == "prefill":
            s.step_tick()
        span = self._export(0, req)
        s._free_slot(0)                    # donate pages to the radix tree
        return span, None

    def _export(self, slot_idx: int, req: Request) -> PageSpan:
        s = self._sched
        slot = s._slots[slot_idx]
        pl = s.page_len
        length = int(req.prompt.size)
        nb = blocks_for_tokens(length, pl)
        pages = np.asarray(s._table[slot_idx, :nb], np.int64)
        layers: List[Dict[str, np.ndarray]] = []
        for c in s._pool["layers"]:
            if "ssm" in c:
                # recurrent state at the full prompt boundary (no decode
                # step has advanced it — that's the _defer_decode cut)
                layers.append({k: np.asarray(c[k][:, slot_idx:slot_idx + 1])
                               for k in c})
            elif s.kv_quant:
                group = {}
                for k in ("k", "v"):
                    group[f"{k}_codes"] = np.asarray(
                        c[f"{k}_codes"][:, pages])
                    group[f"{k}_scale"] = np.asarray(
                        c[f"{k}_scale"][:, pages])
                    group[f"{k}_tail"] = np.asarray(
                        c[f"{k}_tail"][:, slot_idx])
                layers.append(group)
            else:
                layers.append({k: np.asarray(c[k][:, pages])
                               for k in ("k", "v")})
        return PageSpan(
            prompt=np.asarray(req.prompt, np.int32),
            length=length, max_new=int(req.max_new), eos_id=req.eos_id,
            page_len=pl, kv_quant=s.kv_quant, kv_bits=s.kv_bits,
            hit_len=int(slot.hit_len),
            shared_pages=int(slot.hit_len) // pl,
            logits=np.asarray(s._logits[slot_idx]),
            layers=tuple(layers))


class DecodeEngine:
    """Token-generation half of the disaggregated pair.

    Imports :class:`PageSpan`\\ s into its own page pool (fresh pages
    from its allocator — the pool-to-pool transplant) and drives the
    unmodified fused decode tick.  Results come back as the scheduler's
    own :class:`RequestResult`\\ s via :meth:`drain_results`.
    """

    def __init__(self, cfg, params, config: ServeConfig, *, mesh=None):
        _require_paged(config, "DecodeEngine")
        self._sched = ServeScheduler(cfg, params, config, mesh=mesh)
        # never donate retired prompts to a decode-side radix tree:
        # retention would pin transplanted pages and starve later imports
        # — prefix reuse is the prefill engine's job (its tree sees every
        # prompt before a span exists)
        self._sched._radix = None

    @property
    def scheduler(self) -> ServeScheduler:
        return self._sched

    @property
    def active(self) -> int:
        return int(self._sched._active.sum())

    @property
    def has_free_slot(self) -> bool:
        return bool((~self._sched._active).any())

    def admit(self, span: PageSpan, rid: int,
              submit_time: float = float("nan")) -> str:
        """Import ``span`` into a free slot: ``"ok"`` (ticking now),
        ``"full"`` (no free slot — tick and retry), ``"wait"`` (slot
        free but the pool can't cover the span while other imports are
        in flight — tick and retry), or ``"drop"`` (pool can never
        cover it; a rejected result was recorded under ``rid``)."""
        s = self._sched
        if span.page_len != s.page_len or span.kv_quant != s.kv_quant or (
                span.kv_quant and span.kv_bits != s.kv_bits):
            raise ValueError(
                f"PageSpan/config mismatch: span has page_len="
                f"{span.page_len} kv_quant={span.kv_quant} kv_bits="
                f"{span.kv_bits}, decode pool has page_len={s.page_len} "
                f"kv_quant={s.kv_quant} kv_bits={s.kv_bits}")
        free = [i for i in range(s.max_slots) if not s._active[i]]
        if not free:
            return "full"
        slot_idx = free[0]
        # same worst-case sizing as paged admission: prompt + generation
        # + the junk tail of the finishing tick
        need_tokens = min(s.max_len,
                          span.length + span.max_new + s.tick_steps)
        n_total = max(blocks_for_tokens(need_tokens, s.page_len),
                      span.n_blocks)
        pages = s._alloc_pages(n_total)
        if pages is None:
            if s._active.any():
                return "wait"
            why = (f"decode page pool exhausted: span needs {n_total} "
                   f"pages, {s._pages.available} free of "
                   f"{s._pages.capacity}")
            if s.oversize == "raise":
                raise ValueError(why)
            now = time.perf_counter()
            s._results[rid] = RequestResult(
                rid=rid, prompt_len=int(span.prompt.size), tokens=[],
                finish_reason="rejected", admitted_tick=-1,
                finished_tick=s._tick_count, error=why,
                submit_time=submit_time, finish_time=now)
            return "drop"
        self._import(slot_idx, span, pages)
        req = Request(rid=rid, prompt=np.asarray(span.prompt, np.int32),
                      max_new=span.max_new, eos_id=span.eos_id,
                      submit_time=submit_time)
        s._slots[slot_idx] = _Slot(req=req, admitted_tick=s._tick_count,
                                   phase="decode", pages=pages,
                                   hit_len=span.hit_len)
        s._active[slot_idx] = True
        return "ok"

    def _import(self, slot_idx: int, span: PageSpan,
                pages: List[int]) -> None:
        """Scatter the span's state into ``slot_idx``: page contents into
        the freshly-allocated pages, table row, length, logits row, SSM
        state, and (kv_quant) the dense tail ring — the bit-exact mirror
        of ``PrefillEngine._export``."""
        import jax.numpy as jnp
        s = self._sched
        idx = np.asarray(pages[:span.n_blocks], np.int64)
        layers = []
        for c, grp in zip(s._pool["layers"], span.layers):
            if "ssm" in c:
                nc = {k: c[k].at[:, slot_idx:slot_idx + 1].set(
                    jnp.asarray(grp[k]).astype(c[k].dtype)) for k in c}
            elif s.kv_quant:
                nc = dict(c)
                for k in ("k", "v"):
                    for part, ax in ((f"{k}_codes", idx),
                                     (f"{k}_scale", idx)):
                        nc[part] = c[part].at[:, ax].set(
                            jnp.asarray(grp[part]).astype(c[part].dtype))
                    nc[f"{k}_tail"] = c[f"{k}_tail"].at[:, slot_idx].set(
                        jnp.asarray(grp[f"{k}_tail"]).astype(
                            c[f"{k}_tail"].dtype))
            else:
                nc = {k: c[k].at[:, idx].set(
                    jnp.asarray(grp[k]).astype(c[k].dtype))
                    for k in ("k", "v")}
            layers.append(nc)
        length = s._pool["length"].at[slot_idx].set(
            np.int32(span.length))
        s._pool = {"layers": tuple(layers), "length": length}
        s._logits = s._logits.at[slot_idx].set(
            jnp.asarray(span.logits).astype(s._logits.dtype))
        s._table[slot_idx, :] = TRASH_PAGE
        s._table[slot_idx, :len(pages)] = pages

    def step(self) -> bool:
        """One fused decode tick over every live slot (EOS/length
        retirement included); False when nothing is live."""
        return self._sched.step_tick()

    def drain_results(self) -> Dict[int, RequestResult]:
        """Finished results accumulated since the last drain, by rid."""
        out = self._sched._results
        self._sched._results = {}
        return out
