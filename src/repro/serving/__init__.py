from repro.serving.engine import (generate_fn, greedy_generate,
                                  make_decode_loop, make_prefill_step,
                                  make_serve_step, reference_generate)
__all__ = ["generate_fn", "greedy_generate", "make_decode_loop",
           "make_prefill_step", "make_serve_step", "reference_generate"]
