from repro.serving.engine import (greedy_generate, make_prefill_step,
                                  make_serve_step)
__all__ = ["greedy_generate", "make_prefill_step", "make_serve_step"]
