from repro.serving.config import SCHEMA_VERSION, ServeConfig
from repro.serving.engine import (clear_generate_cache, generate_fn,
                                  greedy_generate, make_decode_loop,
                                  make_prefill_step, make_serve_step,
                                  make_slot_prefill, make_slot_prefill_chunk,
                                  make_slot_serve_step,
                                  reference_generate, set_generate_cache_size)
from repro.serving.kvpool import (PagePool, PrefixHit, RadixCache,
                                  blocks_for_tokens)
from repro.serving.router import Router, run_disaggregated
from repro.serving.scheduler import (Request, RequestResult, ServeScheduler,
                                     bucket_for, round_pool_len)
from repro.serving.workers import DecodeEngine, PageSpan, PrefillEngine
__all__ = ["clear_generate_cache", "generate_fn", "greedy_generate",
           "make_decode_loop", "make_prefill_step", "make_serve_step",
           "make_slot_prefill", "make_slot_prefill_chunk",
           "make_slot_serve_step", "reference_generate",
           "set_generate_cache_size", "PagePool", "PrefixHit",
           "RadixCache", "blocks_for_tokens", "Request", "RequestResult",
           "ServeScheduler", "bucket_for", "round_pool_len",
           "SCHEMA_VERSION", "ServeConfig", "PageSpan", "PrefillEngine",
           "DecodeEngine", "Router", "run_disaggregated"]
