"""Host-side paged-KV bookkeeping: the page allocator and the radix
prefix cache.

QeiHaN's thesis is that memory *accesses*, not compute, bound DNN
inference (PAPER §IV) — this module is the serving-level image of that:
instead of one dense ``(max_len, ...)`` cache slab per slot, the KV cache
is a pool of fixed-size **pages** (``page_len`` tokens each) indexed by a
per-slot **page table**, and a **radix tree** over prompt token ids lets a
new request re-use the cached KV of its longest shared prefix — skipping
both the prefill compute and the cache *writes* for every shared token
(DESIGN.md §Paged KV + prefix cache).

Everything in this file is host-side metadata: plain numpy/python, no jax.
The device-side pool layout (``models.model.init_paged_pool``), the
gather-read / scatter-write attention path (``models.attention``) and the
scheduler integration (``serving/scheduler.py``) consume these objects.

* :class:`PagePool` — refcounted page allocator.  Page 0 is reserved as
  the **trash page**: every free/finished slot's page-table entries point
  at it, so masked junk writes (inactive rows in a decode tick, pad
  positions of a prompt chunk) land in a page nothing ever reads
  unmasked.  A page is freed when its refcount reaches zero — shared
  prefix pages survive any single holder's release.
* :class:`RadixCache` — a radix tree over prompt token ids at **page
  granularity**: each edge is the exact ``page_len``-token content of one
  page, so a cache hit is a run of whole pages that can be aliased into
  the new slot's page table (one ``ref`` per page, zero copies).  The
  final partially-matching page, if any, is surfaced as a **copy-on-write
  source**: the scheduler copies it into a fresh page the new slot owns
  exclusively, extending the hit below page granularity while shared
  pages stay immutable.
* **SSM snapshots** — recurrent state can't be aliased like KV rows: a
  Mamba slot needs the state *at the prefix boundary*.  Nodes optionally
  carry a host snapshot of the SSM/conv state at their prefix length
  (captured opportunistically when a chunk boundary lands exactly on the
  cacheable boundary), kept in a bounded LRU — for hybrid/SSM models a
  hit is only usable at a snapshot-bearing node, and partial-page (COW)
  extension is disabled (there is no state snapshot inside a page).
"""

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

TRASH_PAGE = 0


class PagePool:
    """Refcounted fixed-size page allocator (host metadata only).

    ``n_pages`` counts the whole device pool including the reserved trash
    page; ``capacity`` (usable pages) is ``n_pages - 1``.  ``alloc`` is
    all-or-nothing: it never hands out a partial allocation, so a failed
    admission leaves the pool untouched.
    """

    def __init__(self, n_pages: int, page_len: int):
        if n_pages < 2:
            raise ValueError(f"n_pages={n_pages}: need >= 2 (page 0 is the "
                             f"reserved trash page)")
        if page_len < 1:
            raise ValueError(f"page_len={page_len} must be >= 1")
        self.n_pages = int(n_pages)
        self.page_len = int(page_len)
        self.refcount = np.zeros((n_pages,), np.int32)
        self.refcount[TRASH_PAGE] = 1          # never allocated, never freed
        # LIFO free list: pages freed by a retiring request are re-used
        # first, which keeps the touched working set small
        self._free: List[int] = list(range(n_pages - 1, 0, -1))

    @property
    def capacity(self) -> int:
        return self.n_pages - 1

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.capacity - self.available

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` free pages (refcount 1 each), or ``None`` if fewer
        than ``n`` are free — all-or-nothing, the pool is untouched on
        failure."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        if any(self.refcount[p] != 0 for p in pages):
            # A page on the free list with a live reference means some
            # holder's id would silently alias a new allocation — the
            # device-side page tables (and the paged-attention kernel's
            # table walk) have no staleness check, so fail loudly here
            # rather than serve another request's KV.
            bad = [p for p in pages if self.refcount[p] != 0]
            raise RuntimeError(
                f"PagePool.alloc: free-list pages {bad} still referenced "
                f"(refcounts {[int(self.refcount[p]) for p in bad]}) — "
                f"page ids must stay stable while referenced")
        self.refcount[pages] += 1
        return pages

    def ref(self, pages: Sequence[int]) -> None:
        """Take one additional reference on each page (prefix sharing)."""
        for p in pages:
            if not 0 < p < self.n_pages:
                raise ValueError(f"ref: bad page id {p}")
            if self.refcount[p] <= 0:
                raise ValueError(f"ref: page {p} is free")
            self.refcount[p] += 1

    def release(self, pages: Sequence[int]) -> List[int]:
        """Drop one reference per page; pages reaching refcount 0 return
        to the free list.  Returns the page ids actually freed."""
        freed = []
        for p in pages:
            if not 0 < p < self.n_pages:
                raise ValueError(f"release: bad page id {p}")
            if self.refcount[p] <= 0:
                raise ValueError(f"release: page {p} already free")
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                self._free.append(p)
                freed.append(p)
        return freed

    def is_shared(self, page: int) -> bool:
        return self.refcount[page] > 1

    def verify(self) -> None:
        """Cross-check refcounts against the free list; raises
        ``ValueError`` on any inconsistency.  Used by the pool-to-pool
        transplant tests (``serving/workers.py``): after a span export
        donates/releases prefill-side pages and an import allocates
        decode-side pages, BOTH pools must still satisfy the invariants
        — no referenced page on the free list, no leaked page (refcount
        0 yet unavailable), trash page pinned exactly once."""
        free = set(self._free)
        if len(free) != len(self._free):
            raise ValueError("PagePool.verify: free list has duplicates")
        if TRASH_PAGE in free or self.refcount[TRASH_PAGE] != 1:
            raise ValueError("PagePool.verify: trash page not pinned")
        for p in range(1, self.n_pages):
            rc = int(self.refcount[p])
            if rc < 0:
                raise ValueError(f"PagePool.verify: page {p} refcount {rc}")
            if p in free and rc != 0:
                raise ValueError(f"PagePool.verify: page {p} on the free "
                                 f"list with refcount {rc}")
            if p not in free and rc == 0:
                raise ValueError(f"PagePool.verify: page {p} leaked "
                                 f"(refcount 0 but not on the free list)")


@dataclasses.dataclass
class _Node:
    """One radix-tree edge: the exact token content of one page."""
    page: int                               # page id holding this block's KV
    children: Dict[Tuple[int, ...], "_Node"] = \
        dataclasses.field(default_factory=dict)
    last_used: int = 0
    snapshot: Optional[tuple] = None        # host SSM/conv state AT the end
                                            # of this block (hybrid models)
    depth: int = 0                          # blocks from root, 1-based


@dataclasses.dataclass
class PrefixHit:
    """Result of a radix lookup.

    ``pages`` are whole shared pages (the caller must ``ref`` them);
    ``cow_src`` is the partially-matching page to copy-on-write, covering
    ``partial`` extra tokens beyond ``len(pages) * page_len``.
    ``length = len(pages) * page_len + partial`` prompt tokens are served
    from cache; ``snapshot`` is the SSM/conv state at ``length`` (None
    for attention-only models).
    """
    pages: List[int]
    length: int = 0
    partial: int = 0
    cow_src: Optional[int] = None
    snapshot: Optional[tuple] = None


class RadixCache:
    """Page-granular radix tree over prompt token ids.

    Each edge key is the exact ``page_len``-token tuple of one page, so
    walking the tree IS the longest-common-prefix match at page
    granularity; the deepest reachable node's children are additionally
    scanned for the longest *partial* block match (returned as a COW
    source).  The tree holds one pool reference per resident page;
    :meth:`evict` trims least-recently-used leaves to free pool pages.
    """

    def __init__(self, pool: PagePool, *, snapshot_limit: int = 8):
        self.pool = pool
        self.page_len = pool.page_len
        self.snapshot_limit = int(snapshot_limit)
        self._root = _Node(page=TRASH_PAGE)
        self._clock = itertools.count(1)
        self._n_snapshots = 0
        # observability (serve_bench --prefix-trace)
        self.lookups = 0
        self.hits = 0
        self.tokens_hit = 0

    # ------------------------------------------------------------- internals

    def _blocks(self, prompt: np.ndarray) -> List[Tuple[int, ...]]:
        pl = self.page_len
        n = len(prompt) // pl
        return [tuple(int(t) for t in prompt[i * pl:(i + 1) * pl])
                for i in range(n)]

    def _walk(self, prompt: np.ndarray) -> List[_Node]:
        """Nodes along the longest whole-block match, root excluded."""
        path = []
        node = self._root
        for blk in self._blocks(prompt):
            child = node.children.get(blk)
            if child is None:
                break
            path.append(child)
            node = child
        return path

    def _iter_nodes(self):
        stack = [self._root]
        while stack:
            node = stack.pop()
            for key, child in node.children.items():
                yield node, key, child
                stack.append(child)

    # ------------------------------------------------------------------ API

    @property
    def n_pages(self) -> int:
        return sum(1 for _ in self._iter_nodes())

    def verify(self) -> None:
        """Tree/pool consistency: every resident node holds a live page
        reference (depth consistent with its parent, snapshot count
        matching the bound's counter).  Raises ``ValueError`` on any
        violation — paired with :meth:`PagePool.verify` in the
        disaggregated transplant tests."""
        snaps = 0
        for parent, _, child in self._iter_nodes():
            if not 0 < child.page < self.pool.n_pages:
                raise ValueError(f"RadixCache.verify: node page "
                                 f"{child.page} out of range")
            if self.pool.refcount[child.page] < 1:
                raise ValueError(f"RadixCache.verify: node page "
                                 f"{child.page} has no live reference")
            if child.depth != parent.depth + 1:
                raise ValueError(f"RadixCache.verify: node at depth "
                                 f"{child.depth} under parent depth "
                                 f"{parent.depth}")
            snaps += child.snapshot is not None
        if snaps != self._n_snapshots:
            raise ValueError(f"RadixCache.verify: {snaps} snapshots in the "
                             f"tree, counter says {self._n_snapshots}")

    def lookup(self, prompt: np.ndarray, *, max_hit: int,
               need_snapshot: bool = False, min_hit: int = 1,
               allow_partial: bool = True) -> Optional[PrefixHit]:
        """Longest usable cached prefix of ``prompt``.

        ``max_hit`` caps the hit length (pass ``len(prompt) - 1`` so at
        least one suffix token remains to produce the first logits).
        ``need_snapshot`` (SSM/hybrid models) restricts the hit to the
        deepest node carrying a state snapshot and disables partial-page
        extension; ``min_hit`` drops hits too short to be worth the
        chunked suffix path.  Touches matched nodes' LRU clocks.
        """
        self.lookups += 1
        now = next(self._clock)
        path = self._walk(prompt)
        while path and path[-1].depth * self.page_len > max_hit:
            path.pop()
        if need_snapshot:
            while path and path[-1].snapshot is None:
                path.pop()
        for node in path:
            node.last_used = now
        pages = [n.page for n in path]
        hit_len = len(pages) * self.page_len
        partial, cow_src = 0, None
        if allow_partial and not need_snapshot:
            tail = self._root if not path else path[-1]
            rest = np.asarray(prompt[hit_len:])
            best = 0
            for key, child in tail.children.items():
                k = np.asarray(key, rest.dtype)
                lim = min(len(rest), self.page_len, max_hit - hit_len)
                if lim <= best:
                    continue
                eq = k[:lim] == rest[:lim]
                run = int(eq.argmin()) if not eq.all() else lim
                if run > best:
                    best, cow_src = run, child.page
                    if run == lim:
                        break
            if best > 0:
                partial = best
        hit_len += partial
        if hit_len < max(min_hit, 1):
            return None
        self.hits += 1
        self.tokens_hit += hit_len
        return PrefixHit(pages=pages, length=hit_len, partial=partial,
                         cow_src=cow_src if partial else None,
                         snapshot=path[-1].snapshot if path else None)

    def insert(self, prompt: np.ndarray, page_of_block, *,
               snapshot: Optional[tuple] = None) -> int:
        """Insert ``prompt``'s whole-page blocks; ``page_of_block(i)``
        supplies the page id holding block ``i``'s KV (the retiring
        slot's page table).  Existing nodes are re-used (their pages are
        already resident); each NEW node takes one pool reference on its
        page.  ``snapshot`` attaches at the deepest inserted node (the
        cacheable prompt boundary).  Returns the number of new nodes.
        """
        now = next(self._clock)
        node = self._root
        created = 0
        blocks = self._blocks(prompt)
        for i, blk in enumerate(blocks):
            child = node.children.get(blk)
            if child is None:
                page = int(page_of_block(i))
                if page == TRASH_PAGE:
                    break                      # slot never filled this block
                self.pool.ref([page])
                child = _Node(page=page, depth=node.depth + 1)
                node.children[blk] = child
                created += 1
            child.last_used = now
            node = child
        if snapshot is not None and node is not self._root:
            if node.snapshot is None:
                self._n_snapshots += 1
            node.snapshot = snapshot
            self._trim_snapshots(keep=node)
        return created

    def _trim_snapshots(self, keep: Optional[_Node] = None) -> None:
        while self._n_snapshots > self.snapshot_limit:
            cands = [c for _, _, c in self._iter_nodes()
                     if c.snapshot is not None and c is not keep]
            if not cands:
                break
            victim = min(cands, key=lambda n: n.last_used)
            victim.snapshot = None             # pages stay shareable
            self._n_snapshots -= 1

    def evictable_pages(self) -> int:
        """Resident pages eviction could actually free right now: tree
        pages whose only reference is the tree's own (a page a live slot
        still aliases survives its node's eviction)."""
        return sum(1 for _, _, child in self._iter_nodes()
                   if self.pool.refcount[child.page] == 1)

    def evict(self, n_pages_needed: int) -> int:
        """Drop least-recently-used LEAF nodes (releasing their pool
        reference) until at least ``n_pages_needed`` pages are free or
        nothing evictable remains.  A released page is only truly freed
        once no live slot references it.  Returns the number of nodes
        dropped.

        Stops as soon as no resident node could free a page
        (:meth:`evictable_pages` == 0): when every tree page is still
        aliased by a live slot, continuing to drop nodes cannot satisfy
        the request — it would only destroy prefix entries whose pages
        come back to the tree-shareable state the moment those slots
        retire.  (The scheduler guards its call with ``available +
        evictable_pages() >= n``, but evict itself must not over-drain
        on an unsatisfiable ask.)"""
        dropped = 0
        while self.pool.available < n_pages_needed:
            if self.evictable_pages() == 0:
                break
            leaves = [(parent, key, child)
                      for parent, key, child in self._iter_nodes()
                      if not child.children]
            if not leaves:
                break
            parent, key, child = min(leaves, key=lambda t: t[2].last_used)
            if child.snapshot is not None:
                self._n_snapshots -= 1
            del parent.children[key]
            self.pool.release([child.page])
            dropped += 1
        return dropped

    def clear(self) -> None:
        """Release every resident page and reset the tree."""
        for _, _, child in self._iter_nodes():
            self.pool.release([child.page])
        self._root = _Node(page=TRASH_PAGE)
        self._n_snapshots = 0


def blocks_for_tokens(n_tokens: int, page_len: int) -> int:
    """Pages needed to hold ``n_tokens`` (ceil division)."""
    return -(-int(n_tokens) // int(page_len))


def page_kv_bytes(page_len: int, n_kv_heads: int, head_dim: int, *,
                  layers: int = 1, quant: bool = False, kv_bits: int = 4,
                  dtype_bytes: int = 4) -> int:
    """Device bytes ONE pool page holds (K and V, ``layers`` attention
    layer-repeats).  Dense pages store ``dtype_bytes`` per element; log2-
    quantized pages store one packed wire code per element
    (``core.logquant.code_dtype``: 1 byte below 8 exponent bits, else 2)
    plus a per-(page, head) int32 scale exponent.  Pure arithmetic — the
    EXACT-gated byte rows of ``serve_bench --kv-quant`` come from here,
    not from measurement."""
    elems = int(page_len) * int(n_kv_heads) * int(head_dim)
    if quant:
        code = 2 if int(kv_bits) >= 8 else 1
        per = elems * code + int(n_kv_heads) * 4
    else:
        per = elems * int(dtype_bytes)
    return 2 * int(layers) * per


def tail_ring_bytes(page_len: int, n_kv_heads: int, head_dim: int, *,
                    layers: int = 1, dtype_bytes: int = 4) -> int:
    """Device bytes of ONE slot's dense tail ring (quantized pools only):
    ``2 * page_len + 1`` rows — two pages plus the junk bin — per
    direction per layer-repeat.  Per-slot decode-adjacent working set,
    amortized per request by the bench."""
    rows = 2 * int(page_len) + 1
    return (2 * int(layers) * rows * int(n_kv_heads) * int(head_dim)
            * int(dtype_bytes))
