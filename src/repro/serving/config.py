"""``ServeConfig`` — every scheduler/engine knob as ONE frozen, validated,
JSON-serializable value.

The continuous-batching scheduler grew one keyword argument per PR until
its constructor took 22 of them (slots, buckets, chunking, paging, prefix
cache, attention kernel, kv quantization, mesh...).  That shape cannot be
shipped across a process boundary — and disaggregated serving
(``serving/workers.py`` / ``serving/router.py``) needs to rebuild the
SAME scheduler configuration inside prefill and decode worker processes.
So the knobs live here instead:

* **Canonicalized** — ``__post_init__`` normalizes every field to one
  canonical form (buckets sorted/deduped, bool shorthands expanded to
  their mode strings, defaults resolved), so two configs that mean the
  same thing compare equal and ``from_json(cfg.to_json()) == cfg`` holds
  for every valid config.
* **Validated** — every model-independent check that used to live inline
  in ``ServeScheduler.__init__`` runs here, once, with the same error
  messages.  A config that constructs is a config a scheduler accepts.
* **Serializable** — ``to_json`` / ``from_json`` with an explicit
  ``schema`` version field; unknown keys and version mismatches are
  rejected loudly (a silently-dropped knob is a silently-different
  scheduler).
* **Mesh by NAME** — ``mesh_spec`` holds a ``launch.mesh.make_serve_mesh``
  spec string (``"2x2"``, ``"host"``, ...), never a live ``jax.Mesh``:
  device binding is process-local, the spec is what travels.  The
  scheduler resolves it at build time (an explicit ``mesh=`` object
  passed alongside still wins — subprocess tests bind their own devices).

``ServeScheduler(cfg, params, config)`` is the canonical construction;
the legacy 22-kwarg form survives behind a ``DeprecationWarning`` shim
(``scheduler.py``) that routes through this class, so old and new
construction are byte-for-byte the same scheduler.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional, Tuple, Union

DEFAULT_BUCKETS: Tuple[int, ...] = (16, 32, 64, 128)

#: bump when a field is added/removed/renamed or its meaning changes;
#: ``from_json`` refuses other versions rather than guessing
SCHEMA_VERSION = 1


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Frozen, canonical serve-scheduler configuration.

    Field semantics are exactly the old ``ServeScheduler`` keyword
    arguments (see its docstring); the two deliberate differences:

    * ``mesh_spec`` replaces the ``mesh=`` object — a spec *string* for
      ``launch.mesh.make_serve_mesh`` (process-portable), or ``None``.
    * ``quant`` is restricted to ``bool | str`` (a live ``QuantCtx``
      doesn't serialize; every shipping caller passes a backend name).
    """

    max_slots: int = 8
    max_len: int = 256
    buckets: Tuple[int, ...] = DEFAULT_BUCKETS
    quant: Union[bool, str] = False
    with_stats: bool = False
    tick_steps: int = 8
    generate_cache_size: Optional[int] = None
    mesh_spec: Optional[str] = None
    oversize: str = "reject"
    chunked: Union[bool, str] = "off"
    chunk_len: Optional[int] = None
    paged: bool = False
    page_len: int = 16
    n_pages: Optional[int] = None
    prefix_cache: bool = False
    snapshot_limit: int = 8
    min_prefix_hit: Optional[int] = None
    attn_kernel: Union[bool, str] = "off"
    attn_splits: int = 1
    kv_quant: bool = False
    kv_bits: int = 4

    # ------------------------------------------------------- canonicalize
    def __post_init__(self):
        def put(k, v):
            object.__setattr__(self, k, v)

        put("max_slots", int(self.max_slots))
        put("max_len", int(self.max_len))
        put("tick_steps", int(self.tick_steps))
        if self.max_slots < 1 or self.tick_steps < 1:
            raise ValueError("max_slots and tick_steps must be >= 1")
        if self.oversize not in ("reject", "truncate", "raise"):
            raise ValueError(f"oversize={self.oversize!r}: expected "
                             f"'reject', 'truncate', or 'raise'")
        if not isinstance(self.quant, (bool, str)):
            raise ValueError(f"quant={self.quant!r}: ServeConfig takes a "
                             f"bool or backend-name string (a live quant "
                             f"context does not serialize)")
        put("with_stats", bool(self.with_stats))
        if self.generate_cache_size is not None:
            put("generate_cache_size", int(self.generate_cache_size))
        if self.mesh_spec is not None and not isinstance(self.mesh_spec,
                                                         str):
            raise ValueError(f"mesh_spec={self.mesh_spec!r}: expected a "
                             f"make_serve_mesh spec STRING ('2x2', 'host', "
                             f"...) — a live Mesh is process-local; pass "
                             f"it to the scheduler's mesh= instead")
        buckets = tuple(sorted(set(int(b) for b in self.buckets)))
        put("buckets", buckets)
        if not buckets or buckets[-1] > self.max_len:
            raise ValueError(f"buckets {buckets} must be non-empty and fit "
                             f"max_len={self.max_len}")
        chunked = self.chunked
        if isinstance(chunked, bool):
            chunked = "auto" if chunked else "off"
        put("chunked", chunked)
        if chunked not in ("off", "auto", "always"):
            raise ValueError(f"chunked={chunked!r}: expected 'off', 'auto', "
                             f"or 'always'")
        put("chunk_len", int(buckets[0] if self.chunk_len is None
                             else self.chunk_len))
        put("paged", bool(self.paged))
        put("page_len", int(self.page_len))
        put("prefix_cache", bool(self.prefix_cache))
        put("snapshot_limit", int(self.snapshot_limit))
        if self.prefix_cache and not self.paged:
            raise ValueError("prefix_cache=True requires paged=True (prefix "
                             "hits alias shared pages)")
        # prefix-hit admissions ingest the prompt SUFFIX through the chunked
        # path, so the chunk-program invariants hold whenever either is on
        if self.needs_chunk_programs:
            if not 1 <= self.chunk_len <= self.max_len:
                raise ValueError(f"chunk_len={self.chunk_len} must be in "
                                 f"[1, max_len={self.max_len}]")
            if self.max_len % self.chunk_len:
                raise ValueError(f"max_len={self.max_len} must be a "
                                 f"multiple of chunk_len={self.chunk_len}")
        if self.paged:
            if self.page_len < 1:
                raise ValueError(f"page_len={self.page_len} must be >= 1")
            if self.max_len % self.page_len:
                raise ValueError(f"max_len={self.max_len} must be a "
                                 f"multiple of page_len={self.page_len}")
            if self.n_pages is not None:
                put("n_pages", int(self.n_pages))
                if self.n_pages < 2:
                    raise ValueError(f"n_pages={self.n_pages}: need >= 2 "
                                     f"(page 0 is the reserved trash page)")
            put("min_prefix_hit", int(self.page_len
                                      if self.min_prefix_hit is None
                                      else self.min_prefix_hit))
        else:
            # page-pool knobs are meaningless dense — canonicalize so equal
            # dense configs compare equal regardless of leftover values
            put("min_prefix_hit", 0)
        attn_kernel = self.attn_kernel
        if isinstance(attn_kernel, bool):
            attn_kernel = "pallas" if attn_kernel else "off"
        put("attn_kernel", attn_kernel)
        if attn_kernel not in ("off", "pallas"):
            raise ValueError(f"attn_kernel={attn_kernel!r}: expected 'off' "
                             f"or 'pallas'")
        put("attn_splits", int(self.attn_splits))
        if self.attn_splits < 1:
            raise ValueError(f"attn_splits={self.attn_splits} must be >= 1")
        if attn_kernel != "off" and not self.paged:
            raise ValueError("attn_kernel requires paged=True (the kernel "
                             "walks the page tables)")
        put("kv_quant", bool(self.kv_quant))
        put("kv_bits", int(self.kv_bits))
        if self.kv_quant:
            if not self.paged:
                raise ValueError("kv_quant=True requires paged=True (the "
                                 "compressed page format lives in the pool)")
            if not 2 <= self.kv_bits <= 8:
                raise ValueError(f"kv_bits={self.kv_bits} must be in [2, 8]")

    # ----------------------------------------------------------- derived
    @property
    def needs_chunk_programs(self) -> bool:
        return self.chunked != "off" or self.prefix_cache

    @property
    def max_blocks(self) -> int:
        """Page-table width: pages one fully-resident slot spans."""
        if not self.paged:
            raise ValueError("max_blocks: not a paged config")
        return self.max_len // self.page_len

    def resolved_n_pages(self, mesh=None) -> int:
        """Concrete pool size: the explicit ``n_pages``, or the default —
        every slot fully resident, plus prefix-cache retention headroom
        for one max-size prompt, plus the trash page — rounded up to the
        mesh's data-axis size so the pages-on-data sharding engages (an
        EXPLICIT ``n_pages`` is the caller's to align)."""
        if not self.paged:
            return 0
        if self.n_pages is not None:
            return self.n_pages
        n = (self.max_slots * self.max_blocks + 1
             + (self.max_blocks if self.prefix_cache else 0))
        if mesh is not None:
            from repro.launch.mesh import batch_axes
            nb = 1
            for a in batch_axes(mesh):
                nb *= mesh.shape[a]
            n = -(-n // nb) * nb
        return n

    def make_mesh(self):
        """Resolve ``mesh_spec`` to a live mesh in THIS process (None
        spec -> None; needs the devices the spec names)."""
        if self.mesh_spec is None:
            return None
        from repro.launch.mesh import make_serve_mesh
        return make_serve_mesh(self.mesh_spec)

    # -------------------------------------------------------------- JSON
    def to_json(self, *, indent: Optional[int] = None) -> str:
        doc = {"schema": SCHEMA_VERSION}
        doc.update(dataclasses.asdict(self))
        doc["buckets"] = list(self.buckets)
        return json.dumps(doc, indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "ServeConfig":
        try:
            doc = json.loads(text)
        except json.JSONDecodeError as e:
            raise ValueError(f"ServeConfig.from_json: not valid JSON "
                             f"({e})") from None
        if not isinstance(doc, dict):
            raise ValueError(f"ServeConfig.from_json: expected a JSON "
                             f"object, got {type(doc).__name__}")
        doc = dict(doc)
        version = doc.pop("schema", None)
        if version != SCHEMA_VERSION:
            raise ValueError(f"ServeConfig.from_json: schema version "
                             f"{version!r} (this build reads version "
                             f"{SCHEMA_VERSION})")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(doc) - known)
        if unknown:
            raise ValueError(f"ServeConfig.from_json: unknown fields "
                             f"{unknown} (schema version {SCHEMA_VERSION} "
                             f"knows {sorted(known)})")
        if "buckets" in doc:
            doc["buckets"] = tuple(doc["buckets"])
        return cls(**doc)
