"""Continuous-batching serve scheduler over a persistent slot-based cache
pool.

The fused decode engine (``serving/engine.py``) runs one rectangular batch
per compiled program — fine for offline eval, wrong for serving: a finished
row idles its slot until the whole batch drains, and every generate
re-allocates its caches.  This module keeps the quantized decode path
*saturated* under sustained multi-request load, the bandwidth-bound regime
where QeiHaN's plane-skipping pays (PAPER §VI; DESIGN.md §Scheduler):

* **Slot pool** — ONE persistent allocation: ``max_slots`` cache rows of
  ``max_len`` each (``init_caches(per_slot=True)``, per-row ``length``).
  Slots are reset by *overwriting*, never re-allocated.
* **Bucketed prefill** — prompts are right-padded to the smallest
  configured bucket, so prefill compiles once per bucket, not once per
  prompt length.  Pad tokens are masked out of the SSM state
  (``valid_len``) and sit causally after every real token for attention.
* **Chunked prefill** (``chunked="auto"|"always"``) — a prompt is split
  into fixed ``chunk_len`` chunks fed straight into the slot pool across
  successive ticks (``engine.make_slot_prefill_chunk``), interleaved with
  decode for the other slots in ONE jitted mixed tick — a long prompt no
  longer stalls every in-flight decode slot for its full prefill, and
  admission is bounded by ``max_len`` instead of ``buckets[-1]``.  The
  chunk slab is ONE compiled shape for every prompt length (vs one prefill
  program per bucket).  ``"auto"`` (the default when enabling) chunks only
  prompts longer than the largest bucket, so every in-bucket prompt keeps
  the bucketed path's bit-exact token guarantee; ``"always"`` chunks
  everything — maximal interleaving, tokens agree with the bucketed path
  to f32-ULP logits (token-equal on every tested seed/arch, asserted in
  tests, but not *guaranteed* bit-equal: chunk-boundary GEMM shapes
  reassociate the same sums — DESIGN.md §Chunked prefill).
* **Tick loop** — ONE jitted program steps *all* slots ``tick_steps``
  greedy tokens at a time (a ``lax.scan`` over ``make_slot_serve_step``);
  host logic between ticks detects EOS / length exhaustion, retires the
  slot and immediately re-fills it from the queue — decode never drains to
  refill the batch.
* **Per-request traffic stats** — with ``with_stats=True`` each tick
  reports the per-step batch-aggregate ``plane_traffic_fraction`` /
  ``element_traffic_fraction``; the scheduler attributes each step's
  fractions to the requests active at that step and reports the per-request
  mean.
* **Paged KV pool** (``paged=True``) — attention KV moves from dense
  per-slot ``(max_len, ...)`` slabs into a shared pool of fixed-size
  pages (``models.model.init_paged_pool``) indexed through host-side
  per-slot page tables (``serving/kvpool.py``): writes scatter at
  (page, offset), reads gather each slot's pages into its dense logical
  view and run the SAME masked einsums — tokens bit-equal to the dense
  scheduler on prefix-free traffic.  Pool exhaustion waits for in-flight
  retirements, or resolves through the ``oversize`` policy when idle.
* **Radix prefix cache** (``prefix_cache=True``) — retired prompts donate
  their whole-page KV blocks to a radix tree keyed on token ids; a new
  request aliases its longest cached prefix (refcounted shared pages,
  partial tail page via copy-on-write) and ingests only the suffix
  through the chunked path — the shared tokens skip prefill compute AND
  cache writes (DESIGN.md §Paged KV + prefix cache).  SSM/hybrid models
  reuse hits via bounded-LRU state snapshots at page-aligned boundaries.
* **Mesh-native** — pass ``mesh=`` and the slot pool is allocated
  device-sharded exactly once (batch on ``data``, kv-seq / ssm-heads on
  ``model``, per-slot ``(B,)`` lengths on ``data`` —
  ``launch.shardings.serve_shardings``), the prefill / write / tick
  programs are jitted with explicit ``in_shardings`` / ``out_shardings``,
  and admission / retirement keep touching only host-side metadata (the
  ``active`` bitmap and per-slot token lists) — the tick loop performs no
  cross-device gathers beyond the (B, tick_steps) token array every tick
  already syncs to host.  Scheduler tokens are bit-equal to the
  single-device scheduler (tests/test_serve_sharded.py).

Token outputs are exactly the per-request ``greedy_generate`` outputs
(property-tested): same prefill math (padding contributes exact zeros),
same masked decode attention, same greedy sampling.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
import warnings
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import ModelConfig, init_caches, init_paged_pool
from repro.serving import engine
from repro.serving.config import ServeConfig
from repro.serving.kvpool import (TRASH_PAGE, PagePool, RadixCache,
                                  blocks_for_tokens)

# the legacy keyword surface: exactly the ServeConfig fields minus
# mesh_spec (the old signature took a live mesh OBJECT, which stays a
# first-class scheduler argument — device binding is process-local)
_LEGACY_KWARGS = frozenset(
    f.name for f in dataclasses.fields(ServeConfig)) - {"mesh_spec"}


def bucket_for(length: int, buckets: Sequence[int]) -> int:
    """Smallest configured bucket that holds ``length`` real tokens."""
    for b in sorted(buckets):
        if length <= b:
            return b
    raise ValueError(f"prompt length {length} exceeds the largest prefill "
                     f"bucket {max(buckets)}")


def round_pool_len(base: int, chunk_len: int) -> int:
    """Smallest multiple of ``chunk_len`` >= ``base`` — the ``max_len`` a
    chunked :class:`ServeScheduler` accepts (the constructor validates
    rather than silently rounding, so sizing stays an explicit caller
    decision; every CLI/bench derives its pool through this helper)."""
    return -(-int(base) // int(chunk_len)) * int(chunk_len)


@dataclasses.dataclass(frozen=True)
class Request:
    rid: int
    prompt: np.ndarray                  # (L,) int32 token ids
    max_new: int
    eos_id: Optional[int] = None
    submit_time: float = float("nan")   # time.perf_counter() at submit()


@dataclasses.dataclass
class RequestResult:
    rid: int
    prompt_len: int
    tokens: List[int]
    finish_reason: str                  # "eos" | "length" | "rejected"
    admitted_tick: int                  # -1 for rejected requests
    finished_tick: int
    # per-request mean of the per-step batch-aggregate traffic fractions
    # over the steps this request was active (nan without stats)
    plane_traffic_fraction: float = float("nan")
    element_traffic_fraction: float = float("nan")
    error: Optional[str] = None         # why a "rejected" request never ran
    # wall-clock marks on one time.perf_counter() clock — latency reporting
    # (benchmarks/serve_bench.py): TTFT = first_token_time - submit_time
    # (queue wait + prefill), e2e = finish_time - submit_time
    submit_time: float = float("nan")
    first_token_time: float = float("nan")
    finish_time: float = float("nan")


@dataclasses.dataclass
class _Slot:
    req: Request
    admitted_tick: int
    tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    finish_reason: str = ""
    frac_sums: List[float] = dataclasses.field(
        default_factory=lambda: [0.0, 0.0])
    frac_steps: int = 0
    # chunked-prefill state machine: an admitted slot is "prefill" until its
    # last chunk lands (bucketed admissions enter directly at "decode"),
    # then decodes until EOS/length retires it
    phase: str = "decode"               # "prefill" | "decode"
    prefill_pos: int = 0                # prompt tokens ingested so far
    first_token_time: float = float("nan")
    # paged mode: every page this slot holds a reference on (fresh allocs,
    # shared prefix pages, COW copies), the prefix-hit length it was
    # admitted with, and the SSM/conv state snapshot at the cacheable
    # prompt boundary (hybrid models, captured opportunistically)
    pages: List[int] = dataclasses.field(default_factory=list)
    hit_len: int = 0
    snapshot: Optional[tuple] = None


class ServeScheduler:
    """Continuous-batching scheduler: admit -> tick -> retire -> re-fill.

    Greedy decoding only (per-request temperatures would break the shared
    batched argmax; the fused single-batch engine covers sampling).  Audio /
    vision frontends are out of scope — they prefill from embeddings, not
    token ids.

    Usage::

        sc = ServeConfig(max_slots=8, max_len=256)
        sched = ServeScheduler(cfg, params, sc)
        for p in prompts:
            sched.submit(p, max_new=32, eos_id=2)
        results = sched.run()          # List[RequestResult], rid order

    The legacy keyword form (``ServeScheduler(cfg, params, max_slots=8,
    ...)``) still works — it routes through ``ServeConfig`` and emits a
    ``DeprecationWarning``; every knob below is a ``ServeConfig`` field.

    ``chunked="auto"`` (or ``True``) adds chunked prefill: prompts longer
    than the largest bucket — rejected outright without it — are ingested
    ``chunk_len`` tokens per tick (default: the smallest bucket),
    interleaved with decode for the other slots; ``chunked="always"``
    chunks every prompt (maximal interleaving / bounded per-tick latency).
    ``max_len`` must be a multiple of ``chunk_len``.

    ``paged=True`` swaps the dense per-slot KV slabs for the shared page
    pool (``page_len`` tokens per page, ``n_pages`` total — default sizes
    every slot fully resident plus prefix-cache headroom; ``max_len`` must
    be a multiple of ``page_len``); ``prefix_cache=True`` (requires paged)
    adds radix-tree prefix reuse with ``min_prefix_hit`` (default
    ``page_len``) as the smallest hit worth taking and ``snapshot_limit``
    bounding the SSM-state snapshots hybrid models need per hit.

    ``attn_kernel=True`` (or ``"pallas"``; requires ``paged``) routes the
    decode read through the fused paged-attention kernel
    (``kernels/paged_attention``): the kernel walks the page tables
    directly instead of gathering ``pool[table]`` into the dense padded
    view, and ``attn_splits`` partitions the KV page axis flash-decode
    style (partial softmax statistics merged at the end).  Tokens are
    equal to the dense-gather scheduler on every tested seed/arch
    (asserted in tests/test_paged_attention.py); logits agree to f32-ULP
    softmax reassociation — same bar as chunked-vs-bucketed prefill.
    """

    def __init__(self, cfg: ModelConfig, params,
                 config: Optional[ServeConfig] = None, *,
                 mesh=None, **legacy):
        """Build from a :class:`ServeConfig` (canonical form) or the
        legacy keyword surface (deprecated shim: same defaults, same
        validation — it routes through ``ServeConfig`` — byte-for-byte
        the same scheduler, plus a ``DeprecationWarning``).  ``mesh=``
        stays a first-class argument either way: a live mesh is
        process-local device BINDING, not configuration; when only
        ``config.mesh_spec`` is set, it resolves here via
        ``make_serve_mesh``."""
        if cfg.frontend != "none":
            raise ValueError("ServeScheduler serves token-id models only "
                             f"(frontend={cfg.frontend!r})")
        if config is None:
            unknown = sorted(set(legacy) - _LEGACY_KWARGS)
            if unknown:
                raise TypeError(f"ServeScheduler: unexpected keyword "
                                f"arguments {unknown}")
            if legacy:
                warnings.warn(
                    "ServeScheduler(cfg, params, **kwargs) is deprecated: "
                    "build a serving.ServeConfig and pass it as the third "
                    "argument — ServeScheduler(cfg, params, serve_config)",
                    DeprecationWarning, stacklevel=2)
            config = ServeConfig(**legacy)
        elif legacy:
            raise TypeError(f"ServeScheduler: pass EITHER a ServeConfig or "
                            f"legacy keyword arguments, not both (got a "
                            f"config plus {sorted(legacy)})")
        if not isinstance(config, ServeConfig):
            raise TypeError(f"ServeScheduler: config must be a ServeConfig,"
                            f" got {type(config).__name__}")
        if mesh is None:
            mesh = config.make_mesh()
        self.serve_config = config

        # unpack the validated knobs into locals (the builder below) and
        # the long-standing public attributes (benches/tests read these)
        max_slots = config.max_slots
        max_len = config.max_len
        buckets = config.buckets
        quant = config.quant
        with_stats = config.with_stats
        tick_steps = config.tick_steps
        chunk_len = config.chunk_len
        paged = config.paged
        page_len = config.page_len
        prefix_cache = config.prefix_cache
        needs_chunk_programs = config.needs_chunk_programs
        attn_kernel = config.attn_kernel
        kv_quant = config.kv_quant
        kv_bits = config.kv_bits
        if paged:
            max_blocks = config.max_blocks
            n_pages = config.resolved_n_pages(mesh)
            # NB a pool SMALLER than one full slot (max_blocks + 1 pages) is
            # legal: requests that can never fit it resolve through the
            # oversize policy at admission (reject/truncate/raise), so an
            # under-provisioned pool degrades per-request, never crashes
        if attn_kernel != "off":
            # the flag rides the config: every compiled program built below
            # (tick / chunk / mixed) picks up the kernel dispatch through
            # models.attention, with no engine-level plumbing
            cfg = cfg.replace(paged_attn_kernel=attn_kernel,
                              paged_attn_splits=config.attn_splits)
        if kv_quant:
            # like attn_kernel, the quantized-pool mode rides the config:
            # init_paged_pool emits the codes/scale/tail leaves and
            # models.attention dispatches the quantize-on-write path
            cfg = cfg.replace(kv_quant=True, kv_bits=kv_bits)
        self.kv_quant = kv_quant
        self.kv_bits = kv_bits
        self.attn_kernel = attn_kernel
        self.attn_splits = config.attn_splits
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.buckets = buckets
        self.quant = quant
        self.with_stats = with_stats
        self.tick_steps = tick_steps
        self.mesh = mesh
        self.oversize = config.oversize
        self.chunked = config.chunked
        self.chunk_len = chunk_len
        self.paged = paged
        self.page_len = page_len if paged else 0
        self.prefix_cache = prefix_cache
        self._has_ssm = any(k.split("_")[0] == "mamba" for k in cfg.pattern)
        self.min_prefix_hit = config.min_prefix_hit
        self._needs_chunk_programs = needs_chunk_programs
        # disaggregation hook (serving/workers.py PrefillEngine): hold
        # EVERY finishing chunk row out of the same-tick decode scan, so
        # prefill-only ingestion never generates a token — the cut point
        # between the prefill and decode engines is post-chunk, pre-decode
        self._defer_decode = False

        # the generate-program LRU serves the per-request parity / baseline
        # path (greedy_generate): size it so one program per (bucket x
        # float/quant x eos on/off) variant fits without evicting anything.
        # NB the LRU is process-global: the default sizing only ever GROWS
        # it; pass an explicit generate_cache_size only if this scheduler is
        # the sole greedy_generate consumer in the process (shrinking evicts
        # other callers' live programs).
        generate_cache_size = config.generate_cache_size
        if generate_cache_size is None:
            generate_cache_size = max(engine.generate_fn.maxsize,
                                      4 * len(buckets) + 16)
        engine.set_generate_cache_size(generate_cache_size)

        # --- persistent pool (allocated exactly once) ----------------------
        if paged:
            self.max_blocks = max_blocks = max_len // page_len
            self.n_pages = n_pages
            self._pool = init_paged_pool(cfg, max_slots, max_len, n_pages,
                                         page_len, dtype=cfg.dtype)
            self._pages = PagePool(n_pages, page_len)
            # host-side page tables, one row per slot; entry 0 = trash page
            self._table = np.zeros((max_slots, max_blocks), np.int32)
            self._radix = (RadixCache(self._pages,
                                      snapshot_limit=config.snapshot_limit)
                           if prefix_cache else None)
            # prefix-cache observability (serve_bench --prefix-trace):
            # cached_tokens prompt tokens were served straight from shared
            # pages — their prefill compute AND cache writes were skipped
            self.prefix_stats = {"prompt_tokens": 0, "cached_tokens": 0,
                                 "prefill_tokens": 0,
                                 # pool-footprint accounting (serve_bench
                                 # --kv-quant): pages each admitted slot
                                 # held, admissions counted
                                 "pages_held": 0, "admitted": 0}
        else:
            self._pool = init_caches(cfg, max_slots, max_len, dtype=cfg.dtype,
                                     per_slot=True)
            self._pages = self._radix = None
        self._logits = jnp.zeros((max_slots, cfg.vocab_size), cfg.dtype)
        self._active = np.zeros((max_slots,), bool)
        self._slots: List[Optional[_Slot]] = [None] * max_slots

        self._queue: Deque[Request] = deque()
        self._results: Dict[int, RequestResult] = {}
        self._next_rid = 0
        self._tick_count = 0

        # sharding specs: pool batch on `data`, kv-seq/ssm-heads on `model`,
        # per-slot (B,) lengths on `data`; params get the TP rules (incl.
        # packed bit-planes).  The pool is device-put sharded ONCE here —
        # every later tick donates it in place.
        if mesh is not None:
            from repro.launch.shardings import serve_shardings
            spec = serve_shardings(mesh, params, self._pool, batch=max_slots,
                                   paged=self.paged)
            rep = spec["replicated"]
            self.params = params = jax.device_put(params, spec["params"])
            self._pool = jax.device_put(self._pool, spec["caches"])
            self._logits = jax.device_put(self._logits, spec["logits"])
            # batch-1 prefill outputs replicate (a 1-row batch divides no
            # data axis); the slot write scatters them into the sharded pool.
            # Built from the DENSE 1-row cache tree, not the pool — under
            # kv_quant the pool's layer dicts carry codes/scale/tail leaves
            # the prefill output doesn't have
            cache1_sh = jax.tree.map(
                lambda _: rep,
                jax.eval_shape(lambda: init_caches(cfg, 1, max_len,
                                                   dtype=cfg.dtype)))
            # paged mode threads the host-built (B, n_blocks) page table
            # through every device program; its rows ride the slot batch
            # sharding like the token slab
            pt = (spec["tokens"],) if self.paged else ()
            sh = dict(
                prefill_in=(spec["params"], rep, rep),
                prefill_out=(rep, cache1_sh),
                write_in=(spec["caches"], cache1_sh, spec["logits"], rep,
                          rep) + ((rep, rep) if self.paged else ()),
                write_out=(spec["caches"], spec["logits"]),
                tick_in=(spec["params"], spec["caches"], spec["logits"],
                         spec["active"]) + pt,
                tick_out=(spec["logits"], spec["caches"], rep, rep),
                # chunked prefill: the (B, chunk_len) token slab rides the
                # per-slot row sharding (batch on `data`, like the pool);
                # the (B,) valid/fresh/finishing flag vectors ride `active`'s
                chunk_in=(spec["params"], spec["caches"], spec["logits"],
                          spec["tokens"], spec["active"], spec["active"],
                          spec["active"]) + pt,
                chunk_out=(spec["logits"], spec["caches"], rep),
                mixed_in=(spec["params"], spec["caches"], spec["logits"],
                          spec["active"], spec["tokens"], spec["active"],
                          spec["active"], spec["active"]) + pt,
                mixed_out=(spec["logits"], spec["caches"], rep, rep, rep),
                cow_in=(spec["caches"], rep, rep),
                cow_out=spec["caches"],
                snap_in=(spec["caches"], rep),
                snap_out=rep,
                # kv_quant appends the scalar tail-page id operand
                hit_in=(spec["caches"], rep, rep)
                + ((rep,) if self.kv_quant else ()),
                hit_out=spec["caches"],
                hit_snap_in=(spec["caches"], rep, rep, rep)
                + ((rep,) if self.kv_quant else ()),
            )
        else:
            sh = collections.defaultdict(lambda: None)

        # --- compiled programs --------------------------------------------
        # prefill: ONE jit wrapper; it retraces per *bucket* shape only —
        # the compiled-program count is bounded by len(buckets)
        slot_prefill = engine.make_slot_prefill(cfg, quant)

        def prefill(params, prompt, true_len):
            caches = init_caches(cfg, 1, max_len, dtype=cfg.dtype)
            return slot_prefill(params, prompt, true_len, caches)

        self._prefill = engine.jit_sharded(
            prefill, mesh, in_shardings=sh["prefill_in"],
            out_shardings=sh["prefill_out"])

        # slot write: shape-independent of the bucket -> exactly one program.
        # The paged variant scatters the freshly-prefilled dense 1-row cache
        # into the slot's pages — positions < true_len land at (page_row[
        # p // page_len], p % page_len), the rest go to the trash page —
        # while SSM/conv state and logits keep the dense per-slot write.
        def write_slot(pool, slot_cache, pool_logits, slot_logits, i,
                      page_row=None, true_len=None):
            if self.paged:
                pl = self.page_len
                pos = jnp.arange(max_len, dtype=jnp.int32)
                valid = pos < true_len
                page = jnp.where(valid, page_row[pos // pl], TRASH_PAGE)
                off = jnp.where(valid, pos % pl, 0)

                def quant_write(c_pool, c_slot):
                    # quantize the freshly-prefilled dense slab page-wise:
                    # codes under each page's first-row scale, the scale
                    # entries themselves (valid pages only — dead pages
                    # redirect to the trash entry), and the newest two
                    # pages dense into slot i's tail ring (older rows and
                    # pad rows hit the junk bin, row 2*page_len)
                    from repro.core.logquant import (quantize_page_codes,
                                                     scale_exponent)
                    nb_ = max_len // pl
                    ring = 2 * pl
                    bv = jnp.arange(nb_, dtype=jnp.int32) * pl < true_len
                    sp = jnp.where(bv, page_row, TRASH_PAGE)
                    in_ring = valid & (pos >= true_len - ring)
                    toff = jnp.where(in_ring, pos % ring, ring)
                    out = {}
                    for k in ("k", "v"):
                        x = c_slot[k][:, 0].astype(jnp.float32)
                        xb = x.reshape(x.shape[0], nb_, pl, *x.shape[2:])
                        se = scale_exponent(xb[:, :, 0], axis=-1)
                        qc = quantize_page_codes(
                            xb, se[:, :, None, :, None], self.kv_bits)
                        qc = qc.reshape(x.shape[0], max_len, *x.shape[2:])
                        codes = c_pool[f"{k}_codes"]
                        out[f"{k}_codes"] = codes.at[:, page, off].set(
                            qc.astype(codes.dtype))
                        out[f"{k}_scale"] = c_pool[f"{k}_scale"].at[
                            :, sp].set(se)
                        tail = c_pool[f"{k}_tail"]
                        out[f"{k}_tail"] = tail.at[:, i, toff].set(
                            c_slot[k][:, 0].astype(tail.dtype))
                    return out

                layers = []
                for c_pool, c_slot in zip(pool["layers"],
                                          slot_cache["layers"]):
                    if "ssm" in c_pool:
                        layers.append({k: jax.lax.dynamic_update_slice_in_dim(
                            c_pool[k], c_slot[k].astype(c_pool[k].dtype),
                            i, axis=1) for k in c_pool})
                    elif self.kv_quant:
                        layers.append(quant_write(c_pool, c_slot))
                    else:
                        layers.append({k: c_pool[k].at[:, page, off].set(
                            c_slot[k][:, 0].astype(c_pool[k].dtype))
                            for k in ("k", "v")})
                layers = tuple(layers)
                length = jax.lax.dynamic_update_slice_in_dim(
                    pool["length"], true_len[None].astype(jnp.int32),
                    i, axis=0)
            else:
                layers = jax.tree.map(
                    lambda p, s: jax.lax.dynamic_update_slice_in_dim(
                        p, s.astype(p.dtype), i, axis=1),
                    pool["layers"], slot_cache["layers"])
                length = jax.lax.dynamic_update_slice_in_dim(
                    pool["length"], slot_cache["length"].astype(jnp.int32),
                    i, axis=0)
            logits = jax.lax.dynamic_update_slice_in_dim(
                pool_logits, slot_logits.astype(pool_logits.dtype),
                i, axis=0)
            return {"layers": layers, "length": length}, logits

        if self.paged:
            def write_slot_paged(pool, slot_cache, pool_logits, slot_logits,
                                 i, page_row, true_len):
                return write_slot(pool, slot_cache, pool_logits, slot_logits,
                                  i, page_row, true_len)
            self._write = engine.jit_sharded(
                write_slot_paged, mesh, in_shardings=sh["write_in"],
                out_shardings=sh["write_out"], donate_argnums=(0, 2))
        else:
            self._write = engine.jit_sharded(
                write_slot, mesh, in_shardings=sh["write_in"],
                out_shardings=sh["write_out"], donate_argnums=(0, 2))

        # tick: scan tick_steps slot-masked greedy steps -> one program.
        # tick_body is shared verbatim by the standalone tick and the mixed
        # chunk+decode program, so the decode math is one code path.  In
        # paged mode every program additionally takes the host-built page
        # table (constant within a tick: pages are allocated at admission).
        step = engine.make_slot_serve_step(cfg, quant, with_stats=with_stats,
                                           paged=self.paged)

        def tick_body(params, pool, logits, active, page_table=None):
            extra = (page_table,) if self.paged else ()

            def body(carry, _):
                lg, cs = carry
                tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
                out = step(params, cs, tok[:, None], active, *extra)
                if with_stats:
                    lg, cs, stats = out
                    frac = jnp.stack([stats["plane_traffic_fraction"],
                                      stats["element_traffic_fraction"]])
                else:
                    lg, cs = out
                    frac = jnp.zeros((2,), jnp.float32)
                return (lg, cs), (tok, frac)

            (lg, cs), (toks, fracs) = jax.lax.scan(
                body, (logits, pool), None, length=tick_steps)
            return lg, cs, jnp.swapaxes(toks, 0, 1), fracs

        if self.paged:
            def tick_paged(params, pool, logits, active, page_table):
                return tick_body(params, pool, logits, active, page_table)
            self._tick = engine.jit_sharded(
                tick_paged, mesh, in_shardings=sh["tick_in"],
                out_shardings=sh["tick_out"], donate_argnums=(1,))
        else:
            self._tick = engine.jit_sharded(
                tick_body, mesh, in_shardings=sh["tick_in"],
                out_shardings=sh["tick_out"], donate_argnums=(1,))

        # chunked prefill: ONE fixed (B, chunk_len) slab shape regardless of
        # prompt length — the chunk-only program covers prefill-only ticks,
        # the mixed program runs chunk ingestion AND the decode scan in one
        # jitted dispatch so decode never drains while a long prompt ingests
        self._chunk = self._mixed = None
        if self._needs_chunk_programs:
            chunk_step = engine.make_slot_prefill_chunk(
                cfg, quant, with_stats=with_stats, paged=self.paged)

            def chunk_body(params, pool, logits, tokens, valid, fresh,
                           finishing, page_table=None):
                extra = (page_table,) if self.paged else ()
                out = chunk_step(params, pool, logits, tokens, valid, fresh,
                                 finishing, *extra)
                if with_stats:
                    lg, cs, stats = out
                    cfrac = jnp.stack([stats["plane_traffic_fraction"],
                                       stats["element_traffic_fraction"]])
                else:
                    lg, cs = out
                    cfrac = jnp.zeros((2,), jnp.float32)
                return lg, cs, cfrac

            def mixed_tick(params, pool, logits, active, tokens, valid,
                           fresh, finishing, page_table=None):
                lg, cs, cfrac = chunk_body(params, pool, logits, tokens,
                                           valid, fresh, finishing,
                                           page_table)
                lg, cs, toks, fracs = tick_body(params, cs, lg, active,
                                                page_table)
                return lg, cs, toks, fracs, cfrac

            if self.paged:
                def chunk_paged(params, pool, logits, tokens, valid, fresh,
                                finishing, page_table):
                    return chunk_body(params, pool, logits, tokens, valid,
                                      fresh, finishing, page_table)

                def mixed_paged(params, pool, logits, active, tokens, valid,
                                fresh, finishing, page_table):
                    return mixed_tick(params, pool, logits, active, tokens,
                                      valid, fresh, finishing, page_table)
                self._chunk = engine.jit_sharded(
                    chunk_paged, mesh, in_shardings=sh["chunk_in"],
                    out_shardings=sh["chunk_out"], donate_argnums=(1,))
                self._mixed = engine.jit_sharded(
                    mixed_paged, mesh, in_shardings=sh["mixed_in"],
                    out_shardings=sh["mixed_out"], donate_argnums=(1,))
            else:
                self._chunk = engine.jit_sharded(
                    chunk_body, mesh, in_shardings=sh["chunk_in"],
                    out_shardings=sh["chunk_out"], donate_argnums=(1,))
                self._mixed = engine.jit_sharded(
                    mixed_tick, mesh, in_shardings=sh["mixed_in"],
                    out_shardings=sh["mixed_out"], donate_argnums=(1,))

        # paged-only device helpers: copy-on-write page duplication (the
        # partially-matching tail page of a prefix hit is copied into a page
        # the slot owns exclusively before any write can touch it), the
        # SSM-state snapshot gather (prefix-cache donors on hybrid models),
        # and the prefix-hit admission write (length + snapshot restore).
        self._cow = self._snap = None
        if self.paged:
            # COW must copy a quantized page's codes AND its scale entry
            # together — codes are meaningless under another page's scale;
            # the per-slot tail rings aren't page-addressed and pass through
            cow_keys = (("k_codes", "v_codes", "k_scale", "v_scale")
                        if self.kv_quant else ("k", "v"))

            def cow_pages(pool, src, dst):
                layers = []
                for c in pool["layers"]:
                    if "ssm" in c:
                        layers.append(c)
                    else:
                        nc = dict(c)
                        nc.update({k: c[k].at[:, dst].set(
                            jax.lax.dynamic_slice_in_dim(
                                c[k], src, 1, axis=1)[:, 0])
                            for k in cow_keys})
                        layers.append(nc)
                return {"layers": tuple(layers), "length": pool["length"]}

            self._cow = engine.jit_sharded(
                cow_pages, mesh, in_shardings=sh["cow_in"],
                out_shardings=sh["cow_out"], donate_argnums=(0,))

            def snap_slot(pool, i):
                out = []
                for c in pool["layers"]:
                    if "ssm" in c:
                        out.append({k: jax.lax.dynamic_slice_in_dim(
                            c[k], i, 1, axis=1) for k in c})
                return tuple(out)

            self._snap = engine.jit_sharded(
                snap_slot, mesh, in_shardings=sh["snap_in"],
                out_shardings=sh["snap_out"])

            def admit_hit(pool, i, hit_len, snaps=None, tail_pg=None):
                length = jax.lax.dynamic_update_slice_in_dim(
                    pool["length"], hit_len[None].astype(jnp.int32),
                    i, axis=0)
                pl = self.page_len
                tb = jnp.maximum(hit_len - 1, 0) // pl
                half = (tb % 2) * pl
                layers = []
                si = 0
                for c in pool["layers"]:
                    if "ssm" in c and snaps is not None:
                        sn = snaps[si]
                        si += 1
                        layers.append(
                            {k: jax.lax.dynamic_update_slice_in_dim(
                                c[k], sn[k].astype(c[k].dtype), i, axis=1)
                             for k in c})
                    elif "k_codes" in c and tail_pg is not None:
                        # restore slot i's tail ring from the hit's tail
                        # page: the overlay reads the newest page from the
                        # ring, and the previous occupant's rows are stale
                        # junk.  Dequantized rows are exactly what every
                        # later read of these positions would decode from
                        # the pool, so the quantized-read semantics are
                        # unchanged — only the ring-vs-pool routing is.
                        from repro.core.logquant import dequantize_page_codes
                        nc = dict(c)
                        for k in ("k", "v"):
                            pg = jax.lax.dynamic_slice_in_dim(
                                c[f"{k}_codes"], tail_pg, 1, axis=1)[:, 0]
                            se = jax.lax.dynamic_slice_in_dim(
                                c[f"{k}_scale"], tail_pg, 1, axis=1)
                            rows = dequantize_page_codes(
                                pg, se[..., None], self.kv_bits,
                                c[f"{k}_tail"].dtype)
                            nc[f"{k}_tail"] = jax.lax.dynamic_update_slice(
                                c[f"{k}_tail"], rows[:, None],
                                (0, i, half, 0, 0))
                        layers.append(nc)
                    else:
                        layers.append(c)
                return {"layers": tuple(layers), "length": length}

            if self.kv_quant:
                self._admit_hit_plain = engine.jit_sharded(
                    lambda pool, i, hit_len, tail_pg: admit_hit(
                        pool, i, hit_len, tail_pg=tail_pg),
                    mesh, in_shardings=sh["hit_in"],
                    out_shardings=sh["hit_out"], donate_argnums=(0,))
                self._admit_hit_snap = engine.jit_sharded(
                    lambda pool, i, hit_len, snaps, tail_pg: admit_hit(
                        pool, i, hit_len, snaps, tail_pg),
                    mesh, in_shardings=sh["hit_snap_in"],
                    out_shardings=sh["hit_out"], donate_argnums=(0,))
            else:
                self._admit_hit_plain = engine.jit_sharded(
                    lambda pool, i, hit_len: admit_hit(pool, i, hit_len),
                    mesh, in_shardings=sh["hit_in"],
                    out_shardings=sh["hit_out"], donate_argnums=(0,))
                self._admit_hit_snap = engine.jit_sharded(
                    lambda pool, i, hit_len, snaps: admit_hit(
                        pool, i, hit_len, snaps),
                    mesh, in_shardings=sh["hit_snap_in"],
                    out_shardings=sh["hit_out"], donate_argnums=(0,))

    # ------------------------------------------------------------------ API

    def submit(self, prompt, max_new: int, eos_id: Optional[int] = None) -> int:
        """Queue one request; returns its rid (results come back in rid
        order from :meth:`run`).

        A prompt that exceeds the admission bound (without chunking: the
        largest prefill bucket; with ``chunked="auto"|"always"``: only the
        slot capacity — chunking removes the bucket ceiling) or whose
        prompt + ``max_new`` overflows the slot capacity is handled per the
        ``oversize`` policy: ``"reject"`` (default) records a per-request
        ``RequestResult(finish_reason="rejected", error=...)`` and leaves
        every queued/in-flight request untouched — submission during a live
        serve loop must never abort the loop; ``"truncate"`` keeps the most
        recent tokens that fit; ``"raise"`` restores the historical
        ``ValueError`` (batch scripts that want loud failures).  Empty
        prompts and ``max_new < 1`` are caller bugs and always raise.
        """
        now = time.perf_counter()
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        if self.chunked == "off":
            fit = min(self.buckets[-1], self.max_len - max_new)
        else:
            fit = self.max_len - max_new
        if prompt.size > fit:
            if self.chunked == "off" and prompt.size > self.buckets[-1]:
                why = (f"prompt length {prompt.size} exceeds the largest "
                       f"prefill bucket {self.buckets[-1]} (enable chunked "
                       f"prefill to lift the bucket ceiling)")
            else:
                why = (f"prompt ({prompt.size}) + max_new ({max_new}) "
                       f"exceeds the slot capacity max_len={self.max_len}")
            if self.oversize == "raise":
                raise ValueError(why)
            if self.oversize == "truncate" and fit >= 1:
                prompt = prompt[-fit:]           # keep the latest context
            else:
                rid = self._next_rid
                self._next_rid += 1
                self._results[rid] = RequestResult(
                    rid=rid, prompt_len=int(prompt.size), tokens=[],
                    finish_reason="rejected", admitted_tick=-1,
                    finished_tick=self._tick_count, error=why,
                    submit_time=now, finish_time=now)
                return rid
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(rid=rid, prompt=prompt, max_new=max_new,
                                   eos_id=eos_id, submit_time=now))
        return rid

    @property
    def pending(self) -> int:
        return len(self._queue) + int(self._active.sum())

    def compile_stats(self) -> Dict[str, int]:
        """Compiled-program counts — the bucket bound made observable
        (see :func:`engine.compiled_size` for the probe caveat)."""
        size = engine.compiled_size
        stats = {"prefill": size(self._prefill),
                 "tick": size(self._tick),
                 "write_slot": size(self._write)}
        if self._needs_chunk_programs:
            # ONE chunk-slab shape each, regardless of prompt lengths
            stats["chunk"] = size(self._chunk)
            stats["mixed"] = size(self._mixed)
        return stats

    def audit_programs(self) -> "collections.OrderedDict":
        """Every compiled program this scheduler dispatches, as
        ``{name: (fn, example_args)}`` with args matching the live call
        sites exactly (``jax.ShapeDtypeStruct`` stands in for the real
        operands).  Consumed by the static program auditor
        (``repro.analysis``), which traces/lowers these WITHOUT executing
        anything — keep this in sync with the ``step_tick`` / ``_admit*``
        dispatch sites above."""
        cfg = self.cfg
        i32, b1 = jnp.int32, jnp.bool_
        sds = jax.ShapeDtypeStruct

        def abstract(tree):
            return jax.tree.map(
                lambda a: sds(jnp.shape(a), jnp.result_type(a)), tree)

        params = abstract(self.params)
        pool = abstract(self._pool)
        B, V = self.max_slots, cfg.vocab_size
        logits = sds((B, V), cfg.dtype)
        active = sds((B,), b1)
        pt = ((sds((B, self.max_blocks), i32),) if self.paged else ())

        out: "collections.OrderedDict" = collections.OrderedDict()
        for b in self.buckets:
            out[f"prefill_b{b}"] = (
                self._prefill, (params, sds((1, b), i32), sds((1,), i32)))
        # the batch-1 slot cache _write scatters is prefill's second output
        # (NOT init_caches' shape: slot_prefill rewrites `length` to the
        # (1,)-shaped true_len) — eval_shape the real program
        ctx = getattr(self._prefill, "trace_context", None)
        target = getattr(self._prefill, "jitted", self._prefill)
        with (ctx() if ctx is not None else contextlib.nullcontext()):
            _, cache1 = jax.eval_shape(
                target, params, sds((1, self.buckets[0]), i32),
                sds((1,), i32))
        cache1 = abstract(cache1)
        write_args = (pool, cache1, logits, sds((1, V), cfg.dtype),
                      sds((), i32))
        if self.paged:
            write_args += (sds((self.max_blocks,), i32), sds((), i32))
        out["write"] = (self._write, write_args)
        out["tick"] = (self._tick, (params, pool, logits, active) + pt)
        if self._needs_chunk_programs:
            tokens = sds((B, self.chunk_len), i32)
            flags = (sds((B,), i32), active, active)   # valid, fresh, finish
            out["chunk"] = (self._chunk,
                            (params, pool, logits, tokens) + flags + pt)
            out["mixed"] = (self._mixed,
                            (params, pool, logits, active, tokens)
                            + flags + pt)
        if self.paged:
            out["cow"] = (self._cow, (pool, sds((), i32), sds((), i32)))
            hit_args = (pool, sds((), i32), sds((), i32))
            if self.kv_quant:
                hit_args += (sds((), i32),)        # tail_pg
            out["admit_hit"] = (self._admit_hit_plain, hit_args)
            if self._has_ssm:
                out["snap"] = (self._snap, (pool, sds((), i32)))
        return out

    def prefix_cache_stats(self) -> Dict[str, float]:
        """Prefix-cache effectiveness over everything admitted so far:
        ``hit_rate`` is the fraction of prompt tokens served straight from
        shared pages — each such token skipped its prefill compute AND its
        per-layer cache writes (``cache_write_saved_frac`` is the same
        ratio, named for what it means in paper terms: PAPER §VI counts
        avoided memory accesses; DESIGN.md §Paged KV + prefix cache)."""
        if not self.paged:
            raise ValueError("prefix_cache_stats: not a paged scheduler")
        total = max(self.prefix_stats["prompt_tokens"], 1)
        cached = self.prefix_stats["cached_tokens"]
        out = {
            "prompt_tokens": float(self.prefix_stats["prompt_tokens"]),
            "cached_tokens": float(cached),
            "prefill_tokens": float(self.prefix_stats["prefill_tokens"]),
            "hit_rate": cached / total,
            "cache_write_saved_frac": cached / total,
            "pages_in_use": float(self._pages.in_use),
            "pages_free": float(self._pages.available),
        }
        if self._radix is not None:
            out["lookups"] = float(self._radix.lookups)
            out["lookup_hits"] = float(self._radix.hits)
        return out

    def reset_prefix_stats(self) -> None:
        """Zero the prefix-cache counters (benchmarks call this after their
        warm-up traffic so the reported ratios cover only the timed trace;
        cached pages themselves stay resident)."""
        if not self.paged:
            raise ValueError("reset_prefix_stats: not a paged scheduler")
        self.prefix_stats = {k: 0 for k in self.prefix_stats}
        if self._radix is not None:
            self._radix.lookups = self._radix.hits = 0
            self._radix.tokens_hit = 0

    def step_tick(self) -> bool:
        """Admit into every free slot, feed one prompt chunk to every
        prefilling slot, run one fused multi-step decode tick for every
        decoding slot — chunk + decode in ONE jitted program when both kinds
        are live — then retire finished requests.  Returns False when there
        is nothing to do.

        Paged admission can *stall*: if the page pool cannot cover the next
        request even after evicting prefix-cache entries, the request waits
        at the queue head for in-flight slots to retire (their pages free on
        retirement); with an idle system the ``oversize`` policy applies
        instead (reject / truncate / raise) — exhaustion never crashes a
        live serve loop.
        """
        stalled = False
        for i in range(self.max_slots):
            if stalled:
                break
            while not self._active[i] and self._queue:
                req = self._queue.popleft()
                st = self._admit(i, req)
                if st == "wait":
                    self._queue.appendleft(req)
                    stalled = True
                    break
                # "ok" fills the slot (loop exits); "drop" recorded a
                # rejection — try the next queued request for this slot
        if not self._active.any():
            return False

        # ---- build this tick's chunk slab (chunked admissions only) -------
        chunk_rows = [i for i, s in enumerate(self._slots)
                      if s is not None and s.phase == "prefill"]
        valid = np.zeros((self.max_slots,), np.int32)
        defer = np.zeros((self.max_slots,), bool)
        if chunk_rows:
            tokens = np.zeros((self.max_slots, self.chunk_len), np.int32)
            fresh = np.zeros((self.max_slots,), bool)
            finishing = np.zeros((self.max_slots,), bool)
            for i in chunk_rows:
                s = self._slots[i]
                take = min(self.chunk_len,
                           s.req.prompt.size - s.prefill_pos)
                tokens[i, :take] = s.req.prompt[s.prefill_pos:
                                                s.prefill_pos + take]
                valid[i] = take
                fresh[i] = s.prefill_pos == 0 and s.hit_len == 0
                finishing[i] = s.prefill_pos + take >= s.req.prompt.size
                # hybrid-model snapshot capture needs the post-prompt SSM
                # state BEFORE any decode step touches it: when the final
                # chunk lands exactly on the cacheable (page-aligned) prompt
                # boundary, hold the row out of this tick's decode scan and
                # capture after the tick — it starts decoding next tick with
                # identical tokens (the logits/state don't change)
                defer[i] = finishing[i] and (
                    self._defer_decode
                    or (self._wants_snapshot(s)
                        and s.prefill_pos + take
                        == self._cacheable_len(s.req.prompt.size)))
        # a slot whose LAST chunk lands this tick decodes in the same tick:
        # the chunk phase writes its first-token logits before the scan runs
        decode_mask = np.array(
            [s is not None and not s.done
             and (s.phase == "decode"
                  or (chunk_rows and finishing[i] and not defer[i]))
             for i, s in enumerate(self._slots)])

        pt = (jnp.asarray(self._table),) if self.paged else ()
        toks_h = fracs_h = cfrac_h = None
        if chunk_rows and decode_mask.any():
            lg, pool, toks, fracs, cfrac = self._mixed(
                self.params, self._pool, self._logits,
                jnp.asarray(decode_mask), jnp.asarray(tokens),
                jnp.asarray(valid), jnp.asarray(fresh),
                jnp.asarray(finishing), *pt)
            self._logits, self._pool = lg, pool
            toks_h, fracs_h = np.asarray(toks), np.asarray(fracs)
            cfrac_h = np.asarray(cfrac)
        elif chunk_rows:
            lg, pool, cfrac = self._chunk(
                self.params, self._pool, self._logits, jnp.asarray(tokens),
                jnp.asarray(valid), jnp.asarray(fresh),
                jnp.asarray(finishing), *pt)
            self._logits, self._pool = lg, pool
            cfrac_h = np.asarray(cfrac)
        else:
            lg, pool, toks, fracs = self._tick(
                self.params, self._pool, self._logits,
                jnp.asarray(decode_mask), *pt)
            self._logits, self._pool = lg, pool
            toks_h, fracs_h = np.asarray(toks), np.asarray(fracs)

        now = time.perf_counter()

        # ---- chunk-phase bookkeeping --------------------------------------
        for i in chunk_rows:
            s = self._slots[i]
            s.prefill_pos += int(valid[i])
            if finishing[i]:
                s.phase = "decode"
            if (self._wants_snapshot(s) and s.prefill_pos
                    == self._cacheable_len(s.req.prompt.size)):
                # post-tick state is exactly the state at prefill_pos: the
                # row was held out of (or not yet in) the decode scan, and
                # inactive rows' recurrent state is masked frozen
                s.snapshot = self._snap(self._pool,
                                        jnp.asarray(i, jnp.int32))
            if self.with_stats and cfrac_h is not None:
                # the chunk forward's batch-aggregate traffic, attributed to
                # the requests that prefilled this tick (decode steps are
                # attributed below, exactly as before)
                s.frac_sums[0] += float(cfrac_h[0])
                s.frac_sums[1] += float(cfrac_h[1])
                s.frac_steps += 1

        # ---- decode-phase bookkeeping -------------------------------------
        if toks_h is not None:
            for t in range(self.tick_steps):
                for i, slot in enumerate(self._slots):
                    if slot is None or slot.done or not decode_mask[i]:
                        continue
                    tok = int(toks_h[i, t])
                    if not slot.tokens:
                        slot.first_token_time = now
                    slot.tokens.append(tok)
                    if self.with_stats:
                        slot.frac_sums[0] += float(fracs_h[t, 0])
                        slot.frac_sums[1] += float(fracs_h[t, 1])
                        slot.frac_steps += 1
                    if slot.req.eos_id is not None \
                            and tok == slot.req.eos_id:
                        slot.done, slot.finish_reason = True, "eos"
                    elif len(slot.tokens) >= slot.req.max_new:
                        slot.done, slot.finish_reason = True, "length"

        self._tick_count += 1
        for i, slot in enumerate(self._slots):
            if slot is not None and slot.done:
                self._retire(i)
        return True

    def run(self, max_ticks: Optional[int] = None) -> List[RequestResult]:
        """Drive ticks until queue and slots drain (or ``max_ticks``);
        returns every finished result in rid order."""
        ticks = 0
        while self.pending and (max_ticks is None or ticks < max_ticks):
            if not self.step_tick():
                break
            ticks += 1
        return [self._results[rid] for rid in sorted(self._results)]

    # ------------------------------------------------------------ internals

    def _uses_chunks(self, prompt_len: int) -> bool:
        """Chunk-vs-bucket admission policy: ``"always"`` chunks everything;
        ``"auto"`` chunks only prompts no bucket can hold, so in-bucket
        prompts keep the bucketed path's bit-exact token guarantee."""
        if self.chunked == "always":
            return True
        return self.chunked == "auto" and prompt_len > self.buckets[-1]

    def _wants_snapshot(self, slot: _Slot) -> bool:
        """Hybrid/SSM models need the recurrent state at the cacheable
        prompt boundary for a prefix hit to be usable; capture it once,
        opportunistically, when ingestion lands exactly on that boundary."""
        return (self._radix is not None and self._has_ssm
                and slot.snapshot is None)

    def _cacheable_len(self, prompt_len: int) -> int:
        """Prompt tokens coverable by whole shared pages."""
        return (prompt_len // self.page_len) * self.page_len

    def _alloc_pages(self, n: int) -> Optional[List[int]]:
        """Allocate ``n`` fresh pages, evicting LRU prefix-cache entries
        if the free list runs short.  All-or-nothing — and eviction only
        runs when it can actually satisfy the request: an unsatisfiable
        allocation (oversized request, under-provisioned pool) must not
        drain the whole prefix cache on its way to being rejected."""
        got = self._pages.alloc(n)
        if (got is None and self._radix is not None
                and self._pages.available + self._radix.evictable_pages()
                >= n):
            self._radix.evict(n)
            got = self._pages.alloc(n)
        return got

    def _admit(self, slot_idx: int, req: Request) -> str:
        """Fill ``slot_idx`` with ``req``; returns ``"ok"`` (admitted),
        ``"wait"`` (paged pool exhausted while other requests are in
        flight — retry next tick), or ``"drop"`` (request rejected with a
        per-request error result)."""
        if self.paged:
            return self._admit_paged(slot_idx, req)
        length = int(req.prompt.size)
        if self._uses_chunks(length):
            # chunked ingestion: no prefill here — step_tick feeds the
            # prompt chunk-by-chunk into the pool, interleaved with decode
            self._active[slot_idx] = True
            self._slots[slot_idx] = _Slot(req=req,
                                          admitted_tick=self._tick_count,
                                          phase="prefill")
            return "ok"
        self._admit_bucketed(slot_idx, req)
        return "ok"

    def _admit_bucketed(self, slot_idx: int, req: Request,
                        page_args: tuple = ()) -> None:
        """Monolithic bucketed prefill + slot write (dense or paged)."""
        length = int(req.prompt.size)
        bucket = bucket_for(length, self.buckets)
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :length] = req.prompt
        logits1, cache1 = self._prefill(self.params, jnp.asarray(padded),
                                        jnp.asarray([length], jnp.int32))
        self._pool, self._logits = self._write(
            self._pool, cache1, self._logits, logits1,
            jnp.asarray(slot_idx, jnp.int32), *page_args)
        self._active[slot_idx] = True
        self._slots[slot_idx] = _Slot(req=req,
                                      admitted_tick=self._tick_count)

    def _admit_paged(self, slot_idx: int, req: Request,
                     retrying: bool = False) -> str:
        prompt = req.prompt
        length = int(prompt.size)
        pl = self.page_len
        hit = None
        if self._radix is not None:
            # cap the hit at length-1: at least one suffix token must run
            # through prefill to produce the first decode logits
            hit = self._radix.lookup(prompt, max_hit=length - 1,
                                     need_snapshot=self._has_ssm,
                                     min_hit=self.min_prefix_hit,
                                     allow_partial=not self._has_ssm)
        shared = list(hit.pages) if hit is not None else []
        # hold references on every page the hit aliases (shared blocks AND
        # the COW source) BEFORE allocating: allocation may evict radix
        # entries, and the tree's reference may be the only thing keeping
        # these pages alive — without the hold, eviction could free one
        # and the allocator hand it back to us as a "fresh" page
        hold = shared + ([hit.cow_src] if hit is not None
                         and hit.cow_src is not None else [])
        self._pages.ref(hold)
        # worst-case tokens the slot writes: prompt + generation + the junk
        # tail of the tick in which it finishes (same clamp bound as dense)
        need_tokens = min(self.max_len,
                          length + req.max_new + self.tick_steps)
        n_blocks = blocks_for_tokens(need_tokens, pl)
        fresh = self._alloc_pages(n_blocks - len(shared))
        if fresh is None:
            self._pages.release(hold)    # the pool is untouched again
            if self._active.any():
                return "wait"
            why = (f"page pool exhausted: request needs {n_blocks} pages "
                   f"({need_tokens} tokens @ page_len={pl}), "
                   f"{self._pages.available} free of "
                   f"{self._pages.capacity}")
            if self.oversize == "raise":
                raise ValueError(why)
            if self.oversize == "truncate" and not retrying:
                # truncate to what the pool could hold after evicting the
                # prefix cache (the retry's allocation performs the actual
                # eviction), capped at the slot capacity like dense
                usable = self._pages.available + (
                    self._radix.evictable_pages()
                    if self._radix is not None else 0)
                fit = min(usable * pl - req.max_new - self.tick_steps,
                          self.max_len - req.max_new)
                if fit >= 1:
                    cut = dataclasses.replace(req, prompt=prompt[-fit:])
                    return self._admit_paged(slot_idx, cut, retrying=True)
            now = time.perf_counter()
            self._results[req.rid] = RequestResult(
                rid=req.rid, prompt_len=length, tokens=[],
                finish_reason="rejected", admitted_tick=-1,
                finished_tick=self._tick_count, error=why,
                submit_time=req.submit_time, finish_time=now)
            return "drop"
        if hit is not None and hit.cow_src is not None:
            # the partially-matching page is copied into the first fresh
            # page (it IS block len(shared)); the slot owns the copy
            # exclusively, so suffix ingestion can overwrite its tail.
            # The hold reference on the source is dropped after the copy.
            self._pool = self._cow(self._pool,
                                   jnp.asarray(hit.cow_src, jnp.int32),
                                   jnp.asarray(fresh[0], jnp.int32))
            self._pages.release([hit.cow_src])
        pages = shared + fresh
        self._table[slot_idx, :] = TRASH_PAGE
        self._table[slot_idx, :len(pages)] = pages
        self.prefix_stats["prompt_tokens"] += length
        if hit is not None:
            # restore length (and SSM state, hybrid models) at the hit
            # boundary, then ingest only the suffix through the chunk path
            idx = jnp.asarray(slot_idx, jnp.int32)
            hl = jnp.asarray(hit.length, jnp.int32)
            # quantized pool: the slot's tail ring must be seeded from the
            # hit's newest page (the previous occupant's ring rows are
            # junk); the table row above already names that page
            tpg = ((jnp.asarray(
                int(self._table[slot_idx, (hit.length - 1) // pl]),
                jnp.int32),) if self.kv_quant else ())
            if hit.snapshot is not None:
                self._pool = self._admit_hit_snap(self._pool, idx, hl,
                                                  hit.snapshot, *tpg)
            else:
                self._pool = self._admit_hit_plain(self._pool, idx, hl,
                                                   *tpg)
            slot = _Slot(req=req, admitted_tick=self._tick_count,
                         phase="prefill", prefill_pos=hit.length,
                         hit_len=hit.length)
            self.prefix_stats["cached_tokens"] += hit.length
            self.prefix_stats["prefill_tokens"] += length - hit.length
        elif self._uses_chunks(length):
            slot = _Slot(req=req, admitted_tick=self._tick_count,
                         phase="prefill")
            self.prefix_stats["prefill_tokens"] += length
        else:
            self._admit_bucketed(
                slot_idx, req,
                page_args=(jnp.asarray(self._table[slot_idx]),
                           jnp.asarray(length, jnp.int32)))
            slot = self._slots[slot_idx]
            self.prefix_stats["prefill_tokens"] += length
            if (self._wants_snapshot(slot) and length % pl == 0):
                # page-aligned prompt: the freshly-written slot state IS
                # the state at the cacheable boundary — snapshot now,
                # before any decode tick advances it
                slot.snapshot = self._snap(self._pool,
                                           jnp.asarray(slot_idx, jnp.int32))
        slot.pages = pages
        self.prefix_stats["pages_held"] += len(pages)
        self.prefix_stats["admitted"] += 1
        self._active[slot_idx] = True
        self._slots[slot_idx] = slot
        return "ok"

    def _free_slot(self, slot_idx: int) -> None:
        """Release ``slot_idx`` WITHOUT recording a result: donate the
        prompt's pages to the prefix cache, drop the slot's page
        references, clear the table row and the active bit.  ``_retire``
        (result-recording retirement) and the prefill engine's
        export-then-release path (``serving/workers.py`` — the span, not
        a result, is the output) share this."""
        slot = self._slots[slot_idx]
        if self.paged:
            if self._radix is not None:
                # donate the prompt's whole-page blocks to the prefix cache
                # (existing nodes are re-used, new nodes take their own page
                # refs) BEFORE releasing the slot's references
                row = self._table[slot_idx]
                self._radix.insert(slot.req.prompt,
                                   lambda bi: int(row[bi]),
                                   snapshot=slot.snapshot)
            self._pages.release(slot.pages)
            self._table[slot_idx, :] = TRASH_PAGE
        self._active[slot_idx] = False
        self._slots[slot_idx] = None

    def _retire(self, slot_idx: int) -> None:
        slot = self._slots[slot_idx]
        self._free_slot(slot_idx)
        n = max(slot.frac_steps, 1)
        self._results[slot.req.rid] = RequestResult(
            rid=slot.req.rid,
            prompt_len=int(slot.req.prompt.size),
            tokens=list(slot.tokens),
            finish_reason=slot.finish_reason,
            admitted_tick=slot.admitted_tick,
            finished_tick=self._tick_count,
            plane_traffic_fraction=(slot.frac_sums[0] / n
                                    if self.with_stats else float("nan")),
            element_traffic_fraction=(slot.frac_sums[1] / n
                                      if self.with_stats else float("nan")),
            submit_time=slot.req.submit_time,
            first_token_time=slot.first_token_time,
            finish_time=time.perf_counter(),
        )
