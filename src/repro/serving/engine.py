"""Serving layer: prefill + single-token decode steps and the FUSED
autoregressive generation loop.

The decode loop is a single XLA program — ``jax.lax.scan`` over pre-allocated
caches (greedy/temperature sampling), or ``jax.lax.while_loop`` when an
``eos_id`` enables early stop — so an entire generate executes with **no
per-token host round-trips** (DESIGN.md §Serving).  The unfused per-token
Python loop survives only as :func:`reference_generate`, the semantics oracle
for tests and the dispatch-overhead baseline for benchmarks.

Quantized serving is wired end-to-end: ``make_serve_step(quant=True)``
resolves to the **Pallas** bit-plane backend (``bitplane_matmul_pallas``,
interpret mode off-TPU), accepts packed planes from
``quantize_model_params(pack=True)``, and — with ``with_stats=True`` —
reports the per-step ``plane_traffic_fraction`` (the fraction of weight-plane
tiles the kernel actually fetches: the decode-time image of the paper's §VI
memory-access savings).

Slot-pool serving (``serving/scheduler.py``) builds on the per-slot step
builders: ``make_slot_prefill`` (bucketed right-padded prefill),
``make_slot_prefill_chunk`` (chunked prefill — one fixed-shape prompt chunk
per prefilling slot written straight into the pool, DESIGN.md §Chunked
prefill), and ``make_slot_serve_step`` (slot-masked decode).  The chunk and
decode builders take ``paged=True`` to serve the paged KV pool instead of
dense slabs — same math over page-gathered views, an extra ``page_table``
argument (DESIGN.md §Paged KV + prefix cache).

Every step builder is **mesh-native**: pass ``mesh=`` (plus optional
``in_shardings`` / ``out_shardings`` pytrees) and the returned callable is
jitted with those shardings and traced under the model's activation-sharding
binding (``models.sharding.mesh_axes``) — decode runs tensor/data-parallel
with the same token stream as the single-device program (DESIGN.md §Sharded
serving).
"""

from __future__ import annotations

import collections
import contextlib
from typing import Any, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.shiftadd import QuantCtx, as_quant_ctx
from repro.models.model import ModelConfig, forward, init_caches

QuantFlag = Union[bool, str, QuantCtx]


def mesh_fingerprint(mesh) -> Optional[tuple]:
    """Hashable identity of a mesh for program-cache keys: axis names, axis
    sizes, and the device ids in mesh order.  Two meshes with the same
    fingerprint lower to the same partitioned program; ``None`` stands for
    unsharded single-device execution — so sharded and unsharded variants of
    one configuration coexist in the generate-program LRU instead of
    silently reusing a stale compiled program."""
    if mesh is None:
        return None
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
            tuple(int(d.id) for d in mesh.devices.flat))


def jit_sharded(fn, mesh=None, *, in_shardings=None, out_shardings=None,
                donate_argnums=(), static_argnums=()):
    """``jax.jit`` that pins data placement and binds activation sharding.

    With ``mesh=None`` this is plain ``jax.jit`` (the single-device path is
    byte-identical to before the mesh refactor).  With a mesh, the function
    is jitted with the given ``in_shardings`` / ``out_shardings`` and every
    call enters the mesh + ``mesh_axes`` scope so the model's ``shard()``
    hints bind at trace time (decode never sequence-shards: ``seq_shard=
    False``)."""
    kw: dict = {"donate_argnums": donate_argnums}
    if static_argnums:
        kw["static_argnums"] = static_argnums
    if mesh is not None:
        if in_shardings is not None:
            kw["in_shardings"] = in_shardings
        if out_shardings is not None:
            kw["out_shardings"] = out_shardings
    jitted = jax.jit(fn, **kw)
    if mesh is None:
        return jitted

    from repro.launch.mesh import batch_axes
    from repro.models.sharding import mesh_axes

    @contextlib.contextmanager
    def trace_context():
        """The binding every call runs under — exposed so the program
        auditor (``repro.analysis``) can trace/lower the SAME program the
        serve loop executes, without executing it."""
        with mesh, mesh_axes(batch=batch_axes(mesh), model="model",
                             seq_shard=False, sizes=dict(mesh.shape),
                             mesh=mesh):
            yield

    def call(*args, **kwargs):
        with trace_context():
            return jitted(*args, **kwargs)

    def lower(*args, **kwargs):
        with trace_context():
            return jitted.lower(*args, **kwargs)

    call.jitted = jitted
    call.trace_context = trace_context
    call.lower = lower
    return call


def compiled_size(fn) -> int:
    """Compiled-program count of a ``jax.jit`` fn or ``jit_sharded``
    wrapper.  ``_cache_size`` is a private jax API (present on the pinned
    jax 0.4.37); report -1 if a future jax drops it rather than crash."""
    fn = getattr(fn, "jitted", fn)
    probe = getattr(fn, "_cache_size", None)
    return int(probe()) if callable(probe) else -1


def _maybe_shard(fn, mesh, in_shardings, out_shardings):
    """Builders return the bare closure without a mesh (callers jit), or the
    sharded-jitted program with one."""
    if mesh is None:
        return fn
    return jit_sharded(fn, mesh, in_shardings=in_shardings,
                       out_shardings=out_shardings)


def make_prefill_step(cfg: ModelConfig, quant: QuantFlag = False, *,
                      mesh=None, in_shardings=None, out_shardings=None):
    """(params, batch) -> (last-token logits, caches).

    Runs the full forward over the prompt while writing the KV/SSM caches.
    This is what the ``prefill_32k`` shape lowers.  ``quant=True`` resolves
    to the portable "xla" bit-plane backend (prefill GEMMs are MXU-shaped
    already; the plane-skip kernel targets the decode hot path).  With
    ``mesh=`` the returned callable is jitted with the given shardings
    (see :func:`jit_sharded`); without one it is the bare closure and the
    caller jits.
    """
    ctx = as_quant_ctx(quant, default_backend="xla")

    def prefill_step(params, batch, caches):
        logits, caches = forward(
            cfg, params,
            tokens=batch.get("tokens"), embeds=batch.get("embeds"),
            image_embeds=batch.get("image_embeds"),
            caches=caches, quant=ctx)
        return logits[:, -1], caches
    return _maybe_shard(prefill_step, mesh, in_shardings, out_shardings)


def make_serve_step(cfg: ModelConfig, quant: QuantFlag = False,
                    with_stats: bool = False, *,
                    mesh=None, in_shardings=None, out_shardings=None):
    """(params, caches, token) -> (logits, caches[, stats]): ONE new token
    against a pre-filled cache.  This is what ``decode_32k`` / ``long_500k``
    lower.

    ``quant=True`` resolves to the **"pallas"** backend: eligible projections
    run through ``bitplane_matmul_pallas`` (interpret mode off-TPU); pass
    ``quant="xla"`` for the pure-jnp bit-plane form.  ``with_stats=True``
    appends the plane-traffic stats dict (see ``models.model.forward``).
    ``mesh=`` jits with the given shardings (:func:`jit_sharded`).
    """
    ctx = as_quant_ctx(quant, default_backend="pallas")

    def serve_step(params, caches, token):
        if cfg.frontend == "audio_stub":
            # audio stub decodes from a frame embedding, not a token id
            out = forward(cfg, params, embeds=token, caches=caches,
                          quant=ctx, return_stats=with_stats)
        else:
            out = forward(cfg, params, tokens=token, caches=caches,
                          quant=ctx, return_stats=with_stats)
        if with_stats:
            logits, caches, stats = out
            return logits[:, -1], caches, stats
        logits, caches = out
        return logits[:, -1], caches
    return _maybe_shard(serve_step, mesh, in_shardings, out_shardings)


def _mask_recurrent_rows(layers, prev_layers, rows):
    """Per-row select over the SSM/conv *recurrent* leaves of a stacked
    cache ``layers`` tuple: rows where ``rows`` is False revert their
    ssm/conv state to ``prev_layers``'s; everything else (attention KV —
    offset writes, masked and overwritten, never carried) passes through
    from ``layers``.

    A recurrence carries — junk tokens fed to a masked row would compound
    into its state — so every slot-pool step that advances state through
    rows that must NOT move (inactive slots in the decode tick, non-fresh
    rows in the chunk reset) routes through this one helper: leaf layout
    is (R, B, ...trailing) and the row mask broadcasts over repeats and
    whatever trails, so a cache-layout change lands in exactly one place.
    """
    out = []
    for c_new, c_old in zip(layers, prev_layers):
        if "ssm" in c_new:
            out.append({k: jnp.where(
                rows.reshape((1, -1) + (1,) * (c_new[k].ndim - 2)),
                c_new[k], c_old[k]) for k in c_new})
        else:
            out.append(c_new)
    return tuple(out)


def make_slot_serve_step(cfg: ModelConfig, quant: QuantFlag = False,
                         with_stats: bool = False, *, paged: bool = False,
                         mesh=None, in_shardings=None, out_shardings=None):
    """``(params, caches, tokens (B, 1), active (B,)) -> (logits, caches
    [, stats])``: the slot-pool decode step for continuous batching
    (``serving/scheduler.py``).

    ``paged=True`` appends a ``page_table (B, n_blocks)`` argument and
    expects the attention cache leaves in page-pool form
    (``init_paged_pool``): KV reads gather the slot's pages, the
    new-token write scatters into its tail page, and everything else —
    length freezing, SSM-state masking — is identical to the dense path
    (DESIGN.md §Paged KV + prefix cache).  When
    ``cfg.paged_attn_kernel != "off"`` the decode read skips the dense
    ``pool[table]`` gather entirely: attention dispatches to the fused
    paged-attention kernel (``kernels/paged_attention``), which walks the
    same ``page_table`` rows per block via scalar prefetch
    (DESIGN.md §Paged attention kernel).

    The batch shape is the fixed slot pool, so *every* row computes each
    step; ``active`` masks the bookkeeping — an inactive slot's cache
    ``length`` does not advance and its SSM/conv recurrent state passes
    through untouched, so whatever junk it decodes leaves no trace.  The
    state passthrough matters beyond free/retired slots: in the chunked
    mixed tick a slot that is still PREFILLING rides the decode scan
    inactive, and its mid-prompt recurrent state must survive (its junk KV
    single-token writes land at the frozen ``length`` offset, masked by
    ``kv_valid_len`` and overwritten by the next chunk — but a recurrence
    carries, so it is masked explicitly).  ``caches["length"]`` must be the
    per-slot ``(B,)`` form (``init_caches(per_slot=True)``).  With
    ``with_stats=True`` the returned stats dict is the batch-aggregate plane
    traffic of the step — the scheduler attributes it to the requests active
    at that step.
    """
    ctx = as_quant_ctx(quant, default_backend="pallas")

    def slot_step(params, caches, tokens, active, page_table=None):
        out = forward(cfg, params, tokens=tokens, caches=caches,
                      quant=ctx, return_stats=with_stats,
                      page_table=page_table)
        if with_stats:
            logits, new_caches, stats = out
        else:
            logits, new_caches = out
        new_caches = dict(new_caches)
        new_caches["length"] = jnp.where(active, new_caches["length"],
                                         caches["length"])
        new_caches["layers"] = _mask_recurrent_rows(
            new_caches["layers"], caches["layers"], active)
        if with_stats:
            return logits[:, -1], new_caches, stats
        return logits[:, -1], new_caches

    if paged:
        def paged_step(params, caches, tokens, active, page_table):
            return slot_step(params, caches, tokens, active, page_table)
        return _maybe_shard(paged_step, mesh, in_shardings, out_shardings)
    return _maybe_shard(slot_step, mesh, in_shardings, out_shardings)


def make_slot_prefill(cfg: ModelConfig, quant: QuantFlag = False, *,
                      mesh=None, in_shardings=None, out_shardings=None):
    """``(params, prompt (B, bucket), true_len (B,), caches) -> (last-real
    logits (B, V), caches)``: bucketed prefill for slot admission.

    ``prompt`` is right-padded to the bucket length; ``valid_len`` masking
    keeps pad tokens out of the SSM state (attention needs no mask — pads sit
    causally after every real token, and their junk K/V rows are both hidden
    by ``kv_valid_len`` and progressively overwritten by decode writes).  The
    returned logits are gathered at each row's last *real* token and the
    cache ``length`` is the per-row true length, not the bucket.
    """
    ctx = as_quant_ctx(quant, default_backend="xla")

    def prefill(params, prompt, true_len, caches):
        logits, caches = forward(cfg, params, tokens=prompt, caches=caches,
                                 quant=ctx, valid_len=true_len)
        b, _, v = logits.shape
        idx = jnp.broadcast_to((true_len - 1)[:, None, None], (b, 1, v))
        last = jnp.take_along_axis(logits, idx, axis=1)[:, 0]
        caches = dict(caches)
        caches["length"] = true_len
        return last, caches
    return _maybe_shard(prefill, mesh, in_shardings, out_shardings)


def make_slot_prefill_chunk(cfg: ModelConfig, quant: QuantFlag = False,
                            with_stats: bool = False, *, paged: bool = False,
                            mesh=None, in_shardings=None, out_shardings=None):
    """``(params, pool, pool_logits, tokens (B, chunk_len), chunk_valid (B,),
    fresh (B,), finishing (B,)) -> (logits (B, V), pool[, stats])``: ONE
    prompt chunk per prefilling slot, written straight into the slot pool.

    The chunked-prefill ingestion step (``serving/scheduler.py``): each
    prefilling row feeds its next ``chunk_valid[b]`` real prompt tokens
    (right-padded to the fixed ``chunk_len`` slab — ONE compiled shape for
    every prompt length, vs one program per bucket), appended at the row's
    current cache ``length`` via the per-row cache-write path
    (``forward(chunk_valid=...)``).  Rows that are decoding or free ride
    along with ``chunk_valid == 0`` and come out bit-identical.

    * ``fresh`` marks rows ingesting their FIRST chunk: their SSM/conv state
      is zeroed and their ``length`` reset before the forward — admission
      into a previously-used slot must not inherit the retired occupant's
      recurrent state (stale KV rows need no reset; they sit beyond
      ``length`` and are masked then overwritten).
    * ``finishing`` marks rows whose chunk contains the prompt's last token:
      their last-real-token logits are gathered into ``pool_logits`` (the
      decode carry — the next tick samples their first generated token from
      exactly what a bucketed prefill would have produced); other rows keep
      their logits untouched.

    ``quant=True`` resolves to the portable "xla" bit-plane backend like
    bucketed prefill (chunk GEMMs are MXU-shaped; the skip kernel targets
    decode).  ``with_stats=True`` appends the chunk forward's plane-traffic
    stats dict — the scheduler attributes it to the rows prefilling at that
    tick.  ``mesh=`` jits with the given shardings (:func:`jit_sharded`).
    ``paged=True`` appends a ``page_table`` argument and expects page-pool
    attention caches (``init_paged_pool``); slab writes scatter per page,
    pad positions land in the trash page instead of writing back their own
    bytes, and prefix-hit admissions enter with ``fresh=False`` and their
    cache ``length`` pre-set to the hit boundary — the chunk then ingests
    only the prompt SUFFIX (DESIGN.md §Paged KV + prefix cache).
    """
    ctx = as_quant_ctx(quant, default_backend="xla")

    def chunk_step(params, pool, pool_logits, tokens, chunk_valid, fresh,
                   finishing, page_table=None):
        length = jnp.where(fresh, 0, pool["length"])
        zeros = tuple({k: jnp.zeros_like(v) for k, v in c.items()}
                      if "ssm" in c else c for c in pool["layers"])
        caches = {"layers": _mask_recurrent_rows(pool["layers"], zeros,
                                                 jnp.logical_not(fresh)),
                  "length": length}
        out = forward(cfg, params, tokens=tokens, caches=caches, quant=ctx,
                      chunk_valid=chunk_valid, return_stats=with_stats,
                      page_table=page_table)
        if with_stats:
            logits, new_caches, stats = out
        else:
            logits, new_caches = out
        b, _, v = logits.shape
        idx = jnp.broadcast_to(
            jnp.maximum(chunk_valid - 1, 0)[:, None, None], (b, 1, v))
        last = jnp.take_along_axis(logits, idx, axis=1)[:, 0]
        new_logits = jnp.where(finishing[:, None],
                               last.astype(pool_logits.dtype), pool_logits)
        if with_stats:
            return new_logits, new_caches, stats
        return new_logits, new_caches

    if paged:
        def paged_chunk(params, pool, pool_logits, tokens, chunk_valid,
                        fresh, finishing, page_table):
            return chunk_step(params, pool, pool_logits, tokens,
                              chunk_valid, fresh, finishing, page_table)
        return _maybe_shard(paged_chunk, mesh, in_shardings, out_shardings)
    return _maybe_shard(chunk_step, mesh, in_shardings, out_shardings)


# ---------------------------------------------------------------------------
# fused decode loop
# ---------------------------------------------------------------------------

def make_decode_loop(cfg: ModelConfig, max_new: int, *,
                     temperature: float = 0.0,
                     quant: QuantFlag = False,
                     eos_id: Optional[int] = None,
                     with_stats: bool = False):
    """Build ``decode(params, caches, logits, key) -> (tokens, fracs)``.

    ``caches`` must be pre-filled and ``logits`` is the last-prompt-token
    distribution (i.e. the prefill outputs).  The returned function is a
    single jittable program: a ``lax.scan`` over ``max_new`` steps, or — when
    ``eos_id`` is given — a ``lax.while_loop`` that exits as soon as every
    row has emitted ``eos_id`` (remaining slots are ``eos_id``-padded).

    Returns ``tokens`` (B, max_new) int32 and ``stats`` — when
    ``with_stats``, a dict of per-step (max_new,) arrays:
    ``plane_traffic_fraction`` (tile-granular, what the Pallas kernel's skip
    table actually fetches) and ``element_traffic_fraction`` (the ASIC bank
    model, the paper's Fig. 3/§VI number) — else ``None``.  Entry ``i`` is
    the traffic of the forward that *consumed* token ``i``; steps whose
    logits would be dead (the final sampled token, rows all-EOS) are skipped
    entirely — no model forward runs — and report exact zero.
    """
    step = make_serve_step(cfg, quant, with_stats=with_stats)
    greedy = temperature <= 0.0

    def sample(logits, key):
        if greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / temperature, axis=-1).astype(jnp.int32)

    def do_step(params, caches, tok):
        out = step(params, caches, tok[:, None])
        if with_stats:
            logits, caches, stats = out
            frac = jnp.stack([stats["plane_traffic_fraction"],
                              stats["element_traffic_fraction"]])
            return logits, caches, frac
        logits, caches = out
        return logits, caches, jnp.zeros((2,), jnp.float32)

    def decode(params, caches, logits, key):
        b = logits.shape[0]

        if eos_id is None:
            def body(carry, i):
                lg, cs, k = carry
                k, sub = jax.random.split(k)
                tok = sample(lg, sub)
                # the last sampled token's forward would be dead (its logits
                # are never sampled) — skip it, same as the eos branch below;
                # skipped steps report exact-zero traffic stats
                lg, cs, frac = jax.lax.cond(
                    i + 1 < max_new,
                    lambda cs_: do_step(params, cs_, tok),
                    lambda cs_: (lg, cs_, jnp.zeros((2,), jnp.float32)),
                    cs)
                return (lg, cs, k), (tok, frac)

            _, (toks, fracs) = jax.lax.scan(
                body, (logits, caches, key), jnp.arange(max_new))
            toks = jnp.swapaxes(toks, 0, 1)               # (T, B) -> (B, T)
        else:
            def cond(carry):
                i, done = carry[0], carry[1]
                return (i < max_new) & ~jnp.all(done)

            def body(carry):
                i, done, lg, cs, k, toks, fracs = carry
                k, sub = jax.random.split(k)
                tok = jnp.where(done, eos_id, sample(lg, sub))
                toks = jax.lax.dynamic_update_slice_in_dim(
                    toks, tok[:, None], i, axis=1)
                done = done | (tok == eos_id)
                # step the model only if another token will be sampled from
                # its logits: the iteration that fills slot max_new-1 (or
                # completes every row) used to burn one dead forward — a full
                # wasted model step per generate.  Skipped steps leave
                # zeroed traffic stats (they fetched nothing).
                need_step = (i + 1 < max_new) & ~jnp.all(done)
                lg, cs, frac = jax.lax.cond(
                    need_step,
                    lambda cs_: do_step(params, cs_, tok),
                    lambda cs_: (lg, cs_, jnp.zeros((2,), jnp.float32)),
                    cs)
                fracs = jax.lax.dynamic_update_slice_in_dim(
                    fracs, frac[None], i, axis=0)
                return (i + 1, done, lg, cs, k, toks, fracs)

            init = (jnp.zeros((), jnp.int32), jnp.zeros((b,), bool),
                    logits, caches, key,
                    jnp.full((b, max_new), eos_id, jnp.int32),
                    jnp.zeros((max_new, 2), jnp.float32))
            (_, _, _, _, _, toks, fracs) = jax.lax.while_loop(cond, body, init)

        if not with_stats:
            return toks, None
        return toks, {"plane_traffic_fraction": fracs[:, 0],
                      "element_traffic_fraction": fracs[:, 1]}
    return decode


def _build_generate(cfg: ModelConfig, max_new: int, temperature: float,
                    quant: QuantFlag, eos_id: Optional[int],
                    with_stats: bool, mesh=None):
    prefill = make_prefill_step(cfg, quant)
    decode = make_decode_loop(cfg, max_new, temperature=temperature,
                              quant=quant, eos_id=eos_id,
                              with_stats=with_stats)

    def generate(params, prompt, key):
        b, s = prompt.shape
        caches = init_caches(cfg, b, max_len=s + max_new, dtype=cfg.dtype)
        logits, caches = prefill(params, {"tokens": prompt}, caches)
        return decode(params, caches, logits, key)

    # sharded: params arrive device-put to their TP shardings and the
    # activation hints bind inside the trace; cache shardings propagate from
    # the params/batch (the caches are created inside the program)
    return jit_sharded(generate, mesh)


class _GenerateFnCache:
    """LRU of jitted (prefill + fused decode) programs, one per static
    configuration — repeated generates with the same shapes compile exactly
    once.

    Unlike the old ``functools.lru_cache(maxsize=64)`` this bound is
    *adjustable*: under multi-config serving (many (cfg, max_new, quant)
    variants live at once) a fixed 64 silently evicts jitted programs that
    are still in rotation, forcing recompiles — the scheduler sizes it
    explicitly via :func:`set_generate_cache_size`.
    """

    def __init__(self, maxsize: int = 64):
        self._data: "collections.OrderedDict[Tuple, Any]" = \
            collections.OrderedDict()
        self._maxsize = maxsize

    def __call__(self, cfg: ModelConfig, max_new: int, temperature: float,
                 quant: QuantFlag, eos_id: Optional[int], with_stats: bool,
                 mesh=None):
        # the mesh fingerprint is part of the key: switching between sharded
        # and unsharded serving (or between meshes) in one process must NOT
        # reuse the other variant's compiled program
        key = (cfg, max_new, temperature, quant, eos_id, with_stats,
               mesh_fingerprint(mesh))
        fn = self._data.get(key)
        if fn is None:
            fn = self._data[key] = _build_generate(
                cfg, max_new, temperature, quant, eos_id, with_stats, mesh)
        self._data.move_to_end(key)
        while len(self._data) > self._maxsize:
            self._data.popitem(last=False)
        return fn

    def __len__(self) -> int:
        return len(self._data)

    @property
    def maxsize(self) -> int:
        return self._maxsize

    def set_maxsize(self, maxsize: int) -> None:
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        self._maxsize = maxsize
        while len(self._data) > self._maxsize:
            self._data.popitem(last=False)

    def cache_clear(self) -> None:
        self._data.clear()


generate_fn = _GenerateFnCache()


def clear_generate_cache() -> None:
    """Drop every cached jitted generate program (frees their compilation
    caches; the next generate per configuration recompiles)."""
    generate_fn.cache_clear()


def set_generate_cache_size(maxsize: int) -> None:
    """Bound the generate-program LRU explicitly — callers that know their
    live configuration count (the serve scheduler) size it so no in-rotation
    program is ever evicted."""
    generate_fn.set_maxsize(maxsize)


def greedy_generate(cfg: ModelConfig, params, prompt: jnp.ndarray,
                    max_new: int, *, temperature: float = 0.0,
                    key: Optional[jax.Array] = None,
                    quant: QuantFlag = False,
                    eos_id: Optional[int] = None,
                    with_stats: bool = False,
                    mesh=None):
    """Batched autoregressive generation as ONE fused XLA program.

    Token-for-token equivalent to the historical per-token Python loop
    (:func:`reference_generate`, property-tested), but prefill + every decode
    step compile into a single program: no per-token dispatch, no host
    round-trips.  Returns tokens (B, max_new); with ``with_stats=True``
    returns ``(tokens, stats)`` where stats holds the per-step
    ``plane_traffic_fraction`` / ``element_traffic_fraction`` arrays.
    ``mesh=`` runs the whole program tensor/data-parallel (pass params
    already device-put to their TP shardings); the token stream matches the
    single-device program bit-for-bit (tests/test_serve_sharded.py).
    """
    if not isinstance(quant, (bool, str)):
        raise TypeError("greedy_generate takes quant as bool|str; build a "
                        "custom loop via make_decode_loop for a QuantCtx")
    fn = generate_fn(cfg, int(max_new), float(temperature), quant,
                      eos_id if eos_id is None else int(eos_id),
                      bool(with_stats), mesh)
    if key is None:
        key = jax.random.PRNGKey(0)
    toks, fracs = fn(params, prompt, key)
    return (toks, fracs) if with_stats else toks


def reference_generate(cfg: ModelConfig, params, prompt: jnp.ndarray,
                       max_new: int, *, temperature: float = 0.0,
                       key: Optional[jax.Array] = None,
                       quant: QuantFlag = False) -> jnp.ndarray:
    """The unfused per-token Python loop (the pre-fused-engine semantics).

    Kept as the oracle for ``tests/test_serving_fused.py`` and the
    dispatch-overhead baseline for ``benchmarks/decode_bench.py`` — do NOT
    use for serving.
    """
    if key is None:
        # same default as greedy_generate — temperature > 0 with no key used
        # to crash in jax.random.split(None)
        key = jax.random.PRNGKey(0)
    b, s = prompt.shape
    caches = init_caches(cfg, b, max_len=s + max_new, dtype=cfg.dtype)
    prefill = jax.jit(make_prefill_step(cfg, quant))
    step = jax.jit(make_serve_step(cfg, quant))
    logits, caches = prefill(params, {"tokens": prompt}, caches)

    toks = []
    for _ in range(max_new):
        if temperature > 0.0:
            key, sub = jax.random.split(key)
            cur = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            cur = jnp.argmax(logits, axis=-1)
        cur = cur.astype(jnp.int32)
        toks.append(cur)
        logits, caches = step(params, caches, cur[:, None])
    return jnp.stack(toks, axis=1)
