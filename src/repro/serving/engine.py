"""Serving layer: prefill + single-token decode (the dry-run ``serve_step``)
and a batched autoregressive generate loop for the examples."""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import ModelConfig, forward, init_caches


def make_prefill_step(cfg: ModelConfig, quant: bool = False):
    """(params, batch) -> (last-token logits, caches).

    Runs the full forward over the prompt while writing the KV/SSM caches.
    This is what the ``prefill_32k`` shape lowers.
    """
    def prefill_step(params, batch, caches):
        logits, caches = forward(
            cfg, params,
            tokens=batch.get("tokens"), embeds=batch.get("embeds"),
            image_embeds=batch.get("image_embeds"),
            caches=caches, quant=quant)
        return logits[:, -1], caches
    return prefill_step


def make_serve_step(cfg: ModelConfig, quant: bool = False):
    """(params, caches, token) -> (logits, caches): ONE new token against a
    pre-filled cache.  This is what ``decode_32k`` / ``long_500k`` lower."""
    def serve_step(params, caches, token):
        if cfg.frontend == "audio_stub":
            # audio stub decodes from a frame embedding, not a token id
            logits, caches = forward(cfg, params, embeds=token, caches=caches,
                                     quant=quant)
        else:
            logits, caches = forward(cfg, params, tokens=token, caches=caches,
                                     quant=quant)
        return logits[:, -1], caches
    return serve_step


def greedy_generate(cfg: ModelConfig, params, prompt: jnp.ndarray,
                    max_new: int, *, temperature: float = 0.0,
                    key: Optional[jax.Array] = None,
                    quant: bool = False) -> jnp.ndarray:
    """Batched autoregressive generation (example/demo path)."""
    b, s = prompt.shape
    caches = init_caches(cfg, b, max_len=s + max_new, dtype=cfg.dtype)
    prefill = jax.jit(make_prefill_step(cfg, quant))
    step = jax.jit(make_serve_step(cfg, quant))
    logits, caches = prefill(params, {"tokens": prompt}, caches)

    toks = []
    cur = None
    for i in range(max_new):
        if temperature > 0.0:
            key, sub = jax.random.split(key)
            cur = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            cur = jnp.argmax(logits, axis=-1)
        toks.append(cur)
        logits, caches = step(params, caches, cur[:, None])
    return jnp.stack(toks, axis=1)
