"""Serving layer: prefill + single-token decode steps and the FUSED
autoregressive generation loop.

The decode loop is a single XLA program — ``jax.lax.scan`` over pre-allocated
caches (greedy/temperature sampling), or ``jax.lax.while_loop`` when an
``eos_id`` enables early stop — so an entire generate executes with **no
per-token host round-trips** (DESIGN.md §Serving).  The unfused per-token
Python loop survives only as :func:`reference_generate`, the semantics oracle
for tests and the dispatch-overhead baseline for benchmarks.

Quantized serving is wired end-to-end: ``make_serve_step(quant=True)``
resolves to the **Pallas** bit-plane backend (``bitplane_matmul_pallas``,
interpret mode off-TPU), accepts packed planes from
``quantize_model_params(pack=True)``, and — with ``with_stats=True`` —
reports the per-step ``plane_traffic_fraction`` (the fraction of weight-plane
tiles the kernel actually fetches: the decode-time image of the paper's §VI
memory-access savings).
"""

from __future__ import annotations

import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core.shiftadd import QuantCtx, as_quant_ctx
from repro.models.model import ModelConfig, forward, init_caches

QuantFlag = Union[bool, str, QuantCtx]


def make_prefill_step(cfg: ModelConfig, quant: QuantFlag = False):
    """(params, batch) -> (last-token logits, caches).

    Runs the full forward over the prompt while writing the KV/SSM caches.
    This is what the ``prefill_32k`` shape lowers.  ``quant=True`` resolves
    to the portable "xla" bit-plane backend (prefill GEMMs are MXU-shaped
    already; the plane-skip kernel targets the decode hot path).
    """
    ctx = as_quant_ctx(quant, default_backend="xla")

    def prefill_step(params, batch, caches):
        logits, caches = forward(
            cfg, params,
            tokens=batch.get("tokens"), embeds=batch.get("embeds"),
            image_embeds=batch.get("image_embeds"),
            caches=caches, quant=ctx)
        return logits[:, -1], caches
    return prefill_step


def make_serve_step(cfg: ModelConfig, quant: QuantFlag = False,
                    with_stats: bool = False):
    """(params, caches, token) -> (logits, caches[, stats]): ONE new token
    against a pre-filled cache.  This is what ``decode_32k`` / ``long_500k``
    lower.

    ``quant=True`` resolves to the **"pallas"** backend: eligible projections
    run through ``bitplane_matmul_pallas`` (interpret mode off-TPU); pass
    ``quant="xla"`` for the pure-jnp bit-plane form.  ``with_stats=True``
    appends the plane-traffic stats dict (see ``models.model.forward``).
    """
    ctx = as_quant_ctx(quant, default_backend="pallas")

    def serve_step(params, caches, token):
        if cfg.frontend == "audio_stub":
            # audio stub decodes from a frame embedding, not a token id
            out = forward(cfg, params, embeds=token, caches=caches,
                          quant=ctx, return_stats=with_stats)
        else:
            out = forward(cfg, params, tokens=token, caches=caches,
                          quant=ctx, return_stats=with_stats)
        if with_stats:
            logits, caches, stats = out
            return logits[:, -1], caches, stats
        logits, caches = out
        return logits[:, -1], caches
    return serve_step


# ---------------------------------------------------------------------------
# fused decode loop
# ---------------------------------------------------------------------------

def make_decode_loop(cfg: ModelConfig, max_new: int, *,
                     temperature: float = 0.0,
                     quant: QuantFlag = False,
                     eos_id: Optional[int] = None,
                     with_stats: bool = False):
    """Build ``decode(params, caches, logits, key) -> (tokens, fracs)``.

    ``caches`` must be pre-filled and ``logits`` is the last-prompt-token
    distribution (i.e. the prefill outputs).  The returned function is a
    single jittable program: a ``lax.scan`` over ``max_new`` steps, or — when
    ``eos_id`` is given — a ``lax.while_loop`` that exits as soon as every
    row has emitted ``eos_id`` (remaining slots are ``eos_id``-padded).

    Returns ``tokens`` (B, max_new) int32 and ``stats`` — when
    ``with_stats``, a dict of per-step (max_new,) arrays:
    ``plane_traffic_fraction`` (tile-granular, what the Pallas kernel's skip
    table actually fetches) and ``element_traffic_fraction`` (the ASIC bank
    model, the paper's Fig. 3/§VI number) — else ``None``.
    """
    step = make_serve_step(cfg, quant, with_stats=with_stats)
    greedy = temperature <= 0.0

    def sample(logits, key):
        if greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / temperature, axis=-1).astype(jnp.int32)

    def do_step(params, caches, tok):
        out = step(params, caches, tok[:, None])
        if with_stats:
            logits, caches, stats = out
            frac = jnp.stack([stats["plane_traffic_fraction"],
                              stats["element_traffic_fraction"]])
            return logits, caches, frac
        logits, caches = out
        return logits, caches, jnp.zeros((2,), jnp.float32)

    def decode(params, caches, logits, key):
        b = logits.shape[0]

        if eos_id is None:
            def body(carry, _):
                lg, cs, k = carry
                k, sub = jax.random.split(k)
                tok = sample(lg, sub)
                lg, cs, frac = do_step(params, cs, tok)
                return (lg, cs, k), (tok, frac)

            _, (toks, fracs) = jax.lax.scan(
                body, (logits, caches, key), None, length=max_new)
            toks = jnp.swapaxes(toks, 0, 1)               # (T, B) -> (B, T)
        else:
            def cond(carry):
                i, done = carry[0], carry[1]
                return (i < max_new) & ~jnp.all(done)

            def body(carry):
                i, done, lg, cs, k, toks, fracs = carry
                k, sub = jax.random.split(k)
                tok = jnp.where(done, eos_id, sample(lg, sub))
                toks = jax.lax.dynamic_update_slice_in_dim(
                    toks, tok[:, None], i, axis=1)
                done = done | (tok == eos_id)
                lg, cs, frac = do_step(params, cs, tok)
                fracs = jax.lax.dynamic_update_slice_in_dim(
                    fracs, frac[None], i, axis=0)
                return (i + 1, done, lg, cs, k, toks, fracs)

            init = (jnp.zeros((), jnp.int32), jnp.zeros((b,), bool),
                    logits, caches, key,
                    jnp.full((b, max_new), eos_id, jnp.int32),
                    jnp.zeros((max_new, 2), jnp.float32))
            (_, _, _, _, _, toks, fracs) = jax.lax.while_loop(cond, body, init)

        if not with_stats:
            return toks, None
        return toks, {"plane_traffic_fraction": fracs[:, 0],
                      "element_traffic_fraction": fracs[:, 1]}
    return decode


@functools.lru_cache(maxsize=64)
def generate_fn(cfg: ModelConfig, max_new: int, temperature: float,
                quant: QuantFlag, eos_id: Optional[int], with_stats: bool):
    """One jitted (prefill + fused decode) program per static configuration.

    The lru_cache keeps the jit wrapper (and therefore its compilation cache)
    alive across calls — repeated generates with the same shapes compile
    exactly once.
    """
    prefill = make_prefill_step(cfg, quant)
    decode = make_decode_loop(cfg, max_new, temperature=temperature,
                              quant=quant, eos_id=eos_id,
                              with_stats=with_stats)

    def generate(params, prompt, key):
        b, s = prompt.shape
        caches = init_caches(cfg, b, max_len=s + max_new, dtype=cfg.dtype)
        logits, caches = prefill(params, {"tokens": prompt}, caches)
        return decode(params, caches, logits, key)

    return jax.jit(generate)


def greedy_generate(cfg: ModelConfig, params, prompt: jnp.ndarray,
                    max_new: int, *, temperature: float = 0.0,
                    key: Optional[jax.Array] = None,
                    quant: QuantFlag = False,
                    eos_id: Optional[int] = None,
                    with_stats: bool = False):
    """Batched autoregressive generation as ONE fused XLA program.

    Token-for-token equivalent to the historical per-token Python loop
    (:func:`reference_generate`, property-tested), but prefill + every decode
    step compile into a single program: no per-token dispatch, no host
    round-trips.  Returns tokens (B, max_new); with ``with_stats=True``
    returns ``(tokens, stats)`` where stats holds the per-step
    ``plane_traffic_fraction`` / ``element_traffic_fraction`` arrays.
    """
    if not isinstance(quant, (bool, str)):
        raise TypeError("greedy_generate takes quant as bool|str; build a "
                        "custom loop via make_decode_loop for a QuantCtx")
    fn = generate_fn(cfg, int(max_new), float(temperature), quant,
                      eos_id if eos_id is None else int(eos_id),
                      bool(with_stats))
    if key is None:
        key = jax.random.PRNGKey(0)
    toks, fracs = fn(params, prompt, key)
    return (toks, fracs) if with_stats else toks


def reference_generate(cfg: ModelConfig, params, prompt: jnp.ndarray,
                       max_new: int, *, temperature: float = 0.0,
                       key: Optional[jax.Array] = None,
                       quant: QuantFlag = False) -> jnp.ndarray:
    """The unfused per-token Python loop (the pre-fused-engine semantics).

    Kept as the oracle for ``tests/test_serving_fused.py`` and the
    dispatch-overhead baseline for ``benchmarks/decode_bench.py`` — do NOT
    use for serving.
    """
    b, s = prompt.shape
    caches = init_caches(cfg, b, max_len=s + max_new, dtype=cfg.dtype)
    prefill = jax.jit(make_prefill_step(cfg, quant))
    step = jax.jit(make_serve_step(cfg, quant))
    logits, caches = prefill(params, {"tokens": prompt}, caches)

    toks = []
    for _ in range(max_new):
        if temperature > 0.0:
            key, sub = jax.random.split(key)
            cur = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            cur = jnp.argmax(logits, axis=-1)
        cur = cur.astype(jnp.int32)
        toks.append(cur)
        logits, caches = step(params, caches, cur[:, None])
    return jnp.stack(toks, axis=1)
