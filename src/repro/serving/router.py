"""Request router for disaggregated serving: one front door over a
prefill engine and a decode engine.

Two transports, one protocol:

* :class:`Router` — both engines in THIS process.  Deterministic
  round-robin: import ready spans into free decode slots, prefill the
  next queued request, tick the decode fleet, repeat.  Decode tick
  wall-clock is recorded SEPARATELY from prefill work (``
  decode_tick_times``) — that separation is the measurement the
  disaggregated ``serve_bench`` A/B reports: a prompt flood lands on the
  prefill engine, never inside the decode fleet's fused tick.
* :func:`run_disaggregated` — the same protocol over TWO host processes
  (stdlib ``multiprocessing`` spawn + pipes, ``PageSpan.to_bytes`` as
  the wire format).  Each worker rebuilds its model from the arch name
  and its scheduler from ``ServeConfig`` JSON — the payoff of making the
  config serializable (``serving/config.py``).

Per-request semantics match the combined scheduler: the oversize
reject/truncate/raise policy runs prefill-side at submission, rejected
requests come back as ``RequestResult(finish_reason="rejected")`` under
the ROUTER's rid and submit time, finished requests surface the decode
scheduler's own results.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional

import numpy as np

from repro.serving.config import ServeConfig
from repro.serving.scheduler import RequestResult
from repro.serving.workers import DecodeEngine, PageSpan, PrefillEngine


class Router:
    """In-process disaggregated router: submit like the scheduler, run
    to completion, get per-request results in rid order."""

    def __init__(self, cfg, params, config: ServeConfig, *, mesh=None,
                 span_backlog: int = 4):
        self.config = config
        self.prefill = PrefillEngine(cfg, params, config, mesh=mesh)
        self.decode = DecodeEngine(cfg, params, config, mesh=mesh)
        # prefilled spans waiting for a decode slot; bounding the backlog
        # keeps the prefill engine from racing arbitrarily far ahead of
        # the decode fleet (each span pins host copies of its pages)
        self.span_backlog = max(1, int(span_backlog))
        self._queue: deque = deque()
        self._spans: deque = deque()
        self._results: Dict[int, RequestResult] = {}
        self._next_rid = 0
        #: decode-fleet tick wall-clock, prefill work excluded — the
        #: isolation metric of the disaggregated serve_bench A/B
        self.decode_tick_times: List[float] = []

    def submit(self, prompt, max_new: int,
               eos_id: Optional[int] = None) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append((rid, np.asarray(prompt, np.int32), int(max_new),
                            eos_id, time.perf_counter()))
        return rid

    # ------------------------------------------------------------ drive
    def _admit_ready_spans(self) -> bool:
        progressed = False
        while self._spans:
            rid, span, t = self._spans[0]
            status = self.decode.admit(span, rid, t)
            if status in ("full", "wait"):
                break
            self._spans.popleft()
            progressed = True            # "ok", or "drop" (result recorded)
        return progressed

    def _prefill_next(self) -> bool:
        if not self._queue or len(self._spans) >= self.span_backlog:
            return False
        rid, prompt, max_new, eos_id, t = self._queue.popleft()
        span, rejected = self.prefill.prefill(prompt, max_new, eos_id)
        if rejected is not None:
            # re-stamp with the router's identity: the prefill scheduler
            # assigned its own internal rid and submit time
            self._results[rid] = dataclasses.replace(
                rejected, rid=rid, submit_time=t)
        else:
            self._spans.append((rid, span, t))
        return True

    def _tick_decode(self) -> bool:
        if not self.decode.active:
            return False
        t0 = time.perf_counter()
        self.decode.step()
        self.decode_tick_times.append(time.perf_counter() - t0)
        self._results.update(self.decode.drain_results())
        return True

    def step(self) -> bool:
        """One router round; False when no sub-step made progress."""
        progressed = self._admit_ready_spans()
        progressed |= self._prefill_next()
        progressed |= self._tick_decode()
        return progressed

    def run(self) -> List[RequestResult]:
        """Drive everything submitted so far to completion; results in
        rid order (matching ``ServeScheduler.run``)."""
        want = self._next_rid
        while self._queue or self._spans or self.decode.active:
            if not self.step():
                stuck = [rid for rid, _, _ in self._spans]
                raise RuntimeError(
                    f"router wedged: spans for rids {stuck} cannot be "
                    f"imported (decode pool too small for the span?) and "
                    f"no decode work is in flight")
        self._results.update(self.decode.drain_results())
        return [self._results.pop(rid) for rid in range(want)
                if rid in self._results]


# ---------------------------------------------------------------------------
# two-process transport
# ---------------------------------------------------------------------------

def _worker_main(conn, role: str, spec: dict) -> None:
    """Worker process entry (spawn target — must be importable): rebuild
    the model from the arch name and the engine from ServeConfig JSON,
    then serve the parent's RPC loop over the pipe."""
    import jax

    from repro.configs import get_config, get_smoke
    from repro.models import init_params

    cfg = (get_smoke(spec["arch"]) if spec["smoke"]
           else get_config(spec["arch"]))
    if spec.get("f32"):
        import jax.numpy as jnp
        cfg = cfg.replace(dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(spec["seed"]), cfg)
    if spec.get("quant"):
        from repro.models.quantize import quantize_model_params
        params = quantize_model_params(cfg, params)
    config = ServeConfig.from_json(spec["config_json"])

    if role == "prefill":
        eng = PrefillEngine(cfg, params, config)
        while True:
            msg = conn.recv()
            if msg[0] == "stop":
                break
            _, rid, prompt, max_new, eos_id = msg
            span, rejected = eng.prefill(np.asarray(prompt, np.int32),
                                         max_new, eos_id)
            if rejected is not None:
                conn.send(("rejected", rid, rejected.error,
                           rejected.prompt_len))
            else:
                conn.send(("span", rid, span.to_bytes()))
    else:
        eng = DecodeEngine(cfg, params, config)
        while True:
            msg = conn.recv()
            if msg[0] == "stop":
                break
            if msg[0] == "admit":
                _, rid, blob, t = msg
                status = eng.admit(PageSpan.from_bytes(blob), rid, t)
                conn.send(("admitted", rid, status))
            elif msg[0] == "tick":
                t0 = time.perf_counter()
                eng.step()
                dt = time.perf_counter() - t0
                done = [(r.rid, list(r.tokens), r.finish_reason,
                         r.prompt_len, r.error)
                        for r in eng.drain_results().values()]
                conn.send(("results", done, eng.active, dt))
    conn.close()


def _recv(conn, proc, what: str, timeout: float):
    if not conn.poll(timeout):
        alive = proc.is_alive()
        raise RuntimeError(f"disaggregated worker timed out waiting for "
                           f"{what} (alive={alive}, "
                           f"exitcode={proc.exitcode})")
    return conn.recv()


def run_disaggregated(trace, *, arch: str, config: ServeConfig,
                      smoke: bool = True, f32: bool = True, seed: int = 0,
                      quant: bool = False, timeout: float = 600.0):
    """Serve ``trace`` (a list of ``(prompt, max_new, eos_id)``) across
    TWO spawned worker processes — prefill and decode — returning
    ``[(rid, tokens, finish_reason, error), ...]`` in rid order.

    The parent never touches jax: it shuttles prompts to the prefill
    worker, ``PageSpan`` byte frames to the decode worker, and ticks the
    decode worker until every admitted request retires.  Also returns the
    decode worker's per-tick wall-clock (the isolation measurement).
    """
    import multiprocessing as mp

    ctx = mp.get_context("spawn")
    spec = {"arch": arch, "smoke": smoke, "f32": f32, "seed": seed,
            "quant": quant, "config_json": config.to_json()}
    p_parent, p_child = ctx.Pipe()
    d_parent, d_child = ctx.Pipe()
    prefill = ctx.Process(target=_worker_main,
                          args=(p_child, "prefill", spec), daemon=True)
    decode = ctx.Process(target=_worker_main,
                         args=(d_child, "decode", spec), daemon=True)
    prefill.start()
    decode.start()
    results: Dict[int, tuple] = {}
    tick_times: List[float] = []
    in_flight = 0

    def tick_once():
        nonlocal in_flight
        d_parent.send(("tick",))
        _, done, active, dt = _recv(d_parent, decode, "tick", timeout)
        tick_times.append(dt)
        for rid, tokens, reason, plen, err in done:
            results[rid] = (rid, tokens, reason, err)
            in_flight -= 1
        return active

    try:
        for rid, (prompt, max_new, eos_id) in enumerate(trace):
            p_parent.send(("prefill", rid, np.asarray(prompt, np.int32),
                           int(max_new), eos_id))
            kind, _, *payload = _recv(p_parent, prefill, "prefill", timeout)
            if kind == "rejected":
                results[rid] = (rid, [], "rejected", payload[0])
                continue
            blob = payload[0]
            while True:
                d_parent.send(("admit", rid, blob, time.perf_counter()))
                _, _, status = _recv(d_parent, decode, "admit", timeout)
                if status == "ok":
                    in_flight += 1
                    break
                if status == "drop":
                    # the decode engine recorded a rejected result; it
                    # arrives with the next tick's drain like any retire
                    in_flight += 1
                    break
                tick_once()     # "full"/"wait": free a slot by ticking
        while in_flight:
            tick_once()
    finally:
        for conn, proc in ((p_parent, prefill), (d_parent, decode)):
            try:
                conn.send(("stop",))
            except (BrokenPipeError, OSError):
                pass
            proc.join(timeout=30)
            if proc.is_alive():
                proc.terminate()
                proc.join(timeout=10)
    ordered = [results[rid] for rid in sorted(results)]
    return ordered, tick_times
