"""Paper-figure reproductions (one function per figure/table).

Every function returns a list of CSV rows ``(name, value, paper_value)`` so
``benchmarks.run`` can print the side-by-side comparison that EXPERIMENTS.md
§Paper quotes.  Activation statistics come from two sources:

* ``measured`` — our JAX re-implementations of the paper's five workloads
  (random init, synthetic inputs; see models/paper_nets.py for why this is
  representative), and
* ``preset``  — distributions digitized from the paper's own Fig. 2/§VI-B
  numbers, isolating the simulator from our weight initialization.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import log2_quantize
from repro.models.paper_nets import PAPER_ACTIVATIONS
from repro.simulator import (NAHID, NEUROCUBE, PAPER_WORKLOADS, QEIHAN,
                             measure, paper_preset, simulate)

Row = Tuple[str, float, float]

# paper-printed reference values
_PAPER = {
    "neg_frac": {"alexnet": 0.36, "ptblm": 0.98, "transformer": 0.57,
                 "bert-base": 0.82, "bert-large": 0.85},
    "fig3_avg_savings": 0.25,
    "fig9_avg_vs_neurocube": 0.276,
    "fig9_avg_vs_nahid": 0.75,
    "fig10_avg_vs_neurocube": 4.25,
    "fig10_avg_vs_nahid": 1.38,
    "fig10_ptblm_vs_nahid": 1.86,
    "fig10_alexnet_vs_nahid": 1.07,
    "fig11_avg_vs_neurocube": 3.52,
    "fig11_avg_vs_nahid": 1.28,
    "fig11_ptblm_vs_neurocube": 8.2,
    "fig11_ptblm_vs_nahid": 1.6,
}


def measured_stats(seed: int = 0) -> Dict[str, "ActStats"]:
    out = {}
    for name, fn in PAPER_ACTIVATIONS.items():
        acts = fn(jax.random.PRNGKey(seed))
        exps = []
        for _, a in acts:
            q = log2_quantize(jnp.asarray(a))
            exps.append(np.asarray(q.exp).reshape(-1))
        from repro.core.logquant import LogQuantized
        all_exp = np.concatenate(exps)
        out[name] = measure(LogQuantized(
            exp=jnp.asarray(all_exp), sign=jnp.ones_like(jnp.asarray(all_exp))))
    return out


def fig2_histograms(stats_source: str = "measured") -> List[Row]:
    """Fig. 2: negative-exponent fraction of LOG2-quantized activations."""
    rows: List[Row] = []
    stats = measured_stats() if stats_source == "measured" else {
        m: paper_preset(m) for m in PAPER_WORKLOADS}
    for m, st in stats.items():
        rows.append((f"fig2.neg_frac.{m}.{stats_source}",
                     st.negative_fraction, _PAPER["neg_frac"][m]))
        rows.append((f"fig2.pruned.{m}.{stats_source}", st.zero_frac,
                     float("nan")))
    return rows


def fig3_memory_savings(stats_source: str = "preset") -> List[Row]:
    """Fig. 3: estimated weight-bit savings from negative exponents."""
    rows: List[Row] = []
    stats = measured_stats() if stats_source == "measured" else {
        m: paper_preset(m) for m in PAPER_WORKLOADS}
    savs = []
    for m, st in stats.items():
        s = st.estimated_memory_savings()
        savs.append(s)
        rows.append((f"fig3.savings.{m}.{stats_source}", s, float("nan")))
    rows.append((f"fig3.savings.avg.{stats_source}", float(np.mean(savs)),
                 _PAPER["fig3_avg_savings"]))
    return rows


def _simulate_all(stats_source: str = "preset"):
    stats = measured_stats() if stats_source == "measured" else {
        m: paper_preset(m) for m in PAPER_WORKLOADS}
    out = {}
    for name, builder in PAPER_WORKLOADS.items():
        layers = builder()
        st = stats[name]
        out[name] = {c.name: simulate(c, layers, st)
                     for c in (NEUROCUBE, NAHID, QEIHAN)}
    return out


def fig9_memory_accesses(stats_source: str = "preset") -> List[Row]:
    """Fig. 9: normalized total 3D-memory accesses."""
    sims = _simulate_all(stats_source)
    rows: List[Row] = []
    r_nc, r_nh = [], []
    for m, r in sims.items():
        a = r["qeihan"].dram_bits / r["neurocube"].dram_bits
        b = r["qeihan"].dram_bits / r["nahid"].dram_bits
        r_nc.append(a)
        r_nh.append(b)
        rows.append((f"fig9.vs_neurocube.{m}", a, float("nan")))
        rows.append((f"fig9.vs_nahid.{m}", b, float("nan")))
    rows.append(("fig9.vs_neurocube.avg", float(np.mean(r_nc)),
                 _PAPER["fig9_avg_vs_neurocube"]))
    rows.append(("fig9.vs_nahid.avg", float(np.mean(r_nh)),
                 _PAPER["fig9_avg_vs_nahid"]))
    return rows


def fig10_speedups(stats_source: str = "preset") -> List[Row]:
    """Fig. 10: speedups of QeiHaN over the two baselines."""
    sims = _simulate_all(stats_source)
    rows: List[Row] = []
    s_nc, s_nh = [], []
    for m, r in sims.items():
        a = r["neurocube"].time_s / r["qeihan"].time_s
        b = r["nahid"].time_s / r["qeihan"].time_s
        s_nc.append(a)
        s_nh.append(b)
        paper_b = {"ptblm": _PAPER["fig10_ptblm_vs_nahid"],
                   "alexnet": _PAPER["fig10_alexnet_vs_nahid"]}.get(
            m, float("nan"))
        rows.append((f"fig10.vs_neurocube.{m}", a, float("nan")))
        rows.append((f"fig10.vs_nahid.{m}", b, paper_b))
    rows.append(("fig10.vs_neurocube.avg", float(np.mean(s_nc)),
                 _PAPER["fig10_avg_vs_neurocube"]))
    rows.append(("fig10.vs_nahid.avg", float(np.mean(s_nh)),
                 _PAPER["fig10_avg_vs_nahid"]))
    return rows


def fig11_energy(stats_source: str = "preset") -> List[Row]:
    """Fig. 11: normalized energy savings."""
    sims = _simulate_all(stats_source)
    rows: List[Row] = []
    e_nc, e_nh = [], []
    for m, r in sims.items():
        a = r["neurocube"].energy_j / r["qeihan"].energy_j
        b = r["nahid"].energy_j / r["qeihan"].energy_j
        e_nc.append(a)
        e_nh.append(b)
        pa = _PAPER["fig11_ptblm_vs_neurocube"] if m == "ptblm" else float("nan")
        pb = _PAPER["fig11_ptblm_vs_nahid"] if m == "ptblm" else float("nan")
        rows.append((f"fig11.vs_neurocube.{m}", a, pa))
        rows.append((f"fig11.vs_nahid.{m}", b, pb))
    rows.append(("fig11.vs_neurocube.avg", float(np.mean(e_nc)),
                 _PAPER["fig11_avg_vs_neurocube"]))
    rows.append(("fig11.vs_nahid.avg", float(np.mean(e_nh)),
                 _PAPER["fig11_avg_vs_nahid"]))
    return rows


def fig12_energy_breakdown(stats_source: str = "preset") -> List[Row]:
    """Fig. 12: energy breakdown (DRAM share must dominate, per the paper)."""
    sims = _simulate_all(stats_source)
    rows: List[Row] = []
    for m, r in sims.items():
        for accel in ("neurocube", "nahid", "qeihan"):
            br = r[accel].energy_by()
            tot = sum(br.values())
            for k, v in sorted(br.items()):
                rows.append((f"fig12.{m}.{accel}.{k}", v / tot, float("nan")))
    return rows


def table1_model_sizes() -> List[Row]:
    """Table I: INT8 model sizes (MB) of the FC/CONV layers."""
    paper_mb = {"alexnet": 36, "ptblm": 34.2, "transformer": 84,
                "bert-base": 110, "bert-large": 330}
    rows: List[Row] = []
    for name, builder in PAPER_WORKLOADS.items():
        weights = sum(l.weights for l in builder()
                      if not l.name.startswith("lstm") or "_t0" in l.name)
        rows.append((f"table1.int8_mb.{name}", weights / 1e6,
                     paper_mb[name]))
    return rows


ALL_FIGURES = {
    "fig2": fig2_histograms,
    "fig3": fig3_memory_savings,
    "fig9": fig9_memory_accesses,
    "fig10": fig10_speedups,
    "fig11": fig11_energy,
    "fig12": fig12_energy_breakdown,
    "table1": table1_model_sizes,
}
