"""Continuous-batching serve benchmark: sustained tok/s + plane traffic
under a Poisson request trace.

Compares the slot-pool scheduler (``serving/scheduler.py`` — admit /
tick / retire / re-fill, decode never drains) against the *naive serial*
baseline: each request decoded alone through the fused ``greedy_generate``
program, one after another — what you get without a scheduler.  Both sides
are timed warm (compile excluded); baseline prompts are padded to the same
buckets so its compile count is bounded identically.  A second scheduler
pass runs the quantized bit-plane path with per-request
``plane_traffic_fraction`` / ``element_traffic_fraction`` reporting — the
sustained-load image of the paper's §VI memory-access savings.

  PYTHONPATH=src python -m benchmarks.serve_bench            # full bench
  PYTHONPATH=src python -m benchmarks.serve_bench --dry      # CI smoke
  PYTHONPATH=src python -m benchmarks.run --only serve       # via driver

Rows print as ``serve.<name>,<value>,`` CSV like every other bench.
"""

from __future__ import annotations

import argparse
import time
from typing import List, Tuple

import numpy as np


def _make_trace(rng, n_requests: int, vocab: int, min_len: int, max_len: int,
                rate: float) -> List[Tuple[float, np.ndarray]]:
    """Poisson arrivals (exponential gaps at ``rate`` req/s; ``rate=0`` =
    everything queued at t=0) with uniform prompt lengths."""
    arrivals, t = [], 0.0
    for _ in range(n_requests):
        if rate > 0:
            t += float(rng.exponential(1.0 / rate))
        prompt = rng.integers(0, vocab,
                              size=int(rng.integers(min_len, max_len + 1)),
                              ).astype(np.int32)
        arrivals.append((t, prompt))
    return arrivals


def _run_scheduler(sched, trace, max_new: int, eos_id=None):
    """Replay the trace in wall-clock time (fast-forwarding idle gaps);
    returns (results-so-far in rid order, elapsed_busy_seconds).  Every tick
    syncs tokens to host, so the clock reads true device-done time."""
    pending = list(trace)
    t0 = time.perf_counter()
    idle = 0.0
    while pending or sched.pending:
        now = time.perf_counter() - t0 - idle
        while pending and pending[0][0] <= now:
            _, prompt = pending.pop(0)
            sched.submit(prompt, max_new=max_new, eos_id=eos_id)
        if sched.pending:
            sched.step_tick()
        elif pending:
            # fast-forward an empty system to the next arrival: idle time is
            # not "sustained load" and is excluded from the throughput
            idle += pending[0][0] - now
    return sched.run(max_ticks=0), time.perf_counter() - t0 - idle


def _warm_trace(rng, buckets, vocab) -> List[Tuple[float, np.ndarray]]:
    """One request per bucket at t=0 — compiles every prefill variant plus
    the tick program before anything is timed."""
    return [(0.0, rng.integers(0, vocab, size=b).astype(np.int32))
            for b in buckets]


def serve_bench(arch: str = "smollm_135m", n_requests: int = 24,
                max_slots: int = 8, tick_steps: int = 8, max_new: int = 24,
                rate: float = 0.0, seed: int = 0,
                buckets: Tuple[int, ...] = (8, 16, 32)):
    """Returns rows (name, value, reference-nan) for benchmarks.run."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke
    from repro.models import init_params
    from repro.models.quantize import quantize_model_params
    from repro.serving import engine
    from repro.serving.scheduler import ServeScheduler, bucket_for

    cfg = get_smoke(arch).replace(dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    pool_len = max(buckets) + max_new + tick_steps
    trace = _make_trace(rng, n_requests, cfg.vocab_size,
                        min_len=4, max_len=max(buckets), rate=rate)
    total_tokens = n_requests * max_new
    nan = float("nan")
    rows = []

    # --- naive serial baseline: fused generate, one request at a time ------
    key = jax.random.PRNGKey(0)

    def serial_pass():
        for _, prompt in trace:
            b = bucket_for(prompt.size, buckets)
            padded = np.zeros((1, b), np.int32)
            padded[0, :prompt.size] = prompt
            fn = engine.generate_fn(cfg, max_new, 0.0, False, None, False)
            jax.block_until_ready(fn(params, jnp.asarray(padded), key)[0])

    serial_pass()                                    # warm every bucket
    t0 = time.perf_counter()
    serial_pass()
    t_serial = time.perf_counter() - t0
    rows.append((f"serve.{cfg.name}.serial_tok_s",
                 total_tokens / t_serial, nan))

    # --- continuous-batching scheduler, float ------------------------------
    sched = ServeScheduler(cfg, params, max_slots=max_slots,
                           max_len=pool_len, buckets=buckets,
                           tick_steps=tick_steps)
    _run_scheduler(sched, _warm_trace(rng, buckets, cfg.vocab_size), max_new)
    results, t_sched = _run_scheduler(sched, trace, max_new)
    got = sum(len(r.tokens) for r in results[-n_requests:])
    assert got == total_tokens, (got, total_tokens)
    rows.append((f"serve.{cfg.name}.sched_tok_s",
                 total_tokens / t_sched, nan))
    rows.append((f"serve.{cfg.name}.sched_vs_serial_speedup",
                 t_serial / t_sched, nan))

    # --- quantized pass with per-request traffic stats ---------------------
    qparams = quantize_model_params(cfg, params)
    qsched = ServeScheduler(cfg, qparams, max_slots=max_slots,
                            max_len=pool_len, buckets=buckets,
                            quant="xla", with_stats=True,
                            tick_steps=tick_steps)
    _run_scheduler(qsched, _warm_trace(rng, buckets, cfg.vocab_size),
                   max_new)
    qresults, t_q = _run_scheduler(qsched, trace, max_new)
    qresults = qresults[-n_requests:]
    rows.append((f"serve.{cfg.name}.quant.sched_tok_s",
                 total_tokens / t_q, nan))
    rows.append((f"serve.{cfg.name}.quant.plane_traffic_fraction_tile",
                 float(np.mean([r.plane_traffic_fraction
                                for r in qresults])), nan))
    rows.append((f"serve.{cfg.name}.quant.plane_traffic_fraction_element",
                 float(np.mean([r.element_traffic_fraction
                                for r in qresults])), nan))
    return rows


ALL_SERVE_BENCHES = {"serve": serve_bench}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-slots", type=int, default=8)
    ap.add_argument("--tick-steps", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate in req/s (0 = all queued "
                         "at t=0, the sustained-load trace)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dry", action="store_true",
                    help="CI smoke: tiny trace, checks wiring + that the "
                         "scheduler runs end-to-end")
    args = ap.parse_args(argv)

    if args.dry:
        rows = serve_bench(args.arch, n_requests=4, max_slots=2,
                           tick_steps=2, max_new=4, rate=args.rate,
                           seed=args.seed, buckets=(8, 16))
    else:
        rows = serve_bench(args.arch, n_requests=args.requests,
                           max_slots=args.max_slots,
                           tick_steps=args.tick_steps,
                           max_new=args.new_tokens, rate=args.rate,
                           seed=args.seed)
    print("name,value,paper_reference")
    for name, val, _ in rows:
        print(f"{name},{val:.4f},")


if __name__ == "__main__":
    main()
