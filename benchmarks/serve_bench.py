"""Continuous-batching serve benchmark: sustained tok/s, per-request
latency (TTFT + end-to-end p50/p95), per-tick latency, and plane traffic
under a Poisson request trace.

Compares the slot-pool scheduler (``serving/scheduler.py`` — admit /
tick / retire / re-fill, decode never drains) against the *naive serial*
baseline: each request decoded alone through the fused ``greedy_generate``
program, one after another — what you get without a scheduler.  Both sides
are timed warm (compile excluded); baseline prompts are padded to the same
buckets so its compile count is bounded identically.  A second scheduler
pass runs the quantized bit-plane path with per-request
``plane_traffic_fraction`` / ``element_traffic_fraction`` reporting — the
sustained-load image of the paper's §VI memory-access savings.

The **chunked** variant (``serve_bench_chunked`` / ``--chunked``) is the
ISSUE 4 A/B: the same heavy mixed trace (short interactive prompts +
prompts at the largest bucket) replayed through monolithic bucketed
prefill vs chunked prefill (``chunked="always"``), reporting the p95
scheduler-tick latency both ways — the head-of-line stall a monolithic
prefill inflicts on in-flight decodes, removed — plus a long-prompt trace
(prompts past the largest bucket) that only the chunked scheduler can
serve at all.

The **kv-quant** variant (``serve_bench_kv_quant`` / ``--kv-quant``) is the
ISSUE 9 A/B: one deterministic trace through the paged scheduler with dense
f32 pages vs log2-quantized pages (``kv_quant=True``), both on the fused
Pallas kernel — tok/s and TTFT head to head, the per-request token
divergence rate, and the EXACT-gated static pool-byte model (>= 2x fewer
pool bytes per request at 4-bit, tail ring included).

The **sharded** variant (``serve_bench_sharded`` / ``--sharded``) replays
the same trace through a mesh-native scheduler (``mesh='2x2'`` data x model
by default) in a SUBPROCESS with forced host devices — the parent process
keeps its single real device — and asserts token parity against the
single-device scheduler before reporting throughput; it also runs a
chunked-``"auto"`` parity pass with over-bucket prompts.

  PYTHONPATH=src python -m benchmarks.serve_bench            # full bench
  PYTHONPATH=src python -m benchmarks.serve_bench --chunked  # ISSUE 4 A/B
  PYTHONPATH=src python -m benchmarks.serve_bench --dry      # CI smoke
  PYTHONPATH=src python -m benchmarks.serve_bench --sharded  # mesh variant
  PYTHONPATH=src python -m benchmarks.run --only serve       # via driver

Rows print as ``serve.<name>,<value>,`` CSV like every other bench; each
bench pass additionally emits ONE machine-readable ``# json {...}`` line
(ignored by the CSV consumers) carrying the summary metrics and the
per-request records (rid, prompt_len, ttft_s, e2e_s, finish_reason) — the
artifact downstream dashboards ingest, smoke-validated in ``--dry`` CI.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import subprocess
import sys
import time
from typing import List, Tuple

import numpy as np

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _make_trace(rng, n_requests: int, vocab: int, min_len: int, max_len: int,
                rate: float) -> List[Tuple[float, np.ndarray]]:
    """Poisson arrivals (exponential gaps at ``rate`` req/s; ``rate=0`` =
    everything queued at t=0) with uniform prompt lengths."""
    arrivals, t = [], 0.0
    for _ in range(n_requests):
        if rate > 0:
            t += float(rng.exponential(1.0 / rate))
        prompt = rng.integers(0, vocab,
                              size=int(rng.integers(min_len, max_len + 1)),
                              ).astype(np.int32)
        arrivals.append((t, prompt))
    return arrivals


def _run_scheduler(sched, trace, max_new: int, eos_id=None):
    """Replay the trace in wall-clock time (fast-forwarding idle gaps);
    returns (results-so-far in rid order, elapsed_busy_seconds,
    per-step_tick wall seconds).  Every tick syncs tokens to host, so the
    clock reads true device-done time; a tick's duration includes the
    admissions it performed — monolithic prefill stalls show up HERE."""
    pending = list(trace)
    t0 = time.perf_counter()
    idle = 0.0
    tick_times: List[float] = []
    while pending or sched.pending:
        now = time.perf_counter() - t0 - idle
        while pending and pending[0][0] <= now:
            _, prompt = pending.pop(0)
            sched.submit(prompt, max_new=max_new, eos_id=eos_id)
        if sched.pending:
            tt = time.perf_counter()
            sched.step_tick()
            tick_times.append(time.perf_counter() - tt)
        elif pending:
            # fast-forward an empty system to the next arrival: idle time is
            # not "sustained load" and is excluded from the throughput
            idle += pending[0][0] - now
    return (sched.run(max_ticks=0), time.perf_counter() - t0 - idle,
            tick_times)


def _pct(xs, q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else float("nan")


def _request_records(results):
    """Per-request latency records from the scheduler's result timestamps
    (one time.perf_counter clock): ttft = queue wait + prefill up to the
    first generated token; e2e = submit -> retirement."""
    recs = []
    for r in results:
        recs.append({
            "rid": r.rid, "prompt_len": r.prompt_len,
            "finish_reason": r.finish_reason,
            "ttft_s": (r.first_token_time - r.submit_time
                       if np.isfinite(r.first_token_time) else float("nan")),
            "e2e_s": (r.finish_time - r.submit_time
                      if np.isfinite(r.finish_time) else float("nan")),
        })
    return recs


def _latency_rows(prefix: str, results, tick_times):
    """TTFT / end-to-end p50+p95 (ms) over SERVED requests (a rejected
    request's ~0 s turnaround is finite and would deflate the e2e
    percentiles) + p50/p95 per-scheduler-tick latency (ms) — the satellite
    metrics next to tok/s."""
    recs = _request_records(results)
    served = [x for x in recs if x["finish_reason"] != "rejected"]
    ttft = [x["ttft_s"] for x in served if np.isfinite(x["ttft_s"])]
    e2e = [x["e2e_s"] for x in served if np.isfinite(x["e2e_s"])]
    nan = float("nan")
    return [
        (f"{prefix}.ttft_p50_ms", _pct(ttft, 50) * 1e3, nan),
        (f"{prefix}.ttft_p95_ms", _pct(ttft, 95) * 1e3, nan),
        (f"{prefix}.e2e_p50_ms", _pct(e2e, 50) * 1e3, nan),
        (f"{prefix}.e2e_p95_ms", _pct(e2e, 95) * 1e3, nan),
        (f"{prefix}.tick_p50_ms", _pct(tick_times, 50) * 1e3, nan),
        (f"{prefix}.tick_p95_ms", _pct(tick_times, 95) * 1e3, nan),
    ], recs


def _emit_json(bench: str, rows, recs=None) -> None:
    """One machine-readable summary line per bench pass (CSV consumers skip
    ``#`` lines).  json.dumps doubles as the serializability check that
    ``--dry`` CI exercises."""
    obj = {"bench": bench,
           "rows": {name: (None if isinstance(val, float) and np.isnan(val)
                           else float(val)) for name, val, _ in rows}}
    if recs is not None:
        obj["per_request"] = [
            {k: (None if isinstance(v, float) and np.isnan(v) else v)
             for k, v in r.items()} for r in recs]
    print("# json " + json.dumps(obj))


def _warm_trace(rng, buckets, vocab) -> List[Tuple[float, np.ndarray]]:
    """One request per bucket at t=0 — compiles every prefill variant plus
    the tick program before anything is timed."""
    return [(0.0, rng.integers(0, vocab, size=b).astype(np.int32))
            for b in buckets]


def serve_bench(arch: str = "smollm_135m", n_requests: int = 24,
                max_slots: int = 8, tick_steps: int = 8, max_new: int = 24,
                rate: float = 0.0, seed: int = 0,
                buckets: Tuple[int, ...] = (8, 16, 32)):
    """Returns rows (name, value, reference-nan) for benchmarks.run."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke
    from repro.models import init_params
    from repro.models.quantize import quantize_model_params
    from repro.serving import engine
    from repro.serving.config import ServeConfig
    from repro.serving.scheduler import ServeScheduler, bucket_for

    cfg = get_smoke(arch).replace(dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    pool_len = max(buckets) + max_new + tick_steps
    trace = _make_trace(rng, n_requests, cfg.vocab_size,
                        min_len=4, max_len=max(buckets), rate=rate)
    total_tokens = n_requests * max_new
    nan = float("nan")
    rows = []

    # --- naive serial baseline: fused generate, one request at a time ------
    key = jax.random.PRNGKey(0)

    def serial_pass():
        for _, prompt in trace:
            b = bucket_for(prompt.size, buckets)
            padded = np.zeros((1, b), np.int32)
            padded[0, :prompt.size] = prompt
            fn = engine.generate_fn(cfg, max_new, 0.0, False, None, False)
            jax.block_until_ready(fn(params, jnp.asarray(padded), key)[0])

    serial_pass()                                    # warm every bucket
    t0 = time.perf_counter()
    serial_pass()
    t_serial = time.perf_counter() - t0
    rows.append((f"serve.{cfg.name}.serial_tok_s",
                 total_tokens / t_serial, nan))

    # --- continuous-batching scheduler, float ------------------------------
    sched = ServeScheduler(cfg, params,
                           ServeConfig(max_slots=max_slots, max_len=pool_len,
                                       buckets=buckets,
                                       tick_steps=tick_steps))
    _run_scheduler(sched, _warm_trace(rng, buckets, cfg.vocab_size), max_new)
    results, t_sched, ticks = _run_scheduler(sched, trace, max_new)
    got = sum(len(r.tokens) for r in results[-n_requests:])
    assert got == total_tokens, (got, total_tokens)
    rows.append((f"serve.{cfg.name}.sched_tok_s",
                 total_tokens / t_sched, nan))
    rows.append((f"serve.{cfg.name}.sched_vs_serial_speedup",
                 t_serial / t_sched, nan))
    lat_rows, recs = _latency_rows(f"serve.{cfg.name}.sched",
                                   results[-n_requests:], ticks)
    rows += lat_rows

    # --- quantized pass with per-request traffic stats ---------------------
    qparams = quantize_model_params(cfg, params)
    qsched = ServeScheduler(cfg, qparams,
                            ServeConfig(max_slots=max_slots,
                                        max_len=pool_len, buckets=buckets,
                                        quant="xla", with_stats=True,
                                        tick_steps=tick_steps))
    _run_scheduler(qsched, _warm_trace(rng, buckets, cfg.vocab_size),
                   max_new)
    qresults, t_q, _ = _run_scheduler(qsched, trace, max_new)
    qresults = qresults[-n_requests:]
    rows.append((f"serve.{cfg.name}.quant.sched_tok_s",
                 total_tokens / t_q, nan))
    rows.append((f"serve.{cfg.name}.quant.plane_traffic_fraction_tile",
                 float(np.mean([r.plane_traffic_fraction
                                for r in qresults])), nan))
    rows.append((f"serve.{cfg.name}.quant.plane_traffic_fraction_element",
                 float(np.mean([r.element_traffic_fraction
                                for r in qresults])), nan))
    _emit_json("serve", rows, recs)
    return rows


def serve_bench_chunked(arch: str = "smollm_135m", n_requests: int = 24,
                        max_slots: int = 8, tick_steps: int = 8,
                        max_new: int = 16, seed: int = 0,
                        buckets: Tuple[int, ...] = (8, 16, 32)):
    """ISSUE 4 A/B: heavy mixed traffic (half short interactive prompts,
    half at the largest bucket) through monolithic bucketed prefill vs
    chunked prefill, p95 scheduler-tick latency head to head — then a
    long-prompt trace (up to 3x the largest bucket) that monolithic prefill
    would reject outright, served chunked, with TTFT/e2e percentiles."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke
    from repro.models import init_params
    from repro.serving.config import ServeConfig
    from repro.serving.scheduler import ServeScheduler, round_pool_len

    cfg = get_smoke(arch).replace(dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    chunk_len = buckets[0]
    long_max = 3 * max(buckets)
    pool_len = round_pool_len(long_max + max_new + tick_steps, chunk_len)
    nan = float("nan")
    rows = []

    # --- A/B: same in-bucket heavy-mix trace, monolithic vs chunked --------
    # (in-bucket so BOTH sides can serve it; half the prompts sit at the
    # largest bucket — each monolithic admission stalls every decode slot
    # for a full bucket prefill, the chunked side ingests chunk_len/tick)
    mix = []
    for i in range(n_requests):
        n = (max(buckets) if i % 2 == 0
             else int(rng.integers(4, buckets[0] + 1)))
        mix.append((0.0, rng.integers(0, cfg.vocab_size,
                                      size=n).astype(np.int32)))
    warm = _warm_trace(rng, buckets, cfg.vocab_size)
    p95 = {}
    for label, kw in (("mono", {}), ("chunked", {"chunked": "always"})):
        sched = ServeScheduler(cfg, params,
                               ServeConfig(max_slots=max_slots,
                                           max_len=pool_len, buckets=buckets,
                                           tick_steps=tick_steps, **kw))
        _run_scheduler(sched, warm, max_new)
        results, t, ticks = _run_scheduler(sched, mix, max_new)
        results = results[-n_requests:]
        total = sum(len(r.tokens) for r in results)
        rows.append((f"serve.{cfg.name}.chunk_ab[{label}].tok_s",
                     total / t, nan))
        lat, _ = _latency_rows(f"serve.{cfg.name}.chunk_ab[{label}]",
                               results, ticks)
        rows += lat
        p95[label] = _pct(ticks, 95)
    rows.append((f"serve.{cfg.name}.chunk_ab.p95_tick_speedup",
                 p95["mono"] / p95["chunked"], nan))

    # --- long prompts: beyond every bucket, serveable only chunked ---------
    longs = [(0.0, rng.integers(0, cfg.vocab_size,
                                size=int(rng.integers(max(buckets) + 1,
                                                      long_max + 1)),
                                ).astype(np.int32))
             for _ in range(max(2, n_requests // 3))]
    sched = ServeScheduler(cfg, params,
                           ServeConfig(max_slots=max_slots, max_len=pool_len,
                                       buckets=buckets,
                                       tick_steps=tick_steps,
                                       chunked="auto"))
    _run_scheduler(sched, warm + longs[:1], max_new)
    results, t, ticks = _run_scheduler(sched, longs, max_new)
    results = results[-len(longs):]
    served = [r for r in results if r.finish_reason == "length"]
    assert len(served) == len(longs), \
        [r.finish_reason for r in results]      # no rejections: the point
    rows.append((f"serve.{cfg.name}.long.served_frac",
                 len(served) / len(longs), nan))
    rows.append((f"serve.{cfg.name}.long.tok_s",
                 sum(len(r.tokens) for r in served) / t, nan))
    lat, recs = _latency_rows(f"serve.{cfg.name}.long", results, ticks)
    rows += lat
    _emit_json("serve_chunked", rows, recs)
    return rows


def serve_bench_prefix(arch: str = "smollm_135m", n_requests: int = 24,
                       max_slots: int = 4, tick_steps: int = 8,
                       max_new: int = 16, seed: int = 0,
                       prefix_len: int = 48, page_len: int = 16,
                       buckets: Tuple[int, ...] = (16, 64)):
    """ISSUE 5 ``--prefix-trace``: a shared-system-prompt workload — every
    request is one long common prefix plus a short unique tail — replayed
    through the dense ServeScheduler and the paged+prefix-cache scheduler.

    Reports the prefix hit rate, the fraction of prefill cache-write
    traffic the radix cache eliminated (each cached token skips its
    per-layer K/V writes AND its prefill compute — the serving-level image
    of the paper's §VI avoided memory accesses), and TTFT p50/p95 head to
    head.  The first ``max_slots`` admissions necessarily miss (the donor
    retires before its pages become shareable); every later admission hits.
    """
    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke
    from repro.models import init_params
    from repro.serving.config import ServeConfig
    from repro.serving.scheduler import ServeScheduler, round_pool_len

    cfg = get_smoke(arch).replace(dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, size=prefix_len).astype(np.int32)
    trace = []
    for _ in range(n_requests):
        tail = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(4, 13))).astype(np.int32)
        trace.append((0.0, np.concatenate([prefix, tail])))
    pool_len = round_pool_len(prefix_len + 16 + max_new + tick_steps,
                              page_len)
    nan = float("nan")
    rows = []
    ttft95 = {}
    for label, kw in (("dense", {}),
                      ("paged", dict(paged=True, page_len=page_len,
                                     prefix_cache=True, chunked="auto",
                                     chunk_len=page_len))):
        sched = ServeScheduler(cfg, params,
                               ServeConfig(max_slots=max_slots,
                                           max_len=pool_len, buckets=buckets,
                                           tick_steps=tick_steps, **kw))
        _run_scheduler(sched, _warm_trace(rng, buckets, cfg.vocab_size),
                       max_new)
        if label == "paged":
            # warm the HIT-path programs too (suffix chunk ingestion, the
            # mixed chunk+decode tick, prefix-hit admission, partial-block
            # COW): a throwaway shared-prefix family — its prefix differs
            # from the timed trace's, so the timed hit accounting is clean.
            # Sequential waves: the donor must RETIRE before a lookup can
            # hit its pages.
            wp = rng.integers(0, cfg.vocab_size,
                              size=2 * page_len + 3).astype(np.int32)
            tails = [rng.integers(0, cfg.vocab_size,
                                  size=4).astype(np.int32) for _ in range(3)]
            _run_scheduler(sched,
                           [(0.0, np.concatenate([wp, tails[0]]))], max_new)
            _run_scheduler(sched,
                           [(0.0, np.concatenate([wp, tails[1]]))], max_new)
            _run_scheduler(sched,
                           [(0.0, np.concatenate([wp, tails[2]])),
                            (0.0, rng.integers(0, cfg.vocab_size,
                                               size=8).astype(np.int32))],
                           max_new)
            sched.reset_prefix_stats()
        results, t, ticks = _run_scheduler(sched, trace, max_new)
        results = results[-n_requests:]
        total = sum(len(r.tokens) for r in results)
        assert total == n_requests * max_new, (total, n_requests * max_new)
        rows.append((f"serve.{cfg.name}.prefix[{label}].tok_s",
                     total / t, nan))
        lat, recs = _latency_rows(f"serve.{cfg.name}.prefix[{label}]",
                                  results, ticks)
        rows += lat
        ttft95[label] = next(v for n, v, _ in lat if "ttft_p95" in n)
        if label == "paged":
            st = sched.prefix_cache_stats()
            rows.append((f"serve.{cfg.name}.prefix.hit_rate",
                         st["hit_rate"], nan))
            rows.append((f"serve.{cfg.name}.prefix.cache_write_saved_frac",
                         st["cache_write_saved_frac"], nan))
            rows.append((f"serve.{cfg.name}.prefix.lookup_hits",
                         st["lookup_hits"], nan))
    rows.append((f"serve.{cfg.name}.prefix.ttft_p95_speedup",
                 ttft95["dense"] / ttft95["paged"], nan))
    _emit_json("serve_paged", rows, recs)
    return rows


def serve_bench_kv_quant(arch: str = "smollm_135m", n_requests: int = 16,
                         max_slots: int = 4, tick_steps: int = 4,
                         max_new: int = 16, seed: int = 0,
                         page_len: int = 4, kv_bits: int = 4,
                         min_prompt: int = 32,
                         buckets: Tuple[int, ...] = (16, 32, 48)):
    """ISSUE 9 ``--kv-quant``: the same deterministic trace through the
    paged scheduler dense vs log2-quantized (``kv_quant=True``), both on
    the fused Pallas paged-attention kernel.

    Reports tok/s + TTFT/e2e percentiles head to head (advisory), the
    per-request token divergence (``token_bit_equal_frac`` — EXACT-gated:
    given the committed seed the quantized stream is deterministic, so any
    drift is a behavior change), and the static byte model (EXACT-gated,
    pure arithmetic from ``kvpool.page_kv_bytes`` / ``tail_ring_bytes``,
    not measurement): pool bytes per request with the quant side charged
    its full f32 tail-ring working set, the pool-write traffic a completed
    page costs (codes + scale vs f32 rows — the §VI cache-write image),
    and the pool-bytes reduction, asserted >= 2x at 4-bit."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke
    from repro.models import init_params
    from repro.serving.config import ServeConfig
    from repro.serving.kvpool import (blocks_for_tokens, page_kv_bytes,
                                      tail_ring_bytes)
    from repro.serving.scheduler import ServeScheduler, round_pool_len

    cfg = get_smoke(arch).replace(dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    trace = _make_trace(rng, n_requests, cfg.vocab_size,
                        min_len=min_prompt, max_len=max(buckets), rate=0.0)
    pool_len = round_pool_len(max(buckets) + max_new + tick_steps, page_len)
    nan = float("nan")
    rows = []
    tokens = {}
    tok_s = {}
    for label, kw in (("dense", {}),
                      ("quant", dict(kv_quant=True, kv_bits=kv_bits))):
        sched = ServeScheduler(cfg, params,
                               ServeConfig(max_slots=max_slots,
                                           max_len=pool_len, buckets=buckets,
                                           tick_steps=tick_steps, paged=True,
                                           page_len=page_len,
                                           attn_kernel=True,
                                           attn_splits=2, **kw))
        _run_scheduler(sched, _warm_trace(rng, buckets, cfg.vocab_size),
                       max_new)
        results, t, ticks = _run_scheduler(sched, trace, max_new)
        results = results[-n_requests:]
        total = sum(len(r.tokens) for r in results)
        assert total == n_requests * max_new, (total, n_requests * max_new)
        tokens[label] = [r.tokens for r in results]
        tok_s[label] = total / t
        rows.append((f"serve.{cfg.name}.kvq[{label}].tok_s",
                     total / t, nan))
        lat, recs = _latency_rows(f"serve.{cfg.name}.kvq[{label}]",
                                  results, ticks)
        rows += lat
    rows.append((f"serve.{cfg.name}.kvq.quant_vs_dense_tok_s_ratio",
                 tok_s["quant"] / tok_s["dense"], nan))
    equal = [int(a == b) for a, b in zip(tokens["dense"], tokens["quant"])]
    rows.append((f"serve.{cfg.name}.kvq.token_bit_equal_frac",
                 sum(equal) / n_requests, nan))

    # --- static byte model (pure arithmetic; both sides hold the same page
    # count, so page_len cancels out of the saved fractions) ---------------
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    n_attn = cfg.repeats * sum(1 for k in cfg.pattern
                               if k.split("_")[0] != "mamba")
    pages = sum(blocks_for_tokens(p.size + max_new, page_len)
                for _, p in trace)
    dense_pool = pages * page_kv_bytes(page_len, kv, hd, layers=n_attn)
    quant_pool = (pages * page_kv_bytes(page_len, kv, hd, layers=n_attn,
                                        quant=True, kv_bits=kv_bits)
                  + max_slots * tail_ring_bytes(page_len, kv, hd,
                                                layers=n_attn))
    # pool-WRITE traffic: dense writes every token row in f32; quant writes
    # each completed page once as codes + one scale per (page, head).  The
    # per-token tail-ring writes land in the small per-slot ring, not the
    # pool — its full footprint is already charged to quant_pool above.
    dense_write = dense_pool
    quant_write = pages * page_kv_bytes(page_len, kv, hd, layers=n_attn,
                                        quant=True, kv_bits=kv_bits)
    rows.append((f"serve.{cfg.name}.kvq[dense].pool_bytes_per_request",
                 dense_pool / n_requests, nan))
    rows.append((f"serve.{cfg.name}.kvq[quant].pool_bytes_per_request",
                 quant_pool / n_requests, nan))
    rows.append((f"serve.{cfg.name}.kvq.pool_bytes_saved_frac",
                 1.0 - quant_pool / dense_pool, nan))
    rows.append((f"serve.{cfg.name}.kvq.pool_write_saved_frac",
                 1.0 - quant_write / dense_write, nan))
    reduction = dense_pool / quant_pool
    # the ISSUE 9 acceptance bar: >= 2x fewer pool bytes per request on the
    # int8 wire format (2..7 exponent bits); 8-bit codes widen to int16 and
    # land near 1.7x, a documented trade, not a regression
    if kv_bits < 8:
        assert reduction >= 2.0, (reduction, dense_pool, quant_pool)
    rows.append((f"serve.{cfg.name}.kvq.pool_bytes_reduction_x",
                 reduction, nan))
    rows.append((f"serve.{cfg.name}.kvq.tail_ring_bytes_per_slot",
                 float(tail_ring_bytes(page_len, kv, hd, layers=n_attn)),
                 nan))
    _emit_json("kv_quant", rows, recs)
    return rows


def _sharded_child(arch: str, n_requests: int, max_slots: int,
                   tick_steps: int, max_new: int, seed: int,
                   buckets: Tuple[int, ...], mesh_spec: str):
    """Runs INSIDE the forced-multi-device subprocess: single-device vs
    mesh-sharded scheduler over the same trace — parity asserted, both
    throughputs reported."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke
    from repro.launch.mesh import make_serve_mesh
    from repro.models import init_params

    cfg = get_smoke(arch).replace(dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    pool_len = max(buckets) + max_new + tick_steps
    trace = _make_trace(rng, n_requests, cfg.vocab_size,
                        min_len=4, max_len=max(buckets), rate=0.0)
    rows = []
    tokens = {}
    chunk_tokens = {}
    # over-bucket prompts for the chunked parity leg (monolithic rejects
    # them; the chunked scheduler must serve them identically on a mesh)
    chunk_trace = trace[: max(2, n_requests // 4)] + [
        (0.0, rng.integers(0, cfg.vocab_size,
                           size=2 * max(buckets)).astype(np.int32))]
    from repro.serving.scheduler import round_pool_len
    chunk_pool = round_pool_len(2 * max(buckets) + max_new + tick_steps,
                                buckets[0])
    for label, mesh in (("single", None),
                        (mesh_spec, make_serve_mesh(mesh_spec))):
        from repro.serving.config import ServeConfig
        from repro.serving.scheduler import ServeScheduler
        sched = ServeScheduler(cfg, params,
                               ServeConfig(max_slots=max_slots,
                                           max_len=pool_len, buckets=buckets,
                                           tick_steps=tick_steps),
                               mesh=mesh)
        _run_scheduler(sched, _warm_trace(rng, buckets, cfg.vocab_size),
                       max_new)
        results, t, _ = _run_scheduler(sched, trace, max_new)
        tokens[label] = [r.tokens for r in results[-n_requests:]]
        rows.append((f"serve.{cfg.name}.sharded[{label}].tok_s",
                     n_requests * max_new / t, float("nan")))
        csched = ServeScheduler(cfg, params,
                                ServeConfig(max_slots=max_slots,
                                            max_len=chunk_pool,
                                            buckets=buckets,
                                            tick_steps=tick_steps,
                                            chunked="auto"),
                                mesh=mesh)
        cresults, _, _ = _run_scheduler(csched, chunk_trace, max_new)
        assert all(r.finish_reason == "length" for r in cresults), cresults
        chunk_tokens[label] = [r.tokens for r in cresults]
    assert tokens["single"] == tokens[mesh_spec], \
        "sharded scheduler tokens diverged from single-device"
    rows.append((f"serve.{cfg.name}.sharded[{mesh_spec}].bit_equal",
                 1.0, float("nan")))
    assert chunk_tokens["single"] == chunk_tokens[mesh_spec], \
        "sharded CHUNKED scheduler tokens diverged from single-device"
    rows.append((f"serve.{cfg.name}.sharded[{mesh_spec}].chunked_bit_equal",
                 1.0, float("nan")))
    return rows


def serve_bench_sharded(arch: str = "smollm_135m", n_requests: int = 16,
                        max_slots: int = 8, tick_steps: int = 8,
                        max_new: int = 16, seed: int = 0,
                        buckets: Tuple[int, ...] = (8, 16, 32),
                        mesh_spec: str = "2x2", devices: int = 4):
    """Mesh-sharded serve bench: spawns a subprocess with ``devices`` forced
    host devices (the calling process' jax stays single-device) and parses
    its CSV rows.  Registered in ``benchmarks.run`` as ``serve_sharded``."""
    args = ["--child-sharded", "--arch", arch,
            "--requests", str(n_requests), "--max-slots", str(max_slots),
            "--tick-steps", str(tick_steps), "--new-tokens", str(max_new),
            "--seed", str(seed), "--mesh", mesh_spec,
            "--buckets", ",".join(str(b) for b in buckets)]
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
        PYTHONPATH=os.pathsep.join(
            [_REPO, os.path.join(_REPO, "src"),
             os.environ.get("PYTHONPATH", "")]).rstrip(os.pathsep))
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.serve_bench"] + args,
        capture_output=True, text=True, timeout=1200, env=env, cwd=_REPO)
    if out.returncode != 0:
        raise RuntimeError(f"sharded serve bench child failed:\n"
                           f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}")
    rows = []
    for line in out.stdout.splitlines():
        if line.startswith("serve."):
            name, val, _ = line.split(",")
            rows.append((name, float(val), float("nan")))
    if not rows:
        raise RuntimeError(f"sharded serve bench child produced no rows:\n"
                           f"{out.stdout}")
    # the bit_equal / chunked_bit_equal rows are correctness metrics the
    # bench-drift gate (tools/bench_check.py) checks exactly
    _emit_json("serve_sharded", rows)
    return rows


def serve_bench_disagg(arch: str = "smollm_135m", n_short: int = 12,
                       n_long: int = 4, max_slots: int = 4,
                       tick_steps: int = 4, max_new: int = 16,
                       seed: int = 0, page_len: int = 8,
                       buckets: Tuple[int, ...] = (8, 16)):
    """ISSUE 10 A/B: decode saturation + prefill flood, combined scheduler
    vs the disaggregated prefill/decode router (``serving/router.py``) on
    the SAME paged config and trace.

    The trace is ``n_short`` short interactive prompts (they keep the
    decode slots saturated) interleaved with ``n_long`` long prompts at 3x
    the largest bucket (each floods prefill with chunked ingestion).  In
    the combined scheduler every long prompt's chunk rides the same jitted
    mixed tick as the in-flight decodes — the per-tick latency the decode
    traffic observes inflates.  The router runs the same ingestion on the
    PREFILL engine; the decode fleet's ticks are pure decode by
    construction.  Reported: token parity (EXACT-gated — the disaggregated
    stream must be bit-equal to the combined scheduler), tok/s both ways
    (advisory), p95 tick latency both ways, and the isolation ratio
    (combined p95 tick / decode-fleet p95 tick, advisory) — the acceptance
    claim that a prefill flood does not regress decode tick latency."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke
    from repro.models import init_params
    from repro.serving.config import ServeConfig
    from repro.serving.router import Router
    from repro.serving.scheduler import ServeScheduler, round_pool_len

    cfg = get_smoke(arch).replace(dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    chunk_len = buckets[0]
    long_max = 3 * max(buckets)
    quantum = math.lcm(chunk_len, page_len)
    config = ServeConfig(max_slots=max_slots,
                         max_len=round_pool_len(
                             long_max + max_new + tick_steps, quantum),
                         buckets=buckets, tick_steps=tick_steps,
                         chunked="auto", chunk_len=chunk_len,
                         paged=True, page_len=page_len)
    # interleave: every (short, short, long) group keeps decodes live
    # while a long prompt floods prefill
    trace, li = [], 0
    for i in range(n_short + n_long):
        if n_long and i % ((n_short + n_long) // n_long) == 2 and li < n_long:
            n = long_max
            li += 1
        else:
            n = int(rng.integers(4, max(buckets) + 1))
        trace.append((0.0, rng.integers(0, cfg.vocab_size,
                                        size=n).astype(np.int32)))
    warm = _warm_trace(rng, buckets, cfg.vocab_size) + [
        (0.0, rng.integers(0, cfg.vocab_size,
                           size=long_max).astype(np.int32))]
    nan = float("nan")
    rows = []

    # --- combined: chunk ingestion and decode share every tick ------------
    sched = ServeScheduler(cfg, params, config)
    _run_scheduler(sched, warm, max_new)
    results, t_comb, comb_ticks = _run_scheduler(sched, trace, max_new)
    results = results[-len(trace):]
    total = sum(len(r.tokens) for r in results)
    rows.append((f"serve.{cfg.name}.disagg[combined].tok_s",
                 total / t_comb, nan))
    rows.append((f"serve.{cfg.name}.disagg[combined].tick_p95_ms",
                 _pct(comb_ticks, 95) * 1e3, nan))

    # --- disaggregated: same config through the router --------------------
    router = Router(cfg, params, config)
    for _, prompt in warm:
        router.submit(prompt, max_new=max_new)
    router.run()
    router.decode_tick_times.clear()
    for _, prompt in trace:
        router.submit(prompt, max_new=max_new)
    t0 = time.perf_counter()
    dresults = router.run()
    t_dis = time.perf_counter() - t0
    dtotal = sum(len(r.tokens) for r in dresults)
    rows.append((f"serve.{cfg.name}.disagg[router].tok_s",
                 dtotal / t_dis, nan))
    rows.append((f"serve.{cfg.name}.disagg[decode].tick_p95_ms",
                 _pct(router.decode_tick_times, 95) * 1e3, nan))

    # token parity: the disaggregated stream must be bit-equal (EXACT gate)
    equal = (len(results) == len(dresults) and all(
        a.tokens == b.tokens and a.finish_reason == b.finish_reason
        for a, b in zip(results, dresults)))
    rows.append((f"serve.{cfg.name}.disagg.tokens_bit_equal",
                 float(equal), nan))
    assert equal, "disaggregated tokens diverged from combined scheduler"
    # the TTFT-isolation claim: decode-fleet ticks don't pay for prefill
    rows.append((f"serve.{cfg.name}.disagg.isolation_p95_speedup",
                 _pct(comb_ticks, 95) / _pct(router.decode_tick_times, 95),
                 nan))
    lat, recs = _latency_rows(f"serve.{cfg.name}.disagg[router]",
                              dresults, router.decode_tick_times)
    rows += lat
    _emit_json("serve_disagg", rows, recs)
    return rows


ALL_SERVE_BENCHES = {"serve": serve_bench,
                     "serve_chunked": serve_bench_chunked,
                     "serve_paged": serve_bench_prefix,
                     "serve_kv_quant": serve_bench_kv_quant,
                     "serve_sharded": serve_bench_sharded,
                     "serve_disagg": serve_bench_disagg}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-slots", type=int, default=8)
    ap.add_argument("--tick-steps", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate in req/s (0 = all queued "
                         "at t=0, the sustained-load trace)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dry", action="store_true",
                    help="CI smoke: tiny trace, checks wiring + that the "
                         "scheduler runs end-to-end (single-device, chunked "
                         "A/B + long prompts, AND a 2x2 sharded pass incl. "
                         "chunked parity), and validates the # json rows")
    ap.add_argument("--chunked", action="store_true",
                    help="run the chunked-prefill A/B (monolithic vs "
                         "chunked p95 tick latency + long-prompt trace)")
    ap.add_argument("--prefix-trace", action="store_true",
                    help="run the shared-system-prompt workload through the "
                         "dense vs paged+prefix-cache schedulers (hit rate, "
                         "cache-write traffic saved, TTFT p50/p95 A/B)")
    ap.add_argument("--prefix-len", type=int, default=48,
                    help="shared prefix length for --prefix-trace")
    ap.add_argument("--page-len", type=int, default=16,
                    help="KV page size for --prefix-trace")
    ap.add_argument("--kv-quant", action="store_true",
                    help="run the log2-quantized KV-page A/B (dense-paged "
                         "vs kv_quant scheduler: tok/s, TTFT, token "
                         "divergence, EXACT-gated pool-byte savings)")
    ap.add_argument("--kv-bits", type=int, default=4,
                    help="wire exponent bits for --kv-quant")
    ap.add_argument("--disaggregated", action="store_true",
                    help="run the disaggregated A/B (combined scheduler vs "
                         "prefill/decode router: token parity EXACT, decode "
                         "tick-latency isolation under prefill flood)")
    ap.add_argument("--sharded", action="store_true",
                    help="run the mesh-sharded variant (subprocess with "
                         "forced host devices)")
    ap.add_argument("--mesh", default="2x2",
                    help="DxM mesh spec for the sharded variant")
    ap.add_argument("--devices", type=int, default=4,
                    help="forced host device count for the sharded variant")
    ap.add_argument("--buckets", default=None,
                    help="comma-separated prefill buckets (child mode)")
    ap.add_argument("--child-sharded", action="store_true",
                    help=argparse.SUPPRESS)   # internal: runs inside the
                                              # forced-device subprocess
    args = ap.parse_args(argv)

    buckets = (tuple(int(b) for b in args.buckets.split(","))
               if args.buckets else (8, 16, 32))
    if args.child_sharded:
        rows = _sharded_child(args.arch, args.requests, args.max_slots,
                              args.tick_steps, args.new_tokens, args.seed,
                              buckets, args.mesh)
    elif args.dry and args.disaggregated:
        # the multidevice-CI smoke: ONLY the tiny disaggregated A/B (the
        # full --dry suite runs it too, alongside everything else)
        rows = serve_bench_disagg(args.arch, n_short=4, n_long=2,
                                  max_slots=2, tick_steps=2, max_new=4,
                                  seed=args.seed, page_len=8,
                                  buckets=(8, 16))
        names = [n for n, _, _ in rows]
        for want in ("disagg.tokens_bit_equal",
                     "disagg.isolation_p95_speedup"):
            assert any(want in n for n in names), (want, names)
    elif args.dry:
        rows = serve_bench(args.arch, n_requests=4, max_slots=2,
                           tick_steps=2, max_new=4, rate=args.rate,
                           seed=args.seed, buckets=(8, 16))
        rows += serve_bench_chunked(args.arch, n_requests=4, max_slots=2,
                                    tick_steps=2, max_new=4, seed=args.seed,
                                    buckets=(8, 16))
        rows += serve_bench_prefix(args.arch, n_requests=6, max_slots=2,
                                   tick_steps=2, max_new=4, seed=args.seed,
                                   prefix_len=16, page_len=8,
                                   buckets=(8, 32))
        rows += serve_bench_kv_quant(args.arch, n_requests=6, max_slots=2,
                                     tick_steps=2, max_new=4, seed=args.seed,
                                     page_len=4, kv_bits=4, min_prompt=12,
                                     buckets=(8, 16))
        rows += serve_bench_sharded(args.arch, n_requests=4, max_slots=2,
                                    tick_steps=2, max_new=4, seed=args.seed,
                                    buckets=(8, 16), mesh_spec=args.mesh,
                                    devices=args.devices)
        rows += serve_bench_disagg(args.arch, n_short=4, n_long=2,
                                   max_slots=2, tick_steps=2, max_new=4,
                                   seed=args.seed, page_len=8,
                                   buckets=(8, 16))
        # the --dry contract: the latency satellites exist in the emitted
        # rows (CI drift check for the TTFT/p95 reporting)
        names = [n for n, _, _ in rows]
        for want in ("ttft_p50_ms", "ttft_p95_ms", "e2e_p50_ms",
                     "e2e_p95_ms", "tick_p95_ms", "p95_tick_speedup",
                     "long.served_frac", "chunked_bit_equal",
                     "prefix.hit_rate", "prefix.cache_write_saved_frac",
                     "kvq.token_bit_equal_frac", "kvq.pool_bytes_saved_frac",
                     "kvq.pool_bytes_reduction_x",
                     "disagg.tokens_bit_equal",
                     "disagg.isolation_p95_speedup"):
            assert any(want in n for n in names), (want, names)
        # prefix-cache smoke: the shared-prefix trace must actually HIT
        hits = [v for n, v, _ in rows if n.endswith("prefix.lookup_hits")]
        assert hits and hits[0] > 0, rows
    elif args.chunked:
        rows = serve_bench_chunked(args.arch, n_requests=args.requests,
                                   max_slots=args.max_slots,
                                   tick_steps=args.tick_steps,
                                   max_new=args.new_tokens, seed=args.seed,
                                   buckets=buckets)
    elif args.prefix_trace:
        rows = serve_bench_prefix(args.arch, n_requests=args.requests,
                                  max_slots=args.max_slots,
                                  tick_steps=args.tick_steps,
                                  max_new=args.new_tokens, seed=args.seed,
                                  prefix_len=args.prefix_len,
                                  page_len=args.page_len)
    elif args.kv_quant:
        rows = serve_bench_kv_quant(args.arch, n_requests=args.requests,
                                    max_slots=args.max_slots,
                                    tick_steps=args.tick_steps,
                                    max_new=args.new_tokens, seed=args.seed,
                                    kv_bits=args.kv_bits)
    elif args.disaggregated:
        rows = serve_bench_disagg(args.arch,
                                  n_short=max(2, args.requests * 3 // 4),
                                  n_long=max(1, args.requests // 4),
                                  max_slots=args.max_slots,
                                  tick_steps=args.tick_steps,
                                  max_new=args.new_tokens, seed=args.seed,
                                  page_len=args.page_len)
    elif args.sharded:
        rows = serve_bench_sharded(args.arch, n_requests=args.requests,
                                   max_slots=args.max_slots,
                                   tick_steps=args.tick_steps,
                                   max_new=args.new_tokens, seed=args.seed,
                                   buckets=buckets,
                                   mesh_spec=args.mesh, devices=args.devices)
    else:
        rows = serve_bench(args.arch, n_requests=args.requests,
                           max_slots=args.max_slots,
                           tick_steps=args.tick_steps,
                           max_new=args.new_tokens, rate=args.rate,
                           seed=args.seed)
    print("name,value,paper_reference")
    for name, val, _ in rows:
        print(f"{name},{val:.4f},")


if __name__ == "__main__":
    main()
