"""Continuous-batching serve benchmark: sustained tok/s + plane traffic
under a Poisson request trace.

Compares the slot-pool scheduler (``serving/scheduler.py`` — admit /
tick / retire / re-fill, decode never drains) against the *naive serial*
baseline: each request decoded alone through the fused ``greedy_generate``
program, one after another — what you get without a scheduler.  Both sides
are timed warm (compile excluded); baseline prompts are padded to the same
buckets so its compile count is bounded identically.  A second scheduler
pass runs the quantized bit-plane path with per-request
``plane_traffic_fraction`` / ``element_traffic_fraction`` reporting — the
sustained-load image of the paper's §VI memory-access savings.

The **sharded** variant (``serve_bench_sharded`` / ``--sharded``) replays
the same trace through a mesh-native scheduler (``mesh='2x2'`` data x model
by default) in a SUBPROCESS with forced host devices — the parent process
keeps its single real device — and asserts token parity against the
single-device scheduler before reporting throughput.

  PYTHONPATH=src python -m benchmarks.serve_bench            # full bench
  PYTHONPATH=src python -m benchmarks.serve_bench --dry      # CI smoke
  PYTHONPATH=src python -m benchmarks.serve_bench --sharded  # mesh variant
  PYTHONPATH=src python -m benchmarks.run --only serve       # via driver

Rows print as ``serve.<name>,<value>,`` CSV like every other bench.
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time
from typing import List, Tuple

import numpy as np

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _make_trace(rng, n_requests: int, vocab: int, min_len: int, max_len: int,
                rate: float) -> List[Tuple[float, np.ndarray]]:
    """Poisson arrivals (exponential gaps at ``rate`` req/s; ``rate=0`` =
    everything queued at t=0) with uniform prompt lengths."""
    arrivals, t = [], 0.0
    for _ in range(n_requests):
        if rate > 0:
            t += float(rng.exponential(1.0 / rate))
        prompt = rng.integers(0, vocab,
                              size=int(rng.integers(min_len, max_len + 1)),
                              ).astype(np.int32)
        arrivals.append((t, prompt))
    return arrivals


def _run_scheduler(sched, trace, max_new: int, eos_id=None):
    """Replay the trace in wall-clock time (fast-forwarding idle gaps);
    returns (results-so-far in rid order, elapsed_busy_seconds).  Every tick
    syncs tokens to host, so the clock reads true device-done time."""
    pending = list(trace)
    t0 = time.perf_counter()
    idle = 0.0
    while pending or sched.pending:
        now = time.perf_counter() - t0 - idle
        while pending and pending[0][0] <= now:
            _, prompt = pending.pop(0)
            sched.submit(prompt, max_new=max_new, eos_id=eos_id)
        if sched.pending:
            sched.step_tick()
        elif pending:
            # fast-forward an empty system to the next arrival: idle time is
            # not "sustained load" and is excluded from the throughput
            idle += pending[0][0] - now
    return sched.run(max_ticks=0), time.perf_counter() - t0 - idle


def _warm_trace(rng, buckets, vocab) -> List[Tuple[float, np.ndarray]]:
    """One request per bucket at t=0 — compiles every prefill variant plus
    the tick program before anything is timed."""
    return [(0.0, rng.integers(0, vocab, size=b).astype(np.int32))
            for b in buckets]


def serve_bench(arch: str = "smollm_135m", n_requests: int = 24,
                max_slots: int = 8, tick_steps: int = 8, max_new: int = 24,
                rate: float = 0.0, seed: int = 0,
                buckets: Tuple[int, ...] = (8, 16, 32)):
    """Returns rows (name, value, reference-nan) for benchmarks.run."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke
    from repro.models import init_params
    from repro.models.quantize import quantize_model_params
    from repro.serving import engine
    from repro.serving.scheduler import ServeScheduler, bucket_for

    cfg = get_smoke(arch).replace(dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    pool_len = max(buckets) + max_new + tick_steps
    trace = _make_trace(rng, n_requests, cfg.vocab_size,
                        min_len=4, max_len=max(buckets), rate=rate)
    total_tokens = n_requests * max_new
    nan = float("nan")
    rows = []

    # --- naive serial baseline: fused generate, one request at a time ------
    key = jax.random.PRNGKey(0)

    def serial_pass():
        for _, prompt in trace:
            b = bucket_for(prompt.size, buckets)
            padded = np.zeros((1, b), np.int32)
            padded[0, :prompt.size] = prompt
            fn = engine.generate_fn(cfg, max_new, 0.0, False, None, False)
            jax.block_until_ready(fn(params, jnp.asarray(padded), key)[0])

    serial_pass()                                    # warm every bucket
    t0 = time.perf_counter()
    serial_pass()
    t_serial = time.perf_counter() - t0
    rows.append((f"serve.{cfg.name}.serial_tok_s",
                 total_tokens / t_serial, nan))

    # --- continuous-batching scheduler, float ------------------------------
    sched = ServeScheduler(cfg, params, max_slots=max_slots,
                           max_len=pool_len, buckets=buckets,
                           tick_steps=tick_steps)
    _run_scheduler(sched, _warm_trace(rng, buckets, cfg.vocab_size), max_new)
    results, t_sched = _run_scheduler(sched, trace, max_new)
    got = sum(len(r.tokens) for r in results[-n_requests:])
    assert got == total_tokens, (got, total_tokens)
    rows.append((f"serve.{cfg.name}.sched_tok_s",
                 total_tokens / t_sched, nan))
    rows.append((f"serve.{cfg.name}.sched_vs_serial_speedup",
                 t_serial / t_sched, nan))

    # --- quantized pass with per-request traffic stats ---------------------
    qparams = quantize_model_params(cfg, params)
    qsched = ServeScheduler(cfg, qparams, max_slots=max_slots,
                            max_len=pool_len, buckets=buckets,
                            quant="xla", with_stats=True,
                            tick_steps=tick_steps)
    _run_scheduler(qsched, _warm_trace(rng, buckets, cfg.vocab_size),
                   max_new)
    qresults, t_q = _run_scheduler(qsched, trace, max_new)
    qresults = qresults[-n_requests:]
    rows.append((f"serve.{cfg.name}.quant.sched_tok_s",
                 total_tokens / t_q, nan))
    rows.append((f"serve.{cfg.name}.quant.plane_traffic_fraction_tile",
                 float(np.mean([r.plane_traffic_fraction
                                for r in qresults])), nan))
    rows.append((f"serve.{cfg.name}.quant.plane_traffic_fraction_element",
                 float(np.mean([r.element_traffic_fraction
                                for r in qresults])), nan))
    return rows


def _sharded_child(arch: str, n_requests: int, max_slots: int,
                   tick_steps: int, max_new: int, seed: int,
                   buckets: Tuple[int, ...], mesh_spec: str):
    """Runs INSIDE the forced-multi-device subprocess: single-device vs
    mesh-sharded scheduler over the same trace — parity asserted, both
    throughputs reported."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke
    from repro.launch.mesh import make_serve_mesh
    from repro.models import init_params

    cfg = get_smoke(arch).replace(dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    pool_len = max(buckets) + max_new + tick_steps
    trace = _make_trace(rng, n_requests, cfg.vocab_size,
                        min_len=4, max_len=max(buckets), rate=0.0)
    rows = []
    tokens = {}
    for label, mesh in (("single", None),
                        (mesh_spec, make_serve_mesh(mesh_spec))):
        from repro.serving.scheduler import ServeScheduler
        sched = ServeScheduler(cfg, params, max_slots=max_slots,
                               max_len=pool_len, buckets=buckets,
                               tick_steps=tick_steps, mesh=mesh)
        _run_scheduler(sched, _warm_trace(rng, buckets, cfg.vocab_size),
                       max_new)
        results, t = _run_scheduler(sched, trace, max_new)
        tokens[label] = [r.tokens for r in results[-n_requests:]]
        rows.append((f"serve.{cfg.name}.sharded[{label}].tok_s",
                     n_requests * max_new / t, float("nan")))
    assert tokens["single"] == tokens[mesh_spec], \
        "sharded scheduler tokens diverged from single-device"
    rows.append((f"serve.{cfg.name}.sharded[{mesh_spec}].bit_equal",
                 1.0, float("nan")))
    return rows


def serve_bench_sharded(arch: str = "smollm_135m", n_requests: int = 16,
                        max_slots: int = 8, tick_steps: int = 8,
                        max_new: int = 16, seed: int = 0,
                        buckets: Tuple[int, ...] = (8, 16, 32),
                        mesh_spec: str = "2x2", devices: int = 4):
    """Mesh-sharded serve bench: spawns a subprocess with ``devices`` forced
    host devices (the calling process' jax stays single-device) and parses
    its CSV rows.  Registered in ``benchmarks.run`` as ``serve_sharded``."""
    args = ["--child-sharded", "--arch", arch,
            "--requests", str(n_requests), "--max-slots", str(max_slots),
            "--tick-steps", str(tick_steps), "--new-tokens", str(max_new),
            "--seed", str(seed), "--mesh", mesh_spec,
            "--buckets", ",".join(str(b) for b in buckets)]
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
        PYTHONPATH=os.pathsep.join(
            [_REPO, os.path.join(_REPO, "src"),
             os.environ.get("PYTHONPATH", "")]).rstrip(os.pathsep))
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.serve_bench"] + args,
        capture_output=True, text=True, timeout=1200, env=env, cwd=_REPO)
    if out.returncode != 0:
        raise RuntimeError(f"sharded serve bench child failed:\n"
                           f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}")
    rows = []
    for line in out.stdout.splitlines():
        if line.startswith("serve."):
            name, val, _ = line.split(",")
            rows.append((name, float(val), float("nan")))
    if not rows:
        raise RuntimeError(f"sharded serve bench child produced no rows:\n"
                           f"{out.stdout}")
    return rows


ALL_SERVE_BENCHES = {"serve": serve_bench, "serve_sharded": serve_bench_sharded}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-slots", type=int, default=8)
    ap.add_argument("--tick-steps", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate in req/s (0 = all queued "
                         "at t=0, the sustained-load trace)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dry", action="store_true",
                    help="CI smoke: tiny trace, checks wiring + that the "
                         "scheduler runs end-to-end (single-device AND a "
                         "2x2 sharded pass)")
    ap.add_argument("--sharded", action="store_true",
                    help="run the mesh-sharded variant (subprocess with "
                         "forced host devices)")
    ap.add_argument("--mesh", default="2x2",
                    help="DxM mesh spec for the sharded variant")
    ap.add_argument("--devices", type=int, default=4,
                    help="forced host device count for the sharded variant")
    ap.add_argument("--buckets", default=None,
                    help="comma-separated prefill buckets (child mode)")
    ap.add_argument("--child-sharded", action="store_true",
                    help=argparse.SUPPRESS)   # internal: runs inside the
                                              # forced-device subprocess
    args = ap.parse_args(argv)

    buckets = (tuple(int(b) for b in args.buckets.split(","))
               if args.buckets else (8, 16, 32))
    if args.child_sharded:
        rows = _sharded_child(args.arch, args.requests, args.max_slots,
                              args.tick_steps, args.new_tokens, args.seed,
                              buckets, args.mesh)
    elif args.dry:
        rows = serve_bench(args.arch, n_requests=4, max_slots=2,
                           tick_steps=2, max_new=4, rate=args.rate,
                           seed=args.seed, buckets=(8, 16))
        rows += serve_bench_sharded(args.arch, n_requests=4, max_slots=2,
                                    tick_steps=2, max_new=4, seed=args.seed,
                                    buckets=(8, 16), mesh_spec=args.mesh,
                                    devices=args.devices)
    elif args.sharded:
        rows = serve_bench_sharded(args.arch, n_requests=args.requests,
                                   max_slots=args.max_slots,
                                   tick_steps=args.tick_steps,
                                   max_new=args.new_tokens, seed=args.seed,
                                   buckets=buckets,
                                   mesh_spec=args.mesh, devices=args.devices)
    else:
        rows = serve_bench(args.arch, n_requests=args.requests,
                           max_slots=args.max_slots,
                           tick_steps=args.tick_steps,
                           max_new=args.new_tokens, rate=args.rate,
                           seed=args.seed)
    print("name,value,paper_reference")
    for name, val, _ in rows:
        print(f"{name},{val:.4f},")


if __name__ == "__main__":
    main()
