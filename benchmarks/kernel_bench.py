"""Kernel microbenchmarks: wall-time of the jnp path (what the CPU can
measure) + the analytic traffic ratios of the Pallas path (what the TPU
design is judged on).  CSV rows: (name, us_per_call, derived)."""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (log2_quantize, quantize_weights,
                        shiftadd_matmul_bitplane, to_bitplanes,
                        weight_access_report)
from repro.kernels.bitplane_matmul.ops import plane_traffic_fraction

Row = Tuple[str, float, float]


def _time(fn, *args, iters: int = 5) -> float:
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def bench_log2_quant() -> List[Row]:
    rows = []
    for n in (1 << 16, 1 << 20):
        x = jnp.asarray(np.random.default_rng(0).normal(0, 0.3, n),
                        jnp.float32)
        us = _time(jax.jit(log2_quantize), x)
        rows.append((f"log2quant.n{n}", us, n / (us * 1e-6) / 1e9))  # Gelem/s
    return rows


def bench_bitplane_matmul() -> List[Row]:
    rows = []
    rng = np.random.default_rng(1)
    for (m, k, n), sigma in [((128, 512, 512), 0.05),
                             ((128, 512, 512), 0.5)]:
        x = rng.normal(0, sigma, (m, k)).astype(np.float32)
        q = log2_quantize(jnp.asarray(x))
        w = quantize_weights(jnp.asarray(
            rng.normal(0, 0.1, (k, n)).astype(np.float32)), channel_axis=-1)
        planes = to_bitplanes(w.q)
        us = _time(jax.jit(shiftadd_matmul_bitplane), q, planes)
        # derived: fraction of weight-plane tiles the TPU kernel would fetch
        frac = float(plane_traffic_fraction(q.exp, block_m=8, block_k=128))
        rows.append((f"bitplane_matmul.{m}x{k}x{n}.sigma{sigma}", us, frac))
    return rows


def bench_access_savings_by_distribution() -> List[Row]:
    """Element- vs tile-granularity savings as the activation distribution
    cools — the QeiHaN-on-TPU design-space table quoted in EXPERIMENTS.md."""
    rows = []
    rng = np.random.default_rng(2)
    for sigma in (1.0, 0.25, 0.05, 0.01):
        x = rng.normal(0, sigma, (256, 4096)).astype(np.float32)
        q = log2_quantize(jnp.asarray(x))
        rep = weight_access_report(q, tile_k=256)
        rows.append((f"savings.element.sigma{sigma}",
                     float(rep.savings_element) * 100, float("nan")))
        rows.append((f"savings.tile256.sigma{sigma}",
                     float(rep.savings_tile) * 100,
                     float(plane_traffic_fraction(q.exp, block_m=8,
                                                  block_k=256)) * 100))
    return rows


def _paged_attn_case(b=4, page_len=16, nb=32, g=2, r=2, d=16,
                     lengths=(512, 300, 64, 17)):
    """Long-context decode tick: 4 slots over a 512-token table, lengths
    spread so the dense gather streams 4x32 pages while the kernel walk
    touches only ceil(len/page_len) per slot.  Defaults are the RAGGED512
    geometry the static kernel audit registers — one geometry, one table
    builder, so bench and audit gate the same number."""
    from repro.kernels.paged_attention.kernel import make_page_table
    rng = np.random.default_rng(3)
    lens = np.asarray(lengths, np.int32)
    n_pages = 1 + b * nb
    k = jnp.asarray(rng.standard_normal((n_pages, page_len, g, d)),
                    jnp.float32)
    v = jnp.asarray(rng.standard_normal((n_pages, page_len, g, d)),
                    jnp.float32)
    table = make_page_table(lens, nb, page_len)
    q = jnp.asarray(rng.standard_normal((b, 1, g * r, d)), jnp.float32)
    return q, k, v, jnp.asarray(table), jnp.asarray(lens), table, lens


def paged_attn_gate_rows() -> dict:
    """The ``paged_attn`` bench-drift rows (benchmarks/baselines/
    paged_attn.json): ``tokens_bit_equal`` (argmax through a fixed random
    head — token-level kernel-vs-dense parity, EXACT-gated) and
    ``gather_saved_frac`` (page reads the table walk avoids vs the dense
    gather, EXACT-gated — the paper-§IV access-savings image), plus
    advisory CPU wall times (interpret-mode pallas is expected to be slow
    here; the claim is traffic, not CPU speed)."""
    from repro.kernels.paged_attention.ops import (gather_traffic_counts,
                                                   paged_decode_attention)
    from repro.kernels.paged_attention.ref import paged_attention_reference
    q, k, v, table, lens, table_np, lens_np = _paged_attn_case()
    us_dense = _time(jax.jit(paged_attention_reference), q, k, v, table,
                     lens, iters=3)
    ref = paged_attention_reference(q, k, v, table, lens)
    outs, times = {}, {}
    for s in (1, 4):
        times[s] = _time(lambda *a, s=s: paged_decode_attention(*a, splits=s),
                         q, k, v, table, lens, iters=3)
        outs[s] = paged_decode_attention(q, k, v, table, lens, splits=s)
    head = jnp.asarray(np.random.default_rng(9).standard_normal(
        (q.shape[2] * q.shape[3], 64)), jnp.float32)

    def tok(o):
        return np.asarray(jnp.argmax(o.reshape(o.shape[0], -1) @ head,
                                     axis=-1))
    bit = float(all(np.array_equal(tok(ref), tok(o)) for o in outs.values()))
    touched, total = gather_traffic_counts(table_np, lens_np,
                                           page_len=k.shape[1])

    # static estimator rows (EXACT-gated): the audit's ragged512.s1
    # instantiation IS this bench geometry, so the bench baseline and the
    # kernel-audit baseline share one number
    from repro.analysis.kernel_rules import static_traffic
    from repro.analysis.pallas_inspect import vmem_footprint
    from repro.kernels.paged_attention.kernel import audit_specs
    inst = next(i for i in audit_specs() if i.case == "ragged512.s1")
    rec, disagreements = static_traffic(inst)
    assert not disagreements, disagreements
    return {"tokens_bit_equal": bit,
            "gather_saved_frac": 1.0 - touched / total,
            "vmem_bytes": float(vmem_footprint(inst)["vmem_bytes"]),
            "static_bytes_moved": float(rec["bytes_read"]
                                        + rec["bytes_written"]),
            "dense_gather_us": us_dense,
            "kernel_split1_us": times[1],
            "kernel_split4_us": times[4]}


def bench_paged_attention() -> List[Row]:
    rows = paged_attn_gate_rows()
    return [(f"paged_attn.b4.pl16.nb32.{name}", val, float("nan"))
            for name, val in rows.items()]


ALL_KERNEL_BENCHES = {
    "log2quant": bench_log2_quant,
    "bitplane_matmul": bench_bitplane_matmul,
    "access_savings": bench_access_savings_by_distribution,
    "paged_attn": bench_paged_attention,
}
