"""Kernel microbenchmarks: wall-time of the jnp path (what the CPU can
measure) + the analytic traffic ratios of the Pallas path (what the TPU
design is judged on).  CSV rows: (name, us_per_call, derived)."""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (log2_quantize, quantize_weights,
                        shiftadd_matmul_bitplane, to_bitplanes,
                        weight_access_report)
from repro.kernels.bitplane_matmul.ops import plane_traffic_fraction

Row = Tuple[str, float, float]


def _time(fn, *args, iters: int = 5) -> float:
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def bench_log2_quant() -> List[Row]:
    rows = []
    for n in (1 << 16, 1 << 20):
        x = jnp.asarray(np.random.default_rng(0).normal(0, 0.3, n),
                        jnp.float32)
        us = _time(jax.jit(log2_quantize), x)
        rows.append((f"log2quant.n{n}", us, n / (us * 1e-6) / 1e9))  # Gelem/s
    return rows


def bench_bitplane_matmul() -> List[Row]:
    rows = []
    rng = np.random.default_rng(1)
    for (m, k, n), sigma in [((128, 512, 512), 0.05),
                             ((128, 512, 512), 0.5)]:
        x = rng.normal(0, sigma, (m, k)).astype(np.float32)
        q = log2_quantize(jnp.asarray(x))
        w = quantize_weights(jnp.asarray(
            rng.normal(0, 0.1, (k, n)).astype(np.float32)), channel_axis=-1)
        planes = to_bitplanes(w.q)
        us = _time(jax.jit(shiftadd_matmul_bitplane), q, planes)
        # derived: fraction of weight-plane tiles the TPU kernel would fetch
        frac = float(plane_traffic_fraction(q.exp, block_m=8, block_k=128))
        rows.append((f"bitplane_matmul.{m}x{k}x{n}.sigma{sigma}", us, frac))
    return rows


def bench_access_savings_by_distribution() -> List[Row]:
    """Element- vs tile-granularity savings as the activation distribution
    cools — the QeiHaN-on-TPU design-space table quoted in EXPERIMENTS.md."""
    rows = []
    rng = np.random.default_rng(2)
    for sigma in (1.0, 0.25, 0.05, 0.01):
        x = rng.normal(0, sigma, (256, 4096)).astype(np.float32)
        q = log2_quantize(jnp.asarray(x))
        rep = weight_access_report(q, tile_k=256)
        rows.append((f"savings.element.sigma{sigma}",
                     float(rep.savings_element) * 100, float("nan")))
        rows.append((f"savings.tile256.sigma{sigma}",
                     float(rep.savings_tile) * 100,
                     float(plane_traffic_fraction(q.exp, block_m=8,
                                                  block_k=256)) * 100))
    return rows


ALL_KERNEL_BENCHES = {
    "log2quant": bench_log2_quant,
    "bitplane_matmul": bench_bitplane_matmul,
    "access_savings": bench_access_savings_by_distribution,
}
