"""Benchmark driver: one function per paper table/figure + kernel benches
+ the decode-throughput bench + the roofline summary.  Prints
``name,value,reference`` CSV.

  PYTHONPATH=src python -m benchmarks.run             # everything
  PYTHONPATH=src python -m benchmarks.run --only fig9 --stats measured
  PYTHONPATH=src python -m benchmarks.run --only decode
  PYTHONPATH=src python -m benchmarks.run --dry       # CI smoke (fast)
"""

from __future__ import annotations

import argparse
import math


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated figure keys (fig2,...,table1,"
                         "kernels,decode,serve,roofline)")
    ap.add_argument("--stats", default="preset",
                    choices=["preset", "measured", "both"])
    ap.add_argument("--roofline-dir", default="results/dryrun")
    ap.add_argument("--dry", action="store_true",
                    help="CI smoke: import every bench registry and run a "
                         "tiny decode bench — catches wiring/API drift "
                         "without the full measurement cost")
    args = ap.parse_args()

    from benchmarks.decode_bench import ALL_DECODE_BENCHES, decode_bench
    from benchmarks.kernel_bench import ALL_KERNEL_BENCHES
    from benchmarks.paper_figures import ALL_FIGURES
    from benchmarks.serve_bench import ALL_SERVE_BENCHES

    if args.dry:
        import json
        names = (list(ALL_FIGURES) + [f"kernels.{k}" for k in
                                      ALL_KERNEL_BENCHES]
                 + list(ALL_DECODE_BENCHES)
                 + list(ALL_SERVE_BENCHES))
        print(f"# dry run: {len(names)} bench groups registered "
              f"({','.join(names)})")
        print("name,value,paper_reference")
        rows = list(decode_bench(batch=1, prompt_len=8, new_tokens=4,
                                 repeats=1))
        for name, val, _ in rows:
            print(f"{name},{val:.4f},")
        # machine-readable summary for the bench-drift gate
        # (tools/bench_check.py vs benchmarks/baselines/run_dry.json);
        # `registered` catches a bench group silently dropping out
        print("# json " + json.dumps(
            {"bench": "run_dry",
             "rows": dict({"registered_groups": float(len(names))},
                          **{n: float(v) for n, v, _ in rows})}))
        # paged-attention kernel gate (baselines/paged_attn.json): token
        # parity + gather-traffic savings are EXACT rows, wall times
        # advisory — cheap enough (<2 s interpreted) to run in the smoke
        from benchmarks.kernel_bench import paged_attn_gate_rows
        print("# json " + json.dumps(
            {"bench": "paged_attn",
             "rows": {n: float(v)
                      for n, v in paged_attn_gate_rows().items()}}))
        return

    only = set(args.only.split(",")) if args.only else None

    def want(key):
        return only is None or key in only

    print("name,value,paper_reference")
    sources = ["preset", "measured"] if args.stats == "both" else [args.stats]

    for key, fn in ALL_FIGURES.items():
        if not want(key):
            continue
        if key == "table1":
            rows = fn()
        else:
            rows = []
            for src in sources:
                rows += fn(stats_source=src)
        for name, val, ref in rows:
            ref_s = "" if (isinstance(ref, float) and math.isnan(ref)) else f"{ref:.4g}"
            print(f"{name},{val:.4f},{ref_s}")

    if want("kernels"):
        for fn in ALL_KERNEL_BENCHES.values():
            for name, us, derived in fn():
                d = "" if (isinstance(derived, float) and math.isnan(derived)) \
                    else f"{derived:.4g}"
                print(f"kernels.{name},{us:.2f},{d}")

    if want("decode"):
        for fn in ALL_DECODE_BENCHES.values():
            for name, val, _ in fn():
                print(f"{name},{val:.4f},")

    if want("serve"):
        for fn in ALL_SERVE_BENCHES.values():
            for name, val, _ in fn():
                print(f"{name},{val:.4f},")

    if want("roofline"):
        import os
        if os.path.isdir(args.roofline_dir):
            from repro.launch.roofline import load_rows
            for r in load_rows(args.roofline_dir):
                print(f"roofline.{r.arch}.{r.shape}.{r.mesh}.bound_s,"
                      f"{r.bound_s:.4f},{r.dominant}")
                print(f"roofline.{r.arch}.{r.shape}.{r.mesh}.useful_frac,"
                      f"{r.useful_fraction:.4f},")


if __name__ == "__main__":
    main()
