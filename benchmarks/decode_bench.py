"""Decode-throughput benchmark: per-token Python loop vs the fused
``lax.scan`` engine, float vs QeiHaN-quantized, plus the per-step
weight-plane traffic fractions.

This is the serving image of the paper's claim: the win comes from keeping
the datapath busy (fused program, no per-token dispatch) while skipping
weight bit-planes (quant path).  Rows print through ``benchmarks.run`` as
``decode.<name>,<value>,``.

  PYTHONPATH=src python -m benchmarks.run --only decode
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, repeats: int = 3) -> float:
    """Best-of wall time of ``fn(*args)`` after one warmup (compile) call."""
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def decode_bench(arch: str = "smollm_135m", batch: int = 2,
                 prompt_len: int = 16, new_tokens: int = 32,
                 repeats: int = 3):
    """Returns rows (name, value, reference-nan) for benchmarks.run."""
    from repro.configs import get_smoke
    from repro.models import init_params
    from repro.models.quantize import quantize_model_params
    from repro.serving import engine

    cfg = get_smoke(arch).replace(dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (batch, prompt_len), 0, cfg.vocab_size)
    key = jax.random.PRNGKey(0)
    n = batch * new_tokens
    nan = float("nan")
    rows = []

    # --- unfused baseline: pre-jitted steps, timed Python decode loop ------
    from repro.models.model import init_caches
    prefill = jax.jit(engine.make_prefill_step(cfg))
    step = jax.jit(engine.make_serve_step(cfg, quant="xla"))
    step_f = jax.jit(engine.make_serve_step(cfg))

    def py_loop(params, prompt, step_fn):
        caches = init_caches(cfg, batch, prompt_len + new_tokens,
                             dtype=cfg.dtype)
        logits, caches = prefill(params, {"tokens": prompt}, caches)
        cur = None
        for _ in range(new_tokens):
            cur = jnp.argmax(logits, axis=-1)
            logits, caches = step_fn(params, caches, cur[:, None])
        return cur

    t_loop = _time(py_loop, params, prompt, step_f, repeats=repeats)
    rows.append((f"decode.{cfg.name}.float.loop_tok_s", n / t_loop, nan))

    # --- fused scan engine -------------------------------------------------
    fused = engine.generate_fn(cfg, new_tokens, 0.0, False, None, False)
    t_fused = _time(fused, params, prompt, key, repeats=repeats)
    rows.append((f"decode.{cfg.name}.float.fused_tok_s", n / t_fused, nan))
    rows.append((f"decode.{cfg.name}.float.fused_speedup",
                 t_loop / t_fused, nan))

    # --- quantized (xla backend, so CPU timing is the bit-plane math not the
    # pallas interpreter) ---------------------------------------------------
    qparams = quantize_model_params(cfg, params)
    t_qloop = _time(py_loop, qparams, prompt, step, repeats=repeats)
    rows.append((f"decode.{cfg.name}.quant.loop_tok_s", n / t_qloop, nan))
    # time the stats-free program — the traffic accounting adds per-
    # projection skip-table work that the loop/float comparison points lack
    fused_q = engine.generate_fn(cfg, new_tokens, 0.0, "xla", None, False)
    t_qfused = _time(lambda: fused_q(qparams, prompt, key)[0],
                     repeats=repeats)
    rows.append((f"decode.{cfg.name}.quant.fused_tok_s", n / t_qfused, nan))

    fused_q_stats = engine.generate_fn(cfg, new_tokens, 0.0, "xla", None,
                                       True)
    _, stats = fused_q_stats(qparams, prompt, key)
    # average executed forwards only — the final token's forward is skipped
    # (dead logits) and reports an exact-zero stats row
    import numpy as np
    tile = np.asarray(stats["plane_traffic_fraction"])
    elem = np.asarray(stats["element_traffic_fraction"])
    rows.append((f"decode.{cfg.name}.quant.plane_traffic_fraction_tile",
                 float(tile[tile > 0].mean()), nan))
    rows.append((f"decode.{cfg.name}.quant.plane_traffic_fraction_element",
                 float(elem[tile > 0].mean()), nan))
    return rows


ALL_DECODE_BENCHES = {"decode": decode_bench}
