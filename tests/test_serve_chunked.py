"""Chunked prefill through the ServeScheduler (ISSUE 4).

Covers the chunk-boundary lattice (prompt lengths 1, chunk_len±1, exact
multiples, longer than the largest bucket), both admission policies
(``"auto"`` — in-bucket prompts keep the bucketed bit-exact path, only
over-bucket prompts chunk — and ``"always"``), the oversized-prompt
policies under chunking, quantized serving with per-request traffic
attribution, slot-reuse state reset, the one-compiled-chunk-shape bound,
and the latency timestamps serve_bench consumes.

Parity bar: token streams equal the per-request ``greedy_generate``
output.  For bucketed admissions that is the PR 2 bit-equality guarantee;
for chunked admissions the logits agree to f32 ULP (chunk-boundary GEMM
shapes reassociate the same sums — DESIGN.md §Chunked prefill) and the
greedy token streams are asserted equal on every tested
length/arch/backend.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import init_params
from repro.models.quantize import quantize_model_params
from repro.serving import engine
from repro.serving.scheduler import ServeScheduler

BUCKETS = (8, 16)          # chunk_len defaults to buckets[0] == 8
# the boundary lattice: 1, chunk_len-1/exact/+1, bucket edge, multiples,
# > largest bucket (rejected outright before this PR), near slot capacity
CHUNK_LENS = (1, 7, 8, 9, 16, 24, 40, 56)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke("smollm_135m").replace(dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in CHUNK_LENS]
    return cfg, params, prompts


def _reference(cfg, params, prompt, max_new, quant=False):
    return list(np.asarray(engine.greedy_generate(
        cfg, params, jnp.asarray(prompt)[None], max_new=max_new,
        quant=quant))[0])


def test_chunk_boundary_lengths_always_mode(setup):
    """Every boundary length through chunked="always" (every prompt chunks,
    including the one-token one) matches greedy_generate, with interleaving
    forced by 3 slots over 8 requests; exactly ONE chunk and ONE mixed
    program compile across all lengths, and no bucket program is ever
    touched."""
    cfg, params, prompts = setup
    max_new = 7
    sched = ServeScheduler(cfg, params, max_slots=3, max_len=64,
                           buckets=BUCKETS, tick_steps=4, chunked="always")
    for p in prompts:
        sched.submit(p, max_new=max_new)
    results = sched.run()
    assert len(results) == len(prompts)
    for r, p in zip(results, prompts):
        assert r.finish_reason == "length"
        assert r.tokens == _reference(cfg, params, p, max_new), r.prompt_len
        # latency marks ride one clock: submit <= first token <= finish
        assert r.submit_time <= r.first_token_time <= r.finish_time
    stats = sched.compile_stats()
    assert stats["chunk"] == 1 and stats["mixed"] <= 1, stats
    assert stats["prefill"] == 0 and stats["write_slot"] == 0, stats


def test_auto_mode_buckets_short_chunks_long(setup):
    """chunked="auto": in-bucket prompts take the UNCHANGED bucketed path
    (bit-exact by construction — same programs as a chunkless scheduler),
    over-bucket prompts chunk instead of being rejected."""
    cfg, params, prompts = setup
    max_new = 7
    sched = ServeScheduler(cfg, params, max_slots=2, max_len=64,
                           buckets=BUCKETS, tick_steps=4, chunked="auto")
    for p in prompts:
        sched.submit(p, max_new=max_new)
    results = sched.run()
    for r, p in zip(results, prompts):
        assert r.finish_reason == "length"
        assert r.tokens == _reference(cfg, params, p, max_new), r.prompt_len
    stats = sched.compile_stats()
    # short prompts used the bucket programs, long ones the chunk programs
    assert stats["prefill"] == len(BUCKETS) and stats["chunk"] == 1, stats

    # the SAME long prompt is a rejection without chunking
    off = ServeScheduler(cfg, params, max_slots=2, max_len=64,
                         buckets=BUCKETS, tick_steps=4)
    rid = off.submit(prompts[-1], max_new=max_new)
    (r,) = off.run()
    assert r.rid == rid and r.finish_reason == "rejected"
    assert "bucket" in r.error


def test_mamba_chunked_parity():
    """SSM arch: cross-chunk state handoff (ssd init_state + rolling conv
    window + dt-masked pads) and the inactive-row state passthrough in the
    mixed tick — a prefilling slot's recurrent state must survive decode
    scans it rides inactively."""
    cfg = get_smoke("mamba2_780m").replace(dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    lens = (3, 7, 8, 9, 17, 30, 44)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in lens]
    max_new = 5
    for mode in ("auto", "always"):
        sched = ServeScheduler(cfg, params, max_slots=3, max_len=64,
                               buckets=BUCKETS, tick_steps=3, chunked=mode)
        for p in prompts:
            sched.submit(p, max_new=max_new)
        for r, p in zip(sched.run(), prompts):
            assert r.tokens == _reference(cfg, params, p, max_new), \
                (mode, r.prompt_len)


def test_quant_chunked_parity_and_traffic(setup):
    """Quant bit-plane serving through chunked prefill: token parity vs the
    quantized greedy_generate, and chunk-phase plane traffic is attributed
    to the prefilling requests (fractions land in (0, 1])."""
    cfg, params, _ = setup
    qparams = quantize_model_params(cfg, params)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 20, 40)]
    sched = ServeScheduler(cfg, qparams, max_slots=2, max_len=48,
                           buckets=BUCKETS, quant="xla", with_stats=True,
                           tick_steps=2, chunked="always")
    for p in prompts:
        sched.submit(p, max_new=4)
    for r, p in zip(sched.run(), prompts):
        assert r.tokens == _reference(cfg, qparams, p, 4, "xla"), r.prompt_len
        assert 0.0 < r.plane_traffic_fraction <= 1.0
        assert 0.0 < r.element_traffic_fraction <= 1.0


def test_long_prompt_interleaves_with_decode(setup):
    """The headline behavior: while a long prompt ingests chunk-by-chunk,
    short requests on other slots keep decoding — and a short request
    submitted later still FINISHES before the long prompt's first token
    (decode never drains during a long prefill)."""
    cfg, params, _ = setup
    rng = np.random.default_rng(3)
    long_p = rng.integers(0, cfg.vocab_size, size=56).astype(np.int32)
    short_ps = [rng.integers(0, cfg.vocab_size, size=4).astype(np.int32)
                for _ in range(3)]
    sched = ServeScheduler(cfg, params, max_slots=2, max_len=64,
                           buckets=BUCKETS, tick_steps=2, chunked="auto")
    long_rid = sched.submit(long_p, max_new=4)
    rids = [sched.submit(p, max_new=4) for p in short_ps]
    results = {r.rid: r for r in sched.run()}
    for rid, p in zip(rids, short_ps):
        assert results[rid].tokens == _reference(cfg, params, p, 4)
    long_r = results[long_rid]
    assert long_r.tokens == _reference(cfg, params, long_p, 4)
    # 56 tokens / chunk 8 = 7 ingest ticks; the first short request finished
    # while that was still going (finished_tick strictly before the long
    # request's first possible decode tick)
    assert min(results[r].finished_tick for r in rids) <= 7
    assert long_r.finished_tick >= 7


def test_oversize_policies_with_chunking(setup):
    """Regression: reject/truncate/raise still police the slot-capacity
    bound when chunking removes the bucket ceiling."""
    cfg, params, _ = setup
    rng = np.random.default_rng(4)
    big = rng.integers(0, cfg.vocab_size, size=60).astype(np.int32)

    # reject: prompt + max_new > max_len even though chunking would ingest it
    sched = ServeScheduler(cfg, params, max_slots=1, max_len=32,
                           buckets=BUCKETS, tick_steps=2, chunked="auto")
    rid = sched.submit(big, max_new=4)
    ok = sched.submit(big[:20], max_new=4)      # over-bucket but fits: serves
    results = {r.rid: r for r in sched.run()}
    assert results[rid].finish_reason == "rejected"
    assert "slot capacity" in results[rid].error
    assert "bucket" not in results[rid].error   # chunking lifted that bound
    assert results[ok].tokens == _reference(cfg, params, big[:20], 4)

    # truncate: keeps the latest context that fits, then chunks it
    tr = ServeScheduler(cfg, params, max_slots=1, max_len=32,
                        buckets=BUCKETS, tick_steps=2, chunked="auto",
                        oversize="truncate")
    rid = tr.submit(big, max_new=4)
    (r,) = tr.run()
    assert r.rid == rid and r.finish_reason == "length"
    assert r.tokens == _reference(cfg, params, big[-28:], 4)

    # raise: loud failure preserved
    strict = ServeScheduler(cfg, params, max_slots=1, max_len=32,
                            buckets=BUCKETS, tick_steps=2, chunked="auto",
                            oversize="raise")
    with pytest.raises(ValueError, match="slot capacity"):
        strict.submit(big, max_new=4)


def test_slot_reuse_resets_chunked_state(setup):
    """More chunked requests than slots: each slot serves several requests
    back-to-back, so parity of the later ones proves the fresh-row reset
    (ssm/conv zeroed, length restarted) wipes the retired occupant."""
    cfg, params, _ = setup
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (20, 33, 18, 25)]
    sched = ServeScheduler(cfg, params, max_slots=1, max_len=64,
                           buckets=BUCKETS, tick_steps=2, chunked="always")
    for p in prompts:
        sched.submit(p, max_new=4)
    results = sched.run()
    assert sum(r.admitted_tick > 0 for r in results) >= 3
    for r, p in zip(results, prompts):
        assert r.tokens == _reference(cfg, params, p, 4), r.prompt_len


def test_chunked_validation(setup):
    cfg, params, _ = setup
    with pytest.raises(ValueError, match="chunked="):
        ServeScheduler(cfg, params, max_len=32, buckets=BUCKETS,
                       chunked="sometimes")
    with pytest.raises(ValueError, match="multiple of"):
        ServeScheduler(cfg, params, max_len=36, buckets=BUCKETS,
                       chunked="auto")          # 36 % 8 != 0
    with pytest.raises(ValueError, match="chunk_len"):
        ServeScheduler(cfg, params, max_len=32, buckets=BUCKETS,
                       chunked="auto", chunk_len=0)
    # chunked=True is accepted as "auto"; chunk_len irrelevant when off
    s = ServeScheduler(cfg, params, max_len=32, buckets=BUCKETS,
                       chunked=True)
    assert s.chunked == "auto" and s.chunk_len == BUCKETS[0]
    s = ServeScheduler(cfg, params, max_len=36, buckets=BUCKETS)
    assert s.chunked == "off"


def test_rejected_result_carries_timestamps(setup):
    """serve_bench derives TTFT/e2e from the result timestamps; rejected
    requests must carry submit/finish marks too (their e2e is the rejection
    turnaround) while first_token_time stays nan."""
    cfg, params, _ = setup
    sched = ServeScheduler(cfg, params, max_slots=1, max_len=32,
                           buckets=BUCKETS, tick_steps=2)
    rid = sched.submit(np.arange(17, dtype=np.int32), max_new=2)
    r = sched._results[rid]
    assert r.finish_reason == "rejected"
    assert np.isfinite(r.submit_time) and np.isfinite(r.finish_time)
    assert r.finish_time >= r.submit_time
    assert np.isnan(r.first_token_time)
