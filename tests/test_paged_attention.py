"""Paged-attention kernel harness (ISSUE 6): the fused Pallas decode kernel
(``kernels/paged_attention``) vs the dense-gather masked-einsum oracle, the
split-KV (m, l) partial-softmax merge numerics, and end-to-end scheduler
token parity with the kernel dispatched behind ``attn_kernel``.

Property tests use ``hypothesis`` when installed (``requirements-dev.txt``);
without it the same invariants run over a deterministic seeded lattice, so
``python -m pytest`` stays green on a bare ``jax + pytest`` environment.

Exactness bars (documented here, referenced from DESIGN.md):

* **kernel vs reference, float32**: the kernel reassociates the softmax
  (online (m, l) accumulation page by page) while the oracle computes it
  monolithically, so logits agree to f32 rounding of the reassociation —
  measured max abs error ~2e-7 on the lattice; asserted at
  ``rtol=2e-5, atol=2e-6`` (two orders of headroom).
* **bfloat16 inputs**: both paths accumulate in f32 but round the
  probabilities to bf16 before the PV product (matching the dense path's
  ``p.astype(q.dtype)``), so disagreement is bf16-rounding of nearly-equal
  p's; asserted at ``atol=2e-2``.
* **trash-page isolation / split padding / COW aliasing**: BITWISE.  A
  masked position's weight is ``exp(-1e30 - m)`` which underflows to exact
  0.0 in f32, so trash/junk values multiply by literal zero; an all-masked
  split merges with weight ``exp(-1e30 - M)`` = exact 0.0.  These are
  ``assert_array_equal``, not allclose.
* **scheduler tokens**: kernel path equals the dense-gather scheduler
  token-for-token on every tested seed/arch — same empirical bar as
  chunked-vs-bucketed prefill (reassociated logits make bitwise equality
  a per-seed fact, not a guarantee).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed (the deterministic "
                                "lattice covers the same invariants)")

from repro.configs import get_smoke
from repro.kernels.paged_attention.ops import (gather_traffic_counts,
                                               merge_split_softmax,
                                               paged_decode_attention)
from repro.kernels.paged_attention.ref import (NEG_INF,
                                               paged_attention_reference)
from repro.models import init_params
from repro.models.quantize import quantize_model_params
from repro.serving.kvpool import TRASH_PAGE
from repro.serving.scheduler import ServeScheduler

F32_TOL = dict(rtol=2e-5, atol=2e-6)
BF16_TOL = dict(rtol=0.0, atol=2e-2)


def _make_case(rng, *, page_len, nb, g, r, d, lengths, dtype=jnp.float32,
               poison=0.0):
    """Build a pool + per-row page table the way the scheduler lays them
    out: each row's first ``ceil(len/page_len)`` table entries name fresh
    pages, the rest point at the trash page (id 0), whose contents are
    ``poison``."""
    b = len(lengths)
    n_pages = 1 + b * nb
    k = rng.standard_normal((n_pages, page_len, g, d)).astype(np.float32)
    v = rng.standard_normal((n_pages, page_len, g, d)).astype(np.float32)
    k[TRASH_PAGE] = poison
    v[TRASH_PAGE] = poison
    table = np.full((b, nb), TRASH_PAGE, np.int32)
    nxt = 1
    for i, ln in enumerate(lengths):
        for j in range(-(-int(ln) // page_len)):
            table[i, j] = nxt
            nxt += 1
    q = rng.standard_normal((b, 1, g * r, d)).astype(np.float32)
    return (jnp.asarray(q, dtype), jnp.asarray(k, dtype),
            jnp.asarray(v, dtype), jnp.asarray(table),
            jnp.asarray(lengths, jnp.int32))


def _lengths_lattice(page_len, nb):
    """Per-row lengths covering the page-boundary lattice: empty row, one
    token, page_len +/- 1, exact multiples, and the full table."""
    mx = page_len * nb
    cand = [0, 1, page_len - 1, page_len, page_len + 1, 2 * page_len, mx]
    return [ln for ln in dict.fromkeys(cand) if 0 <= ln <= mx]


def _check_parity(rng, *, page_len, nb, g, r, d, splits, dtype=jnp.float32,
                  tol=F32_TOL):
    lengths = _lengths_lattice(page_len, nb)
    q, k, v, table, lens = _make_case(rng, page_len=page_len, nb=nb, g=g,
                                      r=r, d=d, lengths=lengths, dtype=dtype,
                                      poison=1e4)
    out = paged_decode_attention(q, k, v, table, lens, splits=splits)
    ref = paged_attention_reference(q, k, v, table, lens)
    live = np.asarray(lens) > 0
    np.testing.assert_allclose(np.asarray(out, np.float32)[live],
                               np.asarray(ref, np.float32)[live], **tol)
    # length-0 rows (free slots) are finite garbage, never NaN/inf — the
    # scheduler discards them, but a NaN would poison reductions upstream
    assert np.isfinite(np.asarray(out, np.float32)).all()


class TestKernelVsReference:
    """Deterministic parity lattice: page geometry x GQA grouping x splits
    (including splits that do NOT divide the block count, exercising the
    trash-column padding) x dtype, with the trash page poisoned at 1e4."""

    @pytest.mark.parametrize("page_len,nb", [(1, 4), (4, 4), (8, 3)])
    @pytest.mark.parametrize("g,r", [(1, 1), (2, 2), (1, 3)])
    def test_f32_lattice(self, page_len, nb, g, r):
        rng = np.random.default_rng(page_len * 100 + g * 10 + r)
        for splits in (1, 2, 3):
            _check_parity(rng, page_len=page_len, nb=nb, g=g, r=r, d=8,
                          splits=splits)

    def test_bf16_inputs(self):
        rng = np.random.default_rng(42)
        for splits in (1, 2):
            _check_parity(rng, page_len=4, nb=4, g=2, r=2, d=16,
                          splits=splits, dtype=jnp.bfloat16, tol=BF16_TOL)

    def test_gqa_wide_groups(self):
        rng = np.random.default_rng(7)
        _check_parity(rng, page_len=4, nb=2, g=3, r=4, d=16, splits=2)

    @needs_hypothesis
    def test_property_parity(self):
        @settings(max_examples=25, deadline=None)
        @given(page_len=st.integers(1, 8), nb=st.integers(1, 4),
               g=st.integers(1, 3), r=st.integers(1, 4),
               splits=st.integers(1, 4), seed=st.integers(0, 2 ** 16),
               data=st.data())
        def check(page_len, nb, g, r, splits, seed, data):
            mx = page_len * nb
            lengths = data.draw(st.lists(st.integers(0, mx), min_size=1,
                                         max_size=5))
            rng = np.random.default_rng(seed)
            q, k, v, table, lens = _make_case(
                rng, page_len=page_len, nb=nb, g=g, r=r, d=8,
                lengths=lengths, poison=1e4)
            out = paged_decode_attention(q, k, v, table, lens, splits=splits)
            ref = paged_attention_reference(q, k, v, table, lens)
            live = np.asarray(lens) > 0
            np.testing.assert_allclose(np.asarray(out)[live],
                                       np.asarray(ref)[live], **F32_TOL)
            assert np.isfinite(np.asarray(out)).all()
        check()


class TestTrashPageIsolation:
    """Trash-page contents can never reach the logits: outputs are BITWISE
    identical whatever page 0 holds, because every trash-slot position is
    masked to NEG_INF before the online max and its weight underflows to
    exact 0.0."""

    LENGTHS = [0, 1, 3, 4, 5, 16]

    def _outs(self, poison, splits):
        rng = np.random.default_rng(11)
        q, k, v, table, lens = _make_case(
            rng, page_len=4, nb=4, g=2, r=2, d=8,
            lengths=self.LENGTHS, poison=poison)
        return np.asarray(paged_decode_attention(q, k, v, table, lens,
                                                 splits=splits))

    @pytest.mark.parametrize("splits", [1, 2, 3])
    def test_poison_invisible_bitwise(self, splits):
        """Rows with >= 1 valid token: bitwise independent of trash
        contents.  A length-0 row reads ONLY trash pages — its output is
        poison-dependent garbage by construction, which is fine because
        the scheduler never reads a free slot's logits; the contract for
        those rows is finiteness only (no NaN to poison reductions)."""
        live = np.asarray(self.LENGTHS) > 0
        base = self._outs(0.0, splits)
        for poison in (1e4, -1e4):
            out = self._outs(poison, splits)
            np.testing.assert_array_equal(base[live], out[live])
            assert np.isfinite(out).all()

    def test_cow_aliased_tables(self):
        """Prefix-cache aliasing: rows whose tables share page ids (a radix
        hit refs the donor's pages) read identically to a deep-copied
        table — the kernel walk has no per-row ownership assumption."""
        rng = np.random.default_rng(12)
        q, k, v, table, lens = _make_case(
            rng, page_len=4, nb=4, g=2, r=2, d=8, lengths=[8, 9, 12])
        table = np.asarray(table).copy()
        # rows 1 and 2 alias row 0's first two pages (shared 8-token prefix)
        table[1, :2] = table[0, :2]
        table[2, :2] = table[0, :2]
        aliased = paged_decode_attention(q, k, v, jnp.asarray(table), lens,
                                         splits=2)
        # de-alias: copy the shared pages into fresh slots (what COW would
        # materialize) — bitwise-identical reads
        k2, v2 = np.asarray(k).copy(), np.asarray(v).copy()
        k2 = np.concatenate([k2, k2[table[0, :2]], k2[table[0, :2]]])
        v2 = np.concatenate([v2, v2[table[0, :2]], v2[table[0, :2]]])
        fresh = np.arange(len(k2) - 4, len(k2))
        t2 = table.copy()
        t2[1, :2] = fresh[:2]
        t2[2, :2] = fresh[2:]
        deep = paged_decode_attention(q, jnp.asarray(k2), jnp.asarray(v2),
                                      jnp.asarray(t2), lens, splits=2)
        np.testing.assert_array_equal(np.asarray(aliased), np.asarray(deep))


class TestSplitSoftmaxNumerics:
    """The (m, l) partial-reduction merge vs a monolithic softmax.

    Bar: with the global max subtracted, the merge recombination is the
    same sum the monolithic softmax computes, reassociated per split —
    f32 agreement to ``rtol=2e-5, atol=1e-7`` even at logits of +/-1e4
    (both sides are max-shifted so no exp overflows).  Degenerate cases
    (all-masked split, single valid token) are BITWISE."""

    def _partials(self, s, v, bounds):
        """Per-split online-softmax partials of logits ``s (R, K)`` against
        values ``v (K, D)``, split at ``bounds``."""
        ms, ls, accs = [], [], []
        for lo, hi in bounds:
            blk = s[:, lo:hi]
            m = np.max(blk, axis=1) if hi > lo else np.full(s.shape[0],
                                                            NEG_INF)
            p = np.exp(blk - m[:, None])
            ms.append(m)
            ls.append(p.sum(axis=1))
            accs.append(p @ v[lo:hi])
        return (jnp.asarray(np.stack(ms, 1), jnp.float32),
                jnp.asarray(np.stack(ls, 1), jnp.float32),
                jnp.asarray(np.stack(accs, 1), jnp.float32))

    def test_extreme_logits_match_monolithic(self):
        rng = np.random.default_rng(21)
        r, k_len, d = 4, 24, 8
        s = rng.choice([-1e4, -30.0, -1.0, 0.5, 30.0, 1e4],
                       size=(r, k_len)).astype(np.float32)
        v = rng.standard_normal((k_len, d)).astype(np.float32)
        m, l, acc = self._partials(s, v, [(0, 7), (7, 16), (16, 24)])
        merged = np.asarray(merge_split_softmax(m, l, acc, axis=1))
        mono = (np.exp(s - s.max(1, keepdims=True))
                / np.exp(s - s.max(1, keepdims=True)).sum(1, keepdims=True)
                ) @ v
        np.testing.assert_allclose(merged, mono, rtol=2e-5, atol=1e-7)

    def test_all_masked_split_is_bitwise_absent(self):
        """A split whose every position was masked carries m = NEG_INF and
        arbitrary junk in (l, acc); its merge weight exp(NEG_INF - M)
        underflows to exact 0.0, so the result is BITWISE the merge of the
        remaining splits."""
        rng = np.random.default_rng(22)
        r, k_len, d = 3, 12, 4
        s = rng.standard_normal((r, k_len)).astype(np.float32) * 5
        v = rng.standard_normal((k_len, d)).astype(np.float32)
        m, l, acc = self._partials(s, v, [(0, 6), (6, 12)])
        junk_m = jnp.full((r, 1), NEG_INF, jnp.float32)
        junk_l = jnp.full((r, 1), 123.456, jnp.float32)
        junk_a = jnp.full((r, 1, d), -777.0, jnp.float32)
        with_junk = merge_split_softmax(
            jnp.concatenate([m, junk_m], 1), jnp.concatenate([l, junk_l], 1),
            jnp.concatenate([acc, junk_a], 1), axis=1)
        without = merge_split_softmax(m, l, acc, axis=1)
        np.testing.assert_array_equal(np.asarray(with_junk),
                                      np.asarray(without))

    def test_all_splits_masked_is_finite(self):
        """Every split masked (a free slot's row): m = NEG_INF everywhere.
        The merge max-shifts to 0, so l stays positive and the output is
        finite garbage — never NaN (the scheduler discards these rows)."""
        m = jnp.full((2, 3), NEG_INF, jnp.float32)
        l = jnp.full((2, 3), 4.0, jnp.float32)
        acc = jnp.ones((2, 3, 5), jnp.float32)
        out = np.asarray(merge_split_softmax(m, l, acc, axis=1))
        assert np.isfinite(out).all()

    def test_single_valid_token_is_exact(self):
        """One valid token in one split: softmax collapses to probability
        1.0 exactly (p = exp(0), l = 1), so the output IS that token's
        value row, bitwise — however extreme its logit."""
        d = 6
        rng = np.random.default_rng(23)
        vrow = rng.standard_normal((1, d)).astype(np.float32)
        for logit in (-1e4, 0.0, 1e4):
            m = jnp.asarray([[NEG_INF, logit, NEG_INF]], jnp.float32)
            l = jnp.asarray([[7.0, 1.0, 7.0]], jnp.float32)
            acc = jnp.stack([jnp.full((1, d), 9.0), jnp.asarray(vrow),
                             jnp.full((1, d), -9.0)], 1)
            out = np.asarray(merge_split_softmax(m, l, acc, axis=1))
            np.testing.assert_array_equal(out[:, :], vrow)

    def test_kernel_splits_bitwise_vs_monolithic(self):
        """End-to-end split invariants.  (a) When every VALID page of every
        row lands in split 0 (lengths <= 8 of 16 slots, splits=2), the
        other split is all-masked junk and the output is BITWISE the
        splits=1 output.  (b) When valid pages SPAN splits (splits=4, one
        page per split), the merge reassociates — ``exp(s - m_local) *
        exp(m_local - M)`` vs the online path's running rescale — so the
        bar drops to the f32 reassociation tolerance, same as vs the
        oracle."""
        rng = np.random.default_rng(24)
        q, k, v, table, lens = _make_case(
            rng, page_len=4, nb=4, g=2, r=2, d=8, lengths=[4, 7, 8])
        base = np.asarray(paged_decode_attention(q, k, v, table, lens,
                                                 splits=1))
        out2 = np.asarray(paged_decode_attention(q, k, v, table, lens,
                                                 splits=2))
        np.testing.assert_array_equal(base, out2)
        out4 = np.asarray(paged_decode_attention(q, k, v, table, lens,
                                                 splits=4))
        np.testing.assert_allclose(base, out4, **F32_TOL)
        # row 0 (length 4) has its single valid page alone in split 0 even
        # at splits=4: still bitwise
        np.testing.assert_array_equal(base[0], out4[0])

    @needs_hypothesis
    def test_property_merge_associativity(self):
        @settings(max_examples=50, deadline=None)
        @given(seed=st.integers(0, 2 ** 16), n_splits=st.integers(1, 5),
               k_len=st.integers(1, 32))
        def check(seed, n_splits, k_len):
            rng = np.random.default_rng(seed)
            s = (rng.standard_normal((2, k_len)) * 50).astype(np.float32)
            v = rng.standard_normal((k_len, 4)).astype(np.float32)
            cuts = sorted(rng.integers(0, k_len + 1, size=n_splits - 1))
            bounds = list(zip([0] + list(cuts), list(cuts) + [k_len]))
            m, l, acc = self._partials(s, v, bounds)
            merged = np.asarray(merge_split_softmax(m, l, acc, axis=1))
            e = np.exp(s - s.max(1, keepdims=True))
            mono = (e / e.sum(1, keepdims=True)) @ v
            np.testing.assert_allclose(merged, mono, rtol=2e-5, atol=1e-6)
        check()


class TestGatherTraffic:
    def test_counts(self):
        table = np.zeros((3, 4), np.int32)
        touched, total = gather_traffic_counts(table, np.asarray([0, 1, 9]),
                                               page_len=4)
        assert total == 12.0          # dense gather streams every column
        assert touched == 0 + 1 + 3   # kernel walks only ceil(len/pl)


@pytest.fixture(scope="module")
def smollm_setup():
    cfg = get_smoke("smollm_135m").replace(dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 8, 3, 12, 7, 9)]
    return cfg, params, prompts


def _run_sched(cfg, params, prompts, max_new, **kw):
    kw2 = dict(max_slots=2, max_len=64, buckets=(8, 16), tick_steps=4,
               paged=True, page_len=8, prefix_cache=True)
    kw2.update(kw)
    sched = ServeScheduler(cfg, params, **kw2)
    for p in prompts:
        sched.submit(p, max_new=max_new)
    return [r.tokens for r in sched.run()]


class TestSchedulerKernelParity:
    """Acceptance: the kernel path serves the same tokens as the
    dense-gather scheduler (which ISSUE 5 proved bit-equal to the dense
    slab) — float and quantized, MHA and GQA, prefix cache on."""

    def test_smollm_float_tokens_equal(self, smollm_setup):
        cfg, params, prompts = smollm_setup
        dense = _run_sched(cfg, params, prompts, 7)
        for splits in (1, 2):
            kern = _run_sched(cfg, params, prompts, 7, attn_kernel=True,
                              attn_splits=splits)
            assert dense == kern

    def test_smollm_quant_tokens_equal(self, smollm_setup):
        cfg, params, prompts = smollm_setup
        qparams = quantize_model_params(cfg, params)
        dense = _run_sched(cfg, qparams, prompts, 5, quant="xla")
        kern = _run_sched(cfg, qparams, prompts, 5, quant="xla",
                          attn_kernel=True, attn_splits=2)
        assert dense == kern

    def test_qwen3_gqa_tokens_equal(self):
        cfg = get_smoke("qwen3_32b").replace(dtype=jnp.float32)
        params = init_params(jax.random.PRNGKey(1), cfg)
        rng = np.random.default_rng(2)
        prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
                   for n in (4, 11, 6)]
        dense = _run_sched(cfg, params, prompts, 5)
        kern = _run_sched(cfg, params, prompts, 5, attn_kernel="pallas",
                          attn_splits=2)
        assert dense == kern

    def test_kernel_requires_paged(self, smollm_setup):
        cfg, params, _ = smollm_setup
        with pytest.raises(ValueError, match="requires paged"):
            ServeScheduler(cfg, params, max_slots=2, max_len=64,
                           buckets=(8,), attn_kernel=True)
        with pytest.raises(ValueError, match="attn_splits"):
            ServeScheduler(cfg, params, max_slots=2, max_len=64,
                           buckets=(8,), paged=True, page_len=8,
                           attn_kernel=True, attn_splits=0)
