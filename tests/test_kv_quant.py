"""Log2-quantized KV cache pages (ISSUE 9): the packed-code page pool +
f32 tail ring vs the dense paged path, at every layer of the stack —
pool-primitive round-trips, the fused quant kernel vs the dequantized-pool
oracle, adversarial garbage-code isolation, and end-to-end scheduler token
parity (gather vs kernel, prefix hits, COW partial pages, SSM snapshot
restore, GQA, meshes).

Property tests use ``hypothesis`` when installed (``requirements-dev.txt``);
without it the same invariants run over a deterministic seeded lattice.

Exactness bars (referenced from DESIGN.md §Quantized KV pages):

* **code round-trip**: BITWISE.  ``dequantize -> requantize`` under the
  same power-of-two page scale reproduces the packed codes byte-for-byte
  (the rewrite invariant the per-tick page rewrite depends on) — the
  scale never perturbs the mantissa, a decoded power of two re-rounds to
  itself, and pruned values carry the canonical positive-sign sentinel.
* **quant kernel vs dequantized-pool oracle, float32**: the kernel fuses
  the dequant into its block loads, so vs a dense kernel run over the
  *dequantized* pool (tail pages replaced by the ring's exact rows) the
  only difference is softmax reassociation — ``rtol=2e-5, atol=2e-6``,
  the same bar as the dense kernel vs its oracle.
* **garbage-code isolation**: BITWISE.  Trash-page codes/scales and the
  tail ring's dead half decode to large-but-finite values (the summed
  exponent is clamped to the f32 normal range) and are masked before the
  online max, so live-row outputs are ``assert_array_equal``-independent
  of them.
* **scheduler tokens**: quant-gather vs quant-kernel, and prefix-hit vs
  miss admissions of the same prompt, agree token-for-token on every
  tested seed/arch — the same empirical per-seed bar as the dense kernel
  parity suite.  Dense-vs-quant token *divergence* is a measured number,
  EXACT-gated by the ``serve_bench --kv-quant`` baseline, not asserted
  here.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed (the deterministic "
                                "lattice covers the same invariants)")

from repro.configs import get_smoke
from repro.core import (code_dtype, dequantize_page_codes,
                        quantize_page_codes, scale_exponent)
from repro.core.logquant import unpack_codes, zero_sentinel
from repro.kernels.paged_attention.ops import (paged_decode_attention,
                                               paged_decode_attention_quant)
from repro.models import init_params
from repro.serving import engine
from repro.serving.kvpool import TRASH_PAGE
from repro.serving.scheduler import ServeScheduler

F32_TOL = dict(rtol=2e-5, atol=2e-6)
N_BITS_SWEEP = (2, 3, 4, 5, 8)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# pool-primitive round trips (the rewrite invariant)
# ---------------------------------------------------------------------------

def _check_requant_bit_stable(x, n_bits):
    """quantize -> dequantize -> requantize under the SAME scale is a
    bitwise fixed point — what lets the serve tick rewrite a partial
    page's codes every step without drift."""
    x = jnp.asarray(x, jnp.float32)
    se = scale_exponent(x, axis=-1, keepdims=True)
    c1 = quantize_page_codes(x, se, n_bits)
    xh = dequantize_page_codes(c1, se, n_bits)
    c2 = quantize_page_codes(xh, se, n_bits)
    assert c1.dtype == code_dtype(n_bits)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


def _check_dequant_half_octave(x, n_bits):
    """Non-pruned, non-clipped entries decode within half an octave of the
    original (round-to-nearest exponent)."""
    x = jnp.asarray(x, jnp.float32)
    se = scale_exponent(x, axis=-1, keepdims=True)
    codes = quantize_page_codes(x, se, n_bits)
    xh = np.asarray(dequantize_page_codes(codes, se, n_bits))
    q = unpack_codes(codes, n_bits)
    emax = (1 << (n_bits - 1)) - 1
    free = (np.asarray(q.exp) != zero_sentinel(n_bits)) \
        & (np.asarray(q.exp) != emax) & (np.asarray(x) != 0)
    if not free.any():
        return
    ratio = np.abs(xh[free]) / np.abs(np.asarray(x)[free])
    assert ((ratio >= 2 ** -0.51) & (ratio <= 2 ** 0.51)).all()
    # signs survive the trip wherever the value wasn't pruned
    np.testing.assert_array_equal(np.sign(xh[free]),
                                  np.sign(np.asarray(x)[free]))


def _seeded_rows(n_rows=12, width=32):
    rng = np.random.default_rng(77)
    out = []
    for i in range(n_rows):
        mag = rng.choice([1e-5, 1e-2, 0.5, 1.0, 64.0, 1e3], width)
        x = (rng.normal(0, 1.0, width) * mag).astype(np.float32)
        x[rng.random(width) < 0.15] = 0.0       # exact zeros (sentinel path)
        if i % 3 == 0:
            x = -np.abs(x)                       # all-negative rows
        out.append(x)
    return out


class TestPageCodeRoundTrip:
    @pytest.mark.parametrize("n_bits", N_BITS_SWEEP)
    def test_requant_bit_stable_seeded(self, n_bits):
        for x in _seeded_rows():
            _check_requant_bit_stable(x, n_bits)

    @pytest.mark.parametrize("n_bits", N_BITS_SWEEP)
    def test_dequant_half_octave_seeded(self, n_bits):
        for x in _seeded_rows():
            _check_dequant_half_octave(x, n_bits)

    @needs_hypothesis
    def test_requant_bit_stable_property(self):
        @settings(max_examples=150, deadline=None)
        @given(n_bits=st.sampled_from(N_BITS_SWEEP),
               xs=st.lists(st.floats(min_value=-1e4, max_value=1e4, width=32,
                                     allow_nan=False, allow_infinity=False),
                           min_size=1, max_size=64))
        def run(n_bits, xs):
            _check_requant_bit_stable(np.asarray(xs, np.float32), n_bits)
        run()

    def test_zero_page_quantizes_to_sentinel_codes(self):
        """An all-zero page (fresh pool) stores the canonical sentinel code
        everywhere and decodes back to exact +0.0."""
        x = jnp.zeros((4, 8), jnp.float32)
        se = scale_exponent(x, axis=-1, keepdims=True)
        codes = quantize_page_codes(x, se, 4)
        want = np.int8(zero_sentinel(4) << 1)    # positive-sign sentinel
        np.testing.assert_array_equal(np.asarray(codes),
                                      np.full((4, 8), want, np.int8))
        back = np.asarray(dequantize_page_codes(codes, se, 4))
        np.testing.assert_array_equal(back, np.zeros((4, 8), np.float32))
        assert not np.signbit(back).any()

    def test_garbage_scale_decodes_finite(self):
        """Trash-page scales are arbitrary int32 garbage; the dequant clamp
        keeps every decode finite (masking, not saturation arithmetic,
        erases them downstream)."""
        codes = jnp.asarray(np.random.default_rng(0).integers(
            -128, 128, (3, 16)), jnp.int8)
        for se in (10 ** 9, -10 ** 9, 127, -127):
            out = np.asarray(dequantize_page_codes(
                codes, jnp.full((3, 1), se, jnp.int32), 4))
            assert np.isfinite(out).all()


# ---------------------------------------------------------------------------
# quant kernel vs dequantized-pool oracle
# ---------------------------------------------------------------------------

def _make_quant_case(rng, *, page_len, nb, g, r, d, lengths, n_bits=4,
                     trash_garbage=0):
    """Scheduler-layout quant pool: per-row fresh pages + the trash page,
    packed codes with per-(page, head) scales from each page's first row,
    and a 2-page dense tail ring whose *active* half holds the tail-page
    positions exactly — the dead half and the junk bin are garbage."""
    b = len(lengths)
    n_pages = 1 + b * nb
    k = rng.standard_normal((n_pages, page_len, g, d)).astype(np.float32)
    v = rng.standard_normal((n_pages, page_len, g, d)).astype(np.float32)
    table = np.full((b, nb), TRASH_PAGE, np.int32)
    nxt = 1
    for i, ln in enumerate(lengths):
        for j in range(-(-int(ln) // page_len)):
            table[i, j] = nxt
            nxt += 1
    q = rng.standard_normal((b, 1, g * r, d)).astype(np.float32)

    def quantize(pool):
        se = scale_exponent(jnp.asarray(pool[:, 0]), axis=-1)       # (P, G)
        codes = quantize_page_codes(jnp.asarray(pool),
                                    se[:, None, :, None], n_bits)
        return np.asarray(codes).copy(), np.asarray(se, np.int32).copy()

    kc, ks = quantize(k)
    vc, vs = quantize(v)
    # trash-page garbage: arbitrary codes + scales (never a valid write)
    grng = np.random.default_rng(1000 + trash_garbage)
    lo, hi = (-(1 << 8), 1 << 8) if n_bits >= 8 else (-128, 128)
    for c, s in ((kc, ks), (vc, vs)):
        c[TRASH_PAGE] = grng.integers(lo, hi, c[TRASH_PAGE].shape)
        s[TRASH_PAGE] = grng.integers(-10 ** 9, 10 ** 9, s[TRASH_PAGE].shape)

    ring = 2 * page_len
    k_tail = grng.standard_normal((b, ring + 1, g, d)).astype(np.float32) * 1e3
    v_tail = grng.standard_normal((b, ring + 1, g, d)).astype(np.float32) * 1e3
    k_ref = np.asarray(dequantize_page_codes(
        jnp.asarray(kc), jnp.asarray(ks)[:, None, :, None], n_bits)).copy()
    v_ref = np.asarray(dequantize_page_codes(
        jnp.asarray(vc), jnp.asarray(vs)[:, None, :, None], n_bits)).copy()
    for i, ln in enumerate(lengths):
        tb = max(int(ln) - 1, 0) // page_len
        pg = table[i, tb]
        if pg == TRASH_PAGE:
            continue
        half = (tb % 2) * page_len
        k_tail[i, half:half + page_len] = k[pg]
        v_tail[i, half:half + page_len] = v[pg]
        # the oracle's dense pool: dequantized codes everywhere EXCEPT the
        # tail page, which reads the ring's exact rows
        k_ref[pg] = k[pg]
        v_ref[pg] = v[pg]
    as_j = jnp.asarray
    return dict(q=as_j(q), kc=as_j(kc, code_dtype(n_bits)), ks=as_j(ks),
                vc=as_j(vc, code_dtype(n_bits)), vs=as_j(vs),
                k_tail=as_j(k_tail), v_tail=as_j(v_tail),
                k_ref=as_j(k_ref), v_ref=as_j(v_ref),
                table=as_j(table), lens=as_j(lengths, jnp.int32))


def _lengths_lattice(page_len, nb):
    mx = page_len * nb
    cand = [0, 1, page_len - 1, page_len, page_len + 1, 2 * page_len, mx]
    return [ln for ln in dict.fromkeys(cand) if 0 <= ln <= mx]


def _quant_out(c, n_bits, splits):
    return paged_decode_attention_quant(
        c["q"], c["kc"], c["ks"], c["vc"], c["vs"], c["k_tail"], c["v_tail"],
        c["table"], c["lens"], n_bits=n_bits, splits=splits)


class TestQuantKernelVsOracle:
    """The fused dequant (codes + scale -> block rows inside the kernel)
    plus the tail-ring extra split must equal a dense kernel run over the
    dequantized pool with exact tail pages — reassociation tolerance only."""

    @pytest.mark.parametrize("page_len,nb", [(1, 4), (4, 4), (8, 3)])
    @pytest.mark.parametrize("g,r", [(1, 1), (2, 2), (1, 3)])
    def test_f32_lattice(self, page_len, nb, g, r):
        rng = np.random.default_rng(page_len * 100 + g * 10 + r)
        lengths = _lengths_lattice(page_len, nb)
        c = _make_quant_case(rng, page_len=page_len, nb=nb, g=g, r=r, d=8,
                             lengths=lengths)
        live = np.asarray(c["lens"]) > 0
        for splits in (1, 2, 3):
            out = np.asarray(_quant_out(c, 4, splits), np.float32)
            ref = np.asarray(paged_decode_attention(
                c["q"], c["k_ref"], c["v_ref"], c["table"], c["lens"],
                splits=1), np.float32)
            np.testing.assert_allclose(out[live], ref[live], **F32_TOL)
            assert np.isfinite(out).all()

    @pytest.mark.parametrize("n_bits", N_BITS_SWEEP)
    def test_n_bits_sweep(self, n_bits):
        """The oracle is the dequantized pool, so parity is exact-bar at
        every width — 2-bit's huge quantization error cancels on both
        sides; what's tested is the fused dequant arithmetic."""
        rng = np.random.default_rng(300 + n_bits)
        c = _make_quant_case(rng, page_len=4, nb=4, g=2, r=2, d=8,
                             lengths=[0, 1, 3, 4, 5, 9, 16], n_bits=n_bits)
        live = np.asarray(c["lens"]) > 0
        out = np.asarray(_quant_out(c, n_bits, 2), np.float32)
        ref = np.asarray(paged_decode_attention(
            c["q"], c["k_ref"], c["v_ref"], c["table"], c["lens"],
            splits=1), np.float32)
        np.testing.assert_allclose(out[live], ref[live], **F32_TOL)

    def test_gqa_wide_groups(self):
        rng = np.random.default_rng(7)
        c = _make_quant_case(rng, page_len=4, nb=2, g=3, r=4, d=16,
                             lengths=[7, 8, 3, 0])
        live = np.asarray(c["lens"]) > 0
        out = np.asarray(_quant_out(c, 4, 2), np.float32)
        ref = np.asarray(paged_decode_attention(
            c["q"], c["k_ref"], c["v_ref"], c["table"], c["lens"], splits=1),
            np.float32)
        np.testing.assert_allclose(out[live], ref[live], **F32_TOL)

    @needs_hypothesis
    def test_property_parity(self):
        @settings(max_examples=20, deadline=None)
        @given(page_len=st.integers(1, 8), nb=st.integers(1, 4),
               g=st.integers(1, 3), r=st.integers(1, 3),
               n_bits=st.sampled_from(N_BITS_SWEEP),
               splits=st.integers(1, 4), seed=st.integers(0, 2 ** 16),
               data=st.data())
        def check(page_len, nb, g, r, n_bits, splits, seed, data):
            mx = page_len * nb
            lengths = data.draw(st.lists(st.integers(0, mx), min_size=1,
                                         max_size=5))
            rng = np.random.default_rng(seed)
            c = _make_quant_case(rng, page_len=page_len, nb=nb, g=g, r=r,
                                 d=8, lengths=lengths, n_bits=n_bits)
            live = np.asarray(c["lens"]) > 0
            out = np.asarray(_quant_out(c, n_bits, splits), np.float32)
            ref = np.asarray(paged_decode_attention(
                c["q"], c["k_ref"], c["v_ref"], c["table"], c["lens"],
                splits=1), np.float32)
            np.testing.assert_allclose(out[live], ref[live], **F32_TOL)
            assert np.isfinite(out).all()
        check()


class TestGarbageIsolation:
    """Adversarial bytes: trash-page codes/scales, the ring's dead half,
    and the junk bin vary — live-row outputs must be BITWISE identical."""

    LENGTHS = [0, 1, 3, 4, 5, 9, 16]

    def _outs(self, garbage, splits):
        rng = np.random.default_rng(31)
        c = _make_quant_case(rng, page_len=4, nb=4, g=2, r=2, d=8,
                             lengths=self.LENGTHS, trash_garbage=garbage)
        return np.asarray(_quant_out(c, 4, splits))

    @pytest.mark.parametrize("splits", [1, 2, 3])
    def test_garbage_bitwise_invisible(self, splits):
        live = np.asarray(self.LENGTHS) > 0
        base = self._outs(0, splits)
        for garbage in (1, 2):
            out = self._outs(garbage, splits)
            np.testing.assert_array_equal(base[live], out[live])
            assert np.isfinite(out).all()

    def test_aliased_tables_read_like_copies(self):
        """Prefix-cache aliasing: rows sharing page ids (codes AND scales)
        read bitwise like rows with deep-copied pages."""
        rng = np.random.default_rng(32)
        c = _make_quant_case(rng, page_len=4, nb=4, g=2, r=2, d=8,
                             lengths=[8, 9, 12])
        table = np.asarray(c["table"]).copy()
        table[1, :2] = table[0, :2]
        table[2, :2] = table[0, :2]
        aliased = np.asarray(paged_decode_attention_quant(
            c["q"], c["kc"], c["ks"], c["vc"], c["vs"], c["k_tail"],
            c["v_tail"], jnp.asarray(table), c["lens"], n_bits=4, splits=2))
        # materialize the copies (what COW does: codes + scale together)
        kc, ks = np.asarray(c["kc"]).copy(), np.asarray(c["ks"]).copy()
        vc, vs = np.asarray(c["vc"]).copy(), np.asarray(c["vs"]).copy()
        src = table[0, :2]
        kc = np.concatenate([kc, kc[src], kc[src]])
        ks = np.concatenate([ks, ks[src], ks[src]])
        vc = np.concatenate([vc, vc[src], vc[src]])
        vs = np.concatenate([vs, vs[src], vs[src]])
        fresh = np.arange(len(kc) - 4, len(kc))
        t2 = table.copy()
        t2[1, :2] = fresh[:2]
        t2[2, :2] = fresh[2:]
        deep = np.asarray(paged_decode_attention_quant(
            c["q"], jnp.asarray(kc), jnp.asarray(ks), jnp.asarray(vc),
            jnp.asarray(vs), c["k_tail"], c["v_tail"], jnp.asarray(t2),
            c["lens"], n_bits=4, splits=2))
        np.testing.assert_array_equal(aliased, deep)


# ---------------------------------------------------------------------------
# end-to-end scheduler parity
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smollm_setup():
    cfg = get_smoke("smollm_135m").replace(dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 8, 3, 12, 7, 9)]
    return cfg, params, prompts


def _run_sched(cfg, params, prompts, max_new, **kw):
    kw2 = dict(max_slots=2, max_len=64, buckets=(8, 16), tick_steps=4,
               paged=True, page_len=8, prefix_cache=True)
    kw2.update(kw)
    sched = ServeScheduler(cfg, params, **kw2)
    for p in prompts:
        sched.submit(p, max_new=max_new)
    return sched, [r.tokens for r in sched.run()]


class TestSchedulerQuantParity:
    def test_gather_vs_kernel_tokens_equal(self, smollm_setup):
        """The fused quant kernel serves the same tokens as the dequant
        gather path — both read identical bytes (codes, scales, ring), so
        agreement is the dense suite's empirical per-seed bar."""
        cfg, params, prompts = smollm_setup
        _, gather = _run_sched(cfg, params, prompts, 7, kv_quant=True)
        for splits in (1, 2):
            _, kern = _run_sched(cfg, params, prompts, 7, kv_quant=True,
                                 attn_kernel=True, attn_splits=splits)
            assert gather == kern

    def test_deterministic_and_page_len_lattice(self, smollm_setup):
        """Quant serving is deterministic, and every page_len geometry
        (1-token pages, small, default) serves full-length results."""
        cfg, params, prompts = smollm_setup
        for pl in (1, 4, 8):
            _, a = _run_sched(cfg, params, prompts, 5, kv_quant=True,
                              page_len=pl)
            _, b = _run_sched(cfg, params, prompts, 5, kv_quant=True,
                              page_len=pl)
            assert a == b and all(len(t) == 5 for t in a)

    def test_kv_bits_widths_serve(self, smollm_setup):
        cfg, params, prompts = smollm_setup
        for nb in (2, 8):
            _, out = _run_sched(cfg, params, prompts[:3], 4, kv_quant=True,
                                kv_bits=nb, page_len=4)
            assert all(len(t) == 4 for t in out)

    def test_prefix_hit_reproduces_miss_tokens(self, smollm_setup):
        """An exact-repeat prompt served off the prefix cache (aliased
        quant pages + tail-ring restore from dequantized codes) produces
        the same tokens as its miss-path twin — tested seed."""
        cfg, params, _ = smollm_setup
        rng = np.random.default_rng(1)
        base = rng.integers(0, cfg.vocab_size, size=12).astype(np.int32)
        prompts = [base, np.concatenate([base, [5, 7]]), base.copy(),
                   np.concatenate([base, [9]])]
        sched, out = _run_sched(cfg, params, prompts, 6, kv_quant=True,
                                page_len=4)
        assert out[0] == out[2]
        assert sched.prefix_cache_stats()["lookup_hits"] >= 2
        _, kern = _run_sched(cfg, params, prompts, 6, kv_quant=True,
                             page_len=4, attn_kernel=True, attn_splits=2)
        assert out == kern

    def test_cow_partial_page_hit(self, smollm_setup):
        """A prefix ending mid-page: the hit COWs the donor's partial
        quantized page — codes and scale move together — and the tail ring
        is restored from the copied page's dequantized rows."""
        cfg, params, _ = smollm_setup
        rng = np.random.default_rng(4)
        prefix = rng.integers(0, cfg.vocab_size, size=28).astype(np.int32)
        prompts = [np.concatenate([prefix,
                                   rng.integers(0, cfg.vocab_size,
                                                size=t).astype(np.int32)])
                   for t in (6, 5, 4)]
        kw = dict(max_slots=1, buckets=(8, 16, 32), chunked="auto",
                  kv_quant=True)
        sched, out = _run_sched(cfg, params, prompts, 7, **kw)
        st = sched.prefix_cache_stats()
        assert st["cached_tokens"] == 2 * 28, st   # 24 whole-page + 4 COW
        _, again = _run_sched(cfg, params, prompts, 7, **kw)
        assert out == again
        _, kern = _run_sched(cfg, params, prompts, 7, attn_kernel=True,
                             attn_splits=2, **kw)
        assert out == kern

    def test_gqa_tokens_gather_vs_kernel(self):
        cfg = get_smoke("qwen3_32b").replace(dtype=jnp.float32)
        params = init_params(jax.random.PRNGKey(1), cfg)
        rng = np.random.default_rng(2)
        prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
                   for n in (4, 11, 6)]
        _, gather = _run_sched(cfg, params, prompts, 5, kv_quant=True)
        _, kern = _run_sched(cfg, params, prompts, 5, kv_quant=True,
                             attn_kernel=True, attn_splits=2)
        assert gather == kern

    def test_ssm_arch_is_bit_equal_noop(self):
        """A pure-SSM model has no KV pages — kv_quant must be an exact
        no-op: tokens bit-equal to per-request greedy_generate, snapshot
        prefix hits included."""
        cfg = get_smoke("mamba2_780m").replace(dtype=jnp.float32)
        params = init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(5)
        prefix = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
        prompts = [np.concatenate([prefix,
                                   rng.integers(0, cfg.vocab_size,
                                                size=t).astype(np.int32)])
                   for t in (5, 4, 6)]
        sched, out = _run_sched(cfg, params, prompts, 6, max_slots=1,
                                buckets=(8, 16, 32), tick_steps=3,
                                chunked="always", chunk_len=8, kv_quant=True)
        for tokens, p in zip(out, prompts):
            np.testing.assert_array_equal(
                np.asarray(tokens),
                np.asarray(engine.greedy_generate(
                    cfg, params, jnp.asarray(p)[None], max_new=6))[0])
        assert sched.prefix_cache_stats()["lookup_hits"] == 2

    def test_hybrid_snapshot_restore(self):
        """Hybrid (mamba + attn): snapshot hits restore the SSM state AND
        the quantized KV tail ring; repeats reproduce and hits fire."""
        cfg = get_smoke("jamba_v01_52b").replace(dtype=jnp.float32)
        params = init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(5)
        prefix = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
        prompts = [np.concatenate([prefix,
                                   rng.integers(0, cfg.vocab_size,
                                                size=t).astype(np.int32)])
                   for t in (5, 4, 6)]
        kw = dict(max_slots=1, buckets=(8, 16, 32), tick_steps=3,
                  chunked="always", chunk_len=8, kv_quant=True)
        sched, out = _run_sched(cfg, params, prompts, 6, **kw)
        assert sched.prefix_cache_stats()["lookup_hits"] == 2
        _, again = _run_sched(cfg, params, prompts, 6, **kw)
        assert out == again

    def test_constructor_validation(self, smollm_setup):
        cfg, params, _ = smollm_setup
        with pytest.raises(ValueError, match="requires paged"):
            ServeScheduler(cfg, params, max_slots=2, max_len=64, buckets=(8,),
                           kv_quant=True)
        with pytest.raises(ValueError, match="kv_bits"):
            ServeScheduler(cfg, params, max_slots=2, max_len=64, buckets=(8,),
                           paged=True, page_len=8, kv_quant=True, kv_bits=1)
        with pytest.raises(ValueError, match="kv_bits"):
            ServeScheduler(cfg, params, max_slots=2, max_len=64, buckets=(8,),
                           paged=True, page_len=8, kv_quant=True, kv_bits=9)


_SHARDED_BODY = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke
from repro.models import init_params
from repro.serving.scheduler import ServeScheduler
from repro.launch.mesh import make_serve_mesh

cfg = get_smoke("smollm_135m").replace(dtype=jnp.float32)
params = init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
           for n in (5, 12, 3, 9, 30)]

def run(mesh, **kw):
    sched = ServeScheduler(cfg, params, max_slots=2, max_len=64,
                           buckets=(8, 16), tick_steps=4, mesh=mesh,
                           paged=True, page_len=8, prefix_cache=True,
                           chunked="auto", kv_quant=True, **kw)
    for p in prompts:
        sched.submit(p, max_new=8)
    res = sched.run()
    assert all(r.finish_reason == "length" for r in res), res
    return [r.tokens for r in res]

base = run(None)
assert run(None, attn_kernel=True, attn_splits=2) == base
for spec in ("2x2", "4x1"):
    assert run(make_serve_mesh(spec)) == base, spec
    assert run(make_serve_mesh(spec), attn_kernel=True,
               attn_splits=2) == base, spec
    print("kv_quant", spec, "BIT-EQUAL")
print("ok")
"""


class TestShardedQuantScheduler:
    """Quantized pools under a mesh: codes/scales sharded pages-on-data,
    tail rings batch-on-data (launch.shardings.cache_shardings) — tokens
    bit-equal to the single-device quant scheduler, gather + kernel,
    chunked ingestion and prefix hits included."""

    def test_bit_equal_2x2_and_4x1(self):
        src = ("import os\n"
               "os.environ['XLA_FLAGS'] = "
               "'--xla_force_host_platform_device_count=8'\n"
               + textwrap.dedent(_SHARDED_BODY))
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        out = subprocess.run([sys.executable, "-c", src],
                             capture_output=True, text=True, timeout=560,
                             env=env, cwd=REPO)
        assert out.returncode == 0, \
            f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
        assert out.stdout.count("BIT-EQUAL") == 2 and "ok" in out.stdout
