"""Multi-device tests (8 forced host devices, run in subprocesses so the
main pytest process keeps its single real device — per the dry-run rule
that the device-count flag must never be set globally)."""

import os
import subprocess
import sys
import textwrap


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str, devices: int = 8, timeout: int = 560) -> str:
    src = ("import os\n"
           f"os.environ['XLA_FLAGS'] = "
           f"'--xla_force_host_platform_device_count={devices}'\n"
           + textwrap.dedent(body))
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", src], capture_output=True,
                         text=True, timeout=timeout, env=env, cwd=REPO)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


class TestShardedTraining:
    def test_train_step_dp_tp(self):
        out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_smoke
        from repro.models import init_params
        from repro.models.sharding import mesh_axes
        from repro.optim import adamw
        from repro.train.trainer import TrainConfig, make_train_step
        from repro.launch.shardings import (batch_shardings, opt_shardings,
                                            params_shardings)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_smoke("qwen3_32b")
        with mesh, mesh_axes(batch=("data",), model="model", seq_shard=True,
                             sizes=dict(mesh.shape), mesh=mesh):
            params = init_params(jax.random.PRNGKey(0), cfg)
            psh = params_shardings(mesh, params, fsdp_threshold=1)
            params = jax.device_put(params, psh)
            opt = adamw.init(params)
            osh = opt_shardings(mesh, opt, psh)
            opt = jax.device_put(opt, osh)
            batch = {"tokens": jnp.zeros((8, 32), jnp.int32),
                     "labels": jnp.zeros((8, 32), jnp.int32)}
            bsh = batch_shardings(mesh, batch)
            batch = jax.device_put(batch, bsh)
            step = jax.jit(make_train_step(cfg, TrainConfig()),
                           in_shardings=(psh, osh, bsh),
                           donate_argnums=(0, 1))
            params, opt, metrics = step(params, opt, batch)
            print("loss", float(metrics["loss"]))
            assert np.isfinite(float(metrics["loss"]))
        """)
        assert "loss" in out

    def test_moe_ep_shardmap_matches_local(self):
        out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke
        from repro.models import init_params, forward
        from repro.models.sharding import mesh_axes

        cfg = get_smoke("phi35_moe_42b").replace(dtype=jnp.float32,
                                                 capacity_factor=100.0)
        params = init_params(jax.random.PRNGKey(0), cfg)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                    cfg.vocab_size)
        ref, _ = forward(cfg, params, tokens=tokens)       # local path

        mesh = jax.make_mesh((2, 4), ("data", "model"))    # E=4 experts / 4
        with mesh, mesh_axes(batch=("data",), model="model", seq_shard=True,
                             sizes=dict(mesh.shape), mesh=mesh):
            got, _ = jax.jit(lambda p, t: forward(cfg, p, tokens=t))(
                params, tokens)
        err = float(jnp.max(jnp.abs(ref - got)))
        print("ep vs local err", err)
        assert err < 1e-3 * float(jnp.max(jnp.abs(ref)) + 1)
        """)
        assert "ep vs local err" in out

    def test_pipeline_forward_matches_sequential(self):
        out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.train.pipeline import make_pipelined_fn

        n_stages, n_micro, mb, d = 8, 4, 2, 16
        mesh = jax.make_mesh((n_stages,), ("pipe",))
        key = jax.random.PRNGKey(0)
        ws = jax.random.normal(key, (n_stages, d, d)) * 0.3

        def stage_fn(w, x):
            return jnp.tanh(x @ w)

        x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))
        piped = make_pipelined_fn(mesh, stage_fn)
        got = piped(ws, x)
        ref = x
        for i in range(n_stages):
            ref = jax.vmap(lambda xm: stage_fn(ws[i], xm))(ref)
        err = float(jnp.max(jnp.abs(got - ref)))
        print("pipeline err", err)
        assert err < 1e-5
        """)
        assert "pipeline err" in out

    def test_compressed_psum_matches_mean(self):
        out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.optim.compress import compressed_psum_mean
        from repro.models.sharding import shard_map

        mesh = jax.make_mesh((8,), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 64))

        def body(gl):
            red, err = compressed_psum_mean({"g": gl}, "data")
            return red["g"], err["g"]

        fn = shard_map(body, mesh=mesh, in_specs=P("data"),
                       out_specs=(P(), P("data")), check_vma=False)
        red, err = fn(g)
        true_mean = jnp.mean(g.reshape(8, 1, 64), axis=0)
        rel = float(jnp.max(jnp.abs(red[0] - true_mean)) /
                    (jnp.max(jnp.abs(true_mean)) + 1e-9))
        print("compress rel err", rel)
        assert rel < 0.02            # int8 quantization error bound
        # error feedback residual == what was lost
        assert float(jnp.max(jnp.abs(err))) < float(jnp.max(jnp.abs(g))) / 64
        """)
        assert "compress rel err" in out


class TestElasticRestart:
    def test_checkpoint_reshards_on_new_mesh(self, tmp_path):
        # save on a (4,2) mesh, restore on (2,4) — elastic restart
        out = run_py(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.checkpoint import CheckpointManager
        from repro.launch.shardings import params_shardings

        tree = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
        mesh1 = jax.make_mesh((4, 2), ("data", "model"))
        sh1 = params_shardings(mesh1, tree, fsdp_threshold=1)
        t1 = jax.device_put(tree, sh1)
        mgr = CheckpointManager(r"{tmp_path}", keep=2)
        mgr.save(1, t1)

        mesh2 = jax.make_mesh((2, 4), ("data", "model"))
        sh2 = params_shardings(mesh2, tree, fsdp_threshold=1)
        t2 = mgr.restore(1, tree, sh2)
        np.testing.assert_array_equal(np.asarray(t2["w"]), np.asarray(tree["w"]))
        print("elastic ok", t2["w"].sharding)
        """)
        assert "elastic ok" in out
