"""Tests for tools/bench_check.py — the bench-drift gate CI relies on.

Covers the tolerance-class contract: exact metrics fail on any change,
tight metrics respect the 2% rtol, advisory metrics never fail, and
``--update`` rewrites the baselines from the fresh run."""

import importlib.util
import json
import os

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_spec = importlib.util.spec_from_file_location(
    "bench_check", os.path.join(REPO, "tools", "bench_check.py"))
bench_check = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(bench_check)


def _write_fresh(tmp_path, rows, bench="demo", name="fresh.txt"):
    p = tmp_path / name
    p.write_text("# json " + json.dumps({"bench": bench, "rows": rows})
                 + "\n")
    return str(p)


def _write_baseline(tmp_path, rows, bench="demo"):
    d = tmp_path / "baselines"
    d.mkdir(exist_ok=True)
    (d / f"{bench}.json").write_text(
        json.dumps({"bench": bench, "rows": rows}))
    return str(d)


def _run(tmp_path, base_rows, fresh_rows, extra=()):
    base_dir = _write_baseline(tmp_path, base_rows)
    fresh = _write_fresh(tmp_path, fresh_rows)
    return bench_check.main([fresh, "--baseline-dir", base_dir, *extra])


class TestToleranceClasses:
    def test_identical_rows_pass(self, tmp_path):
        rows = {"smoke.bit_equal": 1.0, "decode.tok_s": 42.0}
        assert _run(tmp_path, rows, rows) == 0

    def test_exact_metric_mismatch_fails(self, tmp_path):
        assert _run(tmp_path, {"smoke.bit_equal": 1.0},
                    {"smoke.bit_equal": 0.0}) == 1

    def test_tight_within_rtol_passes(self, tmp_path):
        # 1% drift < 2% rtol
        assert _run(tmp_path, {"q.plane_traffic_fraction": 0.500},
                    {"q.plane_traffic_fraction": 0.505}) == 0

    def test_tight_outside_rtol_fails(self, tmp_path):
        # 4% drift > 2% rtol
        assert _run(tmp_path, {"q.plane_traffic_fraction": 0.500},
                    {"q.plane_traffic_fraction": 0.520}) == 1

    def test_advisory_never_fails(self, tmp_path):
        # a 10x throughput collapse warns but does not gate
        assert _run(tmp_path, {"decode.tok_s": 100.0},
                    {"decode.tok_s": 10.0}) == 0

    def test_missing_row_fails(self, tmp_path):
        assert _run(tmp_path, {"smoke.bit_equal": 1.0, "decode.tok_s": 1.0},
                    {"decode.tok_s": 1.0}) == 1

    def test_new_row_only_warns(self, tmp_path):
        assert _run(tmp_path, {"decode.tok_s": 1.0},
                    {"decode.tok_s": 1.0, "extra.tok_s": 2.0}) == 0

    def test_nan_ness_change_fails(self, tmp_path):
        assert _run(tmp_path, {"smoke.hit_rate": None},
                    {"smoke.hit_rate": 0.5}) == 1


class TestStructural:
    def test_missing_bench_pass_fails(self, tmp_path):
        base_dir = _write_baseline(tmp_path, {"a.bit_equal": 1.0},
                                   bench="gone")
        fresh = _write_fresh(tmp_path, {"b.tok_s": 1.0}, bench="other")
        assert bench_check.main([fresh, "--baseline-dir", base_dir]) == 1

    def test_no_json_lines_is_operational_error(self, tmp_path):
        p = tmp_path / "empty.txt"
        p.write_text("no summaries here\n")
        assert bench_check.main([str(p)]) == 2

    def test_non_bench_baseline_json_ignored(self, tmp_path):
        # program_audit.json (the auditor's budget file) shares the
        # baselines directory but has no "rows" — bench_check must skip it
        rows = {"decode.tok_s": 1.0}
        base_dir = _write_baseline(tmp_path, rows)
        with open(os.path.join(base_dir, "program_audit.json"), "w") as f:
            json.dump({"programs": {"v/tick": {"collectives": {}}}}, f)
        fresh = _write_fresh(tmp_path, rows)
        assert bench_check.main([fresh, "--baseline-dir", base_dir]) == 0


class TestUpdate:
    def test_update_rewrites_baselines(self, tmp_path):
        base_dir = str(tmp_path / "baselines")
        fresh = _write_fresh(tmp_path, {"smoke.bit_equal": 1.0,
                                        "decode.tok_s": 3.5})
        assert bench_check.main([fresh, "--baseline-dir", base_dir,
                                 "--update"]) == 0
        with open(os.path.join(base_dir, "demo.json")) as f:
            doc = json.load(f)
        assert doc["rows"] == {"smoke.bit_equal": 1.0, "decode.tok_s": 3.5}
        # and the rewritten baseline gates clean against the same fresh run
        assert bench_check.main([fresh, "--baseline-dir", base_dir]) == 0

    def test_update_then_drift_fails(self, tmp_path):
        base_dir = str(tmp_path / "baselines")
        fresh = _write_fresh(tmp_path, {"smoke.bit_equal": 1.0})
        assert bench_check.main([fresh, "--baseline-dir", base_dir,
                                 "--update"]) == 0
        drifted = _write_fresh(tmp_path, {"smoke.bit_equal": 0.0},
                               name="drift.txt")
        assert bench_check.main([drifted, "--baseline-dir", base_dir]) == 1
