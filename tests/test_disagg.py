"""Disaggregated prefill/decode serving (``serving/workers.py`` +
``serving/router.py``): PageSpan wire-format round-trips are bit-exact
(float AND kv_quant code/scale/tail payloads), corrupt or truncated frames
are rejected loudly, pool-to-pool transplants leave both page pools and
the prefill-side radix tree consistent (``PagePool.verify`` /
``RadixCache.verify``), and the router serves token streams BIT-EQUAL to
the combined paged scheduler — attention and mamba, float and kv_quant,
in-process and across two spawned worker processes."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import init_params
from repro.serving.config import ServeConfig
from repro.serving.router import Router, run_disaggregated
from repro.serving.scheduler import ServeScheduler
from repro.serving.workers import DecodeEngine, PageSpan, PrefillEngine

CONFIG = ServeConfig(max_slots=2, max_len=48, buckets=(8, 16), tick_steps=2,
                     paged=True, page_len=8, chunked="auto", chunk_len=8)


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_smoke("smollm_135m").replace(dtype=jnp.float32)
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


def _prompts(cfg, sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
            for n in sizes]


def _span_of(cfg, params, config, prompt, max_new=6):
    span, rejected = PrefillEngine(cfg, params, config).prefill(
        prompt, max_new=max_new)
    assert rejected is None
    return span


# --------------------------------------------------------------------------
# wire format
# --------------------------------------------------------------------------


def test_pagespan_round_trip_float(smoke_model):
    """to_bytes -> from_bytes is BIT-exact: every page array, the logits
    row, the prompt, and every scalar field."""
    cfg, params = smoke_model
    span = _span_of(cfg, params, CONFIG, _prompts(cfg, (13,))[0])
    back = PageSpan.from_bytes(span.to_bytes())
    for field in ("length", "max_new", "eos_id", "page_len", "kv_quant",
                  "kv_bits", "hit_len", "shared_pages"):
        assert getattr(back, field) == getattr(span, field)
    np.testing.assert_array_equal(back.prompt, span.prompt)
    assert back.logits.dtype == span.logits.dtype
    np.testing.assert_array_equal(back.logits, span.logits)
    assert len(back.layers) == len(span.layers)
    for a, b in zip(span.layers, back.layers):
        assert sorted(a) == sorted(b)
        for k in a:
            assert b[k].dtype == a[k].dtype, k
            np.testing.assert_array_equal(b[k], a[k], err_msg=k)


def test_pagespan_round_trip_kv_quant(smoke_model):
    """The quantized page format ships codes + per-page scales + the
    dense tail ring — all bit-exact through the wire."""
    cfg, params = smoke_model
    config = dataclasses.replace(CONFIG, kv_quant=True, kv_bits=4)
    span = _span_of(cfg, params, config, _prompts(cfg, (13,))[0])
    keys = set().union(*(set(g) for g in span.layers))
    assert {"k_codes", "v_codes", "k_scale", "v_scale",
            "k_tail", "v_tail"} <= keys
    back = PageSpan.from_bytes(span.to_bytes())
    for a, b in zip(span.layers, back.layers):
        for k in a:
            np.testing.assert_array_equal(b[k], a[k], err_msg=k)


def test_pagespan_rejects_corruption(smoke_model):
    cfg, params = smoke_model
    blob = _span_of(cfg, params, CONFIG, _prompts(cfg, (9,))[0]).to_bytes()

    with pytest.raises(ValueError, match="shorter than the fixed frame"):
        PageSpan.from_bytes(blob[:8])
    with pytest.raises(ValueError, match="bad magic"):
        PageSpan.from_bytes(b"XX" + blob[2:])
    bad_version = blob[:6] + b"\x63\x00\x00\x00" + blob[10:]
    with pytest.raises(ValueError, match="wire version 99"):
        PageSpan.from_bytes(bad_version)
    with pytest.raises(ValueError, match="frame is short"):
        PageSpan.from_bytes(blob[:40])
    flipped = bytearray(blob)
    flipped[len(blob) // 2] ^= 0xFF
    with pytest.raises(ValueError, match="CRC32 mismatch"):
        PageSpan.from_bytes(bytes(flipped))
    # truncating whole payload bytes (with a recomputed CRC) trips the
    # manifest check, not the CRC
    import struct
    import zlib
    fixed = len(b"RPSPAN") + 8
    hdr_len, = struct.unpack_from("<I", blob, len(b"RPSPAN") + 4)
    hdr = blob[fixed:fixed + hdr_len]
    payload = blob[fixed + hdr_len:-4][:-16]
    short = (blob[:fixed + hdr_len] + payload
             + struct.pack("<I", zlib.crc32(hdr + payload)))
    with pytest.raises(ValueError, match="manifest claims"):
        PageSpan.from_bytes(short)


# --------------------------------------------------------------------------
# pool-to-pool transplant integrity
# --------------------------------------------------------------------------


def test_transplant_pool_and_radix_integrity(smoke_model):
    """After exports (prefill side, pages donated to the radix tree) and
    imports (decode side, fresh pages), both pools and the radix tree
    satisfy every refcount/tree invariant — and freeing the decode slots
    returns the pool to fully-available."""
    cfg, params = smoke_model
    config = dataclasses.replace(CONFIG, prefix_cache=True)
    pre = PrefillEngine(cfg, params, config)
    dec = DecodeEngine(cfg, params, config)
    assert dec.scheduler._radix is None  # decode side never retains

    # two prompts sharing a 8-token prefix: the second admission takes a
    # radix hit on the pages the first export donated
    base = _prompts(cfg, (13,))[0]
    prompts = [base, np.concatenate([base[:8], base[:5]])]
    for rid, p in enumerate(prompts):
        span, rejected = pre.prefill(p, max_new=4)
        assert rejected is None
        pre.scheduler._pages.verify()
        pre.scheduler._radix.verify()
        blob = span.to_bytes()
        assert dec.admit(PageSpan.from_bytes(blob), rid=rid,
                         submit_time=0.0) == "ok"
        dec.scheduler._pages.verify()
    assert pre.scheduler._radix.n_pages > 0   # donation really happened

    while dec.active:
        dec.step()
        dec.scheduler._pages.verify()
    results = dec.drain_results()
    assert sorted(results) == [0, 1]
    avail = dec.scheduler._pages.available
    assert avail == dec.scheduler._pages.n_pages - 1  # all but trash page


def test_decode_admission_statuses(smoke_model):
    """'full' when every slot is busy, 'wait' when a slot is free but the
    pool can't cover the span until an active import retires, 'drop'
    (+ rejected result) when the pool can NEVER cover it."""
    cfg, params = smoke_model
    # 4 usable pages (page 0 is the trash page), two slots
    tiny = dataclasses.replace(CONFIG, n_pages=1 + 4)
    pre = PrefillEngine(cfg, params, CONFIG)
    dec = DecodeEngine(cfg, params, tiny)
    spans = [pre.prefill(p, max_new=2)[0]
             for p in _prompts(cfg, (9, 11, 20, 30))]
    # 9/11-token spans need 2 pages each (prompt + new + tick tail)
    assert dec.admit(spans[0], rid=0, submit_time=0.0) == "ok"
    assert dec.admit(spans[1], rid=1, submit_time=0.0) == "ok"
    assert dec.admit(spans[2], rid=2, submit_time=0.0) == "full"
    while dec.active:
        dec.step()
    # 20-token span needs 3 pages: free slot, but only 2 pages free while
    # the other import is live -> wait, then ok once it retires
    assert dec.admit(spans[0], rid=3, submit_time=0.0) == "ok"
    assert dec.admit(spans[2], rid=4, submit_time=0.0) == "wait"
    while dec.active:
        dec.step()
    assert dec.admit(spans[2], rid=4, submit_time=0.0) == "ok"
    while dec.active:
        dec.step()
    # 30-token span needs 5 pages — more than the whole pool, nothing
    # active -> dropped with a rejected result under its rid
    assert dec.admit(spans[3], rid=5, submit_time=0.0) == "drop"
    results = dec.drain_results()
    assert sorted(results) == [0, 1, 3, 4, 5]
    assert results[5].error and results[5].finish_reason == "rejected"


def test_span_config_mismatch_rejected(smoke_model):
    cfg, params = smoke_model
    pre = PrefillEngine(cfg, params, CONFIG)
    span = pre.prefill(_prompts(cfg, (9,))[0], max_new=2)[0]
    dec = DecodeEngine(cfg, params,
                       dataclasses.replace(CONFIG, page_len=4, chunk_len=4))
    with pytest.raises(ValueError, match="page_len"):
        dec.admit(span, rid=0, submit_time=0.0)
    with pytest.raises(ValueError, match="requires a paged ServeConfig"):
        PrefillEngine(cfg, params, ServeConfig(max_len=48, buckets=(8, 16)))


# --------------------------------------------------------------------------
# token parity: router vs combined scheduler
# --------------------------------------------------------------------------


def _parity(cfg, params, config, prompts, max_new=6):
    combined = ServeScheduler(cfg, params, config)
    for p in prompts:
        combined.submit(p, max_new=max_new)
    want = combined.run()

    router = Router(cfg, params, config)
    for p in prompts:
        router.submit(p, max_new=max_new)
    got = router.run()

    assert len(got) == len(want)
    for a, b in zip(want, got):
        assert a.rid == b.rid
        assert a.tokens == b.tokens, f"rid {a.rid} diverged"
        assert a.finish_reason == b.finish_reason
        assert a.error == b.error
    return want


def test_router_parity_float(smoke_model):
    """6 requests on 2 slots force slot reuse on both sides; tokens are
    bit-equal to the combined paged scheduler, and the decode fleet's tick
    clock actually ran isolated."""
    cfg, params = smoke_model
    _parity(cfg, params, CONFIG, _prompts(cfg, (5, 13, 9, 30, 7, 16)))


def test_router_parity_kv_quant(smoke_model):
    cfg, params = smoke_model
    config = dataclasses.replace(CONFIG, kv_quant=True, kv_bits=4)
    _parity(cfg, params, config, _prompts(cfg, (9, 13, 21, 11)))


def test_router_parity_prefix_cache(smoke_model):
    """Prefix-cache hits happen PREFILL-side (the radix tree lives with
    the prefill engine); the served tokens still match the combined
    scheduler whose radix sees the same admission order."""
    cfg, params = smoke_model
    config = dataclasses.replace(CONFIG, prefix_cache=True)
    base = _prompts(cfg, (16,))[0]
    prompts = [base, np.concatenate([base[:8], base[:7]]), base[:12]]
    _parity(cfg, params, config, prompts)


def test_router_parity_mamba():
    """SSM models transplant recurrent state (the span's ssm/conv slices),
    not just KV pages."""
    cfg = get_smoke("mamba2_780m").replace(dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    _parity(cfg, params, CONFIG, _prompts(cfg, (5, 13, 30, 9)))


def test_router_preserves_reject_policy(smoke_model):
    """An unservably long prompt is rejected with the combined
    scheduler's reason and doesn't wedge the stream around it."""
    cfg, params = smoke_model
    prompts = _prompts(cfg, (9, 60, 11))  # 60 + new tokens > max_len=48
    results = _parity(cfg, params, CONFIG, prompts)
    assert results[1].finish_reason == "rejected" and results[1].error
    assert results[0].tokens and results[2].tokens


def test_router_requires_paged(smoke_model):
    cfg, params = smoke_model
    with pytest.raises(ValueError, match="paged ServeConfig"):
        Router(cfg, params, ServeConfig(max_len=48, buckets=(8, 16)))


# --------------------------------------------------------------------------
# two processes (the multidevice-CI step)
# --------------------------------------------------------------------------


def test_two_process_parity():
    """The real deployment shape: prefill and decode in separate spawned
    processes, PageSpans crossing as byte frames.  Tokens must equal the
    single-process combined scheduler's, rejects included."""
    cfg = get_smoke("smollm_135m").replace(dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _prompts(cfg, (5, 13, 60, 9, 16))
    trace = [(p, 4, None) for p in prompts]

    combined = ServeScheduler(cfg, params, CONFIG)
    for p in prompts:
        combined.submit(p, max_new=4)
    want = combined.run()

    got, tick_times = run_disaggregated(trace, arch="smollm_135m",
                                        config=CONFIG, timeout=560.0)
    assert [rid for rid, *_ in got] == [r.rid for r in want]
    for (rid, tokens, reason, error), w in zip(got, want):
        assert tokens == w.tokens, f"rid {rid} diverged across processes"
        assert reason == w.finish_reason
        assert bool(error) == bool(w.error)
    assert tick_times  # the decode worker's isolated tick clock
