"""The continuous-batching slot scheduler: per-request token parity with
``greedy_generate`` (which doubles as slot-reuse isolation — more requests
than slots forces retire + re-fill, so any cache leak from a retired slot
would corrupt its successor's tokens), bounded compilation across prompt
buckets, EOS retirement, per-request traffic stats, SSM pad masking, and
submit-time validation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import init_params
from repro.models.quantize import quantize_model_params
from repro.serving import engine
from repro.serving.scheduler import ServeScheduler, bucket_for


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke("smollm_135m").replace(dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 8, 3, 12, 7, 9)]
    return cfg, params, prompts


def _reference(cfg, params, prompt, max_new, quant=False):
    return np.asarray(engine.greedy_generate(
        cfg, params, jnp.asarray(prompt)[None], max_new=max_new,
        quant=quant))[0]


def test_token_parity_and_slot_reuse_isolation(setup):
    """Acceptance: every request's tokens are exactly the standalone
    greedy_generate output.  With 6 requests on 2 slots each slot serves 3
    requests back-to-back, so parity of the later requests also proves the
    retired occupant's KV/conv/SSM state never leaks into its successor."""
    cfg, params, prompts = setup
    max_new = 10
    sched = ServeScheduler(cfg, params, max_slots=2, max_len=64,
                           buckets=(8, 16), tick_steps=4)
    for p in prompts:
        sched.submit(p, max_new=max_new)
    results = sched.run()
    assert len(results) == len(prompts)
    reused = 0
    for r, p in zip(results, prompts):
        assert r.finish_reason == "length"
        reused += r.admitted_tick > 0
        np.testing.assert_array_equal(
            np.asarray(r.tokens), _reference(cfg, params, p, max_new))
    assert reused >= len(prompts) - 2    # later requests really re-used slots


def test_bounded_compilation_across_buckets(setup):
    """Six distinct prompt lengths but two buckets -> exactly two compiled
    prefill programs; the tick is one program regardless of traffic."""
    cfg, params, prompts = setup
    sched = ServeScheduler(cfg, params, max_slots=2, max_len=48,
                           buckets=(8, 16), tick_steps=3)
    for p in prompts:
        sched.submit(p, max_new=4)
    sched.run()
    stats = sched.compile_stats()
    assert stats["prefill"] == 2, stats
    assert stats["tick"] == 1, stats


def test_eos_retirement_and_refill(setup):
    """A request whose greedy stream hits eos retires early (reason "eos",
    tokens truncated after the eos) and its slot serves the next request."""
    cfg, params, prompts = setup
    max_new = 8
    base = _reference(cfg, params, prompts[0], max_new)
    eos = int(base[2])
    sched = ServeScheduler(cfg, params, max_slots=1, max_len=64,
                           buckets=(8, 16), tick_steps=2)
    sched.submit(prompts[0], max_new=max_new, eos_id=eos)
    sched.submit(prompts[1], max_new=4)
    r0, r1 = sched.run()
    hits = np.nonzero(base == eos)[0]
    np.testing.assert_array_equal(np.asarray(r0.tokens),
                                  base[: int(hits[0]) + 1])
    assert r0.finish_reason == "eos"
    assert r1.finish_reason == "length"
    np.testing.assert_array_equal(np.asarray(r1.tokens),
                                  _reference(cfg, params, prompts[1], 4))


def test_quant_parity_and_per_request_traffic(setup):
    """Quant serving through the scheduler: token parity vs the quantized
    greedy_generate, and each retired request carries its plane-traffic
    fractions (elem at least as fine as tile)."""
    cfg, params, prompts = setup
    qparams = quantize_model_params(cfg, params)
    sched = ServeScheduler(cfg, qparams, max_slots=2, max_len=48,
                           buckets=(8, 16), quant="xla", with_stats=True,
                           tick_steps=2)
    for p in prompts[:4]:
        sched.submit(p, max_new=4)
    for r, p in zip(sched.run(), prompts):
        np.testing.assert_array_equal(
            np.asarray(r.tokens), _reference(cfg, qparams, p, 4, "xla"))
        assert 0.0 < r.plane_traffic_fraction <= 1.0
        assert 0.0 < r.element_traffic_fraction <= r.plane_traffic_fraction + 1e-6


def test_mamba_padded_prefill_parity():
    """SSM arch: bucketed (right-padded) prefill must leave the recurrent
    state and rolling conv window exactly as an unpadded prefill would —
    pad tokens are dt-masked out — so scheduler tokens equal
    greedy_generate even across bucket boundaries."""
    cfg = get_smoke("mamba2_780m").replace(dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (3, 6, 11)]
    sched = ServeScheduler(cfg, params, max_slots=2, max_len=48,
                           buckets=(8, 16), tick_steps=3)
    for p in prompts:
        sched.submit(p, max_new=5)
    for r, p in zip(sched.run(), prompts):
        np.testing.assert_array_equal(
            np.asarray(r.tokens), _reference(cfg, params, p, 5))


def test_submit_validation(setup):
    cfg, params, prompts = setup
    sched = ServeScheduler(cfg, params, max_slots=1, max_len=24,
                           buckets=(8, 16), tick_steps=2)
    # oversized prompts/capacity overflows are REJECTED per-request (they
    # used to raise, killing a live serve loop); caller bugs still raise
    rid = sched.submit(np.arange(17), max_new=2)      # exceeds largest bucket
    rid2 = sched.submit(prompts[0], max_new=64)       # overflows slot capacity
    with pytest.raises(ValueError):
        sched.submit(np.zeros((0,), np.int32), max_new=2)
    with pytest.raises(ValueError):
        sched.submit(prompts[0], max_new=0)
    with pytest.raises(ValueError):
        bucket_for(99, (8, 16))
    with pytest.raises(ValueError):
        ServeScheduler(cfg, params, max_slots=1, max_len=8, buckets=(16,))
    with pytest.raises(ValueError):
        ServeScheduler(cfg, params, max_slots=1, max_len=24, buckets=(8,),
                       oversize="explode")
    results = sched.run()
    by_rid = {r.rid: r for r in results}
    for r in (by_rid[rid], by_rid[rid2]):
        assert r.finish_reason == "rejected" and r.tokens == []
        assert r.admitted_tick == -1 and r.error
    # oversize="raise" restores the historical behavior
    strict = ServeScheduler(cfg, params, max_slots=1, max_len=24,
                            buckets=(8, 16), tick_steps=2, oversize="raise")
    with pytest.raises(ValueError):
        strict.submit(np.arange(17), max_new=2)


def test_oversized_prompt_does_not_abort_inflight(setup):
    """Regression (ISSUE 3): one oversized prompt submitted mid-run must
    yield a per-request error result while every normal request — including
    ones already decoding — still finishes with exact parity tokens."""
    cfg, params, prompts = setup
    # NB not 6: test_serving_fused asserts its max_new=6 generate program
    # never retraces, and _reference() here shares the process-global LRU
    max_new = 11
    sched = ServeScheduler(cfg, params, max_slots=2, max_len=64,
                           buckets=(8, 16), tick_steps=2)
    rids = [sched.submit(p, max_new=max_new) for p in prompts[:3]]
    sched.step_tick()                                 # requests now in flight
    big = sched.submit(np.arange(40, dtype=np.int32), max_new=max_new)
    rids += [sched.submit(p, max_new=max_new) for p in prompts[3:]]
    results = sched.run()
    assert len(results) == len(prompts) + 1
    by_rid = {r.rid: r for r in results}
    assert by_rid[big].finish_reason == "rejected"
    assert by_rid[big].tokens == [] and "bucket" in by_rid[big].error
    for rid, p in zip(rids, prompts):
        r = by_rid[rid]
        assert r.finish_reason == "length"
        np.testing.assert_array_equal(
            np.asarray(r.tokens), _reference(cfg, params, p, max_new))


def test_oversize_truncate_policy(setup):
    """oversize="truncate" keeps the most recent tokens that fit and decodes
    exactly as if the truncated prompt had been submitted."""
    cfg, params, _ = setup
    sched = ServeScheduler(cfg, params, max_slots=1, max_len=32,
                           buckets=(8, 16), tick_steps=2, oversize="truncate")
    rng = np.random.default_rng(7)
    long_prompt = rng.integers(0, cfg.vocab_size, size=25).astype(np.int32)
    rid = sched.submit(long_prompt, max_new=4)
    (r,) = sched.run()
    assert r.rid == rid and r.finish_reason == "length"
    np.testing.assert_array_equal(
        np.asarray(r.tokens), _reference(cfg, params, long_prompt[-16:], 4))


# ---------------------------------------------------------------------------
# bucket_for properties
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False


def _check_bucket_invariants(length, buckets):
    buckets_t = tuple(buckets)
    fitting = [b for b in buckets_t if b >= length]
    if not fitting:
        with pytest.raises(ValueError):
            bucket_for(length, buckets_t)
        return
    got = bucket_for(length, buckets_t)
    assert got in buckets_t                       # a configured bucket
    assert got >= length                          # the prompt fits
    assert got == min(fitting)                    # ... in the SMALLEST one


if HAVE_HYPOTHESIS:
    @settings(max_examples=200, deadline=None)
    @given(length=st.integers(1, 300),
           buckets=st.lists(st.integers(1, 256), min_size=1, max_size=8))
    def test_bucket_for_properties(length, buckets):
        _check_bucket_invariants(length, buckets)
else:                                             # deterministic fallback
    def test_bucket_for_properties():
        rng = np.random.default_rng(42)
        for _ in range(200):
            buckets = rng.integers(1, 257,
                                   size=int(rng.integers(1, 9))).tolist()
            _check_bucket_invariants(int(rng.integers(1, 301)), buckets)


def test_bucket_for_unsorted_and_boundaries():
    """Order-independence and exact-boundary lengths."""
    assert bucket_for(8, (16, 8, 64)) == 8        # exact boundary, unsorted
    assert bucket_for(9, (64, 16, 8)) == 16
    assert bucket_for(64, (64, 16, 8)) == 64
    assert bucket_for(1, (8,)) == 8
    with pytest.raises(ValueError):
        bucket_for(65, (64, 16, 8))


def test_scheduler_sizes_generate_cache(setup):
    """Satellite: the scheduler sizes the generate-program LRU explicitly so
    baseline/parity programs are never silently evicted mid-serve."""
    cfg, params, _ = setup
    old = engine.generate_fn.maxsize
    try:
        ServeScheduler(cfg, params, max_slots=1, max_len=32, buckets=(8,),
                       generate_cache_size=97)
        assert engine.generate_fn.maxsize == 97
    finally:
        engine.set_generate_cache_size(old)
