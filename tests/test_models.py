"""Per-arch smoke tests (reduced configs) + decode equivalence + quant mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke, list_archs
from repro.launch.shapes import SHAPES, input_specs, shape_applicable
from repro.models import (forward, init_caches, init_params, next_token_loss,
                          param_count)

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def make_batch(cfg, b=B, s=S):
    batch = {}
    s_text = s - cfg.n_image_tokens if cfg.frontend == "vision_stub" else s
    if cfg.frontend == "audio_stub":
        batch["embeds"] = jax.random.normal(KEY, (b, s, cfg.d_model),
                                            jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(KEY, (b, s_text), 0,
                                             cfg.vocab_size)
    if cfg.frontend == "vision_stub":
        batch["image_embeds"] = jax.random.normal(
            KEY, (b, cfg.n_image_tokens, cfg.d_model), jnp.float32)
    batch["labels"] = jax.random.randint(KEY, (b, s_text), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", list_archs())
class TestArchSmoke:
    def test_forward_and_train_step(self, arch):
        cfg = get_smoke(arch)
        params = init_params(KEY, cfg)
        batch = make_batch(cfg)
        logits, _ = forward(cfg, params, tokens=batch.get("tokens"),
                            embeds=batch.get("embeds"),
                            image_embeds=batch.get("image_embeds"))
        v = cfg.vocab_size
        assert logits.shape[0] == B and logits.shape[-1] == v
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

        loss, grads = jax.value_and_grad(
            lambda p: next_token_loss(cfg, p, batch))(params)
        assert bool(jnp.isfinite(loss))
        gn = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                 for g in jax.tree.leaves(grads))
        assert bool(jnp.isfinite(gn)) and float(gn) > 0

    def test_full_config_matches_assignment(self, arch):
        cfg = get_config(arch)
        # spot-check the published dims are wired through
        assert cfg.n_layers % len(cfg.pattern) == 0
        pc = param_count(get_smoke(arch))
        assert pc["total"] > 0 and pc["active"] <= pc["total"]

    def test_shape_applicability(self, arch):
        cfg = get_config(arch)
        assert shape_applicable(cfg, "train_4k")
        assert shape_applicable(cfg, "decode_32k")
        if arch in ("mamba2_780m", "jamba_v01_52b"):
            assert shape_applicable(cfg, "long_500k")
        else:
            assert not shape_applicable(cfg, "long_500k")

    def test_input_specs_no_allocation(self, arch):
        cfg = get_config(arch)
        for shape in SHAPES:
            if not shape_applicable(cfg, shape):
                continue
            specs = input_specs(cfg, shape)
            for leaf in jax.tree.leaves(
                    specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)):
                assert isinstance(leaf, jax.ShapeDtypeStruct)


@pytest.mark.parametrize("arch", ["qwen3_32b", "mamba2_780m",
                                  "jamba_v01_52b", "deepseek_moe_16b"])
def test_decode_matches_full_forward(arch):
    cfg = get_smoke(arch).replace(dtype=jnp.float32, capacity_factor=100.0)
    params = init_params(KEY, cfg)
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    logits_full, _ = forward(cfg, params, tokens=tokens)
    caches = init_caches(cfg, B, max_len=S, dtype=jnp.float32)
    outs = []
    for t in range(S):
        lg, caches = forward(cfg, params, tokens=tokens[:, t:t + 1],
                             caches=caches)
        outs.append(lg[:, 0])
    err = float(jnp.max(jnp.abs(logits_full - jnp.stack(outs, axis=1))))
    scale = float(jnp.max(jnp.abs(logits_full)))
    assert err < 1e-4 * max(scale, 1.0), err


def test_prefill_with_cache_matches_forward():
    cfg = get_smoke("qwen3_32b").replace(dtype=jnp.float32)
    params = init_params(KEY, cfg)
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    logits_full, _ = forward(cfg, params, tokens=tokens)
    caches = init_caches(cfg, B, max_len=S + 8, dtype=jnp.float32)
    lg, caches = forward(cfg, params, tokens=tokens, caches=caches)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits_full),
                               rtol=1e-5, atol=1e-5)
    assert int(caches["length"]) == S


def test_qeihan_quant_mode_runs_and_is_close():
    """The paper's technique as a first-class model feature."""
    cfg = get_smoke("smollm_135m").replace(dtype=jnp.float32)
    params = init_params(KEY, cfg)
    from repro.models.quantize import quantize_model_params
    qparams = quantize_model_params(cfg, params)
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    lg_f, _ = forward(cfg, params, tokens=tokens)
    lg_q, _ = forward(cfg, qparams, tokens=tokens, quant=True)
    # LOG2-4bit activations compound noise over 30 layers without the
    # paper's recovery retraining — correlated, not close
    a = np.asarray(lg_f).reshape(-1)
    bq = np.asarray(lg_q).reshape(-1)
    corr = np.corrcoef(a, bq)[0, 1]
    assert corr > 0.6, corr
    assert np.isfinite(bq).all()


def test_musicgen_audio_stub_decode():
    cfg = get_smoke("musicgen_medium").replace(dtype=jnp.float32)
    params = init_params(KEY, cfg)
    emb = jax.random.normal(KEY, (B, 1, cfg.d_model), jnp.float32)
    caches = init_caches(cfg, B, max_len=4, dtype=jnp.float32)
    lg, caches = forward(cfg, params, embeds=emb, caches=caches)
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert int(caches["length"]) == 1


def test_internvl_vision_stub_loss_masks_images():
    cfg = get_smoke("internvl2_26b").replace(dtype=jnp.float32)
    params = init_params(KEY, cfg)
    batch = make_batch(cfg)
    loss = next_token_loss(cfg, params, batch)
    assert bool(jnp.isfinite(loss))
