"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret=True on CPU; same code targets TPU v5e)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import log2_quantize, quantize_weights, to_bitplanes
from repro.kernels import bitplane_matmul_pallas, log2_quantize_pallas
from repro.kernels.bitplane_matmul.ops import plane_traffic_fraction
from repro.kernels.bitplane_matmul.ref import bitplane_matmul_ref
from repro.kernels.log2quant.ref import log2_quantize_ref


class TestLog2QuantKernel:
    @pytest.mark.parametrize("shape", [(8,), (37, 91), (256, 512), (3, 5, 7),
                                       (1, 1), (1024,)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
    def test_sweep_vs_ref(self, shape, dtype):
        rng = np.random.default_rng(hash((shape, str(dtype))) % 2 ** 31)
        x = (rng.normal(0, 4.0, shape).astype(np.float32)
             * rng.choice([1e-3, 1e-1, 1.0, 1e2], shape))
        xj = jnp.asarray(x).astype(dtype)
        e_k, s_k = log2_quantize_pallas(xj, interpret=True)
        e_r, s_r = log2_quantize_ref(xj)
        np.testing.assert_array_equal(np.asarray(e_k), np.asarray(e_r))
        np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_r))

    def test_special_values(self):
        x = jnp.asarray([0.0, -0.0, np.inf, -np.inf, np.nan, 1e-38, -1e-38,
                         2.0 ** -8, 2.0 ** 7, 1.5, -1.5], jnp.float32)
        e_k, s_k = log2_quantize_pallas(x, interpret=True)
        e_r, s_r = log2_quantize_ref(x)
        np.testing.assert_array_equal(np.asarray(e_k), np.asarray(e_r))

    def test_nbits_variants(self):
        x = jnp.asarray(np.random.default_rng(0).normal(0, 1, 512), jnp.float32)
        for n_bits in (3, 4, 5):
            e_k, _ = log2_quantize_pallas(x, n_bits=n_bits, interpret=True)
            q = log2_quantize(x, n_bits=n_bits)
            np.testing.assert_array_equal(np.asarray(e_k), np.asarray(q.exp))


class TestBitplaneMatmulKernel:
    def _case(self, m, k, n, seed, zero_frac=0.1, scale=0.5):
        rng = np.random.default_rng(seed)
        x = rng.normal(0, scale, (m, k)).astype(np.float32)
        x[rng.random((m, k)) < zero_frac] = 0.0
        q = log2_quantize(jnp.asarray(x))
        w = quantize_weights(
            jnp.asarray(rng.normal(0, 0.1, (k, n)).astype(np.float32)),
            channel_axis=-1)
        return q, w

    @pytest.mark.parametrize("m,k,n", [
        (8, 32, 16), (96, 200, 130), (128, 128, 128), (1, 7, 3),
        (130, 260, 100),
    ])
    def test_sweep_exact(self, m, k, n):
        q, w = self._case(m, k, n, seed=m + k + n)
        y_k = bitplane_matmul_pallas(q.exp, q.sign, to_bitplanes(w.q),
                                     interpret=True)
        y_r = bitplane_matmul_ref(q.exp, q.sign, w.q)
        np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_r))

    @pytest.mark.parametrize("block", [(64, 64, 64), (128, 256, 128)])
    def test_block_shapes(self, block):
        bm, bk, bn = block
        q, w = self._case(100, 300, 96, seed=11)
        y_k = bitplane_matmul_pallas(q.exp, q.sign, to_bitplanes(w.q),
                                     block_m=bm, block_k=bk, block_n=bn,
                                     interpret=True)
        y_r = bitplane_matmul_ref(q.exp, q.sign, w.q)
        np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_r))

    def test_extreme_exponents(self):
        rng = np.random.default_rng(5)
        x = np.concatenate([
            rng.normal(0, 1e-3, (32, 64)),      # deeply negative exps
            rng.normal(0, 100.0, (32, 64)),     # positive exps (left shift)
            np.zeros((32, 64)),                 # pruned
        ], axis=1).astype(np.float32)
        q = log2_quantize(jnp.asarray(x))
        w = quantize_weights(jnp.asarray(
            rng.normal(0, 0.1, (192, 64)).astype(np.float32)), channel_axis=-1)
        y_k = bitplane_matmul_pallas(q.exp, q.sign, to_bitplanes(w.q),
                                     interpret=True)
        y_r = bitplane_matmul_ref(q.exp, q.sign, w.q)
        np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_r))

    def test_plane_skip_saves_traffic_for_cold_acts(self):
        """All-small activations -> high plane-skip fraction (paper Fig. 3
        at tile granularity)."""
        x = jnp.full((128, 512), 0.01, jnp.float32)     # exp ~ -7
        q = log2_quantize(x)
        frac = float(plane_traffic_fraction(q.exp))
        assert frac <= 2.0 / 8.0 + 1e-6                  # >= 6 planes skipped

    def test_plane_skip_none_for_hot_acts(self):
        x = jnp.full((128, 512), 4.0, jnp.float32)       # exp = +2
        q = log2_quantize(x)
        assert float(plane_traffic_fraction(q.exp)) == 1.0

    def test_fully_pruned_tile_skips_everything(self):
        q = log2_quantize(jnp.zeros((128, 128), jnp.float32))
        assert float(plane_traffic_fraction(q.exp)) == 0.0
        y = bitplane_matmul_pallas(q.exp, q.sign,
                                   to_bitplanes(jnp.ones((128, 128), jnp.int8)),
                                   interpret=True)
        assert int(jnp.abs(y).max()) == 0
