"""Static kernel-verifier tests (PR 7 injected-violation style): every
kernel rule family gets a test proving it FIRES on an injected violation
and a test proving it stays quiet on the shipped instantiations — plus
the static-vs-runtime traffic agreement gates and the simulator loader."""

import dataclasses
import json
import os

import numpy as np
import pytest

from repro.analysis import kernel_rules
from repro.analysis.pallas_inspect import (DOUBLE_BUFFER, block_traffic,
                                           check_bounds, iter_grid,
                                           vmem_footprint)
from repro.analysis.report import AuditReport, load_waivers

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE = os.path.join(REPO, "benchmarks/baselines/kernel_audit.json")
PAGED_BENCH = os.path.join(REPO, "benchmarks/baselines/paged_attn.json")


def _shipped(name):
    for inst in kernel_rules.registered_instantiations():
        if inst.name == name:
            return inst
    raise KeyError(name)


def _corrupt_table(inst, bi, j, value):
    """Same instantiation, one page-table entry rewritten."""
    table = np.array(inst.scalars[0])
    table[bi, j] = value
    meta = dict(inst.meta, table=table)
    return dataclasses.replace(inst, scalars=(table,) + inst.scalars[1:],
                               meta=meta)


# ---------------------------------------------------------------------------
# rule 1: index-map bounds proofs
# ---------------------------------------------------------------------------


class TestIndexBounds:
    def test_shipped_instantiations_prove_in_bounds(self):
        insts = kernel_rules.registered_instantiations()
        kernels = {i.kernel for i in insts}
        assert kernels == {"paged_attention", "bitplane_matmul",
                           "log2quant"}
        for inst in insts:
            assert not check_bounds(inst), inst.name

    def test_oob_table_entry_flagged(self):
        inst = _shipped("paged_attention/ragged512.s1")
        n_pages = inst.meta["n_pages"]
        bad = _corrupt_table(inst, 0, 3, n_pages + 7)  # past the pool
        f = kernel_rules.rule_index_bounds(bad)
        assert f and all(x.rule == "kernel-index-bounds" for x in f)
        assert any("k_pool" in x.detail for x in f)

    def test_negative_table_entry_flagged(self):
        inst = _shipped("paged_attention/ragged512.s1")
        bad = _corrupt_table(inst, 1, 0, -2)
        assert kernel_rules.rule_index_bounds(bad)

    def test_trash_entry_in_live_zone_flagged(self):
        # slot 0 has 512 tokens = 32 live columns; column 5 -> trash page
        inst = _shipped("paged_attention/ragged512.s1")
        bad = _corrupt_table(inst, 0, 5, inst.meta["trash_page"])
        f = kernel_rules.rule_index_bounds(bad)
        assert f and "unreachable" in f[0].detail

    def test_bad_index_map_arity_flagged(self):
        inst = _shipped("log2quant/decode_f32.b256x512")
        op = inst.inputs[0]
        bad_op = dataclasses.replace(op, index_map=lambda i, j: (i, j, 0))
        bad = dataclasses.replace(inst, inputs=(bad_op,))
        v = check_bounds(bad)
        assert v and "arity" in v[0].detail


# ---------------------------------------------------------------------------
# rule 2: VMEM budgets
# ---------------------------------------------------------------------------


class TestVmemBudget:
    def test_footprint_double_buffers_io_not_scratch(self):
        inst = _shipped("bitplane_matmul/canon_s1.b128")
        fp = vmem_footprint(inst)
        # 4 streamed operands double-buffered + 1 scratch, single
        assert fp["n_buffers"] == 5
        assert fp["buffers"]["planes"] == DOUBLE_BUFFER * 8 * 128 * 128
        assert fp["buffers"]["scratch0"] == 128 * 128 * 4
        assert fp["vmem_bytes"] == sum(fp["buffers"].values())

    def test_over_budget_scratch_fails(self, tmp_path, monkeypatch):
        inst = _shipped("bitplane_matmul/canon_s1.b128")
        fat = dataclasses.replace(
            inst, scratch=inst.scratch + (((4096, 4096), "float32"),))
        assert vmem_footprint(fat)["vmem_bytes"] \
            > kernel_rules.VMEM_LIMIT_BYTES
        monkeypatch.setattr(kernel_rules, "registered_instantiations",
                            lambda: [fat])
        fnd, _ = kernel_rules.run_kernel_audit(
            str(tmp_path / "b.json"), update_baselines=True,
            with_per_tick=False)
        assert any(f.rule == "kernel-vmem-budget"
                   and "cap" in f.detail for f in fnd)

    def test_budget_drift_fails_and_match_passes(self):
        fresh = {"kernels": {"k/c": {"n_buffers": 3, "vmem_bytes": 1000,
                                     "bytes_read": 5, "fetches": {"x": 2}}},
                 "per_tick": {}}
        same = json.loads(json.dumps(fresh))
        assert not kernel_rules.check_kernel_budgets(fresh, same)

        drift = json.loads(json.dumps(fresh))
        drift["kernels"]["k/c"]["n_buffers"] = 4          # exact gate
        f = kernel_rules.check_kernel_budgets(fresh, drift)
        assert f and f[0].rule == "kernel-vmem-budget"

        drift = json.loads(json.dumps(fresh))
        drift["kernels"]["k/c"]["vmem_bytes"] = 1200      # 20% > 10% rtol
        assert kernel_rules.check_kernel_budgets(fresh, drift)

        ok = json.loads(json.dumps(fresh))
        ok["kernels"]["k/c"]["vmem_bytes"] = 1050         # 5% < 10% rtol
        assert not kernel_rules.check_kernel_budgets(fresh, ok)

    def test_unbaselined_instantiation_fails(self):
        fresh = {"kernels": {"k/new": {"n_buffers": 1, "vmem_bytes": 8}},
                 "per_tick": {}}
        f = kernel_rules.check_kernel_budgets(fresh, {"kernels": {}})
        assert f and "no committed budget" in f[0].detail
        # and the stale direction
        f = kernel_rules.check_kernel_budgets({"kernels": {},
                                               "per_tick": {}}, fresh)
        assert f and "no longer registered" in f[0].detail


# ---------------------------------------------------------------------------
# rule 3: padding / masked-tail lints
# ---------------------------------------------------------------------------


class TestUnmaskedTail:
    def test_shipped_instantiations_quiet(self):
        for inst in kernel_rules.registered_instantiations():
            assert not kernel_rules.rule_unmasked_tail(inst), inst.name

    def test_non_dividing_block_flagged(self):
        inst = _shipped("log2quant/decode_f32.b256x512")
        op = inst.inputs[0]
        bad_op = dataclasses.replace(op, shape=(op.shape[0] + 60,
                                                op.shape[1]))
        bad = dataclasses.replace(inst, inputs=(bad_op,))
        f = kernel_rules.rule_unmasked_tail(bad)
        assert f and f[0].rule == "kernel-unmasked-tail"
        assert "does not divide" in f[0].detail

    def test_declared_masked_tail_quiet(self):
        inst = _shipped("log2quant/decode_f32.b256x512")
        op = inst.inputs[0]
        bad_op = dataclasses.replace(op, shape=(op.shape[0] + 60,
                                                op.shape[1]))
        declared = dataclasses.replace(
            inst, inputs=(bad_op,), meta={"masked_dims": {"x": (0,)}})
        assert not kernel_rules.rule_unmasked_tail(declared)

    def test_stale_page_in_dead_zone_flagged(self):
        # slot 3 has 17 tokens = 2 live columns; column 9 -> a real page
        inst = _shipped("paged_attention/ragged512.s1")
        bad = _corrupt_table(inst, 3, 9, 4)
        f = kernel_rules.rule_unmasked_tail(bad)
        assert f and f[0].rule == "kernel-unmasked-tail"
        assert "trash page" in f[0].detail


# ---------------------------------------------------------------------------
# rule 4: static byte-traffic model
# ---------------------------------------------------------------------------


class TestTrafficModel:
    def test_static_matches_runtime_on_ragged512(self):
        """The acceptance gate: the static model derives the measured
        gather_saved_frac bit-for-bit from BlockSpecs x grid x table."""
        inst = _shipped("paged_attention/ragged512.s1")
        rec, disagreements = kernel_rules.static_traffic(inst)
        assert not disagreements
        assert rec["gather_saved_frac"] == 0.5546875
        with open(PAGED_BENCH) as f:
            rows = json.load(f)["rows"]
        assert rec["gather_saved_frac"] == rows["gather_saved_frac"]
        assert float(rec["bytes_read"] + rec["bytes_written"]) \
            == rows["static_bytes_moved"]
        assert float(vmem_footprint(inst)["vmem_bytes"]) \
            == rows["vmem_bytes"]

    def test_split_invariance(self):
        # splitting the page walk must not change bytes moved or pages hit
        r1, _ = kernel_rules.static_traffic(
            _shipped("paged_attention/ragged512.s1"))
        r4, _ = kernel_rules.static_traffic(
            _shipped("paged_attention/ragged512.s4"))
        assert r1["gather_saved_frac"] == r4["gather_saved_frac"]
        assert r1["fetches"]["k_pool"] == r4["fetches"]["k_pool"]

    def test_runtime_disagreement_flagged(self, monkeypatch):
        # force the runtime counter to disagree -> the rule must fire
        from repro.kernels.paged_attention import ops
        inst = _shipped("paged_attention/ragged512.s1")
        monkeypatch.setattr(ops, "gather_traffic_counts",
                            lambda *a, **k: (1.0, 2.0))
        _, disagreements = kernel_rules.static_traffic(inst)
        assert disagreements
        assert disagreements[0].rule == "kernel-traffic-model"

    def test_bitplane_static_matches_runtime_counters(self):
        import jax.numpy as jnp

        from repro.core.access_model import needed_bits
        from repro.kernels.bitplane_matmul.ops import plane_traffic_counts

        inst = _shipped("bitplane_matmul/canon_s1.b128")
        rec, disagreements = kernel_rules.static_traffic(inst)
        assert not disagreements
        exp = inst.meta["exp"]
        f, t = plane_traffic_counts(jnp.asarray(exp, jnp.int8))
        assert rec["plane_traffic_fraction_tile"] == float(f) / float(t)
        assert rec["element_bits"] == int(jnp.sum(needed_bits(
            jnp.asarray(exp, jnp.int8))))

    def test_bitplane_tampered_skip_table_flagged(self):
        inst = _shipped("bitplane_matmul/canon_s1.b128")
        table = np.array(inst.meta["min_plane"])
        table[0, 0] += 1  # skip one plane too many
        meta = dict(inst.meta, min_plane=table)
        bad = dataclasses.replace(inst, scalars=(table,), meta=meta)
        _, disagreements = kernel_rules.static_traffic(bad)
        assert any("min_plane" in f.detail for f in disagreements)

    def test_pruned_tiles_skip_all_planes(self):
        rec, _ = kernel_rules.static_traffic(
            _shipped("bitplane_matmul/pruned_half.b128"))
        # half the K range is sentinel-pruned: those tiles fetch 0 planes
        assert rec["plane_traffic_fraction_tile"] < 0.55

    def test_revisit_elision(self):
        # out block of the bitplane kernel changes only when (mi, ni)
        # does: K-innermost revisits must not be double-billed
        inst = _shipped("bitplane_matmul/canon_s1.b128")
        tr = block_traffic(inst)
        n_out_blocks = inst.grid[0] * inst.grid[1]
        assert tr["fetches"]["out"] == n_out_blocks
        assert tr["fetches"]["planes"] == len(list(iter_grid(inst.grid)))

    def test_clean_audit_against_committed_baselines(self):
        fnd, rec = kernel_rules.run_kernel_audit(BASELINE,
                                                 with_per_tick=False)
        assert not fnd, [f.key() + ": " + f.detail for f in fnd]
        assert len(rec["kernels"]) >= 9  # 3 kernels x >= 3 cases


# ---------------------------------------------------------------------------
# per-tick composition + the simulator cost table
# ---------------------------------------------------------------------------


class TestPerTickCensus:
    @pytest.fixture(scope="class")
    def census(self):
        return kernel_rules.per_tick_census()

    def test_tick_launch_counts(self, census):
        # 2 tick_steps x 3 layers: 6 attention launches; the quant tick
        # adds 7 bitplane GEMM sites per step = 42 launches
        assert census["paged_kernel"]["kernels"][
            "paged_attention"]["calls"] == 6
        q = census["paged_kernel-quant"]["kernels"]
        assert q["paged_attention"]["calls"] == 6
        assert q["bitplane_matmul"]["calls"] == 42

    def test_census_matches_committed_baseline(self, census):
        with open(BASELINE) as f:
            base = json.load(f)["per_tick"]
        assert not kernel_rules.check_kernel_budgets(
            {"kernels": {}, "per_tick": census},
            {"kernels": {}, "per_tick": base})

    def test_call_count_drift_fails(self, census):
        with open(BASELINE) as f:
            base = json.load(f)["per_tick"]
        drifted = json.loads(json.dumps(census))
        drifted["paged_kernel"]["kernels"]["paged_attention"]["calls"] += 1
        f = kernel_rules.check_kernel_budgets(
            {"kernels": {}, "per_tick": drifted},
            {"kernels": {}, "per_tick": base})
        assert f and f[0].rule == "kernel-traffic-model"
        assert "launches" in f[0].detail

    def test_simulator_loads_cost_table(self):
        from repro.simulator import load_kernel_cost_table
        table = load_kernel_cost_table(BASELINE)
        assert set(table) == {"paged_kernel", "paged_kernel-quant"}
        q = table["paged_kernel-quant"]
        assert q["tick_bytes_total"] == sum(
            v["operand_bytes"] for v in q["kernels"].values())
        assert q["kernels"]["bitplane_matmul"]["calls"] == 42


# ---------------------------------------------------------------------------
# waiver registry validation + report plumbing
# ---------------------------------------------------------------------------


class TestWaiverValidation:
    def test_unknown_rule_id_rejected(self, tmp_path):
        p = tmp_path / "w.json"
        p.write_text(json.dumps({"waivers": [
            {"rule": "kernel-index-bounds-typo", "match": "*",
             "reason": "legit reason"}]}))
        with pytest.raises(ValueError, match="unknown rule"):
            load_waivers(str(p), known_rules=("kernel-index-bounds",))

    def test_known_rule_id_accepted(self, tmp_path):
        p = tmp_path / "w.json"
        p.write_text(json.dumps({"waivers": [
            {"rule": "kernel-index-bounds", "match": "*",
             "reason": "legit reason"}]}))
        ws = load_waivers(str(p), known_rules=("kernel-index-bounds",))
        assert len(ws) == 1

    def test_no_registry_skips_validation(self, tmp_path):
        p = tmp_path / "w.json"
        p.write_text(json.dumps({"waivers": [
            {"rule": "anything", "match": "*", "reason": "r"}]}))
        assert load_waivers(str(p))  # legacy call: no registry, no check

    def test_committed_waiver_file_validates_against_registry(self):
        from repro.analysis.audit import ALL_RULES
        assert set(kernel_rules.KERNEL_RULES) <= set(ALL_RULES)
        load_waivers(os.path.join(REPO, "tools/audit_waivers.json"),
                     known_rules=ALL_RULES)

    def test_report_embeds_kernel_records(self):
        rep = AuditReport(kernels={"kernels": {"k/c": {"vmem_bytes": 1}}})
        doc = json.loads(rep.to_json())
        assert doc["kernels"]["kernels"]["k/c"]["vmem_bytes"] == 1


class TestBenchClassification:
    def test_new_rows_gate_exact(self):
        import importlib.util
        spec = importlib.util.spec_from_file_location(
            "bench_check", os.path.join(REPO, "tools/bench_check.py"))
        bc = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(bc)
        assert bc.classify("paged_attn.b4.pl16.nb32.vmem_bytes") == "exact"
        assert bc.classify(
            "paged_attn.b4.pl16.nb32.static_bytes_moved") == "exact"
        assert bc.classify(
            "paged_attn.b4.pl16.nb32.kernel_split1_us") == "advisory"
