"""The fused decode engine: scan-generate equals the per-token reference
loop token-for-token, compiles once, early-stops on EOS, and runs the
quantized bit-plane path (pallas == xla, packed == unpacked) with per-step
plane-traffic reporting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import init_params
from repro.models.quantize import quantize_model_params
from repro.serving import engine


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke("smollm_135m").replace(dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    return cfg, params, prompt


@pytest.fixture(scope="module")
def qsetup(setup):
    cfg, params, prompt = setup
    return cfg, quantize_model_params(cfg, params), prompt


def test_fused_matches_reference_loop(setup):
    """Acceptance: the scan program reproduces the seed Python loop exactly."""
    cfg, params, prompt = setup
    ref = engine.reference_generate(cfg, params, prompt, max_new=6)
    got = engine.greedy_generate(cfg, params, prompt, max_new=6)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))
    assert got.shape == (2, 6)


def test_single_compilation(setup):
    """Acceptance: the whole generate is ONE XLA program — two calls leave
    exactly one entry in the jit cache (a per-token loop would retrace or at
    minimum re-dispatch per token; dispatch count is not observable, cache
    size is)."""
    cfg, params, prompt = setup
    fn = engine.generate_fn(cfg, 6, 0.0, False, None, False)
    fn(params, prompt, jax.random.PRNGKey(0))
    fn(params, prompt, jax.random.PRNGKey(0))
    assert fn._cache_size() == 1


def test_temperature_sampling_matches_reference(setup):
    cfg, params, prompt = setup
    key = jax.random.PRNGKey(7)
    a = engine.greedy_generate(cfg, params, prompt, max_new=5,
                               temperature=0.8, key=key)
    b = engine.reference_generate(cfg, params, prompt, max_new=5,
                                  temperature=0.8, key=key)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_eos_while_loop_early_stop(setup):
    """eos_id switches the loop to lax.while_loop: rows match the greedy
    output up to (and including) their first EOS, then pad with EOS."""
    cfg, params, prompt = setup
    base = np.asarray(engine.greedy_generate(cfg, params, prompt, max_new=6))
    eos = int(base[0, 2])
    toks = np.asarray(engine.greedy_generate(cfg, params, prompt, max_new=6,
                                             eos_id=eos))
    for r in range(base.shape[0]):
        hits = np.nonzero(base[r] == eos)[0]
        j = int(hits[0]) if hits.size else base.shape[1] - 1
        np.testing.assert_array_equal(toks[r, :j + 1], base[r, :j + 1])
        assert (toks[r, j:] == eos).all() or not hits.size


def test_reference_generate_default_key_sampling(setup):
    """Regression: temperature > 0 with key=None used to crash in
    jax.random.split(None); it now defaults the key like greedy_generate —
    so the two must still agree token-for-token."""
    cfg, params, prompt = setup
    a = engine.reference_generate(cfg, params, prompt, max_new=4,
                                  temperature=0.8)
    b = engine.greedy_generate(cfg, params, prompt, max_new=4,
                               temperature=0.8)
    c = engine.reference_generate(cfg, params, prompt, max_new=4,
                                  temperature=0.8,
                                  key=jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_eos_terminal_step_skips_dead_forward(qsetup):
    """Regression: the while_loop body used to run one extra model forward
    after the final accepted token (a dead forward per generate).  Executed
    forwards are observable through the traffic stats — every real forward
    on the quant path fetches planes (fraction > 0), a skipped one reports
    exactly 0 — so the step count must be first_eos.max(), not +1."""
    cfg, qparams, prompt = qsetup
    base = np.asarray(engine.greedy_generate(cfg, qparams, prompt, max_new=6,
                                             quant="xla"))
    eos = int(base[0, 2])
    toks, stats = engine.greedy_generate(cfg, qparams, prompt, max_new=6,
                                         quant="xla", eos_id=eos,
                                         with_stats=True)
    toks = np.asarray(toks)
    frac = np.asarray(stats["plane_traffic_fraction"])
    hits = toks == eos
    first = np.where(hits.any(1), hits.argmax(1), toks.shape[1] - 1)
    n_forwards = int(first.max())       # tokens 0..max-1 consumed, no more
    assert (frac[:n_forwards] > 0).all(), frac
    assert (frac[n_forwards:] == 0).all(), frac


def test_eos_with_temperature_sampling_and_stats(qsetup):
    """eos early-stop x temperature sampling x with_stats together (only
    greedy eos was exercised before): rows match the eos-free sampled run up
    to (and including) their first EOS, pad with EOS after, and the stats
    arrays stay per-step shaped with zeros exactly on skipped steps."""
    cfg, qparams, prompt = qsetup
    key = jax.random.PRNGKey(3)
    max_new = 6
    base = np.asarray(engine.greedy_generate(
        cfg, qparams, prompt, max_new=max_new, temperature=0.8, key=key,
        quant="xla"))
    eos = int(base[1, 1])
    toks, stats = engine.greedy_generate(
        cfg, qparams, prompt, max_new=max_new, temperature=0.8, key=key,
        quant="xla", eos_id=eos, with_stats=True)
    toks = np.asarray(toks)
    frac = np.asarray(stats["plane_traffic_fraction"])
    elem = np.asarray(stats["element_traffic_fraction"])
    assert frac.shape == (max_new,) and elem.shape == (max_new,)
    for r in range(base.shape[0]):
        hits = np.nonzero(base[r] == eos)[0]
        j = int(hits[0]) if hits.size else base.shape[1] - 1
        np.testing.assert_array_equal(toks[r, :j + 1], base[r, :j + 1])
        assert (toks[r, j:] == eos).all() or not hits.size
    hits = toks == eos
    first = np.where(hits.any(1), hits.argmax(1), toks.shape[1] - 1)
    n_forwards = int(first.max())
    assert (frac[:n_forwards] > 0).all() and (frac[n_forwards:] == 0).all()
    assert (elem[:n_forwards] > 0).all() and (elem[n_forwards:] == 0).all()


def test_generate_cache_clear_and_resize(setup):
    """The generate-program LRU is explicitly controllable: clear empties
    it, set_generate_cache_size bounds it (evicting oldest-first)."""
    cfg, params, prompt = setup
    old_size = engine.generate_fn.maxsize
    try:
        engine.clear_generate_cache()
        assert len(engine.generate_fn) == 0
        engine.greedy_generate(cfg, params, prompt, max_new=2)
        engine.greedy_generate(cfg, params, prompt, max_new=3)
        assert len(engine.generate_fn) == 2
        engine.set_generate_cache_size(1)
        assert len(engine.generate_fn) == 1
        assert engine.generate_fn.maxsize == 1
        # the survivor is the most recent entry: re-requesting it is a hit
        fn = engine.generate_fn(cfg, 3, 0.0, False, None, False)
        assert len(engine.generate_fn) == 1
        assert fn is engine.generate_fn(cfg, 3, 0.0, False, None, False)
        with pytest.raises(ValueError):
            engine.set_generate_cache_size(0)
    finally:
        engine.set_generate_cache_size(old_size)


def test_quant_pallas_matches_xla_exactly(qsetup):
    """Acceptance: quant decode runs through bitplane_matmul_pallas — and
    because both backends are exact integer programs, the kernel path must
    reproduce the jnp bit-plane path bit-for-bit."""
    cfg, qparams, prompt = qsetup
    t_xla = engine.greedy_generate(cfg, qparams, prompt, max_new=4,
                                   quant="xla")
    t_pallas = engine.greedy_generate(cfg, qparams, prompt, max_new=4,
                                      quant=True)      # True -> pallas
    np.testing.assert_array_equal(np.asarray(t_xla), np.asarray(t_pallas))


def test_packed_planes_decode_matches_unpacked(setup, qsetup):
    cfg, params, prompt = setup
    _, qparams, _ = qsetup
    qpacked = quantize_model_params(cfg, params, pack=True)
    a = engine.greedy_generate(cfg, qparams, prompt, max_new=4, quant="xla")
    b = engine.greedy_generate(cfg, qpacked, prompt, max_new=4, quant="xla")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_plane_traffic_stats_reported(qsetup):
    cfg, qparams, prompt = qsetup
    toks, stats = engine.greedy_generate(cfg, qparams, prompt, max_new=4,
                                         quant="xla", with_stats=True)
    tile = np.asarray(stats["plane_traffic_fraction"])
    elem = np.asarray(stats["element_traffic_fraction"])
    assert tile.shape == (4,) and elem.shape == (4,)
    # the final token's forward is skipped (dead logits) -> exact-zero row
    assert ((tile[:-1] > 0.0) & (tile[:-1] <= 1.0)).all()
    assert ((elem[:-1] > 0.0) & (elem[:-1] <= 1.0)).all()
    assert tile[-1] == 0.0 and elem[-1] == 0.0
    # element granularity is at least as fine as tile granularity
    assert (elem <= tile + 1e-6).all()


def test_quant_decode_close_to_float(setup, qsetup):
    """Quant vs float decode agree within the shift-add quantization
    tolerance.  4-bit LOG2 activations carry half-an-octave of resolution,
    so after 3 layers the logits correlate strongly but are not tight
    (single-layer rel error is < 0.25, see test_core_quant's
    test_quantized_linear_error; composition roughly doubles it) — token
    sequences may diverge at argmax near-ties, which is expected.  The
    exactness guarantees live in the backend/packing equivalence tests."""
    cfg, params, prompt = setup
    _, qparams, _ = qsetup
    from repro.models.model import init_caches
    b, s = prompt.shape
    max_len = s + 1
    prefill_f = jax.jit(engine.make_prefill_step(cfg))
    prefill_q = jax.jit(engine.make_prefill_step(cfg, quant="xla"))
    lf, _ = prefill_f(params, {"tokens": prompt},
                      init_caches(cfg, b, max_len, dtype=cfg.dtype))
    lq, _ = prefill_q(qparams, {"tokens": prompt},
                      init_caches(cfg, b, max_len, dtype=cfg.dtype))
    lf, lq = np.asarray(lf, np.float32), np.asarray(lq, np.float32)
    # cosine similarity per row of the logit vectors (chance level ~0 for a
    # 256-way vocab; measured ~0.77-0.84 on this config/seed)
    cos = (lf * lq).sum(-1) / (np.linalg.norm(lf, axis=-1)
                               * np.linalg.norm(lq, axis=-1) + 1e-9)
    assert (cos > 0.6).all(), cos
    rel = np.abs(lf - lq).mean() / (np.abs(lf).mean() + 1e-9)
    assert rel < 1.0, rel
