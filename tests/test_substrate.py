"""Substrate tests: optimizer, data pipeline, checkpointing, watchdog."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticLM
from repro.optim import adamw
from repro.train.trainer import StragglerWatchdog


class TestAdamW:
    def test_converges_on_quadratic(self):
        cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                                total_steps=200)
        params = {"w": jnp.asarray([3.0, -2.0, 5.0])}
        state = adamw.init(params)
        target = jnp.asarray([1.0, 1.0, 1.0])

        @jax.jit
        def step(p, s):
            g = jax.grad(lambda pp: jnp.sum((pp["w"] - target) ** 2))(p)
            return adamw.update(cfg, g, s, p)

        for _ in range(150):
            params, state, metrics = step(params, state)
        np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                                   atol=1e-2)
        assert float(metrics["lr"]) <= cfg.lr

    def test_clipping_bounds_update(self):
        cfg = adamw.AdamWConfig(lr=1.0, clip_norm=1e-3, warmup_steps=0,
                                total_steps=10)
        params = {"w": jnp.zeros(4)}
        state = adamw.init(params)
        g = {"w": jnp.full(4, 1e6)}
        new_p, _, m = adamw.update(cfg, g, state, params)
        assert float(m["grad_norm"]) > 1e5
        assert float(jnp.max(jnp.abs(new_p["w"]))) < 10.0

    def test_schedule_shape(self):
        cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                                min_lr_ratio=0.1)
        lrs = [float(adamw.schedule(cfg, jnp.asarray(s)))
               for s in [0, 5, 10, 50, 100]]
        assert lrs[0] == 0.0 and lrs[1] == pytest.approx(0.5)
        assert lrs[2] == pytest.approx(1.0)
        assert lrs[4] == pytest.approx(0.1, abs=1e-6)


class TestData:
    def test_deterministic_across_instances(self):
        cfg = DataConfig(global_batch=8, seq_len=32, vocab_size=1000, seed=3)
        a = SyntheticLM(cfg).batch(11, shard=2, n_shards=4)
        b = SyntheticLM(cfg).batch(11, shard=2, n_shards=4)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_shards_disjoint_streams(self):
        cfg = DataConfig(global_batch=8, seq_len=64, vocab_size=50000)
        a = SyntheticLM(cfg).batch(0, shard=0, n_shards=2)
        b = SyntheticLM(cfg).batch(0, shard=1, n_shards=2)
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(global_batch=2, seq_len=16, vocab_size=100)
        batch = SyntheticLM(cfg).batch(0)
        np.testing.assert_array_equal(batch["labels"][:, :-1],
                                      batch["tokens"][:, 1:])

    def test_bad_shard_count_raises(self):
        cfg = DataConfig(global_batch=6, seq_len=8, vocab_size=10)
        with pytest.raises(ValueError):
            SyntheticLM(cfg).batch(0, shard=0, n_shards=4)


class TestCheckpoint:
    def _state(self, seed):
        k = jax.random.PRNGKey(seed)
        return {"params": {"w": jax.random.normal(k, (8, 4))},
                "opt": {"m": jnp.zeros((8, 4)), "step": jnp.asarray(7)}}

    def test_roundtrip_exact(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        st = self._state(0)
        mgr.save(5, st, {"loss": 1.5})
        got = mgr.restore(5, st)
        for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert mgr.metadata(5)["loss"] == 1.5

    def test_keep_k_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in range(1, 6):
            mgr.save(s, self._state(s))
        assert mgr.all_steps() == [4, 5]
        assert mgr.latest_step() == 5

    def test_async_save_and_error_surfacing(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        mgr.save_async(1, self._state(1))
        mgr.wait()
        assert mgr.all_steps() == [1]
        # duplicate step -> FileExistsError surfaced on wait()
        mgr.save_async(1, self._state(1))
        with pytest.raises(FileExistsError):
            mgr.wait()

    def test_atomicity_no_tmp_left(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        mgr.save(3, self._state(3))
        assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]

    def test_shape_mismatch_rejected(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        mgr.save(1, self._state(0))
        bad = {"params": {"w": jnp.zeros((2, 2))},
               "opt": {"m": jnp.zeros((8, 4)), "step": jnp.asarray(0)}}
        with pytest.raises(ValueError):
            mgr.restore(1, bad)


class TestWatchdog:
    def test_flags_outlier(self):
        wd = StragglerWatchdog(factor=3.0, warmup=3)
        flags = [wd.observe(t) for t in [1.0, 1.1, 0.9, 1.0, 10.0, 1.0]]
        assert flags == [False, False, False, False, True, False]
        assert wd.flagged == 1
