"""Program-auditor tests: every rule family gets (a) an injected-violation
test proving the rule FIRES and (b) a clean-program test proving it stays
quiet — plus waiver/report plumbing and a live single-variant audit."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import jaxpr_rules, sharding_rules
from repro.analysis.budgets import check_budgets
from repro.analysis.recompile import check_census
from repro.analysis.report import (AuditReport, Finding, Waiver,
                                   apply_waivers, load_waivers)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# rule family 1: jaxpr rules
# ---------------------------------------------------------------------------


class TestJaxprRules:
    def test_debug_callback_flagged(self):
        def bad(x):
            jax.debug.print("leftover {x}", x=x[0])
            return x * 2

        j = jax.make_jaxpr(bad)(jnp.zeros((4,)))
        f = jaxpr_rules.rule_no_host_callback(j, "v", "p")
        assert f and f[0].rule == "no-host-callback"
        assert "debug_callback" in f[0].detail

    def test_callback_inside_scan_flagged(self):
        # the rule must see through lax.scan's body jaxpr
        def bad(x):
            def body(c, _):
                jax.debug.print("tick {c}", c=c[0])
                return c * 2, c

            return jax.lax.scan(body, x, None, length=3)

        j = jax.make_jaxpr(bad)(jnp.zeros((4,)))
        assert jaxpr_rules.rule_no_host_callback(j, "v", "p")

    def test_clean_program_quiet(self):
        j = jax.make_jaxpr(lambda x: jnp.tanh(x) * 2)(jnp.zeros((4,)))
        assert not jaxpr_rules.rule_no_host_callback(j, "v", "p")
        assert not jaxpr_rules.rule_no_double_precision(j, "v", "p")
        assert not jaxpr_rules.rule_no_integer_upcast(j, "v", "p")

    def test_f64_flagged(self):
        from jax.experimental import enable_x64
        with enable_x64():
            j = jax.make_jaxpr(
                lambda x: x.astype(jnp.float64) + 1.0)(
                    jnp.zeros((3,), jnp.float32))
        f = jaxpr_rules.rule_no_double_precision(j, "v", "p")
        assert f and f[0].rule == "no-double-precision"
        assert "float64" in f[0].detail

    def test_i64_flagged(self):
        from jax.experimental import enable_x64
        with enable_x64():
            j = jax.make_jaxpr(
                lambda x: x.astype(jnp.int64) * 2)(
                    jnp.zeros((3,), jnp.int32))
        f = jaxpr_rules.rule_no_integer_upcast(j, "v", "p")
        assert f and f[0].rule == "no-integer-upcast"
        assert "int64" in f[0].detail


class TestDensePoolGather:
    N_PAGES = 34

    def test_dense_gather_flagged(self):
        pool = jnp.zeros((2, self.N_PAGES, 4, 8), jnp.float32)
        table = jnp.zeros((3,), jnp.int32)

        def bad(pool, table):
            return pool[:, table]           # dense pool[table] fallback

        j = jax.make_jaxpr(bad)(pool, table)
        f = jaxpr_rules.rule_no_dense_pool_gather(
            j, "v", "tick", n_pages=self.N_PAGES)
        assert f and f[0].rule == "no-dense-pool-gather"

    def test_integer_index_gather_quiet(self):
        # page-table index arithmetic (int gathers) must pass
        table = jnp.zeros((4, 8), jnp.int32)
        idx = jnp.zeros((3,), jnp.int32)
        j = jax.make_jaxpr(lambda t, i: t[:, i])(table, idx)
        assert not jaxpr_rules.rule_no_dense_pool_gather(
            j, "v", "tick", n_pages=self.N_PAGES)

    def test_float_gather_off_pool_quiet(self):
        # float gather NOT carrying the page axis is not the pool
        x = jnp.zeros((2, 16, 8), jnp.float32)
        idx = jnp.zeros((3,), jnp.int32)
        j = jax.make_jaxpr(lambda x, i: x[:, i])(x, idx)
        assert not jaxpr_rules.rule_no_dense_pool_gather(
            j, "v", "tick", n_pages=self.N_PAGES)

    def test_real_paged_tick_without_kernel_has_dense_gather(self):
        # positive control on a REAL program: kernel off -> the tick's
        # attention gathers pool[table] densely, and the rule sees it
        from repro.analysis.programs import (AUDIT_N_PAGES, Variant,
                                             build_scheduler)
        sched = build_scheduler(Variant("paged", False, None))
        fn, args = sched.audit_programs()["tick"]
        j = jaxpr_rules.make_program_jaxpr(fn, args)
        assert jaxpr_rules.rule_no_dense_pool_gather(
            j, "paged", "tick", n_pages=AUDIT_N_PAGES)

    def test_real_kernel_tick_clean(self):
        # the PR 6 kernel's whole point: no dense pool gather in tick
        from repro.analysis.programs import (AUDIT_N_PAGES, Variant,
                                             build_scheduler)
        sched = build_scheduler(Variant("paged_kernel", False, None))
        fn, args = sched.audit_programs()["tick"]
        j = jaxpr_rules.make_program_jaxpr(fn, args)
        assert not jaxpr_rules.rule_no_dense_pool_gather(
            j, "paged_kernel", "tick", n_pages=AUDIT_N_PAGES)


# ---------------------------------------------------------------------------
# rule family 2: sharded-rearrange hazard
# ---------------------------------------------------------------------------


class TestShardedRearrange:
    @pytest.fixture()
    def mesh(self):
        # degenerate 1x1 mesh: PartitionSpec bookkeeping is identical to a
        # real mesh, so the rule is testable on one device
        return jax.make_mesh((1, 1), ("data", "model"))

    def test_model_sharded_concat_flagged(self, mesh):
        s = NamedSharding(mesh, P(None, "model"))

        def bad(x):
            x = jax.lax.with_sharding_constraint(x, s)
            return jnp.concatenate([x, x], axis=1)

        j = jax.make_jaxpr(bad)(jnp.zeros((4, 8)))
        f = sharding_rules.rule_sharded_rearrange(j, "v", "p")
        assert f and f[0].rule == "sharded-rearrange"
        assert "concatenate" in f[0].detail

    def test_replicated_pin_quiet(self, mesh):
        s = NamedSharding(mesh, P(None, None))

        def good(x):
            x = jax.lax.with_sharding_constraint(x, s)
            return jnp.concatenate([x, x], axis=1)

        j = jax.make_jaxpr(good)(jnp.zeros((4, 8)))
        assert not sharding_rules.rule_sharded_rearrange(j, "v", "p")

    def test_concat_on_unsharded_axis_quiet(self, mesh):
        # model on dim 1, concat along dim 0: legal
        s = NamedSharding(mesh, P(None, "model"))

        def good(x):
            x = jax.lax.with_sharding_constraint(x, s)
            return jnp.concatenate([x, x], axis=0)

        j = jax.make_jaxpr(good)(jnp.zeros((4, 8)))
        assert not sharding_rules.rule_sharded_rearrange(j, "v", "p")

    def test_pin_survives_dtype_convert(self, mesh):
        # convert_element_type is spec-transparent: still flagged
        s = NamedSharding(mesh, P(None, "model"))

        def bad(x):
            x = jax.lax.with_sharding_constraint(x, s)
            x = x.astype(jnp.bfloat16)
            return jnp.split(x, 2, axis=1)

        j = jax.make_jaxpr(bad)(jnp.zeros((4, 8)))
        f = sharding_rules.rule_sharded_rearrange(j, "v", "p")
        assert f

    def test_reshape_merging_model_dim_flagged(self, mesh):
        s = NamedSharding(mesh, P(None, "model", None))

        def bad(x):
            x = jax.lax.with_sharding_constraint(x, s)
            return x.reshape(4, 32)         # merges the model-sharded dim

        j = jax.make_jaxpr(bad)(jnp.zeros((4, 8, 4)))
        f = sharding_rules.rule_sharded_rearrange(j, "v", "p")
        assert f and "reshape" in f[0].detail

    def test_unpinned_tensor_untracked(self, mesh):
        # no adjacent pin -> the rule does not guess
        j = jax.make_jaxpr(
            lambda x: jnp.concatenate([x, x], axis=1))(jnp.zeros((4, 8)))
        assert not sharding_rules.rule_sharded_rearrange(j, "v", "p")


# ---------------------------------------------------------------------------
# rule family 3: HLO budgets
# ---------------------------------------------------------------------------


class TestBudgetGate:
    BASE = {"chunked@2x2/tick": {
        "collectives": {"all-reduce": 32, "all-gather": 24},
        "collective_bytes": {"all-reduce": 8000.0, "all-gather": 6000.0},
        "traffic_bytes": 1.0e6,
    }}

    def _fresh(self, **over):
        f = json.loads(json.dumps(self.BASE))
        f["chunked@2x2/tick"].update(over)
        return f

    def test_identical_budgets_pass(self):
        assert not check_budgets(self._fresh(), self.BASE)

    def test_extra_collective_launch_fails_exact(self):
        fresh = self._fresh(
            collectives={"all-reduce": 33, "all-gather": 24})
        f = check_budgets(fresh, self.BASE)
        assert f and f[0].rule == "hlo-budget"
        assert "all-reduce count 33 != budget 32" in f[0].detail

    def test_new_collective_kind_fails(self):
        fresh = self._fresh(collectives={"all-reduce": 32, "all-gather": 24,
                                         "all-to-all": 2})
        assert check_budgets(fresh, self.BASE)

    def test_bytes_within_rtol_pass(self):
        fresh = self._fresh(traffic_bytes=1.05e6)    # 5% < 10% rtol
        assert not check_budgets(fresh, self.BASE)

    def test_bytes_outside_rtol_fail(self):
        fresh = self._fresh(traffic_bytes=1.5e6)     # 50% > 10% rtol
        f = check_budgets(fresh, self.BASE)
        assert f and "traffic_bytes" in f[0].detail

    def test_unbaselined_program_fails(self):
        fresh = dict(self._fresh())
        fresh["paged@2x2/tick"] = fresh["chunked@2x2/tick"]
        f = check_budgets(fresh, self.BASE)
        assert any("no committed budget" in x.detail for x in f)

    def test_stale_baseline_entry_fails(self):
        f = check_budgets({}, self.BASE)
        assert any("no longer audited" in x.detail for x in f)


# ---------------------------------------------------------------------------
# rule family 4: recompile census
# ---------------------------------------------------------------------------


class TestRecompileCensus:
    def test_census_match_quiet(self):
        assert not check_census({"tick": 1, "prefill": 2},
                                {"tick": 1, "prefill": 2})

    def test_retrace_leak_flagged(self):
        f = check_census({"tick": 3, "prefill": 2},
                         {"tick": 1, "prefill": 2})
        assert f and f[0].rule == "recompile-census"
        assert "3 compiled programs, expected 1" in f[0].detail

    def test_probe_unavailable_flagged(self):
        f = check_census({"tick": -1}, {"tick": 1})
        assert f and "probe unavailable" in f[0].detail

    def test_missing_program_flagged(self):
        assert check_census({"tick": 1}, {"tick": 1, "chunk": 1})


# ---------------------------------------------------------------------------
# waivers / report
# ---------------------------------------------------------------------------


class TestWaivers:
    def test_reasonless_waiver_rejected(self, tmp_path):
        p = tmp_path / "w.json"
        p.write_text(json.dumps(
            {"waivers": [{"rule": "r", "match": "*", "reason": "  "}]}))
        with pytest.raises(ValueError, match="reason"):
            load_waivers(str(p))

    def test_waiver_glob_covers(self):
        w = Waiver(rule="hlo-budget", match="paged*/tick", reason="by design")
        assert w.covers(Finding(rule="hlo-budget", variant="paged@2x2",
                                program="tick", detail=""))
        assert not w.covers(Finding(rule="hlo-budget", variant="paged@2x2",
                                    program="mixed", detail=""))
        assert not w.covers(Finding(rule="no-host-callback",
                                    variant="paged@2x2", program="tick",
                                    detail=""))

    def test_apply_waivers_marks_and_filters(self):
        fs = [Finding(rule="r", variant="v", program="tick", detail="a"),
              Finding(rule="r", variant="v", program="mixed", detail="b")]
        live = apply_waivers(fs, [Waiver(rule="r", match="v/tick",
                                         reason="known")])
        assert [f.program for f in live] == ["mixed"]
        assert fs[0].waived and fs[0].waive_reason == "known"
        assert not fs[1].waived

    def test_committed_waiver_file_loads(self):
        # the real committed file must always parse (reasons non-empty)
        load_waivers(os.path.join(REPO, "tools", "audit_waivers.json"))

    def test_report_json_roundtrip(self):
        r = AuditReport(variants=["v"], programs_audited=3,
                        rules_run=["r"],
                        findings=[Finding(rule="r", variant="v",
                                          program="p", detail="d")])
        doc = json.loads(r.to_json())
        assert doc["n_failures"] == 1
        assert doc["findings"][0]["rule"] == "r"


# ---------------------------------------------------------------------------
# live audits (trace-only, single device)
# ---------------------------------------------------------------------------


class TestLiveAudit:
    def test_bucketed_variant_audits_clean(self):
        from repro.analysis.audit import audit_variant
        from repro.analysis.programs import Variant
        report = AuditReport()
        audit_variant(Variant("bucketed", False, None), report,
                      with_budgets=False)
        # 2 prefill buckets + write + tick
        assert report.programs_audited == 4
        assert not report.findings

    def test_recompile_audit_clean(self):
        from repro.analysis.recompile import run_recompile_audit
        findings, census = run_recompile_audit()
        assert not findings, findings
        assert census["prefill"] == 2 and census["chunk"] == 1


class TestShardedAudit:
    """2x2-mesh audit in a subprocess (forced host devices must never be
    set in the main pytest process — same rule as tests/test_distributed)."""

    def test_sharded_variant_audits_clean(self):
        body = textwrap.dedent("""
        import os
        os.environ['XLA_FLAGS'] = \
            '--xla_force_host_platform_device_count=8'
        from repro.analysis.audit import audit_variant
        from repro.analysis.programs import Variant
        from repro.analysis.report import AuditReport
        r = AuditReport()
        audit_variant(Variant("bucketed", False, "2x2"), r,
                      with_budgets=False)
        assert not r.findings, r.findings
        print("programs:", r.programs_audited)
        """)
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        out = subprocess.run([sys.executable, "-c", body],
                             capture_output=True, text=True, timeout=560,
                             env=env, cwd=REPO)
        assert out.returncode == 0, \
            f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
        assert "programs: 4" in out.stdout
