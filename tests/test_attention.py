"""Flash attention (custom VJP) and cache-dtype tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import forward, init_caches, init_params
from repro.models.attention import flash_attention


def naive_attention(q, k, v, qp, kp):
    b, sq, h, d = q.shape
    g = k.shape[2]
    r = h // g
    qg = q.reshape(b, sq, g, r, d)
    s = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k) / jnp.sqrt(d * 1.0)
    mask = kp[:, None, None, None, :] <= qp[:, None, None, :, None]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("bgrqk,bkgd->bqgrd", p, v)
    return o.reshape(b, sq, h, d)


class TestFlashAttention:
    @pytest.mark.parametrize("chunk", [8, 16, 48])
    @pytest.mark.parametrize("gqa", [(8, 4), (6, 6), (4, 1)])
    def test_forward_matches_naive(self, chunk, gqa):
        h, g = gqa
        key = jax.random.PRNGKey(h * g + chunk)
        B, S, D = 2, 48, 16
        q = jax.random.normal(key, (B, S, h, D))
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, g, D))
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, g, D))
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        o1 = flash_attention(q, k, v, pos, pos, causal=True, kv_chunk=chunk)
        o2 = naive_attention(q, k, v, pos, pos)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   atol=2e-6, rtol=2e-5)

    def test_custom_vjp_matches_autodiff(self):
        key = jax.random.PRNGKey(0)
        B, S, H, G, D = 2, 40, 8, 4, 16
        q = jax.random.normal(key, (B, S, H, D))
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, G, D))
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, G, D))
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        # weighted sum so cotangents are non-uniform
        w = jax.random.normal(jax.random.fold_in(key, 3), (B, S, H, D))

        def f_flash(q, k, v):
            return (flash_attention(q, k, v, pos, pos, causal=True,
                                    kv_chunk=16) * w).sum()

        def f_naive(q, k, v):
            return (naive_attention(q, k, v, pos, pos) * w).sum()

        g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(f_naive, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-5, rtol=1e-4)

    def test_valid_len_masks_cache_tail(self):
        key = jax.random.PRNGKey(4)
        B, S, H, G, D = 1, 32, 4, 2, 8
        q = jax.random.normal(key, (B, 1, H, D))
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, G, D))
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, G, D))
        qp = jnp.full((B, 1), 15)
        kp = jnp.broadcast_to(jnp.arange(S), (B, S))
        out_a = flash_attention(q, k, v, qp, kp,
                                kv_valid_len=jnp.asarray([16]))
        # zeroing the tail beyond valid_len must not change the result
        k2 = k.at[:, 16:].set(99.0)
        v2 = v.at[:, 16:].set(99.0)
        out_b = flash_attention(q, k2, v2, qp, kp,
                                kv_valid_len=jnp.asarray([16]))
        np.testing.assert_allclose(np.asarray(out_a), np.asarray(out_b),
                                   atol=1e-6)


class TestCacheDtype:
    def test_f8_cache_decode_correlates(self):
        cfg = get_smoke("qwen3_32b").replace(dtype=jnp.float32)
        params = init_params(jax.random.PRNGKey(0), cfg)
        B, S = 2, 12
        tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                    cfg.vocab_size)
        full, _ = forward(cfg, params, tokens=tokens)
        caches = init_caches(cfg, B, max_len=S, dtype=jnp.float8_e4m3fn)
        outs = []
        for t in range(S):
            lg, caches = forward(cfg, params, tokens=tokens[:, t:t + 1],
                                 caches=caches)
            outs.append(lg[:, 0])
        dec = jnp.stack(outs, 1)
        corr = np.corrcoef(np.asarray(full).ravel(),
                           np.asarray(dec).ravel())[0, 1]
        assert corr > 0.98, corr

    def test_cache_dtype_config_plumbs(self):
        cfg = get_smoke("qwen3_32b").replace(cache_dtype=jnp.float8_e4m3fn)
        caches = init_caches(cfg, 2, 8)
        assert caches["layers"][0]["k"].dtype == jnp.float8_e4m3fn
