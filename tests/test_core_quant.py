"""Unit + property tests for the QeiHaN core quantization math.

Property tests use ``hypothesis`` when it is installed (see
``requirements-dev.txt``); without it the same invariants run over
deterministic seeded sweeps, so ``python -m pytest`` stays green on a bare
``jax + pytest`` environment.
"""

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed (deterministic "
                                "fallback cases cover the same invariants)")

from repro.core import (calibrate_act_scale, code_dtype, from_bitplanes,
                        log2_dequantize, log2_quantize, log2_quantize_naive,
                        needed_bits, pack_codes, pack_planes,
                        quantize_weights, quantized_linear_apply,
                        quantized_linear_init, shift_product,
                        shiftadd_matmul_bitplane, shiftadd_matmul_elementwise,
                        shiftadd_matmul_exact, to_bitplanes, unpack_codes,
                        unpack_planes, weight_access_report, zero_sentinel)
from repro.core.logquant import (LogQuantized, negative_fraction,
                                 scale_exponent)

if HAVE_HYPOTHESIS:
    finite_f32 = st.floats(min_value=-1e4, max_value=1e4, width=32,
                           allow_nan=False, allow_infinity=False)


def _seeded_float_batches(n_batches=20, max_size=64):
    """Deterministic stand-in for the hypothesis float-list strategy: mixed
    magnitudes (1e-6..1e3), zeros and sign flips, seeded."""
    rng = np.random.default_rng(1234)
    out = []
    for _ in range(n_batches):
        size = int(rng.integers(1, max_size + 1))
        mag = rng.choice([1e-6, 1e-3, 0.1, 1.0, 30.0, 1e3], size)
        x = (rng.normal(0, 1.0, size) * mag).astype(np.float32)
        x[rng.random(size) < 0.1] = 0.0
        out.append(x)
    return out


# ---------------------------------------------------------------------------
# LOG2 quantization (paper Eqs. 2-4, Fig. 5)
# ---------------------------------------------------------------------------

def _check_comparator_matches_naive(xs):
    x = jnp.asarray(xs, jnp.float32)
    a = log2_quantize(x)
    b = log2_quantize_naive(x)
    np.testing.assert_array_equal(np.asarray(a.exp), np.asarray(b.exp))


def _check_dequant_within_half_octave(xs):
    x = jnp.asarray(xs, jnp.float32)
    q = log2_quantize(x)
    xh = log2_dequantize(q)
    alive = np.asarray(q.exp) != zero_sentinel()
    if not alive.any():
        return
    ratio = np.abs(np.asarray(xh))[alive] / np.abs(np.asarray(x))[alive]
    # round-to-nearest exponent => ratio within [2^-0.5, 2^0.5]
    clipped = np.asarray(q.exp)[alive] == 7
    ok = (ratio >= 2 ** -0.51) & (ratio <= 2 ** 0.51) | clipped
    assert ok.all()


class TestLog2Quant:
    def test_exact_powers_of_two(self):
        x = jnp.asarray([2.0 ** e for e in range(-7, 8)], jnp.float32)
        q = log2_quantize(x)
        assert q.exp.tolist() == list(range(-7, 8))
        assert jnp.all(q.sign == 1)

    def test_zero_and_negatives(self):
        q = log2_quantize(jnp.asarray([0.0, -0.0, -4.0, 4.0], jnp.float32))
        assert q.exp[0] == zero_sentinel() and q.exp[1] == zero_sentinel()
        assert q.exp[2] == 2 and q.sign[2] == -1
        assert q.exp[3] == 2 and q.sign[3] == 1

    def test_small_values_prune(self):
        # anything rounding below -8 prunes to the sentinel
        q = log2_quantize(jnp.asarray([1e-30, 2.0 ** -9, 2.0 ** -20],
                                      jnp.float32))
        assert jnp.all(q.exp == zero_sentinel())

    def test_clip_to_max(self):
        q = log2_quantize(jnp.asarray([1e30, jnp.inf], jnp.float32))
        assert jnp.all(q.exp == 7)

    def test_nan_prunes(self):
        q = log2_quantize(jnp.asarray([jnp.nan], jnp.float32))
        assert q.exp[0] == zero_sentinel()

    def test_sqrt2_boundary(self):
        # below sqrt(2) rounds down, above rounds up; f32(sqrt2) < sqrt2
        lo = np.float32(np.sqrt(2.0)) - np.float32(1e-6)
        hi = np.float32(np.sqrt(2.0)) + np.float32(1e-6)
        q = log2_quantize(jnp.asarray([lo, hi]))
        assert q.exp[0] == 0 and q.exp[1] == 1

    @needs_hypothesis
    def test_comparator_matches_naive_property(self):
        @settings(max_examples=300, deadline=None)
        @given(st.lists(finite_f32, min_size=1, max_size=64))
        def run(xs):
            _check_comparator_matches_naive(xs)
        run()

    def test_comparator_matches_naive_seeded(self):
        for xs in _seeded_float_batches():
            _check_comparator_matches_naive(xs)

    @needs_hypothesis
    def test_dequant_within_half_octave_property(self):
        @settings(max_examples=200, deadline=None)
        @given(st.lists(finite_f32.filter(lambda v: abs(v) > 2 ** -8),
                        min_size=1, max_size=64))
        def run(xs):
            _check_dequant_within_half_octave(xs)
        run()

    def test_dequant_within_half_octave_seeded(self):
        for xs in _seeded_float_batches():
            _check_dequant_within_half_octave(xs)

    def test_pack_unpack_codes(self):
        x = jnp.asarray(np.random.default_rng(0).normal(0, 1, 256),
                        jnp.float32)
        q = log2_quantize(x)
        q2 = unpack_codes(pack_codes(q))
        np.testing.assert_array_equal(np.asarray(q.exp), np.asarray(q2.exp))
        np.testing.assert_array_equal(np.asarray(q.sign), np.asarray(q2.sign))

    def test_bf16_f16_inputs(self):
        x = np.random.default_rng(1).normal(0, 1, 128).astype(np.float32)
        for dt in (jnp.bfloat16, jnp.float16):
            q32 = log2_quantize(jnp.asarray(x).astype(dt).astype(jnp.float32))
            qdt = log2_quantize(jnp.asarray(x).astype(dt))
            np.testing.assert_array_equal(np.asarray(q32.exp),
                                          np.asarray(qdt.exp))


def _check_pack_roundtrip_width(xs, n_bits):
    """(exp, sign) -> packed wire code -> (exp, sign) is lossless at every
    encoding width, zero-sentinel and negative entries included."""
    q = log2_quantize(jnp.asarray(xs, jnp.float32), n_bits)
    codes = pack_codes(q, n_bits)
    assert codes.dtype == code_dtype(n_bits)
    q2 = unpack_codes(codes, n_bits)
    np.testing.assert_array_equal(np.asarray(q.exp), np.asarray(q2.exp))
    np.testing.assert_array_equal(np.asarray(q.sign), np.asarray(q2.sign))


class TestCodeWidths:
    """Wire-code round trips across encoding widths (the quantized KV pool
    stores these codes; ISSUE 9).  n_bits=8 is the width whose packed code
    (9 bits with the sign) outgrows int8 — the ``code_dtype`` widening."""

    WIDTHS = (2, 3, 4, 5, 8)

    @pytest.mark.parametrize("n_bits", WIDTHS)
    def test_pack_roundtrip_seeded(self, n_bits):
        for xs in _seeded_float_batches():
            _check_pack_roundtrip_width(xs, n_bits)

    @pytest.mark.parametrize("n_bits", WIDTHS)
    def test_sentinel_and_extremes_roundtrip(self, n_bits):
        # exact zeros (sentinel), +/- of the tiniest/hugest magnitudes, and
        # both clip directions survive the pack
        edge = [0.0, -0.0, 1e-30, -1e-30, 1e30, -1e30, 1.0, -1.0,
                2.0 ** zero_sentinel(n_bits), -(2.0 ** zero_sentinel(n_bits))]
        _check_pack_roundtrip_width(edge, n_bits)
        q = log2_quantize(jnp.asarray(edge, jnp.float32), n_bits)
        assert int(q.exp[0]) == zero_sentinel(n_bits)

    @pytest.mark.parametrize("n_bits", WIDTHS)
    def test_negative_fraction_survives_pack(self, n_bits):
        """The D&S unit's Fig. 2 statistic reads unpacked codes; packing
        must preserve it exactly — negative-heavy batches included."""
        rng = np.random.default_rng(9)
        x = -np.abs(rng.normal(0, 0.3, 128)).astype(np.float32)
        x[:8] = 0.0
        q = log2_quantize(jnp.asarray(x), n_bits)
        q2 = unpack_codes(pack_codes(q, n_bits), n_bits)
        np.testing.assert_array_equal(
            np.asarray(negative_fraction(q, n_bits)),
            np.asarray(negative_fraction(q2, n_bits)))

    @needs_hypothesis
    def test_pack_roundtrip_property(self):
        @settings(max_examples=150, deadline=None)
        @given(n_bits=st.sampled_from(WIDTHS),
               xs=st.lists(finite_f32, min_size=1, max_size=64))
        def run(n_bits, xs):
            _check_pack_roundtrip_width(xs, n_bits)
        run()

    def test_n_bits_property_reports_actual_width(self):
        """LogQuantized.n_bits: the smallest width whose exponent range
        (sentinel included) covers the stored exponents."""
        def q(exps):
            e = jnp.asarray(exps, jnp.int8)
            return LogQuantized(exp=e, sign=jnp.ones_like(e))
        assert q([0, 1, -1]).n_bits == 2
        assert q([-2]).n_bits == 2            # exactly the 2-bit sentinel
        assert q([2]).n_bits == 3             # above the 2-bit max of 1
        assert q([-3]).n_bits == 3
        assert q([7]).n_bits == 4
        assert q([-8, 7]).n_bits == 4
        assert q([8]).n_bits == 5
        assert q([127]).n_bits == 8
        assert q([-128]).n_bits == 8
        assert q([]).n_bits == 2              # empty: smallest encoding
        for n in (2, 3, 4, 5, 8):
            got = log2_quantize(jnp.asarray(_seeded_float_batches(1)[0]), n)
            assert got.n_bits <= n

    def test_code_dtype_widening(self):
        assert code_dtype(2) == jnp.int8 and code_dtype(7) == jnp.int8
        assert code_dtype(8) == jnp.int16


class TestScaleExponent:
    def test_power_of_two_scale(self):
        x = jnp.asarray([[0.75, -3.0, 0.0], [2.0 ** -9, 0.0, 0.0],
                         [0.0, 0.0, 0.0]], jnp.float32)
        se = scale_exponent(x, axis=-1)
        assert se.tolist() == [1, -9, 0]      # floor(log2 max|x|); zeros -> 0
        assert se.dtype == jnp.int32

    def test_scaled_quantize_is_idempotent(self):
        """The KV-page rewrite invariant at the core level: dividing by the
        power-of-two scale then log2-quantizing an already-dequantized
        value reproduces the exponent exactly (mantissa field 0 sits below
        the sqrt(2) comparator threshold)."""
        rng = np.random.default_rng(13)
        x = (rng.normal(0, 2.0, 256) * rng.choice([1e-3, 1.0, 1e2], 256)
             ).astype(np.float32)
        se = scale_exponent(jnp.asarray(x), axis=-1, keepdims=True)
        inv = jnp.exp2(-se.astype(jnp.float32))
        q1 = log2_quantize(jnp.asarray(x) * inv)
        xh = log2_dequantize(q1) * jnp.exp2(se.astype(jnp.float32))
        q2 = log2_quantize(xh * inv)
        np.testing.assert_array_equal(np.asarray(q1.exp), np.asarray(q2.exp))


# ---------------------------------------------------------------------------
# bit-planes (paper §IV-B)
# ---------------------------------------------------------------------------

def _check_roundtrip(ws):
    q = jnp.asarray(ws, jnp.int8)
    planes = to_bitplanes(q)
    np.testing.assert_array_equal(np.asarray(from_bitplanes(planes)),
                                  np.asarray(q, np.int32))


def _check_dropping_low_planes_is_shift(w, k):
    """The paper's core identity: floor(w / 2^k) uses only planes >= k."""
    planes = to_bitplanes(jnp.asarray([w], jnp.int8))
    masked = planes.at[:k].set(0)
    got = int(from_bitplanes(masked)[0]) >> k         # shift of masked value
    assert got == w >> k


class TestBitplanes:
    @needs_hypothesis
    def test_roundtrip_property(self):
        @settings(max_examples=100, deadline=None)
        @given(st.lists(st.integers(-127, 127), min_size=1, max_size=128))
        def run(ws):
            _check_roundtrip(ws)
        run()

    def test_roundtrip_seeded(self):
        rng = np.random.default_rng(5)
        for _ in range(10):
            _check_roundtrip(rng.integers(-127, 128,
                                          rng.integers(1, 129)).tolist())
        _check_roundtrip(list(range(-127, 128)))      # exhaustive int8 range

    def test_pack_roundtrip(self):
        rng = np.random.default_rng(2)
        q = jnp.asarray(rng.integers(-127, 128, (64, 32)), jnp.int8)
        planes = to_bitplanes(q)
        packed = pack_planes(planes, axis=0)
        assert packed.shape == (8, 8, 32)
        np.testing.assert_array_equal(np.asarray(unpack_planes(packed, axis=0)),
                                      np.asarray(planes))

    @needs_hypothesis
    def test_dropping_low_planes_property(self):
        @settings(max_examples=100, deadline=None)
        @given(st.integers(-127, 127), st.integers(1, 7))
        def run(w, k):
            _check_dropping_low_planes_is_shift(w, k)
        run()

    def test_dropping_low_planes_exhaustive(self):
        for w in range(-127, 128):
            for k in range(1, 8):
                _check_dropping_low_planes_is_shift(w, k)


# ---------------------------------------------------------------------------
# shift-add matmul (paper Eq. 5): three forms agree exactly
# ---------------------------------------------------------------------------

def _check_shift_product(w, e):
    q = LogQuantized(exp=jnp.asarray([e], jnp.int8),
                     sign=jnp.asarray([1], jnp.int8))
    got = int(shift_product(jnp.asarray([w], jnp.int8), q)[0])
    if e == -8:
        assert got == 0
    elif e >= 0:
        assert got == w * (2 ** e)
    else:
        assert got == w >> (-e)


class TestShiftAdd:
    def _rand(self, m, k, n, seed=0, zero_frac=0.1, scale=0.5):
        rng = np.random.default_rng(seed)
        x = rng.normal(0, scale, (m, k)).astype(np.float32)
        x[rng.random((m, k)) < zero_frac] = 0.0
        q = log2_quantize(jnp.asarray(x))
        w = quantize_weights(
            jnp.asarray(rng.normal(0, 0.1, (k, n)).astype(np.float32)),
            channel_axis=-1)
        return q, w

    @pytest.mark.parametrize("m,k,n", [(4, 16, 8), (3, 100, 17), (16, 64, 64)])
    def test_bitplane_equals_elementwise(self, m, k, n):
        q, w = self._rand(m, k, n, seed=m * k + n)
        y0 = shiftadd_matmul_elementwise(q, w.q)
        y1 = shiftadd_matmul_bitplane(q, to_bitplanes(w.q))
        np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))

    def test_truncation_error_bounded_per_term(self):
        q, w = self._rand(8, 128, 16, seed=7)
        y_t = shiftadd_matmul_elementwise(q, w.q).astype(jnp.float32)
        y_e = shiftadd_matmul_exact(q, w.q)
        # floor() loses < 1 per contributing term
        assert float(jnp.max(jnp.abs(y_t - y_e))) < 128

    @needs_hypothesis
    def test_shift_product_property(self):
        @settings(max_examples=50, deadline=None)
        @given(st.integers(-127, 127), st.integers(-8, 7))
        def run(w, e):
            _check_shift_product(w, e)
        run()

    def test_shift_product_exhaustive(self):
        for w in (-127, -64, -3, -1, 0, 1, 3, 64, 127):
            for e in range(-8, 8):
                _check_shift_product(w, e)

    def test_quantized_linear_error(self):
        rng = np.random.default_rng(3)
        x = rng.normal(0, 1.0, (8, 256)).astype(np.float32)
        w = rng.normal(0, 0.05, (256, 64)).astype(np.float32)
        p = quantized_linear_init(jnp.asarray(w),
                                  act_scale=calibrate_act_scale(jnp.asarray(x)))
        y = np.asarray(quantized_linear_apply(p, jnp.asarray(x)))
        ref = x @ w
        rel = np.abs(y - ref).mean() / (np.abs(ref).mean() + 1e-9)
        assert rel < 0.25        # LOG2-4bit acts x INT8 weights, no retrain

    def test_backends_agree_exactly(self):
        """The pallas kernel (interpret off-TPU) and the jnp bit-plane form
        compute the identical int32 result through the layer API."""
        rng = np.random.default_rng(11)
        x = jnp.asarray(rng.normal(0, 0.5, (4, 96)).astype(np.float32))
        w = jnp.asarray(rng.normal(0, 0.05, (96, 40)).astype(np.float32))
        p = quantized_linear_init(w)
        y_xla = quantized_linear_apply(p, x, backend="xla")
        y_pl = quantized_linear_apply(p, x, backend="pallas")
        np.testing.assert_array_equal(np.asarray(y_xla), np.asarray(y_pl))


# ---------------------------------------------------------------------------
# memory-access model (paper Fig. 3)
# ---------------------------------------------------------------------------

def _check_savings_bounds(xs):
    q = log2_quantize(jnp.asarray(xs, jnp.float32))
    rep = weight_access_report(q)
    assert -1e-6 <= float(rep.savings_element) <= 1.0
    assert float(rep.element_bits) <= float(rep.baseline_bits)


class TestAccessModel:
    def test_needed_bits(self):
        e = jnp.asarray([-8, -7, -3, -1, 0, 3, 7], jnp.int8)
        nb = needed_bits(e)
        assert nb.tolist() == [0, 1, 5, 7, 8, 8, 8]

    def test_all_negative_saves(self):
        q = log2_quantize(jnp.full((1024,), 0.04, jnp.float32))  # exp ~ -5
        rep = weight_access_report(q)
        assert 0.3 < float(rep.savings_element) < 0.8
        assert float(rep.savings_tile) <= float(rep.savings_element) + 1e-6

    def test_positive_exponents_save_nothing(self):
        q = log2_quantize(jnp.full((512,), 8.0, jnp.float32))
        rep = weight_access_report(q)
        assert float(rep.savings_element) == 0.0

    @needs_hypothesis
    def test_savings_bounds_property(self):
        @settings(max_examples=50, deadline=None)
        @given(st.lists(finite_f32, min_size=8, max_size=512))
        def run(xs):
            _check_savings_bounds(xs)
        run()

    def test_savings_bounds_seeded(self):
        for xs in _seeded_float_batches():
            if len(xs) >= 8:
                _check_savings_bounds(xs)
