"""Host-side paged-KV bookkeeping (serving/kvpool.py): the refcounted page
allocator (fragmentation + reuse, shared pages freed only at last release,
all-or-nothing allocation) and the page-granular radix prefix cache
(longest-prefix lookup, partial-block COW surfacing, LRU eviction, snapshot
bounding).  No jax: these are the pure-metadata invariants the scheduler
builds on."""

import numpy as np
import pytest

from repro.serving.kvpool import (TRASH_PAGE, PagePool, RadixCache,
                                  blocks_for_tokens, page_kv_bytes,
                                  tail_ring_bytes)


class TestPagePool:
    def test_trash_page_reserved(self):
        pool = PagePool(8, 4)
        assert pool.capacity == 7 and pool.available == 7
        got = pool.alloc(7)
        assert TRASH_PAGE not in got and len(set(got)) == 7
        assert pool.alloc(1) is None

    def test_fragmentation_then_reuse(self):
        """Interleaved retire/admit: free pages scattered across the pool
        must be re-usable regardless of order, and the allocator never
        double-hands a page."""
        pool = PagePool(17, 4)
        slots = [pool.alloc(4) for _ in range(4)]       # all 16 pages out
        assert all(s is not None for s in slots)
        pool.release(slots[1])                          # free a middle run
        pool.release(slots[3])
        assert pool.available == 8
        # re-admit into the fragmented free set, different granularity
        a = pool.alloc(3)
        b = pool.alloc(5)
        assert a is not None and b is not None
        live = set(slots[0]) | set(slots[2]) | set(a) | set(b)
        assert len(live) == 16                          # no page handed twice
        assert pool.alloc(1) is None

    def test_alloc_all_or_nothing(self):
        pool = PagePool(6, 4)
        assert pool.alloc(9) is None
        assert pool.available == 5                      # untouched on failure
        assert pool.alloc(5) is not None

    def test_shared_page_freed_only_at_last_release(self):
        pool = PagePool(4, 4)
        (page,) = pool.alloc(1)
        pool.ref([page])                                # 2 holders
        pool.ref([page])                                # 3 holders
        assert pool.is_shared(page)
        assert pool.release([page]) == []
        assert pool.release([page]) == []
        assert pool.available == 2                      # still held
        assert pool.release([page]) == [page]           # last holder frees
        assert pool.available == 3
        with pytest.raises(ValueError):
            pool.release([page])                        # double free

    def test_ref_and_release_validation(self):
        pool = PagePool(4, 4)
        with pytest.raises(ValueError):
            pool.ref([TRASH_PAGE])
        with pytest.raises(ValueError):
            pool.ref([3])                               # free page
        with pytest.raises(ValueError):
            PagePool(1, 4)                              # no room for trash

    def test_blocks_for_tokens(self):
        assert blocks_for_tokens(1, 8) == 1
        assert blocks_for_tokens(8, 8) == 1
        assert blocks_for_tokens(9, 8) == 2             # page_len ∤ length
        assert blocks_for_tokens(17, 8) == 3


class TestPoolByteModel:
    """Pure-arithmetic device-byte model for dense vs log2-quantized pages
    (ISSUE 9): these numbers feed the EXACT-gated rows of ``serve_bench
    --kv-quant`` — pin them here so a silent layout change trips a test
    before it corrupts a baseline."""

    def test_dense_page_bytes(self):
        # page_len=4, 1 kv head, 16 dims, f32: 2 dirs * 4*1*16 * 4B
        assert page_kv_bytes(4, 1, 16) == 512
        assert page_kv_bytes(4, 1, 16, layers=3) == 3 * 512
        assert page_kv_bytes(4, 1, 16, dtype_bytes=2) == 256   # bf16 pool

    def test_quant_page_bytes(self):
        # 4-bit: 1 code byte per element + one int32 scale per (page, head)
        assert page_kv_bytes(4, 1, 16, quant=True) == 2 * (64 + 4)
        # 8-bit codes widen to int16
        assert page_kv_bytes(4, 1, 16, quant=True, kv_bits=8) \
            == 2 * (128 + 4)
        # kv_bits 2..7 all pack into the same 1-byte container
        assert page_kv_bytes(4, 1, 16, quant=True, kv_bits=2) \
            == page_kv_bytes(4, 1, 16, quant=True, kv_bits=7)

    def test_quant_saving_at_least_2x_f32(self):
        """The ISSUE 9 acceptance floor: sub-8-bit pages cut f32 pool bytes
        by >= 2x for every realistic geometry (scale overhead included)."""
        for pl in (4, 8, 16):
            for g, d in ((1, 16), (2, 64), (8, 128)):
                dense = page_kv_bytes(pl, g, d, layers=3)
                quant = page_kv_bytes(pl, g, d, layers=3, quant=True)
                assert dense / quant >= 2.0, (pl, g, d)

    def test_tail_ring_bytes(self):
        # 2*page_len + 1 dense f32 rows per direction per layer
        assert tail_ring_bytes(4, 1, 16) == 2 * 9 * 16 * 4
        assert tail_ring_bytes(8, 2, 8, layers=2, dtype_bytes=2) \
            == 2 * 2 * 17 * 2 * 8 * 2


def _prompt(rng, n, vocab=100):
    return rng.integers(0, vocab, size=n).astype(np.int32)


class TestRadixCache:
    def _seed(self, cache, pool, prompt):
        """Insert ``prompt`` as a retired slot would: its full blocks live
        in freshly-allocated pages."""
        n = len(prompt) // cache.page_len
        pages = pool.alloc(n)
        cache.insert(prompt, lambda i: pages[i])
        pool.release(pages)                             # tree keeps its refs
        return pages

    def test_lookup_whole_blocks(self):
        pool = PagePool(32, 4)
        cache = RadixCache(pool)
        rng = np.random.default_rng(0)
        prompt = _prompt(rng, 14)                       # 3 full blocks + 2
        pages = self._seed(cache, pool, prompt)
        hit = cache.lookup(np.concatenate([prompt[:12], _prompt(rng, 5)]),
                           max_hit=16, allow_partial=False)
        assert hit is not None and hit.length == 12
        assert hit.pages == pages and hit.cow_src is None
        # miss: different first block
        assert cache.lookup(_prompt(rng, 14), max_hit=13,
                            allow_partial=False) is None

    def test_max_hit_caps_to_leave_suffix(self):
        """A fully-cached prompt must still leave >= 1 suffix token (the
        first decode logits come from the suffix prefill)."""
        pool = PagePool(32, 4)
        cache = RadixCache(pool)
        rng = np.random.default_rng(1)
        prompt = _prompt(rng, 12)                       # exactly 3 blocks
        self._seed(cache, pool, prompt)
        hit = cache.lookup(prompt, max_hit=len(prompt) - 1,
                           allow_partial=False)
        assert hit is not None and hit.length == 8      # capped below 12

    def test_partial_block_surfaces_cow_source(self):
        pool = PagePool(32, 4)
        cache = RadixCache(pool)
        rng = np.random.default_rng(2)
        prompt = _prompt(rng, 16)
        pages = self._seed(cache, pool, prompt)
        # shares 2 full blocks + 3 tokens of block 2
        probe = np.concatenate([prompt[:11], _prompt(rng, 6)])
        hit = cache.lookup(probe, max_hit=16)
        assert hit is not None
        assert hit.length == 11 and hit.partial == 3
        assert hit.cow_src == pages[2]
        assert hit.pages == pages[:2]

    def test_min_hit_threshold(self):
        pool = PagePool(32, 4)
        cache = RadixCache(pool)
        rng = np.random.default_rng(3)
        prompt = _prompt(rng, 8)
        self._seed(cache, pool, prompt)
        probe = np.concatenate([prompt[:5], _prompt(rng, 8)])
        assert cache.lookup(probe, max_hit=12, min_hit=6) is None
        hit = cache.lookup(probe, max_hit=12, min_hit=5)
        assert hit is not None and hit.length == 5

    def test_insert_dedups_and_refcounts(self):
        """Two prompts sharing a prefix: the shared block exists once, its
        page refcount reflects tree ownership, and eviction frees pages
        only when no slot still holds them."""
        pool = PagePool(32, 4)
        cache = RadixCache(pool)
        rng = np.random.default_rng(4)
        a = _prompt(rng, 8)
        b = np.concatenate([a[:4], _prompt(rng, 4)])
        pages_a = self._seed(cache, pool, a)
        pages_b = pool.alloc(2)
        cache.insert(b, lambda i: pages_b[i])
        pool.release(pages_b)
        # shared first block: b's node 0 re-used a's node -> b's page 0
        # reference was dropped with the slot release (not kept by the tree)
        assert cache.n_pages == 3                       # a0 a1 b1, not b0
        assert pool.refcount[pages_a[0]] == 1           # tree only
        free_before = pool.available
        cache.evict(pool.capacity)                      # drop everything
        assert pool.available == free_before + 3
        assert cache.n_pages == 0

    def test_eviction_is_lru_leaf_first(self):
        pool = PagePool(32, 4)
        cache = RadixCache(pool)
        rng = np.random.default_rng(5)
        a, b = _prompt(rng, 8), _prompt(rng, 8)
        self._seed(cache, pool, a)
        pages_b = self._seed(cache, pool, b)
        cache.lookup(np.concatenate([b, _prompt(rng, 1)]), max_hit=8)  # touch
        cache.evict(pool.available + 2)                 # force 2 drops
        # a's chain (least recent) went first; b's most-recent block stays
        assert cache.lookup(np.concatenate([b[:4], _prompt(rng, 5)]),
                            max_hit=8, allow_partial=False) is not None
        assert pool.refcount[pages_b[0]] == 1

    def test_unsatisfiable_evict_keeps_aliased_tree(self):
        """Regression (ISSUE 6 "stable page ids while referenced"): when
        every tree page is still aliased by a live slot, ``evict`` cannot
        free anything — it must stop immediately instead of draining the
        whole tree.  The old behaviour destroyed every prefix entry (the
        pages would have become shareable again the moment the slots
        retired) while freeing zero pages."""
        pool = PagePool(8, 4)
        cache = RadixCache(pool)
        rng = np.random.default_rng(7)
        a = _prompt(rng, 8)
        pages = pool.alloc(2)                   # the live slot's pages
        cache.insert(a, lambda i: pages[i])     # tree takes refs -> rc 2
        # slot still running: do NOT release.  Nothing is evictable.
        assert cache.evictable_pages() == 0
        free_before = pool.available
        assert cache.evict(pool.capacity) == 0  # unsatisfiable: no drops
        assert pool.available == free_before
        assert cache.n_pages == 2               # tree intact
        # the slot retires -> pages become tree-only -> evict works again
        pool.release(pages)
        assert cache.evictable_pages() == 2
        assert cache.evict(pool.capacity) == 2
        assert cache.n_pages == 0

    def test_evict_through_aliased_leaf_reaches_free_interior(self):
        """Mixed aliasing: a freeable interior node behind a slot-aliased
        leaf.  Evict may drop the aliased leaf (releasing only the tree's
        reference — the live slot keeps the page and its id) to reach the
        interior page it CAN free, and the slot's page is never handed to
        a later alloc while the slot still holds it."""
        pool = PagePool(8, 4)
        cache = RadixCache(pool)
        rng = np.random.default_rng(8)
        a = _prompt(rng, 8)                     # blocks a0, a1
        interior = pool.alloc(1)                # a0: tree-only after release
        leaf = pool.alloc(1)                    # a1: aliased by a live slot
        cache.insert(a, lambda i: (interior + leaf)[i])
        pool.release(interior)                  # a0 rc=1 (tree only)
        # `leaf` rc=2: tree + the live slot (not released)
        assert cache.evictable_pages() == 1
        freed_goal = pool.available + 1
        dropped = cache.evict(freed_goal)
        assert pool.available == freed_goal     # interior page came free
        assert dropped == 2                     # aliased leaf + interior
        assert pool.refcount[leaf[0]] == 1      # slot's ref intact
        # exhaust the pool: the slot's page id must never be re-handed
        grabbed = pool.alloc(pool.available)
        assert leaf[0] not in grabbed
        pool.release(leaf)                      # slot retires cleanly

    def test_release_during_iteration_of_radix_edge(self):
        """A slot releasing its pages while the tree still references them
        (retire order: insert-then-release) must leave every edge valid:
        lookups after the release return the same stable page ids, and
        those ids are not on the free list."""
        pool = PagePool(16, 4)
        cache = RadixCache(pool)
        rng = np.random.default_rng(9)
        a = _prompt(rng, 12)
        pages = self._seed(cache, pool, a)      # insert + release
        hit = cache.lookup(np.concatenate([a, _prompt(rng, 1)]), max_hit=12,
                           allow_partial=False)
        assert hit is not None and hit.pages == pages
        # none of the tree's pages leaked onto the free list
        grabbed = pool.alloc(pool.available)
        assert not (set(grabbed) & set(pages))

    def test_alloc_refuses_referenced_free_list_page(self):
        """Allocator invariant: a page must have refcount 0 when it leaves
        the free list.  A corrupted free list (page freed while a holder
        remains — e.g. a double-release bug upstream) raises instead of
        silently aliasing one request's KV into another's page table."""
        pool = PagePool(6, 4)
        (page,) = pool.alloc(1)
        pool._free.append(page)                 # simulate the corruption
        with pytest.raises(RuntimeError, match="still referenced"):
            pool.alloc(pool.available)
        # a clean pool still allocates to exactly empty
        pool2 = PagePool(6, 4)
        held = pool2.alloc(2)
        pool2.ref(held)                         # aliased refs held elsewhere
        rest = pool2.alloc(pool2.available)     # alloc exactly at pool-empty
        assert rest is not None and pool2.available == 0
        assert pool2.alloc(1) is None
        assert not (set(held) & set(rest))

    def test_snapshot_gating_and_lru_bound(self):
        pool = PagePool(64, 4)
        cache = RadixCache(pool, snapshot_limit=2)
        rng = np.random.default_rng(6)
        prompts = [_prompt(rng, 8) for _ in range(3)]
        for i, p in enumerate(prompts):
            pages = pool.alloc(2)
            cache.insert(p, lambda bi: pages[bi], snapshot=("snap", i))
            pool.release(pages)
        # bounded: only 2 snapshots survive; the evicted one gates lookups
        with_snap = [cache.lookup(np.concatenate([p, _prompt(rng, 1)]),
                                  max_hit=8, need_snapshot=True)
                     for p in prompts]
        assert sum(h is not None for h in with_snap) == 2
        # need_snapshot=True never returns partial/COW extensions
        hit = next(h for h in with_snap if h is not None)
        assert hit.partial == 0 and hit.cow_src is None
        # the pages themselves survive snapshot trimming (attn reuse)
        assert cache.n_pages == 6
