"""``ServeConfig``: the one frozen, serializable home of every scheduler
knob.  JSON round-trip over the full shipping-config matrix, canonical
forms (bool shorthands, bucket dedup) comparing equal, every validation
moved out of ``ServeScheduler.__init__`` still firing with its message,
the versioned schema rejecting foreign documents, the legacy 22-kwarg
constructor shim (DeprecationWarning + byte-identical scheduler), and the
launcher's flags -> config -> ``--dump-config`` -> ``--config`` loop."""

import dataclasses
import json
import warnings

import pytest

from repro.serving.config import DEFAULT_BUCKETS, SCHEMA_VERSION, ServeConfig

# every structurally distinct configuration the repo ships: the audit
# matrix modes, the bench configs, and the launcher-derived shapes
MATRIX = [
    ServeConfig(),
    ServeConfig(max_slots=2, max_len=32, buckets=(8, 16), tick_steps=2),
    ServeConfig(max_slots=4, max_len=32, buckets=(8, 16), quant="pallas",
                with_stats=True),
    ServeConfig(max_slots=4, max_len=32, buckets=(8, 16), chunked="always",
                chunk_len=8),
    ServeConfig(max_slots=4, max_len=32, buckets=(8, 16), chunked="auto",
                chunk_len=8, oversize="truncate"),
    ServeConfig(max_slots=4, max_len=32, buckets=(8, 16), paged=True,
                page_len=4, n_pages=34, prefix_cache=True, chunked="auto",
                chunk_len=8),
    ServeConfig(max_slots=4, max_len=32, buckets=(8, 16), paged=True,
                page_len=4, attn_kernel="pallas", attn_splits=2),
    ServeConfig(max_slots=2, max_len=64, buckets=(8, 16), paged=True,
                page_len=8, kv_quant=True, kv_bits=4, chunked="auto"),
    ServeConfig(max_slots=4, max_len=32, buckets=(8, 16), mesh_spec="2x2",
                generate_cache_size=8, snapshot_limit=4),
    ServeConfig(max_slots=4, max_len=48, buckets=(8, 16), paged=True,
                page_len=8, prefix_cache=True, min_prefix_hit=8,
                chunked="auto", chunk_len=8, oversize="raise"),
]


@pytest.mark.parametrize("cfg", MATRIX, ids=lambda c: f"slots{c.max_slots}-"
                         f"{c.chunked}-{'paged' if c.paged else 'dense'}-"
                         f"{'kvq' if c.kv_quant else c.attn_kernel}")
def test_json_round_trip(cfg):
    """from_json(to_json(cfg)) == cfg for every shipping config — the
    property that makes the config safe to ship across processes."""
    back = ServeConfig.from_json(cfg.to_json())
    assert back == cfg
    # and the wire form is stable: serializing the round-tripped config
    # reproduces the same document
    assert json.loads(back.to_json()) == json.loads(cfg.to_json())


def test_schema_version_on_the_wire():
    doc = json.loads(ServeConfig().to_json())
    assert doc["schema"] == SCHEMA_VERSION


def test_canonicalization_makes_equivalent_configs_equal():
    """Bool shorthands expand to mode strings, buckets sort + dedup,
    chunk_len defaults to the smallest bucket — equivalent spellings are
    EQUAL, so cross-process config comparison is meaningful."""
    a = ServeConfig(max_len=128, buckets=(32, 16, 16), chunked=True,
                    paged=True, page_len=16, attn_kernel=True)
    b = ServeConfig(max_len=128, buckets=(16, 32), chunked="auto",
                    chunk_len=16, paged=True, page_len=16,
                    attn_kernel="pallas")
    assert a == b
    assert a.buckets == (16, 32)
    assert a.chunked == "auto" and a.attn_kernel == "pallas"
    # dense configs ignore leftover pool knobs (min_prefix_hit zeroes)
    assert (ServeConfig(min_prefix_hit=7)
            == ServeConfig(min_prefix_hit=None))


def test_defaults_are_the_old_scheduler_defaults():
    cfg = ServeConfig()
    assert cfg.max_slots == 8 and cfg.max_len == 256
    assert cfg.buckets == DEFAULT_BUCKETS
    assert cfg.chunked == "off" and not cfg.paged and not cfg.kv_quant


@pytest.mark.parametrize("kwargs,match", [
    (dict(max_slots=0), "max_slots and tick_steps"),
    (dict(tick_steps=0), "max_slots and tick_steps"),
    (dict(oversize="drop"), "'reject', 'truncate', or 'raise'"),
    (dict(buckets=()), "must be non-empty"),
    (dict(max_len=16, buckets=(8, 32)), "fit max_len"),
    (dict(chunked="sometimes"), "'off', 'auto', or 'always'"),
    (dict(max_len=30, buckets=(8,), chunked="auto", chunk_len=8),
     "multiple of chunk_len"),
    (dict(max_len=30, buckets=(8,), paged=True, page_len=4),
     "multiple of page_len"),
    (dict(paged=True, n_pages=1), "reserved trash page"),
    (dict(prefix_cache=True), "requires paged=True"),
    (dict(attn_kernel="pallas"), "requires paged=True"),
    (dict(attn_kernel="vulkan", paged=True), "'off' or 'pallas'"),
    (dict(paged=True, attn_splits=0), "must be >= 1"),
    (dict(kv_quant=True), "requires paged=True"),
    (dict(kv_quant=True, paged=True, kv_bits=1), "must be in \\[2, 8\\]"),
    (dict(mesh_spec=object()), "spec STRING"),
    (dict(quant=object()), "does not serialize"),
])
def test_validation(kwargs, match):
    """Every model-independent check that used to live inline in
    ``ServeScheduler.__init__`` fires at construction, with its message."""
    with pytest.raises(ValueError, match=match):
        ServeConfig(**kwargs)


def test_from_json_rejects_foreign_documents():
    with pytest.raises(ValueError, match="not valid JSON"):
        ServeConfig.from_json("{nope")
    with pytest.raises(ValueError, match="expected a JSON object"):
        ServeConfig.from_json("[1, 2]")
    with pytest.raises(ValueError, match="schema version 99"):
        ServeConfig.from_json(json.dumps({"schema": 99}))
    with pytest.raises(ValueError, match="schema version None"):
        ServeConfig.from_json(json.dumps({"max_slots": 4}))
    doc = json.loads(ServeConfig().to_json())
    doc["n_slots"] = 4  # plausible typo for max_slots
    with pytest.raises(ValueError, match=r"unknown fields \['n_slots'\]"):
        ServeConfig.from_json(json.dumps(doc))


def test_derived_properties():
    cfg = ServeConfig(max_slots=2, max_len=32, buckets=(8,), paged=True,
                      page_len=4, prefix_cache=True, chunked="auto",
                      chunk_len=8)
    assert cfg.max_blocks == 8
    # default pool: slots fully resident + retention headroom + trash page
    assert cfg.resolved_n_pages() == 2 * 8 + 1 + 8
    assert dataclasses.replace(cfg, n_pages=34).resolved_n_pages() == 34
    with pytest.raises(ValueError, match="not a paged config"):
        _ = ServeConfig().max_blocks
    assert ServeConfig().resolved_n_pages() == 0
    assert ServeConfig().make_mesh() is None


# --------------------------------------------------------------------------
# the legacy keyword shim (satellite 1)
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_model():
    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke
    from repro.models import init_params

    cfg = get_smoke("smollm_135m").replace(dtype=jnp.float32)
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


def test_legacy_kwargs_shim_warns_and_matches(smoke_model):
    """The deprecated 22-kwarg constructor routes through ServeConfig:
    it warns, and the scheduler it builds serves EXACTLY the tokens the
    config-form scheduler serves."""
    import numpy as np

    from repro.serving.scheduler import ServeScheduler

    cfg, params = smoke_model
    with pytest.warns(DeprecationWarning, match="build a serving.ServeConfig"):
        legacy = ServeScheduler(cfg, params, max_slots=2, max_len=32,
                                buckets=(8, 16), tick_steps=2)
    sc = ServeConfig(max_slots=2, max_len=32, buckets=(8, 16), tick_steps=2)
    assert legacy.serve_config == sc
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        modern = ServeScheduler(cfg, params, sc)  # canonical form: silent

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
               for n in (5, 8, 12)]
    for p in prompts:
        legacy.submit(p, max_new=6)
        modern.submit(p, max_new=6)
    for a, b in zip(legacy.run(), modern.run()):
        assert a.tokens == b.tokens and a.finish_reason == b.finish_reason


def test_shim_rejects_mixed_and_unknown_kwargs(smoke_model):
    from repro.serving.scheduler import ServeScheduler

    cfg, params = smoke_model
    sc = ServeConfig(max_slots=2, max_len=32, buckets=(8,))
    with pytest.raises(TypeError, match="EITHER a ServeConfig or"):
        ServeScheduler(cfg, params, sc, max_slots=4)
    with pytest.raises(TypeError, match=r"unexpected keyword arguments"):
        ServeScheduler(cfg, params, max_slotz=4)


# --------------------------------------------------------------------------
# the launcher loop: flags -> config -> --dump-config -> --config
# --------------------------------------------------------------------------


def test_cli_dump_config_round_trip(tmp_path, capsys):
    """``--dump-config`` commits exactly what the flags derive, and
    ``--config`` (via ``--dump-config -`` re-emission) loads it back to
    an equal config — the committed-file workflow, no model built."""
    from repro.launch.serve import main

    flags = ["--arch", "smollm_135m", "--smoke", "--continuous",
             "--paged", "--page-len", "8", "--chunked", "--prefix-cache",
             "--max-slots", "2", "--tick-steps", "2",
             "--prompt-len", "16", "--new-tokens", "8"]
    path = tmp_path / "serve.json"
    main(flags + ["--dump-config", str(path)])
    cfg = ServeConfig.from_json(path.read_text())
    assert cfg.paged and cfg.prefix_cache and cfg.chunked == "auto"
    assert cfg.page_len == 8 and cfg.max_slots == 2

    # loading the committed file wins over the (different!) flags
    main(["--arch", "smollm_135m", "--smoke", "--continuous",
          "--max-slots", "7", "--config", str(path), "--dump-config", "-"])
    assert ServeConfig.from_json(capsys.readouterr().out) == cfg


def test_cli_flags_map_to_config(capsys):
    """build_serve_config is a pure flags->config mapping: quant backend,
    kv-quant bits, and the lcm pool rounding all land in the config."""
    from repro.launch.serve import main

    main(["--arch", "smollm_135m", "--smoke", "--continuous",
          "--quant", "--kv-quant", "3", "--page-len", "4",
          "--chunked", "--chunk-len", "8", "--prompt-len", "16",
          "--new-tokens", "8", "--dump-config", "-"])
    cfg = ServeConfig.from_json(capsys.readouterr().out)
    assert cfg.quant == "pallas" and cfg.kv_quant and cfg.kv_bits == 3
    assert cfg.paged  # kv-quant implies paged
    # ONE lcm rounding: pool is a multiple of both chunk_len and page_len
    assert cfg.max_len % 8 == 0 and cfg.max_len % 4 == 0
