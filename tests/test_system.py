"""End-to-end system behaviour: train-to-convergence smoke, resume-after-
crash, quantized serving, dry-run smoke cell, HLO analyzer sanity."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.data import DataConfig, SyntheticLM
from repro.models import init_params
from repro.optim import adamw
from repro.serving import greedy_generate
from repro.train import TrainConfig, train_loop

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_training_reduces_loss():
    cfg = get_smoke("smollm_135m").replace(dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params)
    data = SyntheticLM(DataConfig(global_batch=4, seq_len=32,
                                  vocab_size=cfg.vocab_size))
    tcfg = TrainConfig(optimizer=adamw.AdamWConfig(
        lr=1e-3, warmup_steps=2, total_steps=40))
    losses = []
    params, opt, _ = train_loop(
        cfg, tcfg, params, opt,
        (jax.tree.map(jnp.asarray, data.batch(s)) for s in range(25)),
        hook=lambda s, m: losses.append(float(m["loss"])))
    assert losses[-1] < losses[0] - 0.2, (losses[0], losses[-1])
    assert all(np.isfinite(losses))


def test_greedy_generate_deterministic():
    cfg = get_smoke("qwen3_32b").replace(dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    a = greedy_generate(cfg, params, prompt, max_new=6)
    b = greedy_generate(cfg, params, prompt, max_new=6)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (2, 6)


def test_train_driver_resume(tmp_path):
    """Kill-and-restart through the production driver: training must resume
    from the checkpoint (fault tolerance) and not repeat earlier steps."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    ckpt = str(tmp_path / "ck")
    base = [sys.executable, "-m", "repro.launch.train", "--arch",
            "smollm-135m", "--smoke", "--mesh", "host", "--global-batch", "4",
            "--seq-len", "32", "--checkpoint-dir", ckpt,
            "--checkpoint-every", "5", "--resume", "auto"]
    r1 = subprocess.run(base + ["--steps", "5"], capture_output=True,
                        text=True, env=env, cwd=REPO, timeout=560)
    assert r1.returncode == 0, r1.stderr
    r2 = subprocess.run(base + ["--steps", "8"], capture_output=True,
                        text=True, env=env, cwd=REPO, timeout=560)
    assert r2.returncode == 0, r2.stderr
    assert "resumed from step 5" in r2.stdout
    assert '"step": 5' in r2.stdout and '"step": 4' not in r2.stdout


def test_quantized_serving_runs():
    cfg = get_smoke("smollm_135m").replace(dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    from repro.models.quantize import quantize_model_params
    qparams = quantize_model_params(cfg, params)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                cfg.vocab_size)
    toks = greedy_generate(cfg, qparams, prompt, max_new=4, quant=True)
    assert toks.shape == (1, 4)
    assert bool(jnp.all((toks >= 0) & (toks < cfg.vocab_size)))


def test_dryrun_smoke_cell():
    """The dry-run pipeline end-to-end on a reduced config (512 placeholder
    devices, real 16x16 mesh, lower+compile+analyses)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "smollm-135m",
         "--shape", "train_4k", "--smoke", "--out", "/tmp/dryrun_test"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=560)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "memory_analysis" in out.stdout
    assert "cost_analysis" in out.stdout


def test_hlo_analyzer_scales_scan_flops():
    from repro.launch.hlo_analysis import analyze_hlo

    def f(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y.sum()

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.float32),
        jax.ShapeDtypeStruct((8, 128), jnp.float32)).compile()
    res = analyze_hlo(comp.as_text())
    analytic = 7 * 2 * 8 * 128 * 128
    assert abs(res["flops"] / analytic - 1.0) < 0.05
    assert res["unknown_trip_loops"] == 0
