"""Paged-KV serving (ISSUE 5): the paged scheduler must be BIT-EQUAL to the
dense ServeScheduler on prefix-free traffic (attention + mamba, float +
quant, bucketed + chunked admission) and TOKEN-EXACT vs per-request
``greedy_generate`` on prefix hits (whole-page aliasing, partial-block COW,
SSM snapshot restore).  Pool exhaustion goes through the PR 3
reject/truncate/raise policies — never a crash — and waits for in-flight
pages when the system can free them by retiring."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import init_params
from repro.models.quantize import quantize_model_params
from repro.serving import engine
from repro.serving.scheduler import ServeScheduler


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke("smollm_135m").replace(dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 8, 3, 12, 7, 9)]
    return cfg, params, prompts


def _reference(cfg, params, prompt, max_new, quant=False):
    return np.asarray(engine.greedy_generate(
        cfg, params, jnp.asarray(prompt)[None], max_new=max_new,
        quant=quant))[0]


def _run(cfg, params, prompts, max_new, **kw):
    sched = ServeScheduler(cfg, params, **kw)
    for p in prompts:
        sched.submit(p, max_new=max_new)
    return sched, sched.run()


def test_paged_bit_equal_dense_prefix_free(setup):
    """Acceptance: page-gathered reads + per-page scatter writes reproduce
    the dense slab BIT-FOR-BIT — same admissions, same tokens — including
    slot reuse (6 requests on 2 slots) and page_len not dividing the
    prompt lengths (5, 8, 3, ... over page_len=8)."""
    cfg, params, prompts = setup
    max_new = 10
    kw = dict(max_slots=2, max_len=64, buckets=(8, 16), tick_steps=4)
    _, dense = _run(cfg, params, prompts, max_new, **kw)
    _, paged = _run(cfg, params, prompts, max_new, paged=True, page_len=8,
                    **kw)
    for d, p, prompt in zip(dense, paged, prompts):
        assert d.tokens == p.tokens
        np.testing.assert_array_equal(
            np.asarray(p.tokens), _reference(cfg, params, prompt, max_new))


def test_paged_bit_equal_dense_chunked_and_quant(setup):
    """Chunked (over-bucket prompts) and quantized decode through pages:
    still bit-equal to the dense chunked scheduler."""
    cfg, params, prompts = setup
    rng = np.random.default_rng(3)
    traffic = prompts[:3] + [rng.integers(0, cfg.vocab_size,
                                          size=30).astype(np.int32)]
    qparams = quantize_model_params(cfg, params)
    kw = dict(max_slots=2, max_len=64, buckets=(8, 16), tick_steps=3,
              chunked="auto", quant="xla")
    _, dense = _run(cfg, qparams, traffic, 6, **kw)
    _, paged = _run(cfg, qparams, traffic, 6, paged=True, page_len=8, **kw)
    assert all(d.finish_reason == "length" for d in dense)
    for d, p in zip(dense, paged):
        assert d.tokens == p.tokens


def test_paged_bit_equal_dense_mamba():
    """SSM arch: dense per-slot recurrent state + paged KV don't interact;
    tokens stay bit-equal to the dense scheduler."""
    cfg = get_smoke("mamba2_780m").replace(dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (3, 6, 11)]
    kw = dict(max_slots=2, max_len=48, buckets=(8, 16), tick_steps=3)
    _, dense = _run(cfg, params, prompts, 5, **kw)
    _, paged = _run(cfg, params, prompts, 5, paged=True, page_len=8, **kw)
    for d, p, prompt in zip(dense, paged, prompts):
        assert d.tokens == p.tokens
        np.testing.assert_array_equal(
            np.asarray(p.tokens), _reference(cfg, params, prompt, 5))


def test_prefix_hit_token_exact_and_write_savings(setup):
    """Shared-prefix traffic: later requests alias the donor's pages (hit
    = the whole-page prefix), skip that prefill, and still produce the
    exact per-request greedy_generate tokens."""
    cfg, params, _ = setup
    rng = np.random.default_rng(2)
    max_new = 8
    prefix = rng.integers(0, cfg.vocab_size, size=24).astype(np.int32)
    prompts = [np.concatenate([prefix, rng.integers(0, cfg.vocab_size,
                                                    size=t).astype(np.int32)])
               for t in (5, 3, 7, 4)]
    sched, res = _run(cfg, params, prompts, max_new, max_slots=1,
                      max_len=64, buckets=(8, 16, 32), tick_steps=4,
                      paged=True, page_len=8, prefix_cache=True)
    for r, p in zip(res, prompts):
        assert r.finish_reason == "length"
        np.testing.assert_array_equal(
            np.asarray(r.tokens), _reference(cfg, params, p, max_new))
    st = sched.prefix_cache_stats()
    # requests 1..3 each alias the full 24-token page-aligned prefix
    assert st["cached_tokens"] == 3 * 24
    assert st["lookup_hits"] == 3
    assert st["cache_write_saved_frac"] > 0.5


def test_partial_block_cow_hit(setup):
    """A prefix ending mid-page extends the hit below page granularity by
    copy-on-write: the shared page is copied into a slot-owned page whose
    tail the suffix overwrites — the shared original stays intact (the
    donor's pages still serve later exact-prefix requests)."""
    cfg, params, _ = setup
    rng = np.random.default_rng(4)
    # NB not 6: test_serving_fused asserts its max_new=6 generate program
    # never retraces, and _reference() shares the process-global LRU
    max_new = 7
    prefix = rng.integers(0, cfg.vocab_size, size=28).astype(np.int32)
    prompts = [np.concatenate([prefix, rng.integers(0, cfg.vocab_size,
                                                    size=t).astype(np.int32)])
               for t in (6, 5, 4)]
    sched, res = _run(cfg, params, prompts, max_new, max_slots=1,
                      max_len=64, buckets=(8, 16, 32), tick_steps=4,
                      paged=True, page_len=8, prefix_cache=True,
                      chunked="auto")
    for r, p in zip(res, prompts):
        np.testing.assert_array_equal(
            np.asarray(r.tokens), _reference(cfg, params, p, max_new))
    st = sched.prefix_cache_stats()
    # hits at 24 full-page tokens + 4 COW-extended tokens each
    assert st["cached_tokens"] == 2 * 28, st


def test_mamba_prefix_hit_via_snapshot():
    """Hybrid/SSM prefix reuse: the donor's recurrent state snapshot at the
    page-aligned boundary restores into the hitting slot; tokens equal the
    standalone generate.  chunk_len == page_len keeps every chunk boundary
    snapshot-eligible."""
    cfg = get_smoke("mamba2_780m").replace(dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    prefix = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
    prompts = [np.concatenate([prefix, rng.integers(0, cfg.vocab_size,
                                                    size=t).astype(np.int32)])
               for t in (5, 4, 6)]
    sched, res = _run(cfg, params, prompts, 6, max_slots=1, max_len=64,
                      buckets=(8, 16, 32), tick_steps=3, paged=True,
                      page_len=8, prefix_cache=True, chunked="always",
                      chunk_len=8)
    for r, p in zip(res, prompts):
        np.testing.assert_array_equal(
            np.asarray(r.tokens), _reference(cfg, params, p, 6))
    st = sched.prefix_cache_stats()
    assert st["lookup_hits"] == 2 and st["cached_tokens"] == 2 * 16


def test_eviction_under_pressure_during_hit_admission(setup):
    """A hit admission whose fresh-page allocation must EVICT prefix-cache
    entries: the evicted donor's pages free up, while the pages the hit
    itself aliases survive eviction (the admission holds references on
    them before allocating — regression for the evict-then-alias race).
    """
    cfg, params, _ = setup
    rng = np.random.default_rng(6)
    prefA = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
    donor_a = np.concatenate([prefA, rng.integers(0, cfg.vocab_size,
                                                  size=4).astype(np.int32)])
    donor_b = rng.integers(0, cfg.vocab_size, size=20).astype(np.int32)
    sched = ServeScheduler(cfg, params, max_slots=1, max_len=48,
                           buckets=(8, 16, 32), tick_steps=2, paged=True,
                           page_len=8, n_pages=8, prefix_cache=True,
                           chunked="auto")
    for p in (donor_a, donor_b):
        sched.submit(p, max_new=4)
    sched.run()
    # tree now holds 2 pages each for A and B; 3 of 7 pages free.  The
    # next prompt hits A's 16-token prefix and needs 4 fresh pages ->
    # the allocator must evict B's LRU leaf to satisfy it.
    probe = np.concatenate([prefA, rng.integers(0, cfg.vocab_size,
                                                size=20).astype(np.int32)])
    rid = sched.submit(probe, max_new=4)
    res = {r.rid: r for r in sched.run()}
    assert res[rid].finish_reason == "length"
    np.testing.assert_array_equal(
        np.asarray(res[rid].tokens), _reference(cfg, params, probe, 4))
    st = sched.prefix_cache_stats()
    assert st["cached_tokens"] >= 16            # the hit really aliased A


def test_pool_exhaustion_reject_policy(setup):
    """A pool too small for a queued request while the system is idle
    REJECTS with a per-request error result (PR 3 policy) instead of
    crashing or deadlocking; normal requests around it still serve."""
    cfg, params, prompts = setup
    sched = ServeScheduler(cfg, params, max_slots=2, max_len=32,
                           buckets=(8, 16), tick_steps=2, paged=True,
                           page_len=8, n_pages=5)   # 4 usable pages
    ok1 = sched.submit(prompts[0], max_new=4)       # needs 2 pages
    # 16-token prompt + 4 new + 2 tick slack = 22 tokens -> 3 pages; fits
    # the POOL only when nothing else is resident -> admitted after ok1
    # retires, not rejected
    ok2 = sched.submit(prompts[3], max_new=4)
    res = {r.rid: r for r in sched.run()}
    assert res[ok1].finish_reason == "length"
    assert res[ok2].finish_reason == "length"
    np.testing.assert_array_equal(
        np.asarray(res[ok2].tokens), _reference(cfg, params, prompts[3], 4))

    # a request that can NEVER fit (the pool is smaller than its page
    # need even when idle) -> rejected at admission with error, loop alive
    small = ServeScheduler(cfg, params, max_slots=1, max_len=32,
                           buckets=(8, 16), tick_steps=2, paged=True,
                           page_len=8, n_pages=3)   # 2 usable pages
    big = small.submit(prompts[3], max_new=4)       # 12 + 4 + 2 -> 3 pages
    ok = small.submit(prompts[2], max_new=4)        # 3 + 4 + 2 -> 2 pages
    out = {r.rid: r for r in small.run()}
    assert out[big].finish_reason == "rejected"
    assert "page pool exhausted" in out[big].error
    assert out[ok].finish_reason == "length"


def test_unsatisfiable_alloc_does_not_drain_prefix_cache(setup):
    """An admission the pool can NEVER satisfy must be rejected without
    evicting the prefix cache on the way out (eviction only runs when it
    can actually produce enough pages) — one oversized request must not
    turn every later admission into a miss."""
    cfg, params, _ = setup
    rng = np.random.default_rng(7)
    prefix = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)

    def mk(t):
        tail = rng.integers(0, cfg.vocab_size, size=t).astype(np.int32)
        return np.concatenate([prefix, tail])

    small = ServeScheduler(cfg, params, max_slots=1, max_len=32,
                           buckets=(8, 16, 32), tick_steps=2, paged=True,
                           page_len=8, n_pages=4, prefix_cache=True)
    small.submit(mk(2), max_new=4)        # donor: 18 tok -> 3 pages, fits
    small.run()
    assert small._radix.n_pages == 2      # 2 whole-page prompt blocks kept
    # 26-token prompt needs 4 pages; available(1) + evictable(2) < 4 ->
    # rejected WITHOUT touching the cache
    big = small.submit(mk(10), max_new=4)
    hit_prompt = mk(1)                    # 17 tok: hits the cached prefix
    ok = small.submit(hit_prompt, max_new=4)
    out = {r.rid: r for r in small.run()}
    assert out[big].finish_reason == "rejected"
    assert small._radix.n_pages == 2      # cache survived the rejection
    assert out[ok].finish_reason == "length"
    np.testing.assert_array_equal(
        np.asarray(out[ok].tokens), _reference(cfg, params, hit_prompt, 4))
    assert small.prefix_cache_stats()["cached_tokens"] >= 16


def test_pool_exhaustion_truncate_and_raise(setup):
    cfg, params, prompts = setup
    trunc = ServeScheduler(cfg, params, max_slots=1, max_len=32,
                           buckets=(8, 16), tick_steps=2, paged=True,
                           page_len=8, n_pages=3, oversize="truncate")
    rid = trunc.submit(prompts[3], max_new=4)       # needs 3 of 2 pages
    (r,) = trunc.run()
    assert r.rid == rid and r.finish_reason == "length"
    # truncated to the most recent fit tokens: 2*8 - 4 new - 2 slack = 10
    np.testing.assert_array_equal(
        np.asarray(r.tokens), _reference(cfg, params, prompts[3][-10:], 4))

    strict = ServeScheduler(cfg, params, max_slots=1, max_len=32,
                            buckets=(8, 16), tick_steps=2, paged=True,
                            page_len=8, n_pages=3, oversize="raise")
    strict.submit(prompts[3], max_new=4)
    with pytest.raises(ValueError, match="page pool exhausted"):
        strict.run()


def test_paged_constructor_validation(setup):
    cfg, params, _ = setup
    with pytest.raises(ValueError, match="multiple of"):
        ServeScheduler(cfg, params, max_slots=1, max_len=30, buckets=(8,),
                       paged=True, page_len=8)
    with pytest.raises(ValueError, match="trash page"):
        ServeScheduler(cfg, params, max_slots=1, max_len=32, buckets=(8,),
                       paged=True, page_len=8, n_pages=1)
    with pytest.raises(ValueError, match="requires paged"):
        ServeScheduler(cfg, params, max_slots=1, max_len=32, buckets=(8,),
                       prefix_cache=True)


def test_eos_retirement_frees_pages(setup):
    """EOS mid-stream retires the slot and releases its pages back to the
    allocator; the freed pages serve the next admission."""
    cfg, params, prompts = setup
    max_new = 8
    base = _reference(cfg, params, prompts[0], max_new)
    eos = int(base[2])
    sched = ServeScheduler(cfg, params, max_slots=1, max_len=32,
                           buckets=(8, 16), tick_steps=2, paged=True,
                           page_len=8, n_pages=5)
    sched.submit(prompts[0], max_new=max_new, eos_id=eos)
    sched.submit(prompts[1], max_new=4)
    r0, r1 = sched.run()
    assert r0.finish_reason == "eos"
    np.testing.assert_array_equal(np.asarray(r1.tokens),
                                  _reference(cfg, params, prompts[1], 4))
    assert sched._pages.in_use == 0                 # everything released
