"""Simulator invariants + paper-number reproduction (EXPERIMENTS.md §Paper)."""

import numpy as np
import pytest

from repro.simulator import (ALL_ACCELERATORS, PAPER_WORKLOADS,
                             gaussian_stats, paper_preset, simulate)


@pytest.fixture(scope="module")
def results():
    out = {}
    for name, builder in PAPER_WORKLOADS.items():
        layers = builder()
        st = paper_preset(name)
        out[name] = {c.name: simulate(c, layers, st) for c in ALL_ACCELERATORS}
    return out


class TestInvariants:
    def test_qeihan_never_more_accesses_than_nahid(self, results):
        for name, r in results.items():
            assert r["qeihan"].dram_bits <= r["nahid"].dram_bits + 1e-6, name

    def test_qeihan_faster_and_greener_than_nahid(self, results):
        for name, r in results.items():
            assert r["qeihan"].time_s <= r["nahid"].time_s * 1.001, name
            assert r["qeihan"].energy_j <= r["nahid"].energy_j * 1.001, name

    def test_speedup_positive_vs_neurocube(self, results):
        for name, r in results.items():
            assert r["neurocube"].time_s / r["qeihan"].time_s > 1.0, name

    def test_energy_breakdown_sums(self, results):
        for r in results.values():
            for sim in r.values():
                total = sim.energy_j
                parts = sum(sim.energy_by().values())
                assert abs(total - parts) / total < 1e-9

    def test_dram_dominates_energy(self, results):
        # paper Fig. 12: "the DRAM consumes most of the energy in all cases"
        for name, r in results.items():
            br = r["qeihan"].energy_by()
            assert br["dram"] == max(br.values()), name


class TestPaperNumbers:
    """Loose bands around the paper's printed averages (§VI)."""

    def test_fig3_avg_memory_savings(self):
        savs = [paper_preset(m).estimated_memory_savings()
                for m in PAPER_WORKLOADS]
        assert 0.15 < float(np.mean(savs)) < 0.40      # paper: 0.25

    def test_access_ratio_vs_nahid(self, results):
        ratios = [r["qeihan"].dram_bits / r["nahid"].dram_bits
                  for r in results.values()]
        assert 0.6 < float(np.mean(ratios)) < 0.85     # paper: 0.75

    def test_speedup_vs_nahid(self, results):
        spd = [r["nahid"].time_s / r["qeihan"].time_s
               for r in results.values()]
        assert 1.2 < float(np.mean(spd)) < 1.6         # paper: 1.38

    def test_ptblm_best_alexnet_worst_vs_nahid(self, results):
        spd = {n: r["nahid"].time_s / r["qeihan"].time_s
               for n, r in results.items()}
        assert max(spd, key=spd.get) == "ptblm"        # paper: 1.86x best
        assert min(spd, key=spd.get) == "alexnet"      # paper: 1.07x worst

    def test_energy_vs_nahid(self, results):
        e = [r["nahid"].energy_j / r["qeihan"].energy_j
             for r in results.values()]
        assert 1.1 < float(np.mean(e)) < 1.6           # paper: 1.28


class TestStats:
    def test_gaussian_negative_fraction_monotone(self):
        fracs = [gaussian_stats(c, 2.0, 0.1).negative_fraction
                 for c in (-4, -2, 0, 2)]
        assert all(a > b for a, b in zip(fracs, fracs[1:]))

    def test_presets_match_paper_negativity(self):
        for name, target in [("ptblm", 0.98), ("bert-base", 0.82),
                             ("bert-large", 0.85), ("transformer", 0.57),
                             ("alexnet", 0.36)]:
            got = paper_preset(name).negative_fraction
            assert abs(got - target) < 0.02, (name, got)

    def test_needed_bits_range(self):
        for m in PAPER_WORKLOADS:
            st = paper_preset(m)
            assert 1.0 <= st.mean_needed_bits() <= 8.0
