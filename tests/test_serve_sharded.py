"""Mesh-sharded serving: ServeScheduler and greedy_generate under host-device
meshes must be BIT-EQUAL to their single-device twins (attention + mamba,
float + quant), the generate-program LRU must keep sharded and unsharded
programs apart, and the serve partition rules must land where DESIGN.md
§Sharded serving says they do.

Subprocess pattern as in tests/test_distributed.py: every case forces its
own host device count so the main pytest process keeps the single real
device.  These tests double as the regression net for the CPU-SPMD hazards
this PR worked around (split/concat along a sharded axis and model-sharded
recurrent scan carries are miscompiled by the jax 0.4.37 CPU SPMD pipeline
on partially-replicated meshes — see models/sharding.py::shard/replicate and
launch/shardings.py::cache_shardings).
"""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str, devices: int = 8, timeout: int = 560) -> str:
    src = ("import os\n"
           f"os.environ['XLA_FLAGS'] = "
           f"'--xla_force_host_platform_device_count={devices}'\n"
           + textwrap.dedent(body))
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", src], capture_output=True,
                         text=True, timeout=timeout, env=env, cwd=REPO)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


_SCHED_BODY = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke
from repro.models import init_params
from repro.models.quantize import quantize_model_params
from repro.serving.scheduler import ServeScheduler
from repro.launch.mesh import make_serve_mesh

cfg = get_smoke("{arch}").replace(dtype=jnp.float32)
params = init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
           for n in (5, 12, 3, 9)]

def run(ps, quant, mesh):
    sched = ServeScheduler(cfg, ps, max_slots=2, max_len=64, buckets=(8, 16),
                           tick_steps=4, quant=quant, mesh=mesh)
    for p in prompts:
        sched.submit(p, max_new=8)
    # an oversized prompt mid-run must reject, not abort (sharded too)
    big = sched.submit(np.arange(40, dtype=np.int32), max_new=8)
    res = sched.run()
    assert res[big].finish_reason == "rejected", res[big]
    return [r.tokens for r in res if r.rid != big]

for quant, ps in ((False, params), ("xla", quantize_model_params(cfg, params))):
    base = run(ps, quant, None)
    assert all(len(t) == 8 for t in base)
    for spec in ("2x2", "4x1"):
        got = run(ps, quant, make_serve_mesh(spec))
        assert got == base, (quant, spec, base, got)
        print("{arch}", quant, spec, "BIT-EQUAL")
print("ok")
"""


class TestShardedScheduler:
    def test_attention_bit_equal_2x2_and_4x1(self):
        out = run_py(_SCHED_BODY.format(arch="smollm_135m"))
        assert out.count("BIT-EQUAL") == 4 and "ok" in out

    def test_mamba_bit_equal_2x2_and_4x1(self):
        out = run_py(_SCHED_BODY.format(arch="mamba2_780m"))
        assert out.count("BIT-EQUAL") == 4 and "ok" in out


_CHUNKED_BODY = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke
from repro.models import init_params
from repro.models.quantize import quantize_model_params
from repro.serving.scheduler import ServeScheduler
from repro.launch.mesh import make_serve_mesh

cfg = get_smoke("{arch}").replace(dtype=jnp.float32)
params = init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
# chunk boundaries + prompts past the largest bucket: the mixed
# chunk+decode program must partition exactly (chunk slab on `data`, flag
# vectors like `active`)
prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
           for n in (5, 12, 30, 9, 40)]

def run(ps, quant, mesh):
    sched = ServeScheduler(cfg, ps, max_slots=2, max_len=64,
                           buckets=(8, 16), tick_steps=4, quant=quant,
                           mesh=mesh, chunked="auto")
    for p in prompts:
        sched.submit(p, max_new=8)
    res = sched.run()
    assert all(r.finish_reason == "length" for r in res), res
    return [r.tokens for r in res]

for quant, ps in ((False, params), ("xla", quantize_model_params(cfg, params))):
    base = run(ps, quant, None)
    assert all(len(t) == 8 for t in base)
    for spec in ("2x2", "4x1"):
        got = run(ps, quant, make_serve_mesh(spec))
        assert got == base, (quant, spec, base, got)
        print("{arch}", "chunked", quant, spec, "BIT-EQUAL")
print("ok")
"""


class TestShardedChunkedScheduler:
    """ISSUE 4: chunked prefill under a mesh — the (B, chunk_len) slab and
    the mixed chunk+decode program run tensor/data-parallel with token
    streams bit-equal to the single-device chunked scheduler, long
    (over-bucket) prompts included."""

    def test_attention_chunked_bit_equal(self):
        out = run_py(_CHUNKED_BODY.format(arch="smollm_135m"))
        assert out.count("BIT-EQUAL") == 4 and "ok" in out

    def test_mamba_chunked_bit_equal(self):
        out = run_py(_CHUNKED_BODY.format(arch="mamba2_780m"))
        assert out.count("BIT-EQUAL") == 4 and "ok" in out


_PAGED_BODY = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke
from repro.models import init_params
from repro.models.quantize import quantize_model_params
from repro.serving.scheduler import ServeScheduler
from repro.launch.mesh import make_serve_mesh

cfg = get_smoke("{arch}").replace(dtype=jnp.float32)
params = init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
# prefix-free mix incl. an over-bucket prompt (chunked ingestion) — the
# paged scheduler must stay bit-equal to ITS single-device twin, and that
# twin is bit-equal to the dense scheduler (tests/test_serve_paged.py)
prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
           for n in (5, 12, 3, 9, 30)]

def run(ps, quant, mesh):
    sched = ServeScheduler(cfg, ps, max_slots=2, max_len=64, buckets=(8, 16),
                           tick_steps=4, quant=quant, mesh=mesh, paged=True,
                           page_len=8, prefix_cache=True, chunked="auto")
    for p in prompts:
        sched.submit(p, max_new=8)
    res = sched.run()
    assert all(r.finish_reason == "length" for r in res), res
    return [r.tokens for r in res]

for quant, ps in ((False, params), ("xla", quantize_model_params(cfg, params))):
    base = run(ps, quant, None)
    assert all(len(t) == 8 for t in base)
    for spec in ("2x2", "4x1"):
        got = run(ps, quant, make_serve_mesh(spec))
        assert got == base, (quant, spec, base, got)
        print("{arch}", "paged", quant, spec, "BIT-EQUAL")
print("ok")
"""


class TestShardedPagedScheduler:
    """ISSUE 5: the paged KV pool under a mesh — page pool sharded
    pages-on-data, page tables host-built and threaded through the jitted
    programs, scatter/gather in (page, offset) form (no sharded-axis
    reshape) — token streams bit-equal to the single-device paged
    scheduler on 2x2 and 4x1 meshes, float + quant, incl. chunked
    ingestion of over-bucket prompts and prefix-cache admissions."""

    def test_attention_paged_bit_equal(self):
        out = run_py(_PAGED_BODY.format(arch="smollm_135m"))
        assert out.count("BIT-EQUAL") == 4 and "ok" in out

    def test_mamba_paged_bit_equal(self):
        out = run_py(_PAGED_BODY.format(arch="mamba2_780m"))
        assert out.count("BIT-EQUAL") == 4 and "ok" in out


_SPLITKV_BODY = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke
from repro.models import init_params
from repro.serving.scheduler import ServeScheduler
from repro.launch.mesh import make_serve_mesh

cfg = get_smoke("smollm_135m").replace(dtype=jnp.float32)
params = init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
           for n in (5, 12, 3, 9, 30)]

def run(mesh, kernel, splits=2):
    sched = ServeScheduler(cfg, params, max_slots=2, max_len=64,
                           buckets=(8, 16), tick_steps=4, mesh=mesh,
                           paged=True, page_len=8, prefix_cache=True,
                           chunked="auto", attn_kernel=kernel,
                           attn_splits=splits)
    for p in prompts:
        sched.submit(p, max_new=8)
    res = sched.run()
    assert all(r.finish_reason == "length" for r in res), res
    return [r.tokens for r in res]

dense = run(None, False)
base = run(None, True)
# kernel vs dense-gather: token-equal on the tested seed (reassociated
# softmax makes this empirical, same bar as tests/test_paged_attention.py)
assert base == dense, (base, dense)
for spec in ("2x2", "4x1"):
    got = run(make_serve_mesh(spec), True)
    assert got == base, (spec, base, got)
    print("splitkv", spec, "BIT-EQUAL")
print("ok")
"""


class TestShardedSplitKVKernel:
    """ISSUE 6: the fused paged-attention kernel under a mesh.  The
    interpret-mode pallas call lowers to plain lax ops, so GSPMD
    partitions it like any other program; the "kvsplit" hints put the
    split-KV axis on `model` (launch.shardings.split_kv_specs) and the
    only cross-shard reduction is the tiny (m, l) statistics merge.
    Token streams must be bit-equal to the single-device kernel scheduler
    (which this body also checks equals the dense-gather scheduler)."""

    def test_kernel_split2_bit_equal_2x2_and_4x1(self):
        out = run_py(_SPLITKV_BODY)
        assert out.count("BIT-EQUAL") == 2 and "ok" in out


class TestShardedEngine:
    def test_greedy_generate_bit_equal_and_lru_key(self):
        out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke
        from repro.models import init_params
        from repro.serving import engine
        from repro.launch.mesh import make_serve_mesh
        from repro.launch.shardings import params_shardings

        cfg = get_smoke("smollm_135m").replace(dtype=jnp.float32)
        params = init_params(jax.random.PRNGKey(0), cfg)
        prompt = jnp.asarray(np.random.default_rng(1).integers(
            0, cfg.vocab_size, size=(2, 10)), jnp.int32)
        engine.clear_generate_cache()
        base = engine.greedy_generate(cfg, params, prompt, 12)
        assert len(engine.generate_fn) == 1
        mesh = make_serve_mesh("2x2")
        sp = jax.device_put(params, params_shardings(mesh, params, fsdp=False))
        got = engine.greedy_generate(cfg, sp, prompt, 12, mesh=mesh)
        # sharded is a DISTINCT cached program (stale-reuse regression) ...
        assert len(engine.generate_fn) == 2
        np.testing.assert_array_equal(np.asarray(base), np.asarray(got))
        # ... and both variants stay warm side by side
        again = engine.greedy_generate(cfg, params, prompt, 12)
        np.testing.assert_array_equal(np.asarray(base), np.asarray(again))
        assert len(engine.generate_fn) == 2
        mesh2 = make_serve_mesh("4x1")
        assert engine.mesh_fingerprint(mesh) != engine.mesh_fingerprint(mesh2)
        assert engine.mesh_fingerprint(None) is None
        print("generate sharded ok")
        """)
        assert "generate sharded ok" in out

    def test_step_builders_jit_with_shardings(self):
        """make_prefill_step / make_serve_step with mesh= return sharded-
        jitted programs whose outputs equal the bare closures'."""
        out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke
        from repro.models import init_params, init_caches
        from repro.serving import engine
        from repro.launch.mesh import make_serve_mesh
        from repro.launch.shardings import (batch_shardings, cache_shardings,
                                            params_shardings)

        cfg = get_smoke("smollm_135m").replace(dtype=jnp.float32)
        params = init_params(jax.random.PRNGKey(0), cfg)
        mesh = make_serve_mesh("2x2")
        b, s, max_len = 4, 8, 32
        caches = init_caches(cfg, b, max_len, dtype=cfg.dtype)
        prompt = jnp.asarray(np.random.default_rng(0).integers(
            0, cfg.vocab_size, size=(b, s)), jnp.int32)

        ref_pre = engine.make_prefill_step(cfg)
        lg0, c0 = jax.jit(ref_pre)(params, {"tokens": prompt}, caches)

        psh = params_shardings(mesh, params, fsdp=False)
        csh = cache_shardings(mesh, caches, batch=b)
        bsh = batch_shardings(mesh, {"tokens": prompt})
        sp = jax.device_put(params, psh)
        pre = engine.make_prefill_step(cfg, mesh=mesh,
                                       in_shardings=(psh, bsh, csh),
                                       out_shardings=None)
        lg1, c1 = pre(sp, {"tokens": prompt}, jax.device_put(caches, csh))
        # logits may differ in the psum LSBs (TP reassociation); the serving
        # guarantee is token-level bit-equality
        np.testing.assert_allclose(np.asarray(lg0), np.asarray(lg1),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(jnp.argmax(lg0, -1)),
                                      np.asarray(jnp.argmax(lg1, -1)))

        tok = jnp.argmax(lg0, -1).astype(jnp.int32)[:, None]
        ref_step = engine.make_serve_step(cfg)
        lg2, _ = jax.jit(ref_step)(params, c0, tok)
        step = engine.make_serve_step(cfg, mesh=mesh)
        lg3, _ = step(sp, c1, tok)
        np.testing.assert_array_equal(np.asarray(jnp.argmax(lg2, -1)),
                                      np.asarray(jnp.argmax(lg3, -1)))
        print("builders sharded ok")
        """)
        assert "builders sharded ok" in out


class TestMeshBuilders:
    def test_host_mesh_single_device_fallback_warns(self):
        """One visible device + model_parallel>1 falls back to 1 with a
        warning instead of dying (the old bare assert also vanished under
        python -O).  Subprocess with a FORCED single device: the CI
        multi-device step runs this file under an 8-device XLA_FLAGS env."""
        out = run_py("""
        import warnings
        from repro.launch.mesh import make_host_mesh
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            mesh = make_host_mesh(4)
        assert mesh.shape["model"] == 1, mesh.shape
        assert any("falling back" in str(x.message) for x in w)
        print("fallback ok")
        """, devices=1)
        assert "fallback ok" in out

    def test_host_mesh_indivisible_raises_value_error(self):
        out = run_py("""
        from repro.launch.mesh import make_host_mesh
        try:
            make_host_mesh(3)          # 8 devices % 3 != 0
        except ValueError as e:
            assert "8 devices" in str(e), e
            print("raised ok")
        """)
        assert "raised ok" in out

    def test_serve_mesh_spec_errors(self):
        import pytest

        from repro.launch.mesh import make_serve_mesh
        with pytest.raises(ValueError, match="expected"):
            make_serve_mesh("2by2")
        with pytest.raises(ValueError, match="host_platform_device_count"):
            make_serve_mesh("4x4")     # single-device main process


class TestServeShardings:
    def test_partition_rules(self):
        """The serve bundle pins what DESIGN.md §Sharded serving promises:
        pool batch + per-slot lengths on `data`, kv-seq on `model`, SSM state
        batch-only, packed planes on `model`."""
        out = run_py("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_smoke
        from repro.models import init_params, init_caches
        from repro.models.quantize import quantize_model_params
        from repro.launch.mesh import make_serve_mesh
        from repro.launch.shardings import serve_shardings

        mesh = make_serve_mesh("2x2")
        for arch in ("smollm_135m", "mamba2_780m"):
            cfg = get_smoke(arch).replace(dtype=jnp.float32)
            params = quantize_model_params(
                cfg, init_params(jax.random.PRNGKey(0), cfg))
            pool = init_caches(cfg, 4, 64, dtype=cfg.dtype, per_slot=True)
            spec = serve_shardings(mesh, params, pool, batch=4)
            assert spec["caches"]["length"].spec == P("data")
            assert spec["logits"].spec == P("data", None)
            assert spec["active"].spec == P("data")
            flat = jax.tree_util.tree_flatten_with_path(spec["caches"])[0]
            for path, sh in flat:
                name = jax.tree_util.keystr(path)
                if "'k'" in name or "'v'" in name:
                    assert sh.spec[1] == "data" and sh.spec[2] == "model", \\
                        (name, sh.spec)
                if "'ssm'" in name or "'conv'" in name:
                    assert sh.spec[1] == "data", (name, sh.spec)
                    assert all(e != "model" for e in sh.spec), (name, sh.spec)
            pflat = jax.tree_util.tree_flatten_with_path(spec["params"])[0]
            plane_specs = [sh.spec for path, sh in pflat
                           if "planes" in jax.tree_util.keystr(path)]
            assert plane_specs and any("model" in str(s) for s in plane_specs)
            print(arch, "rules ok")
        """)
        assert out.count("rules ok") == 2
