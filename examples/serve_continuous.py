"""Continuous-batching serving example: a mixed queue of variable-length
requests through the persistent slot pool.

``ServeScheduler`` (``repro.serving.scheduler``) admits prompts into free
cache slots via bucketed prefill, steps every active slot through the fused
slot-masked decode tick, retires requests on EOS or length, and immediately
re-fills the freed slot from the queue — the decode batch never drains.
Each request's tokens are exactly what a standalone ``greedy_generate``
would produce (this script verifies it), and with ``--quant`` each request
reports its plane-traffic fractions — the paper's §VI memory-access savings
under sustained multi-request load.

  PYTHONPATH=src python examples/serve_continuous.py
  PYTHONPATH=src python examples/serve_continuous.py --arch mamba2-780m
  PYTHONPATH=src python examples/serve_continuous.py --quant --backend xla
  PYTHONPATH=src python examples/serve_continuous.py --chunked   # long prompts

``--chunked`` enables chunked prefill (ISSUE 4): the request mix draws
prompts up to 120 tokens — past the largest (64) bucket, a hard rejection
without chunking — and ingests them chunk-by-chunk across ticks while the
other slots keep decoding; parity vs ``greedy_generate`` still holds.

``--mesh DxM`` serves tensor/data-parallel over a host-device mesh (pool
batch-sharded on ``data``, weights TP on ``model``); the per-request parity
check against ``greedy_generate`` still holds bit-for-bit.  On a CPU box
pair it with ``--host-devices N``:

  PYTHONPATH=src python examples/serve_continuous.py --mesh 2x2 --host-devices 4
"""

import argparse
import sys
import time

# must precede the first jax import (jax locks the device count at init;
# repro.launch.host_devices is deliberately jax-free)
if __name__ == "__main__":
    from repro.launch.host_devices import force_host_devices
    force_host_devices(sys.argv)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.launch.mesh import make_serve_mesh
from repro.models import init_params
from repro.models.quantize import quantize_model_params
from repro.serving import ServeScheduler, greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--max-slots", type=int, default=3)
    ap.add_argument("--tick-steps", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--quant", action="store_true")
    ap.add_argument("--backend", default="pallas", choices=["pallas", "xla"])
    ap.add_argument("--mesh", default=None,
                    help="DxM data x model mesh (e.g. 2x2) for sharded "
                         "serving; default single-device")
    ap.add_argument("--host-devices", type=int, default=None,
                    help="force N host devices (see module docstring)")
    ap.add_argument("--chunked", nargs="?", const="auto", default="off",
                    choices=["off", "auto", "always"],
                    help="chunked prefill: the request mix adds prompts "
                         "past the largest bucket (up to 120 tokens), "
                         "ingested chunk-by-chunk interleaved with decode")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch).replace(dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    if args.quant:
        params = quantize_model_params(cfg, params)
    quant = args.backend if args.quant else False
    mesh = make_serve_mesh(args.mesh) if args.mesh else None

    from repro.serving.scheduler import round_pool_len
    long_max = 120 if args.chunked != "off" else 32
    pool = round_pool_len(
        max(64, long_max) + args.new_tokens + args.tick_steps, 8)
    sched = ServeScheduler(cfg, params, max_slots=args.max_slots,
                           max_len=pool,
                           buckets=(8, 16, 32, 64), quant=quant,
                           with_stats=args.quant,
                           tick_steps=args.tick_steps, mesh=mesh,
                           chunked=args.chunked)

    rng = np.random.default_rng(args.seed)
    prompts = [rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(3, long_max + 1))
                            ).astype(np.int32)
               for _ in range(args.requests)]
    for p in prompts:
        sched.submit(p, max_new=args.new_tokens)

    t0 = time.perf_counter()
    results = sched.run()
    dt = time.perf_counter() - t0
    total = sum(len(r.tokens) for r in results)
    mode = f"qeihan-int8-bitplane[{args.backend}]" if args.quant else "float"
    if mesh is not None:
        mode += f" | mesh {args.mesh}"
    print(f"[{cfg.name} | {mode}] {len(results)} requests / "
          f"{args.max_slots} slots: {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s incl. compile)")
    print("compiled programs:", sched.compile_stats())

    mismatches = 0
    for r, p in zip(results, prompts):
        ref = np.asarray(greedy_generate(
            cfg, params, jnp.asarray(p)[None], max_new=args.new_tokens,
            quant=quant))[0]
        ok = np.array_equal(np.asarray(r.tokens), ref[: len(r.tokens)])
        mismatches += not ok
        line = (f"  rid {r.rid}: prompt {r.prompt_len:>2} tok -> "
                f"{len(r.tokens)} new ({r.finish_reason}), ticks "
                f"{r.admitted_tick}-{r.finished_tick}, "
                f"parity={'OK' if ok else 'MISMATCH'}")
        if args.quant:
            line += (f", plane {r.plane_traffic_fraction:.3f} / "
                     f"elem {r.element_traffic_fraction:.3f}")
        print(line)
    print("token parity vs greedy_generate:",
          "ALL OK" if not mismatches else f"{mismatches} MISMATCHES")


if __name__ == "__main__":
    main()
