"""Full paper-analysis walkthrough: Fig. 2 histograms -> Fig. 3 savings ->
Figs. 9-11 accelerator comparison, on both measured and paper-preset
activation statistics.  This is the reproduction artifact behind
EXPERIMENTS.md §Paper.

  PYTHONPATH=src python examples/qeihan_analysis.py
"""

import sys

import numpy as np

try:
    import benchmarks  # noqa: F401  (repo root already on sys.path)
except ImportError:  # `python examples/...` puts examples/ first, not the root
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks.paper_figures import (fig10_speedups, fig11_energy,  # noqa: E402
                                      fig2_histograms, fig3_memory_savings,
                                      fig9_memory_accesses)


def show(rows, title):
    print(f"\n== {title} ==")
    for name, val, ref in rows:
        ref_s = "" if (isinstance(ref, float) and np.isnan(ref)) \
            else f"   [paper: {ref:.3g}]"
        print(f"  {name:<44} {val:8.4f}{ref_s}")


def main():
    show(fig2_histograms("preset"), "Fig.2 exponent negativity (paper preset)")
    show(fig2_histograms("measured"),
         "Fig.2 exponent negativity (measured from our JAX paper nets)")
    show(fig3_memory_savings("preset"), "Fig.3 estimated memory savings")
    show(fig9_memory_accesses("preset"), "Fig.9 normalized memory accesses")
    show(fig10_speedups("preset"), "Fig.10 speedups")
    show(fig11_energy("preset"), "Fig.11 energy savings")


if __name__ == "__main__":
    main()
