"""Batched serving example: prefill + FUSED decode across the arch zoo,
float vs QeiHaN-quantized weights side by side, with per-step weight-plane
traffic reporting.

The decode loop is one jitted ``lax.scan`` program (see
``repro.serving.engine``) — per-token Python dispatch is gone.  With
``--quant`` the serve steps run through the plane-skipping Pallas kernel
(interpret off-TPU); ``--pack`` serves the packed bit-plane deploy format.

  PYTHONPATH=src python examples/serve_decode.py --arch qwen3-32b
  PYTHONPATH=src python examples/serve_decode.py --arch mamba2-780m --quant
  PYTHONPATH=src python examples/serve_decode.py --arch smollm-135m \
      --quant --pack --backend xla
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.core import log2_quantize, weight_access_report
from repro.models import init_caches, init_params
from repro.models.quantize import quantize_model_params
from repro.serving import greedy_generate


def _audio_generate(cfg, params, key, batch, new_tokens, quant):
    """Audio stub: decode frame-by-frame from synthetic embeddings — also a
    single ``lax.scan`` (frames are precomputed, so they stream as xs)."""
    from repro.serving.engine import make_serve_step
    step = make_serve_step(cfg, quant)
    embs = jax.vmap(lambda k: jax.random.normal(
        k, (batch, 1, cfg.d_model)))(jax.random.split(key, new_tokens))

    @jax.jit
    def run(params, embs):
        caches = init_caches(cfg, batch, new_tokens, dtype=jnp.float32)

        def body(caches, emb):
            lg, caches = step(params, caches, emb)
            return caches, jnp.argmax(lg, -1)

        _, toks = jax.lax.scan(body, caches, embs)
        return jnp.swapaxes(toks, 0, 1)

    return run(params, embs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--quant", action="store_true")
    ap.add_argument("--backend", default="pallas", choices=["pallas", "xla"])
    ap.add_argument("--pack", action="store_true",
                    help="pack bit-planes 8-to-a-byte (int8-footprint "
                         "deploy format)")
    args = ap.parse_args()

    cfg = get_smoke(args.arch).replace(dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    if args.quant:
        params = quantize_model_params(cfg, params, pack=args.pack)
    quant = args.backend if args.quant else False

    stats = None
    if cfg.frontend == "audio_stub":
        t0 = time.perf_counter()
        out = _audio_generate(cfg, params, key, args.batch, args.new_tokens,
                              quant)
        dt = time.perf_counter() - t0
    else:
        prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                    cfg.vocab_size)
        t0 = time.perf_counter()
        out = greedy_generate(cfg, params, prompt, max_new=args.new_tokens,
                              quant=quant, with_stats=args.quant)
        if args.quant:
            out, stats = out
        dt = time.perf_counter() - t0

    n = args.batch * args.new_tokens
    mode = (f"qeihan-int8-bitplane[{args.backend}"
            f"{'+packed' if args.pack else ''}]" if args.quant else "float")
    print(f"[{cfg.name} | {mode}] {n} tokens in {dt:.2f}s "
          f"({n / dt:.1f} tok/s, fused decode incl. compile)")
    print("tokens[0]:", out[0].tolist())
    if stats is not None:
        import numpy as np
        # executed forwards only (the terminal step is skipped, stats row 0)
        tile = np.asarray(stats["plane_traffic_fraction"])
        elem = np.asarray(stats["element_traffic_fraction"])
        ran = tile > 0
        print(f"per-step plane traffic: {float(tile[ran].mean()):.3f} "
              f"tile-granular (kernel), {float(elem[ran].mean()):.3f} "
              f"element-granular (ASIC)")

    # what the QeiHaN memory system would have saved on this workload
    x = jax.random.normal(key, (1024, cfg.d_model)) * 0.3
    rep = weight_access_report(log2_quantize(x))
    print(f"weight-bit savings at this activation distribution: "
          f"{float(rep.savings_element):.1%} (element) / "
          f"{float(rep.savings_tile):.1%} (tile)")


if __name__ == "__main__":
    main()
