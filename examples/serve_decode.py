"""Batched serving example: prefill + decode across the arch zoo, float vs
QeiHaN-quantized weights side by side, with per-layer access accounting.

  PYTHONPATH=src python examples/serve_decode.py --arch qwen3-32b
  PYTHONPATH=src python examples/serve_decode.py --arch mamba2-780m --quant
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke, list_archs
from repro.core import log2_quantize, weight_access_report
from repro.models import forward, init_caches, init_params
from repro.models.quantize import quantize_model_params
from repro.serving import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--quant", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke(args.arch).replace(dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    if args.quant:
        params = quantize_model_params(cfg, params)

    if cfg.frontend == "audio_stub":
        # decode frame-by-frame from synthetic embeddings
        caches = init_caches(cfg, args.batch, args.new_tokens,
                             dtype=jnp.float32)
        toks = []
        t0 = time.perf_counter()
        for t in range(args.new_tokens):
            emb = jax.random.normal(jax.random.fold_in(key, t),
                                    (args.batch, 1, cfg.d_model))
            lg, caches = forward(cfg, params, embeds=emb, caches=caches,
                                 quant=args.quant)
            toks.append(jnp.argmax(lg[:, -1], -1))
        dt = time.perf_counter() - t0
        out = jnp.stack(toks, 1)
    else:
        prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                    cfg.vocab_size)
        t0 = time.perf_counter()
        out = greedy_generate(cfg, params, prompt, max_new=args.new_tokens,
                              quant=args.quant)
        dt = time.perf_counter() - t0

    n = args.batch * args.new_tokens
    mode = "qeihan-int8-bitplane" if args.quant else "float"
    print(f"[{cfg.name} | {mode}] {n} tokens in {dt:.2f}s "
          f"({n / dt:.1f} tok/s on CPU)")
    print("tokens[0]:", out[0].tolist())

    # what the QeiHaN memory system would have saved on this workload
    x = jax.random.normal(key, (1024, cfg.d_model)) * 0.3
    rep = weight_access_report(log2_quantize(x))
    print(f"weight-bit savings at this activation distribution: "
          f"{float(rep.savings_element):.1%} (element) / "
          f"{float(rep.savings_tile):.1%} (tile)")


if __name__ == "__main__":
    main()
