"""Quickstart: the QeiHaN technique end-to-end in 60 lines.

1. LOG2-quantize activations (paper Eqs. 2-4) and look at the exponent
   histogram (Fig. 2's observation: most exponents are negative),
2. estimate the weight-memory savings that buys (Fig. 3),
3. run the exact bit-plane shift-add GEMM and compare against float,
4. swap a model's projections onto the quantized path and generate text.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core import (log2_quantize, negative_fraction, pruned_fraction,
                        quantize_weights, shiftadd_matmul_bitplane,
                        shiftadd_matmul_exact, to_bitplanes,
                        weight_access_report)
from repro.models import init_params
from repro.models.quantize import quantize_model_params
from repro.serving import greedy_generate


def main():
    rng = np.random.default_rng(0)

    # --- 1. LOG2 quantization of a typical post-norm activation tensor ----
    x = jnp.asarray(rng.normal(0, 0.3, (128, 512)).astype(np.float32))
    q = log2_quantize(x)
    print(f"negative exponents: {float(negative_fraction(q)):.1%} "
          f"(paper observes 36%..98% across DNNs)")
    print(f"pruned (zero/small): {float(pruned_fraction(q)):.1%}")

    # --- 2. the memory saving those negative exponents imply --------------
    rep = weight_access_report(q)
    print(f"estimated weight-bit savings: {float(rep.savings_element):.1%} "
          f"element-granular (ASIC), {float(rep.savings_tile):.1%} "
          f"tile-granular (TPU kernel)")

    # --- 3. exact shift-add GEMM vs float GEMM ----------------------------
    w = jnp.asarray(rng.normal(0, 0.1, (512, 256)).astype(np.float32))
    qw = quantize_weights(w, channel_axis=-1)
    y_int = shiftadd_matmul_bitplane(q, to_bitplanes(qw.q))
    y_ref = shiftadd_matmul_exact(q, qw.q)
    print(f"shift-add vs exact fixed-point: max diff "
          f"{float(jnp.max(jnp.abs(y_int - y_ref))):.1f} "
          f"(floor truncation, < K={x.shape[1]})")
    y_float = (x @ w)
    y_deq = y_int.astype(jnp.float32) * qw.scale.reshape(1, -1)
    rel = float(jnp.mean(jnp.abs(y_deq - y_float)) /
                jnp.mean(jnp.abs(y_float)))
    print(f"quantized GEMM relative error vs float: {rel:.3f}")

    # --- 4. a whole model on the QeiHaN path -------------------------------
    cfg = get_smoke("smollm-135m").replace(dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    qparams = quantize_model_params(cfg, params)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                cfg.vocab_size)
    toks_f = greedy_generate(cfg, params, prompt, max_new=8)
    toks_q = greedy_generate(cfg, qparams, prompt, max_new=8, quant=True)
    print("float  generation:", toks_f[0].tolist())
    print("qeihan generation:", toks_q[0].tolist())


if __name__ == "__main__":
    main()
