"""End-to-end training driver example: train a ~100M-class model (smollm
family) for a few hundred steps with checkpointing, restart and straggler
tracking — the deliverable-(b) end-to-end example.

CPU demo (reduced config, ~2 min):
  PYTHONPATH=src python examples/train_lm.py --steps 60

Full smollm-135m on a real mesh (same code path):
  PYTHONPATH=src python examples/train_lm.py --full --steps 300 \
      --global-batch 64 --seq-len 1024
"""

import argparse

from repro.launch import train as train_driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="use the published smollm-135m config "
                         "(default: reduced smoke config for CPU)")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    argv = ["--arch", "smollm-135m",
            "--mesh", "host",
            "--steps", str(args.steps),
            "--global-batch", str(args.global_batch),
            "--seq-len", str(args.seq_len),
            "--checkpoint-dir", args.checkpoint_dir,
            "--checkpoint-every", "20",
            "--resume", "auto",
            "--log-every", "5"]
    if not args.full:
        argv.append("--smoke")
    train_driver.main(argv)


if __name__ == "__main__":
    main()
