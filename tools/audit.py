"""Static program auditor CLI — trace/lower every serve program, run the
rule families, gate on committed budgets and waivers.

Usage (CI runs exactly this):

    PYTHONPATH=src python tools/audit.py --host-devices 8 \\
        --report audit_report.json

    # after a deliberate sharding/collective change:
    PYTHONPATH=src python tools/audit.py --host-devices 8 \\
        --update-baselines

Exit codes: 0 clean (waived findings allowed), 1 unwaived findings,
2 operational error.  ``--host-devices`` must come before the first jax
import, which is why this file imports jax lazily.
"""

import argparse
import sys

try:
    import repro  # noqa: F401  (PYTHONPATH=src already set)
except ImportError:  # bare checkout: resolve src/ relative to this file
    from pathlib import Path

    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.launch.host_devices import force_host_devices  # noqa: E402

WAIVERS_PATH = "tools/audit_waivers.json"


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    force_host_devices(argv)  # BEFORE any jax import

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--host-devices",
        type=int,
        default=None,
        help="force N XLA host devices (needed for the mesh variants; "
        "8 covers the 2x2 matrix)",
    )
    ap.add_argument(
        "--mesh",
        default="2x2",
        help="mesh specs to audit, comma-separated ('' for single-device "
        "only; default: 2x2)",
    )
    ap.add_argument(
        "--report",
        default=None,
        metavar="PATH",
        help="write the machine-readable JSON report here",
    )
    ap.add_argument(
        "--baselines",
        default=None,
        metavar="PATH",
        help="budget baseline file (default: "
        "benchmarks/baselines/program_audit.json)",
    )
    ap.add_argument(
        "--update-baselines",
        action="store_true",
        help="rewrite the budget baselines from this run instead of "
        "gating against them",
    )
    ap.add_argument(
        "--waivers",
        default=WAIVERS_PATH,
        metavar="PATH",
        help=f"waiver file (default: {WAIVERS_PATH})",
    )
    ap.add_argument(
        "--no-budgets",
        action="store_true",
        help="skip the HLO budget gate (rule family 3)",
    )
    ap.add_argument(
        "--no-recompile",
        action="store_true",
        help="skip the recompile census sweep (rule family 4)",
    )
    ap.add_argument(
        "--kernels",
        action="store_true",
        help="run the static Pallas kernel verifier (rule family 5: "
        "index-map bounds, VMEM budgets, tail lints, byte-traffic model)",
    )
    ap.add_argument(
        "--kernel-baselines",
        default=None,
        metavar="PATH",
        help="kernel budget baseline file (default: "
        "benchmarks/baselines/kernel_audit.json)",
    )
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args(argv)

    from repro.analysis import budgets as budgets_mod
    from repro.analysis import kernel_rules
    from repro.analysis.audit import ALL_RULES, run_audit
    from repro.analysis.report import apply_waivers, load_waivers

    def log(msg):
        if not args.quiet:
            print(msg, flush=True)

    mesh_specs = [None] + [m for m in args.mesh.split(",") if m]

    try:
        waivers = load_waivers(args.waivers, known_rules=ALL_RULES)
    except FileNotFoundError:
        waivers = []
    except (KeyError, ValueError) as e:
        print(f"audit: bad waiver file: {e}", file=sys.stderr)
        return 2

    try:
        report = run_audit(
            mesh_specs,
            baseline_path=args.baselines or budgets_mod.BASELINE_PATH,
            kernel_baseline_path=(args.kernel_baselines
                                  or kernel_rules.KERNEL_BASELINE_PATH),
            update_baselines=args.update_baselines,
            with_budgets=not args.no_budgets,
            with_recompile=not args.no_recompile,
            with_kernels=args.kernels,
            log=log,
        )
    except FileNotFoundError as e:
        print(
            f"audit: missing baseline ({e}) — run with --update-baselines first",
            file=sys.stderr,
        )
        return 2

    live = apply_waivers(report.findings, waivers)
    if args.report:
        with open(args.report, "w") as f:
            f.write(report.to_json())
        log(f"report -> {args.report}")

    n_waived = sum(1 for f in report.findings if f.waived)
    n_kernel = len(report.kernels.get("kernels", {}))
    print(
        f"audit: {len(report.variants)} variants, "
        f"{report.programs_audited} programs, "
        f"{len(report.budgets)} budgets checked, "
        f"{n_kernel} kernel instantiations, "
        f"{len(report.findings)} findings "
        f"({n_waived} waived, {len(live)} failing)"
    )
    for f in report.findings:
        if f.waived:
            print(f"  WAIVED {f.key()}: {f.detail}")
            print(f"         reason: {f.waive_reason}")
    for f in live:
        print(f"  FAIL {f.key()}: {f.detail}")
    return 1 if live else 0


if __name__ == "__main__":
    raise SystemExit(main())
