"""Debug: top collective ops in a saved HLO (loop-scaled)."""

import re
import sys

sys.path.insert(0, "src")
from repro.launch.hlo_analysis import (  # noqa: E402 (needs sys.path)
    _TRIP_RE,
    _split_computations,
    _type_bytes,
)

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def top(path, k=20):
    hlo = open(path).read()
    comps = _split_computations(hlo)
    entry = comps["__entry__"]
    items = []

    def walk(name, mult):
        comp = comps.get(name)
        if comp is None:
            return
        for ins in comp.instrs:
            if ins.op == "while":
                m = _TRIP_RE.search(ins.line)
                trips = int(m.group(1)) if m else 1
                bm = re.search(r"body=%?([\w\.\-]+)", ins.line)
                if bm:
                    walk(bm.group(1), mult * trips)
                continue
            if ins.op in ("call", "conditional", "async-start"):
                for key in ("calls", "to_apply", "branch_computations"):
                    mm = re.search(key + r"=\{?([^,}\s]+)", ins.line)
                    if mm:
                        walk(mm.group(1).strip().lstrip("%"), mult)
                continue
            base = ins.op
            for suf in ("-start", "-done"):
                if base.endswith(suf):
                    base = base[: -len(suf)]
            if base in _COLLECTIVES and not ins.op.endswith("-start"):
                rb = _type_bytes(ins.type_str) * mult
                items.append((rb, base, ins.type_str[:70], mult))

    walk(entry.name, 1)
    items.sort(reverse=True)
    for rb, op, t, mult in items[:k]:
        print(f"{rb / 2**30:9.2f} GiB  x{mult:<5} {op:<20} {t}")


top(sys.argv[1])
