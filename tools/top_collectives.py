"""Debug: top collective ops in a saved HLO (loop-scaled).

Usage: python tools/top_collectives.py dump.hlo.txt
"""

import sys

try:
    import repro  # noqa: F401  (PYTHONPATH=src already set)
except ImportError:  # bare checkout: resolve src/ relative to this file
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.analysis.hlo import (
    collective_base,
    scaled_instructions,
    split_computations,
    type_bytes,
)


def top(path, k=20):
    hlo = open(path).read()
    items = []
    for ins, mult in scaled_instructions(split_computations(hlo)):
        base = collective_base(ins.op)
        if base is not None and not ins.op.endswith("-start"):
            rb = type_bytes(ins.type_str) * mult
            items.append((rb, base, ins.type_str[:70], mult))
    items.sort(reverse=True)
    for rb, op, t, mult in items[:k]:
        print(f"{rb / 2**30:9.2f} GiB  x{mult:<5} {op:<20} {t}")


if __name__ == "__main__":
    top(sys.argv[1])
