"""Bench-drift gate: compare fresh ``# json`` bench rows against committed
baselines with per-metric tolerances.

Baselines live in ``benchmarks/baselines/<bench>.json`` — one file per bench
pass, captured from the ``# json {...}`` summary line each pass of
``benchmarks.run --dry`` / ``benchmarks.serve_bench --dry`` emits.  CI's
``bench-smoke`` job tees the fresh dry-run output to files, runs this
checker against the baselines, and uploads the fresh JSON as a workflow
artifact — so every CI run both GATES on drift and accretes a measurement
trajectory.

Tolerance classes (by row-name pattern):

* **exact** — correctness metrics (``bit_equal``, ``served_frac``,
  ``hit_rate``, ``lookup_hits``, ``registered_groups``, ...): any change
  fails the gate.  These are deterministic given the committed seeds; a
  diff means a behavior change, not noise.
* **tight** — deterministic-but-float metrics (plane-traffic fractions):
  small relative tolerance for BLAS/libm variation across runners.
* **advisory** — throughput / latency (``tok_s``, ``_ms``, ``speedup``):
  reported, never failed — CI CPUs are too noisy to gate on.

Missing rows (present in the baseline, absent fresh) and missing bench
passes always fail: structural drift means a metric silently stopped being
measured.  New rows only warn — refresh the baselines with ``--update``.

Usage::

    python -m benchmarks.run --dry | tee /tmp/run_dry.txt
    python -m benchmarks.serve_bench --dry | tee /tmp/serve_dry.txt
    python tools/bench_check.py /tmp/run_dry.txt /tmp/serve_dry.txt
    python tools/bench_check.py --update /tmp/*.txt   # re-baseline
"""

import argparse
import json
import os
import re
import sys

DEFAULT_BASELINE_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "benchmarks",
    "baselines",
)

EXACT = re.compile(
    r"(bit_equal|served_frac|hit_rate|lookup_hits|saved_frac"
    r"|registered_groups|vmem_bytes|static_bytes)"
)
TIGHT = re.compile(r"(plane_traffic|element_traffic)")
TIGHT_RTOL = 0.02
ADVISORY = re.compile(r"(tok_s|_ms$|_s$|speedup|_us$)")


def classify(name):
    if EXACT.search(name):
        return "exact"
    if TIGHT.search(name):
        return "tight"
    if ADVISORY.search(name):
        return "advisory"
    return "advisory"


def parse_json_lines(path):
    """All ``# json {...}`` summaries in one captured-output file, keyed by
    their ``bench`` name."""
    out = {}
    with open(path) as f:
        for line in f:
            if line.startswith("# json "):
                obj = json.loads(line[len("# json "):])
                out[obj["bench"]] = obj
    return out


def compare(bench, base_rows, fresh_rows):
    """Returns (failures, warnings) message lists for one bench pass."""
    failures, warnings = [], []
    for name, base in base_rows.items():
        if name not in fresh_rows:
            failures.append(f"{bench}: row {name!r} missing from fresh run")
            continue
        fresh = fresh_rows[name]
        if base is None or fresh is None:
            if (base is None) != (fresh is None):
                failures.append(
                    f"{bench}: {name}: nan-ness changed "
                    f"(baseline={base}, fresh={fresh})"
                )
            continue
        kind = classify(name)
        if kind == "exact":
            if abs(fresh - base) > 1e-9:
                failures.append(
                    f"{bench}: {name}: exact metric drifted "
                    f"{base} -> {fresh}"
                )
        elif kind == "tight":
            tol = TIGHT_RTOL * max(abs(base), 1e-9)
            if abs(fresh - base) > tol:
                failures.append(
                    f"{bench}: {name}: drifted beyond {TIGHT_RTOL:.0%} "
                    f"({base} -> {fresh})"
                )
        else:
            if base and abs(fresh - base) > 0.25 * abs(base):
                warnings.append(
                    f"{bench}: {name}: {base:.4g} -> {fresh:.4g} "
                    f"({(fresh - base) / base:+.0%}, advisory)"
                )
    for name in fresh_rows:
        if name not in base_rows:
            warnings.append(
                f"{bench}: new row {name!r} not in baseline "
                f"(run with --update to adopt)"
            )
    return failures, warnings


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="compare fresh bench output against committed baselines"
    )
    ap.add_argument(
        "fresh",
        nargs="+",
        help="files holding captured bench stdout (with '# json' lines)",
    )
    ap.add_argument("--baseline-dir", default=DEFAULT_BASELINE_DIR)
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated bench names: check (or --update) just these "
        "baselines and ignore the rest — for CI jobs that run a single "
        "bench pass (e.g. --only serve_disagg in the multidevice job)",
    )
    ap.add_argument(
        "--update",
        action="store_true",
        help="write/refresh the baseline files from the fresh runs "
        "instead of checking",
    )
    args = ap.parse_args(argv)

    only = set(args.only.split(",")) if args.only else None

    fresh = {}
    for path in args.fresh:
        fresh.update(parse_json_lines(path))
    if only is not None:
        missing = only - set(fresh)
        if missing:
            print(
                f"bench_check: --only names {sorted(missing)} but the fresh "
                f"run produced no '# json' summary for them"
            )
            return 2
        fresh = {b: obj for b, obj in fresh.items() if b in only}
    if not fresh:
        print("bench_check: no '# json' lines found in inputs", flush=True)
        return 2

    if args.update:
        os.makedirs(args.baseline_dir, exist_ok=True)
        for bench, obj in sorted(fresh.items()):
            out = os.path.join(args.baseline_dir, f"{bench}.json")
            with open(out, "w") as f:
                json.dump(
                    {"bench": bench, "rows": obj["rows"]},
                    f,
                    indent=2,
                    sort_keys=True,
                )
                f.write("\n")
            print(f"bench_check: wrote {out}")
        return 0

    failures, warnings = [], []
    baselines = {}
    for fn in sorted(os.listdir(args.baseline_dir)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(args.baseline_dir, fn)) as f:
            doc = json.load(f)
        if "rows" not in doc:
            # not a bench baseline — e.g. program_audit.json, the program
            # auditor's budget file (gated by tools/audit.py, not here)
            continue
        if only is not None and fn[: -len(".json")] not in only:
            continue
        baselines[fn[: -len(".json")]] = doc
    for bench, base in baselines.items():
        if bench not in fresh:
            failures.append(
                f"{bench}: baseline exists but the fresh run produced no "
                f"'# json' summary for it"
            )
            continue
        fails, warns = compare(bench, base["rows"], fresh[bench]["rows"])
        failures += fails
        warnings += warns
    for bench in fresh:
        if bench not in baselines:
            warnings.append(
                f"{bench}: no committed baseline (run with --update)"
            )

    for w in warnings:
        print(f"WARN  {w}")
    for f_ in failures:
        print(f"FAIL  {f_}")
    n_rows = sum(len(fresh[b]["rows"]) for b in fresh)
    print(
        f"bench_check: {len(baselines)} baselines, {n_rows} fresh rows, "
        f"{len(failures)} failures, {len(warnings)} warnings"
    )
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
