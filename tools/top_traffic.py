"""Debug: top traffic-contributing top-level ops in a saved HLO (loop-scaled)."""

import re
import sys

sys.path.insert(0, "src")
from repro.launch.hlo_analysis import (  # noqa: E402 (needs sys.path)
    _SKIP_TRAFFIC,
    _TRIP_RE,
    _split_computations,
    _type_bytes,
)


def top_ops(path, k=25):
    hlo = open(path).read()
    comps = _split_computations(hlo)
    entry = comps["__entry__"]
    # compute multipliers: walk while nesting
    items = []

    def walk(name, mult):
        comp = comps.get(name)
        if comp is None:
            return
        for ins in comp.instrs:
            if ins.op == "while":
                m = _TRIP_RE.search(ins.line)
                trips = int(m.group(1)) if m else 1
                bm = re.search(r"body=%?([\w\.\-]+)", ins.line)
                if bm:
                    walk(bm.group(1), mult * trips)
                continue
            if ins.op in ("call", "conditional", "async-start"):
                for key in ("calls", "to_apply", "branch_computations"):
                    mm = re.search(key + r"=\{?([^,}\s]+)", ins.line)
                    if mm:
                        walk(mm.group(1).strip().lstrip("%"), mult)
                continue
            if ins.op in _SKIP_TRAFFIC:
                continue
            rb = _type_bytes(ins.type_str) * 2 * mult
            items.append((rb, ins.op, ins.type_str[:60], ins.name[:40], mult))

    walk(entry.name, 1)
    items.sort(reverse=True)
    total = sum(i[0] for i in items)
    print(f"total traffic: {total / 1e9:.1f} GB")
    for rb, op, t, nm, mult in items[:k]:
        print(f"{rb / 1e9:9.2f} GB  x{mult:<5} {op:<22} {t}")


top_ops(sys.argv[1])
