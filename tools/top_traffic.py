"""Debug: top traffic-contributing top-level ops in a saved HLO (loop-scaled).

Usage: python tools/top_traffic.py dump.hlo.txt
"""

import sys

try:
    import repro  # noqa: F401  (PYTHONPATH=src already set)
except ImportError:  # bare checkout: resolve src/ relative to this file
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.analysis.hlo import (
    SKIP_TRAFFIC,
    scaled_instructions,
    split_computations,
    type_bytes,
)


def top_ops(path, k=25):
    hlo = open(path).read()
    items = []
    for ins, mult in scaled_instructions(split_computations(hlo)):
        if ins.op in SKIP_TRAFFIC:
            continue
        rb = type_bytes(ins.type_str) * 2 * mult
        items.append((rb, ins.op, ins.type_str[:60], ins.name[:40], mult))
    items.sort(reverse=True)
    total = sum(i[0] for i in items)
    print(f"total traffic: {total / 1e9:.1f} GB")
    for rb, op, t, _nm, mult in items[:k]:
        print(f"{rb / 1e9:9.2f} GB  x{mult:<5} {op:<22} {t}")


if __name__ == "__main__":
    top_ops(sys.argv[1])
